//! Minimal, dependency-free drop-in for the `anyhow` error-handling crate.
//!
//! Vendored so that `cargo build && cargo test` work from a bare checkout
//! with NO network access at all (the CI gate allows crates.io, but the
//! build should not need even that).  Only the surface this workspace uses
//! is provided: `Result`, `Error`, the `Context` trait, and the `anyhow!`,
//! `bail!`, `ensure!` macros.  Swapping back to the real crate is a
//! one-line change in `rust/Cargo.toml`.

use std::fmt;

/// A string-backed error with a context chain, printed as
/// `outermost: ...: innermost`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// (and thus `?` on io/parse/... errors) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment on `Result` and `Option`, as in the real crate.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn fails() -> Result<usize> {
        let n: usize = "nope".parse()?; // ParseIntError -> Error via From
        Ok(n)
    }

    #[test]
    fn conversion_and_context() {
        let e = fails().context("parsing config").unwrap_err();
        assert!(e.to_string().starts_with("parsing config: "));
        let o: Option<u8> = None;
        assert_eq!(
            o.with_context(|| format!("missing {}", "field")).unwrap_err().to_string(),
            "missing field"
        );
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<u8> {
            ensure!(flag, "flag was {flag}");
            ensure!(flag);
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(1)
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        let e: Error = anyhow!("x = {}", 3);
        assert_eq!(format!("{e:?}"), "x = 3");
    }
}
