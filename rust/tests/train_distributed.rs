//! Integration gates for the ZeRO-sharded, checkpointed training driver:
//!
//! * W=4 reproduces the W=1 loss curve BIT-FOR-BIT (and the checkpoints
//!   are byte-identical, since the format is world-size independent);
//! * a killed run (`halt_after`) resumed from its checkpoint produces a
//!   loss CSV byte-identical to the uninterrupted run (append, not
//!   truncate);
//! * the new grad_step + ShardedAdam driver at W=1 bit-matches the legacy
//!   fused `train_step_*` artifact loop it replaced;
//! * `TrainReport::wire_bytes` matches the ZeRO formula measured by the
//!   comm counters.

use std::path::PathBuf;
use std::sync::Arc;

use lasp2::config::{Pattern, Variant};
use lasp2::coordinator::{param_specs, FlatLayout};
use lasp2::runtime::{Engine, Value};
use lasp2::train::{train, Checkpoint, TrainOpts};
use lasp2::Tensor;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lasp2_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts(steps: usize) -> TrainOpts {
    TrainOpts { steps, log_every: 0, ..Default::default() }
}

#[test]
fn w4_bit_reproduces_w1_loss_curve_and_checkpoint() {
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("w4_vs_w1");
    let run = |world: usize| {
        let ck = dir.join(format!("w{world}.ckpt"));
        let o = TrainOpts {
            world,
            save: Some(ck.to_str().unwrap().into()),
            ..opts(6)
        };
        let rep = train(&engine, Variant::Basic, &pattern, "basic_pure", &o).unwrap();
        (rep, std::fs::read(ck).unwrap())
    };
    let (r1, ck1) = run(1);
    let (r4, ck4) = run(4);
    assert_eq!(r1.losses.len(), r4.losses.len());
    for (i, (a, b)) in r1.losses.iter().zip(&r4.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {i}: {a} != {b}");
    }
    // the checkpoint stores gathered, unpadded state — so the files from
    // both world sizes must be byte-identical, not merely close
    assert_eq!(ck1, ck4, "checkpoint bytes differ between W=1 and W=4");
    // and the memory claim: W=4 holds 1/4 of the replicated moments
    assert_eq!(r1.opt_bytes_per_rank, r1.opt_bytes_replicated);
    assert!(
        r4.opt_bytes_per_rank <= r1.opt_bytes_replicated / 4 + 8,
        "{} vs {}",
        r4.opt_bytes_per_rank,
        r1.opt_bytes_replicated
    );
    assert!(r4.wire_bytes > 0);
    assert_eq!(r1.wire_bytes, 0);
}

#[test]
fn kill_and_resume_is_bit_identical() {
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("kill_resume");
    let path = |n: &str| -> String { dir.join(n).to_str().unwrap().into() };

    // uninterrupted reference: 8 steps, one CSV, snapshot at the end
    let full = TrainOpts {
        csv: Some(path("full.csv")),
        save: Some(path("full.ckpt")),
        ..opts(8)
    };
    train(&engine, Variant::Basic, &pattern, "basic_pure", &full).unwrap();

    // killed run: same schedule, halted after 4 steps...
    let halted = TrainOpts {
        csv: Some(path("resumed.csv")),
        save: Some(path("part.ckpt")),
        halt_after: 4,
        ..opts(8)
    };
    let rh = train(&engine, Variant::Basic, &pattern, "basic_pure", &halted).unwrap();
    assert_eq!(rh.losses.len(), 4);
    let ck = Checkpoint::load(&path("part.ckpt")).unwrap();
    assert_eq!(ck.steps_done, 4);
    assert_eq!(ck.data_cursor, 4);

    // ...then resumed to completion, APPENDING to the same CSV
    let resumed = TrainOpts {
        csv: Some(path("resumed.csv")),
        save: Some(path("part.ckpt")),
        resume: Some(path("part.ckpt")),
        ..opts(8)
    };
    let rr = train(&engine, Variant::Basic, &pattern, "basic_pure", &resumed).unwrap();
    assert_eq!(rr.start_step, 4);
    assert_eq!(rr.losses.len(), 4);

    let a = std::fs::read(path("full.csv")).unwrap();
    let b = std::fs::read(path("resumed.csv")).unwrap();
    assert_eq!(
        String::from_utf8_lossy(&a),
        String::from_utf8_lossy(&b),
        "resumed loss CSV is not a bit-identical continuation"
    );
    // end state identical too: both checkpoints captured step 8
    assert_eq!(
        std::fs::read(path("full.ckpt")).unwrap(),
        std::fs::read(path("part.ckpt")).unwrap()
    );
}

#[test]
fn resume_rejects_mismatched_runs() {
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("resume_validation");
    let ck: String = dir.join("s.ckpt").to_str().unwrap().into();
    let o = TrainOpts {
        save: Some(ck.clone()),
        halt_after: 2,
        ..opts(8)
    };
    train(&engine, Variant::Basic, &pattern, "basic_pure", &o).unwrap();
    let resume = |mutate: &dyn Fn(&mut TrainOpts)| {
        let mut o = TrainOpts { resume: Some(ck.clone()), ..opts(8) };
        mutate(&mut o);
        train(&engine, Variant::Basic, &pattern, "basic_pure", &o)
    };
    assert!(resume(&|_| {}).is_ok());
    // different data stream, schedule horizon, or task must refuse
    assert!(resume(&|o| o.seed = 1).is_err(), "seed mismatch accepted");
    assert!(resume(&|o| o.steps = 9).is_err(), "horizon mismatch accepted");
    assert!(resume(&|o| o.mlm = true).is_err(), "task mismatch accepted");
    assert!(resume(&|o| o.peak_lr = 1e-3).is_err(), "lr mismatch accepted");
}

#[test]
fn w1_driver_bit_matches_legacy_train_step_artifact() {
    // the refactor's no-regression gate: the grad_step + ShardedAdam path
    // must reproduce, bit for bit, what the fused train_step artifact
    // (forward + backward + Adam in one executable) computed before it
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let steps = 5usize;
    let dir = tmpdir("legacy_parity");
    let ckpath: String = dir.join("new.ckpt").to_str().unwrap().into();
    let o = TrainOpts { save: Some(ckpath.clone()), ..opts(steps) };
    let rep = train(&engine, Variant::Basic, &pattern, "basic_pure", &o).unwrap();

    // hand-drive the legacy artifact exactly as the old driver did
    let cfg = &engine.model;
    let specs = param_specs(cfg, Variant::Basic, &pattern);
    let params = lasp2::coordinator::Params::from_init_artifact(
        &engine,
        Variant::Basic,
        &pattern,
        "init_basic_pure",
        0,
    )
    .unwrap();
    let n_params = specs.len();
    let mut flat: Vec<Tensor> = specs
        .iter()
        .map(|(n, _, _)| params.get(n).unwrap().clone())
        .collect();
    let mut mom: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
    let mut vel: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
    let exe = engine.artifact("train_step_basic_pure").unwrap();
    let (bsz, seq) = (cfg.train_batch, cfg.train_seq);
    let mut data = lasp2::data::BatchIter::causal(cfg.vocab, bsz, seq, 0);
    let mut legacy_losses = Vec::new();
    for it in 0..steps {
        let b = data.next_batch();
        let lr = lasp2::train::lr_schedule(it, steps, 3e-3, 1e-6);
        let mut ins: Vec<Value> = Vec::new();
        ins.extend(flat.iter().map(|t| Value::F32(t.clone())));
        ins.extend(mom.iter().map(|t| Value::F32(t.clone())));
        ins.extend(vel.iter().map(|t| Value::F32(t.clone())));
        ins.push(Value::I32(b.tokens, vec![bsz, seq]));
        ins.push(Value::I32(b.targets, vec![bsz, seq]));
        ins.push(Value::F32(Tensor::new(vec![bsz, seq], b.loss_mask)));
        ins.push(Value::F32(Tensor::scalar1(lr)));
        ins.push(Value::F32(Tensor::scalar1((it + 1) as f32)));
        let mut outs = exe.run(&ins).unwrap();
        legacy_losses.push(outs.pop().unwrap().data()[0]);
        vel = outs.split_off(2 * n_params);
        mom = outs.split_off(n_params);
        flat = outs;
    }

    for (i, (a, b)) in rep.losses.iter().zip(&legacy_losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss step {i}: {a} != {b}");
    }
    // parameters too, via the checkpoint the new driver wrote
    let ck = Checkpoint::load(&ckpath).unwrap();
    let layout = FlatLayout::new(&specs);
    let legacy_flat = layout.flatten(&flat, layout.total());
    assert_eq!(ck.params.len(), legacy_flat.len());
    for (j, (a, b)) in ck.params.iter().zip(&legacy_flat).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param element {j}: {a} != {b}");
    }
}

#[test]
fn wire_bytes_match_zero_formula() {
    // per rank per step the driver moves: reduce_scatter of the padded
    // grad vector ((W-1)/W · 4·E bytes), all_gather of the updated shard
    // ((W-1) · 4·E/W), and the scalar loss gather ((W-1) · 4).  A save
    // adds the two-moment state gather ((W-1) · 2 · 4·E/W per rank).
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("wire_accounting");
    let steps = 3usize;
    let world = 4usize;
    let o = TrainOpts {
        world,
        save: Some(dir.join("w.ckpt").to_str().unwrap().into()),
        ..opts(steps)
    };
    let rep = train(&engine, Variant::Basic, &pattern, "basic_pure", &o).unwrap();
    let layout = FlatLayout::new(&param_specs(&engine.model, Variant::Basic, &pattern));
    let e = layout.padded(world) as u64;
    let (w, s) = (world as u64, steps as u64);
    let per_step = w * (w - 1) * (4 * e / w)  // reduce_scatter
        + w * (w - 1) * (4 * e / w)           // shard all_gather
        + w * (w - 1) * 4; // loss all_gather
    let per_save = w * (w - 1) * 2 * (4 * e / w);
    assert_eq!(rep.wire_bytes, s * per_step + per_save);
    // 3 collectives per rank per step + 1 per rank at the save
    assert_eq!(rep.collective_ops, s * 3 * w + w);
}

#[test]
fn engine_is_shared_across_ranks() {
    // smoke for the Arc<Engine> plumbing: two world sizes back-to-back on
    // one engine (artifact cache shared), W=2 also bit-matching W=1
    let engine: Arc<Engine> = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let r1 = train(&engine, Variant::Basic, &pattern, "basic_pure", &opts(4)).unwrap();
    let r2 = train(
        &engine,
        Variant::Basic,
        &pattern,
        "basic_pure",
        &TrainOpts { world: 2, ..opts(4) },
    )
    .unwrap();
    for (a, b) in r1.losses.iter().zip(&r2.losses) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
