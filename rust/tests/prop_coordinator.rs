//! Property-based tests on coordinator invariants.
//!
//! The offline registry carries no proptest, so this uses a seeded-sweep
//! harness (`for_seeds`): deterministic pseudo-random cases, failure
//! messages carry the seed for reproduction.  Invariants covered:
//!   * the gated state-combine monoid (associativity, identity) that
//!     underlies Eq. 9 and the Table-5 split gathers;
//!   * prefix/suffix state algebra vs naive folds;
//!   * collectives (ordering, self-consistency, split equivalence,
//!     byte accounting) over random world sizes and payload shapes;
//!   * schedule-plan accounting vs the paper's §3.4 closed forms over
//!     random model shapes.

use lasp2::comm::World;
use lasp2::config::Scheduler;
use lasp2::coordinator::plan::{build_plan, SimShape};
use lasp2::data::Rng;
use lasp2::tensor::{
    prefix_states, state_combine, suffix_dstates, ChunkState, Tensor,
};

fn for_seeds(n: u64, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed * 7919 + 13);
        f(seed, &mut rng);
    }
}

fn rand_state(rng: &mut Rng, h: usize, fk: usize, dh: usize, seed: u64) -> ChunkState {
    let m = Tensor::randn(&[h, fk, dh], seed ^ rng.next_u64());
    let a = Tensor::new(
        vec![h, fk],
        (0..h * fk).map(|_| 0.9 + 0.1 * rng.f32()).collect(),
    );
    ChunkState { m, a }
}

#[test]
fn prop_combine_associative() {
    for_seeds(50, |seed, rng| {
        let h = 1 + rng.below(3);
        let fk = 1 + rng.below(6);
        let dh = 1 + rng.below(6);
        let a = rand_state(rng, h, fk, dh, seed);
        let b = rand_state(rng, h, fk, dh, seed + 1);
        let c = rand_state(rng, h, fk, dh, seed + 2);
        let l = state_combine(&state_combine(&a, &b), &c);
        let r = state_combine(&a, &state_combine(&b, &c));
        assert!(l.m.allclose(&r.m, 1e-4), "seed {seed}");
        assert!(l.a.allclose(&r.a, 1e-4), "seed {seed}");
    });
}

#[test]
fn prop_combine_identity() {
    for_seeds(30, |seed, rng| {
        let s = rand_state(rng, 2, 4, 4, seed);
        let id = ChunkState::zero_like(&s);
        let l = state_combine(&id, &s);
        let r = state_combine(&s, &id);
        assert!(l.m.allclose(&s.m, 1e-6) && l.a.allclose(&s.a, 1e-6));
        assert!(r.m.allclose(&s.m, 1e-6) && r.a.allclose(&s.a, 1e-6));
    });
}

#[test]
fn prop_prefix_states_match_fold() {
    for_seeds(30, |seed, rng| {
        let t = 2 + rng.below(6);
        let states: Vec<ChunkState> =
            (0..t).map(|i| rand_state(rng, 2, 3, 5, seed + i as u64)).collect();
        let (prefixes, total) = prefix_states(&states);
        // naive left fold
        let mut acc = ChunkState::zero_like(&states[0]);
        for (i, s) in states.iter().enumerate() {
            assert!(prefixes[i].m.allclose(&acc.m, 1e-4), "seed {seed} chunk {i}");
            acc = state_combine(&acc, s);
        }
        assert!(total.m.allclose(&acc.m, 1e-4), "seed {seed}");
    });
}

#[test]
fn prop_suffix_sums_match_naive() {
    for_seeds(30, |seed, rng| {
        let t = 2 + rng.below(6);
        let ds: Vec<Tensor> =
            (0..t).map(|i| Tensor::randn(&[2, 3, 3], seed + i as u64)).collect();
        let suf = suffix_dstates(&ds);
        for i in 0..t {
            let mut want = Tensor::zeros(&[2, 3, 3]);
            for d in ds.iter().skip(i + 1) {
                want.add_assign(d);
            }
            assert!(suf[i].allclose(&want, 1e-4), "seed {seed} chunk {i}");
        }
    });
}

#[test]
fn prop_all_gather_identical_everywhere() {
    for_seeds(12, |seed, rng| {
        let w = 1 + rng.below(6);
        let rows = 1 + rng.below(8);
        let cols = 1 + rng.below(8);
        let world = World::new(w);
        let results = world.run(|comm| {
            comm.all_gather(vec![Tensor::randn(
                &[rows, cols],
                seed * 100 + comm.rank() as u64,
            )])
            .unwrap()
        });
        // every rank must see the same gathered list, ordered by rank
        for r in &results {
            assert_eq!(r.len(), w);
            for (rank, msg) in r.iter().enumerate() {
                let want = Tensor::randn(&[rows, cols], seed * 100 + rank as u64);
                assert_eq!(msg[0], want, "seed {seed}");
            }
        }
    });
}

#[test]
fn prop_split_gather_equivalence() {
    for_seeds(10, |seed, rng| {
        let w = 2 + rng.below(4);
        let n = 1 + rng.below(40);
        let splits = 1 + rng.below(7);
        let world = World::new(w);
        let base = world.run(|comm| {
            comm.all_gather(vec![Tensor::randn(&[n], seed + comm.rank() as u64)])
                .unwrap()
        });
        let world2 = World::new(w);
        let split = world2.run(move |comm| {
            comm.all_gather_split(
                vec![Tensor::randn(&[n], seed + comm.rank() as u64)],
                splits,
            )
            .unwrap()
        });
        for (a, b) in base.iter().zip(&split) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x[0], y[0], "seed {seed} splits {splits}");
            }
        }
    });
}

#[test]
fn prop_gather_byte_accounting() {
    for_seeds(10, |seed, rng| {
        let w = 2 + rng.below(5);
        let n = 1 + rng.below(100);
        let world = World::new(w);
        world.run(|comm| {
            comm.all_gather(vec![Tensor::randn(&[n], seed)]).unwrap();
        });
        let snap = world.counters();
        assert_eq!(snap.bytes as usize, w * (w - 1) * n * 4, "seed {seed}");
        assert_eq!(snap.collective_ops as usize, w);
    });
}

#[test]
fn prop_plan_step_counts_match_paper() {
    // §3.4 over random shapes: LASP-2 2 steps/iter/layer, LASP-1 2(W-1).
    for_seeds(25, |seed, rng| {
        let w = 2 + rng.below(127);
        let layers = 1 + rng.below(32);
        let mut shape = SimShape::linear_llama3_1b(w, w * 1024, 1 + rng.below(4));
        shape.n_linear_layers = layers as f64;
        let l2 = build_plan(&shape, Scheduler::Lasp2, 1).account(w);
        assert_eq!(l2.collective_steps, 2 * layers, "seed {seed}");
        assert_eq!(l2.p2p_steps, 0);
        let l1 = build_plan(&shape, Scheduler::Lasp1, 1).account(w);
        assert_eq!(l1.p2p_steps, 2 * (w - 1) * layers, "seed {seed}");
        assert_eq!(l1.collective_steps, 0);
        // both move the same state bytes per iteration
        assert!((l1.bytes - l2.bytes).abs() <= 1e-6 * l2.bytes, "seed {seed}");
    });
}

#[test]
fn prop_plan_state_traffic_seq_invariant() {
    // LASP-2 traffic must not depend on sequence length; Megatron-SP and
    // Ring traffic must grow linearly with it.
    for_seeds(15, |seed, rng| {
        let w = 2 + rng.below(63);
        let c1 = 1024.0 * (1 + rng.below(8)) as f64;
        let mk = |c: f64| {
            let mut s = SimShape::linear_llama3_1b(w, (c as usize) * w, 1);
            s.chunk = c;
            s
        };
        let l2a = build_plan(&mk(c1), Scheduler::Lasp2, 1).account(w);
        let l2b = build_plan(&mk(c1 * 2.0), Scheduler::Lasp2, 1).account(w);
        assert!((l2a.bytes - l2b.bytes).abs() < 1e-6, "seed {seed}");
        let ma = build_plan(&mk(c1), Scheduler::MegatronSp, 1).account(w);
        let mb = build_plan(&mk(c1 * 2.0), Scheduler::MegatronSp, 1).account(w);
        assert!(
            (mb.bytes / ma.bytes - 2.0).abs() < 1e-6,
            "seed {seed}: megatron bytes must double"
        );
    });
}

#[test]
fn prop_ring_send_recv_permutation() {
    // after k ring hops every rank holds the value originating k ranks to
    // its right — the ring must be a clean cyclic permutation
    for_seeds(8, |seed, rng| {
        let w = 2 + rng.below(6);
        let hops = 1 + rng.below(w - 1);
        let world = World::new(w);
        let results = world.run(|comm| {
            let mut val = comm.rank() as f32;
            for _ in 0..hops {
                comm.send(comm.right(), vec![Tensor::full(&[1], val)]).unwrap();
                val = comm.recv(comm.left()).unwrap()[0].data()[0];
            }
            val
        });
        for (rank, v) in results.iter().enumerate() {
            let want = ((rank + w - hops) % w) as f32;
            assert_eq!(*v, want, "seed {seed} w {w} hops {hops}");
        }
    });
}
