//! The quantized decode-readout contract (`--decode-dtype bf16|int8`):
//! opt-in reduced-precision embedding storage for the bandwidth-bound
//! per-token logit readout.  Pinned here on the tiny preset:
//!
//! * logits track the f32 path within 1e-2 (absolute, on unit-scale
//!   activations) at every decode position — the parity bound the CLI
//!   help advertises;
//! * greedy decoding (argmax) is unchanged wherever the f32 logit margin
//!   is wider than twice that bound, i.e. everywhere it could matter;
//! * the quantized path is actually active (bits differ from f32 —
//!   otherwise the gate is wired to nothing);
//! * switching back to `F32` restores the bit-exact artifact path;
//! * the quantized rows are themselves deterministic run to run.

use lasp2::config::{Pattern, Variant};
use lasp2::coordinator::Params;
use lasp2::runtime::Engine;
use lasp2::serve::{argmax, Model};
use lasp2::tensor::quant::DecodeDtype;
use lasp2::tensor::Tensor;

const STEPS: usize = 48;
const TOL: f32 = 1e-2;

fn model_for(ratio: &str, dtype: DecodeDtype) -> Model {
    let engine = Engine::load_preset("tiny").expect("native tiny preset");
    let pattern = Pattern::from_ratio(engine.model.n_layers, ratio).unwrap();
    let params = Params::randn(&engine.model, Variant::Basic, &pattern, 11);
    let mut model = Model::from_parts(engine, params);
    model.set_decode_dtype(dtype).unwrap();
    model
}

fn toks() -> Vec<i32> {
    (0..STEPS as i32).map(|i| (i * 7 + 3) % 256).collect()
}

/// Decode the fixed token stream, returning one logits row per position.
fn rows(model: &Model) -> Vec<Tensor> {
    let mut s = model.session();
    toks().iter().map(|&t| s.decode(t).unwrap()).collect()
}

#[test]
fn quantized_logits_track_f32_within_tolerance_and_keep_argmax() {
    for ratio in ["0", "1/2"] {
        let exact = rows(&model_for(ratio, DecodeDtype::F32));
        for dtype in [DecodeDtype::Bf16, DecodeDtype::Int8] {
            let quant = rows(&model_for(ratio, dtype));
            let mut any_diff = false;
            for (pos, (e, q)) in exact.iter().zip(&quant).enumerate() {
                let (ed, qd) = (e.data(), q.data());
                assert_eq!(ed.len(), qd.len());
                for (j, (a, b)) in ed.iter().zip(qd).enumerate() {
                    assert!(
                        (a - b).abs() <= TOL,
                        "{} ratio {ratio} pos {pos} logit {j}: {a} vs {b}",
                        dtype.name()
                    );
                    any_diff |= a.to_bits() != b.to_bits();
                }
                // argmax-stability wherever the f32 margin exceeds what
                // quantization could flip (top-2 gap > 2 * TOL)
                let top = argmax(ed) as usize;
                let runner_up = ed
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != top)
                    .map(|(_, v)| *v)
                    .fold(f32::NEG_INFINITY, f32::max);
                if ed[top] - runner_up > 2.0 * TOL {
                    assert_eq!(
                        argmax(qd) as usize,
                        top,
                        "{} ratio {ratio} pos {pos}: argmax flipped",
                        dtype.name()
                    );
                }
            }
            // the quantized path must actually engage: identical bits on
            // every row would mean --decode-dtype is wired to nothing
            assert!(any_diff, "{} ratio {ratio}: logits never differed", dtype.name());
        }
    }
}

#[test]
fn setting_dtype_back_to_f32_restores_bit_exact_path() {
    let exact = rows(&model_for("0", DecodeDtype::F32));
    let engine = Engine::load_preset("tiny").unwrap();
    let pattern = Pattern::from_ratio(engine.model.n_layers, "0").unwrap();
    let params = Params::randn(&engine.model, Variant::Basic, &pattern, 11);
    let mut model = Model::from_parts(engine, params);
    model.set_decode_dtype(DecodeDtype::Int8).unwrap();
    assert_eq!(model.decode_dtype(), DecodeDtype::Int8);
    model.set_decode_dtype(DecodeDtype::F32).unwrap();
    assert_eq!(model.decode_dtype(), DecodeDtype::F32);
    for (e, g) in exact.iter().zip(rows(&model)) {
        let eb: Vec<u32> = e.data().iter().map(|v| v.to_bits()).collect();
        let gb: Vec<u32> = g.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(eb, gb);
    }
}

#[test]
fn quantized_rows_are_deterministic_run_to_run() {
    let model = model_for("0", DecodeDtype::Bf16);
    let first = rows(&model);
    let again = rows(&model);
    for (a, b) in first.iter().zip(&again) {
        let ab: Vec<u32> = a.data().iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb);
    }
}
