//! Integration gates for the fault-injection harness and elastic recovery:
//!
//! * a W=4 run losing rank 3 mid-step rolls back to the last snapshot,
//!   shrinks to W=2, and finishes with a loss curve (and CSV) BIT-IDENTICAL
//!   to the uninterrupted run;
//! * injected message corruption is caught by the per-message checksum and
//!   either retried to the exact payload or surfaced as
//!   `CommError::Corrupt` — a wrong tensor is never returned;
//! * the two-barrier generation fencing keeps `all_gather` / `all_to_all`
//!   results bit-identical and rank-ordered when one rank is delayed
//!   (CI runs this under both `LASP2_THREADS=1` and `4`);
//! * a poison serve request fails alone: survivors produce the same
//!   `output_digest` with and without it in the trace;
//! * checkpoint rotation: a corrupted or truncated newest snapshot is
//!   rejected by its checksum and `--resume` falls back to `.prev`,
//!   still appending a byte-identical loss CSV.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use lasp2::comm::{CommError, FaultPlan, World};
use lasp2::config::{Pattern, Variant};
use lasp2::runtime::Engine;
use lasp2::serve::{Model, Request, ServeConfig, ServeLoop};
use lasp2::train::{checkpoint, fault_op_for_step, train, Checkpoint, TrainOpts};
use lasp2::Tensor;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lasp2_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn path(dir: &Path, n: &str) -> String {
    dir.join(n).to_str().unwrap().into()
}

fn opts(steps: usize) -> TrainOpts {
    TrainOpts { steps, log_every: 0, ..Default::default() }
}

#[test]
fn w4_crash_resumes_at_w2_with_bitwise_loss_curve() {
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("fault_crash");
    let steps = 8usize;
    let save_every = 2usize;

    let clean = TrainOpts {
        world: 4,
        csv: Some(path(&dir, "clean.csv")),
        ..opts(steps)
    };
    let rc = train(&engine, Variant::Basic, &pattern, "basic_pure", &clean).unwrap();
    assert_eq!(rc.recoveries, 0);

    // crash rank 3 one full step past the last snapshot (step 5 of 8,
    // snapshots after steps 2/4/6/8): the driver must discard the partial
    // step, reload step 4, and continue on the surviving pow2 world
    let crash_step = steps - 3;
    let crash_op = fault_op_for_step(0, crash_step, save_every, steps);
    let faulty = TrainOpts {
        world: 4,
        csv: Some(path(&dir, "faulty.csv")),
        save: Some(path(&dir, "faulty.ckpt")),
        save_every,
        faults: Some(Arc::new(FaultPlan::new().crash(3, crash_op))),
        ..opts(steps)
    };
    let rf = train(&engine, Variant::Basic, &pattern, "basic_pure", &faulty).unwrap();
    assert_eq!(rf.recoveries, 1, "exactly one elastic recovery");
    assert_eq!(rf.world, 2, "pow2 shrink 4 -> 2 after losing rank 3");
    assert!(rf.steps_lost >= 1, "crashing past a snapshot loses work");

    assert_eq!(rc.losses.len(), rf.losses.len());
    for (i, (a, b)) in rc.losses.iter().zip(&rf.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "loss step {i}: {a} != {b}");
    }
    // the CSV too: rollback sanitizes stale rows, then appends — the file
    // must end up byte-identical to the uninterrupted run's
    assert_eq!(
        std::fs::read_to_string(path(&dir, "clean.csv")).unwrap(),
        std::fs::read_to_string(path(&dir, "faulty.csv")).unwrap(),
        "recovered loss CSV differs from the uninterrupted run"
    );
}

#[test]
fn corruption_is_retried_bit_exact_or_surfaced_never_wrong() {
    // transient: rank 1's copy of rank 0's payload arrives corrupted twice,
    // with four retries allowed — every rank must end up with the exact
    // rank-ordered payloads
    let plan = Arc::new(FaultPlan::new().corrupt(1, 0, 0, 2).with_retry(4, 50));
    let world = World::new(4);
    world.install_faults(plan.clone());
    let results = world.run_catch(|c| {
        c.all_gather(vec![Tensor::randn(&[64], 4000 + c.rank() as u64)])
    });
    for (rank, r) in results.into_iter().enumerate() {
        let got = r.expect("no panic").expect("transient corruption must be retried");
        for (src, m) in got.iter().enumerate() {
            assert_eq!(
                m[0],
                Tensor::randn(&[64], 4000 + src as u64),
                "rank {rank} holds wrong data from {src}"
            );
        }
    }
    assert!(plan.retries() >= 2, "expected >= 2 retries, saw {}", plan.retries());

    // persistent: corruption outlives the retry budget — the affected rank
    // surfaces a typed error, everyone else sees clean data, and a wrong
    // tensor is never returned anywhere
    let plan = Arc::new(FaultPlan::new().corrupt(1, 0, 0, 8).with_retry(2, 50));
    let world = World::new(4);
    world.install_faults(plan);
    let results = world.run_catch(|c| {
        c.all_gather(vec![Tensor::randn(&[64], 5000 + c.rank() as u64)])
    });
    for (rank, r) in results.into_iter().enumerate() {
        match r.expect("no panic") {
            Err(CommError::Corrupt { src, dst, attempts, .. }) => {
                assert_eq!(rank, 1, "only rank 1 should surface the corruption");
                assert_eq!((src, dst), (0, 1));
                assert!(attempts >= 3, "budget of 2 retries means >= 3 attempts");
            }
            Err(e) => panic!("rank {rank}: unexpected error {e}"),
            Ok(got) => {
                assert_ne!(rank, 1, "rank 1 must not get data past the checksum");
                for (src, m) in got.iter().enumerate() {
                    assert_eq!(m[0], Tensor::randn(&[64], 5000 + src as u64));
                }
            }
        }
    }
}

#[test]
fn straggler_delay_keeps_collectives_bit_identical_and_rank_ordered() {
    let w = 4usize;
    // rank 2 stalls 25 ms at each of its first two ops; the two-barrier
    // fencing must still hand every rank the same rank-ordered results
    let plan = Arc::new(FaultPlan::new().delay(2, 0, 25_000).delay(2, 1, 25_000));
    let world = World::new(w);
    world.install_faults(plan.clone());
    let results = world.run_catch(|c| {
        let r = c.rank() as u64;
        let g = c.all_gather(vec![Tensor::randn(&[32], 7000 + r)])?;
        let msgs: Vec<_> = (0..4u64)
            .map(|d| vec![Tensor::randn(&[16], 8000 + r * 4 + d)])
            .collect();
        let x = c.all_to_all(msgs)?;
        Ok::<_, CommError>((g, x))
    });
    for (rank, res) in results.into_iter().enumerate() {
        let (g, x) = res.expect("no panic").expect("a straggler must not fail anyone");
        assert_eq!(g.len(), w);
        assert_eq!(x.len(), w);
        for src in 0..w {
            assert_eq!(
                g[src][0],
                Tensor::randn(&[32], 7000 + src as u64),
                "all_gather rank {rank}: slot {src} not rank-ordered/bit-exact"
            );
            assert_eq!(
                x[src][0],
                Tensor::randn(&[16], 8000 + (src * 4 + rank) as u64),
                "all_to_all rank {rank}: slot {src} not rank-ordered/bit-exact"
            );
        }
    }
    assert_eq!(plan.injected(), 2, "both delay events must have fired");
}

#[test]
fn serve_poison_request_leaves_survivors_bit_identical() {
    let model = Model::load("tiny", Variant::Basic, "0", 1).expect("tiny artifacts");
    model.warmup_serving().expect("serving artifacts");
    let window = model.config().max_seq;

    fn standard_requests(sl: &mut ServeLoop<'_>) {
        for k in 0..3u64 {
            sl.enqueue(Request {
                id: k,
                arrival_tick: k,
                prompt: (0..40)
                    .map(|i| ((i * 7 + k as usize * 13 + 5) % 256) as i32)
                    .collect(),
                prefix_len: 0,
                max_new: 6,
                deadline_tick: k + 64,
            });
        }
    }

    let clean = {
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        standard_requests(&mut sl);
        sl.run().unwrap()
    };
    assert_eq!(clean.sessions, 3);
    assert_eq!(clean.failed_requests, 0);

    let poisoned = {
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        standard_requests(&mut sl);
        // a prompt of exactly max_seq tokens prefills fine but leaves no
        // room to decode: admitted, then fails at runtime — alone
        sl.enqueue(Request {
            id: 9,
            arrival_tick: 0,
            prompt: vec![3; window],
            prefix_len: 0,
            max_new: 4,
            deadline_tick: 64,
        });
        sl.run().unwrap()
    };
    assert_eq!(poisoned.rejected_requests, 0, "runtime failure, not admission");
    assert_eq!(poisoned.failed_requests, 1);
    assert_eq!(poisoned.sessions, 3, "only the survivors finish");
    assert_eq!(
        poisoned.output_digest, clean.output_digest,
        "survivor outputs must be bit-identical with and without the poison"
    );

    // and a prompt that can never prefill is rejected at admission without
    // aborting the loop
    let mut sl = ServeLoop::new(&model, ServeConfig::default());
    sl.enqueue(Request {
        id: 0,
        arrival_tick: 0,
        prompt: vec![1; window + 1],
        prefix_len: 0,
        max_new: 4,
        deadline_tick: 64,
    });
    let sum = sl.run().unwrap();
    assert_eq!(sum.rejected_requests, 1);
    assert_eq!(sum.sessions, 0);
    assert_eq!(sum.generated_tokens, 0);
}

#[test]
fn resume_falls_back_to_prev_checkpoint_when_newest_is_corrupt() {
    let engine = Engine::load_preset("tiny").expect("tiny artifacts");
    let pattern = Pattern("LL".into());
    let dir = tmpdir("fault_fallback");

    let full = TrainOpts { csv: Some(path(&dir, "full.csv")), ..opts(8) };
    train(&engine, Variant::Basic, &pattern, "basic_pure", &full).unwrap();

    // halted run snapshots at steps 2 and 4; rotation keeps both
    let ck = path(&dir, "part.ckpt");
    let halted = TrainOpts {
        csv: Some(path(&dir, "resumed.csv")),
        save: Some(ck.clone()),
        save_every: 2,
        halt_after: 4,
        ..opts(8)
    };
    train(&engine, Variant::Basic, &pattern, "basic_pure", &halted).unwrap();
    let prev = checkpoint::prev_path(&ck);
    assert!(Path::new(&prev).exists(), "rotation must keep the previous snapshot");

    // flip one byte mid-file: the checksum must reject it outright
    let mut bytes = std::fs::read(&ck).unwrap();
    bytes[bytes.len() / 2] ^= 0x40;
    std::fs::write(&ck, &bytes).unwrap();
    assert!(Checkpoint::load(&ck).is_err(), "corrupt checkpoint accepted");
    let (fb, fell_back) = Checkpoint::load_with_fallback(&ck).unwrap();
    assert!(fell_back, "fallback path not taken");
    assert_eq!(fb.steps_done, 2, "fallback must be the step-2 snapshot");

    // truncation is rejected the same way
    let tr = path(&dir, "trunc.ckpt");
    std::fs::write(&tr, &bytes[..bytes.len() / 3]).unwrap();
    assert!(Checkpoint::load(&tr).is_err(), "truncated checkpoint accepted");

    // resuming through the corrupt newest lands on .prev (step 2) and the
    // CSV still reconstructs the uninterrupted run byte for byte
    let resumed = TrainOpts {
        csv: Some(path(&dir, "resumed.csv")),
        save: Some(ck.clone()),
        save_every: 2,
        resume: Some(ck.clone()),
        ..opts(8)
    };
    let rr = train(&engine, Variant::Basic, &pattern, "basic_pure", &resumed).unwrap();
    assert_eq!(rr.start_step, 2, "resume must start from the fallback snapshot");
    assert_eq!(rr.losses.len(), 6);
    assert_eq!(
        std::fs::read_to_string(path(&dir, "full.csv")).unwrap(),
        std::fs::read_to_string(path(&dir, "resumed.csv")).unwrap(),
        "fallback-resumed loss CSV differs from the uninterrupted run"
    );
}
