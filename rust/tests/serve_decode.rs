//! Serving-layer correctness: `Session::decode` must reproduce the
//! `forward_mono_*` oracle logits AT EVERY POSITION (<= 1e-4 max rel err)
//! for all six linear variants, a hybrid pattern, and the std softmax
//! baseline; plus prefill/decode mixing, snapshot/restore determinism,
//! batched-vs-single equality, and the constant-memory property itself.

use lasp2::config::{Pattern, Variant};
use lasp2::coordinator::{forward_mono, Params};
use lasp2::runtime::Engine;
use lasp2::serve::{argmax, Batch, Model};
use lasp2::tensor::Tensor;

const N: usize = 64; // 2 tiny chunks — forward_mono_*_N64 artifacts exist

fn model_for(variant: Variant, ratio: &str, seed: u64) -> Model {
    let engine = Engine::load_preset("tiny").expect("native tiny preset");
    let pattern = Pattern::from_ratio(engine.model.n_layers, ratio).unwrap();
    let params = Params::randn(&engine.model, variant, &pattern, seed);
    Model::from_parts(engine, params)
}

fn tokens(seed: i32) -> Vec<i32> {
    (0..N as i32).map(|i| (i * 7 + 3 + seed * 13) % 256).collect()
}

fn mono(model: &Model, artifact: &str, toks: &[i32]) -> Tensor {
    forward_mono(model.engine(), artifact, model.params(), toks).unwrap()
}

/// Decode `toks` one token at a time from a fresh session; stack logits.
fn decode_all(model: &Model, toks: &[i32]) -> Tensor {
    let vb = model.config().vocab;
    let mut s = model.session();
    let rows: Vec<Tensor> = toks
        .iter()
        .map(|&t| s.decode(t).unwrap().reshape(&[1, vb]))
        .collect();
    Tensor::cat0(&rows)
}

#[test]
fn decode_matches_mono_every_position_all_linear_variants() {
    let toks = tokens(0);
    for &variant in Variant::linear_variants() {
        let model = model_for(variant, "0", 11);
        let got = decode_all(&model, &toks);
        let want = mono(
            &model,
            &format!("forward_mono_{}_pure_N{N}", variant.name()),
            &toks,
        );
        assert!(
            got.allclose(&want, 1e-4),
            "{variant}: decode vs mono max rel err {}",
            got.max_rel_err(&want)
        );
    }
}

#[test]
fn decode_matches_mono_hybrid_and_std() {
    let toks = tokens(1);
    // hybrid LN: linear recurrent state + std KV cache in one stack
    let model = model_for(Variant::Basic, "1/2", 7);
    let got = decode_all(&model, &toks);
    let want = mono(&model, &format!("forward_mono_basic_h2_N{N}"), &toks);
    assert!(
        got.allclose(&want, 1e-4),
        "hybrid h2: {}",
        got.max_rel_err(&want)
    );
    // all-std softmax baseline through the KV-cache decode path
    let model = model_for(Variant::Softmax, "all", 9);
    let got = decode_all(&model, &toks);
    let want = mono(&model, &format!("forward_mono_softmax_std_N{N}"), &toks);
    assert!(
        got.allclose(&want, 1e-4),
        "softmax std: {}",
        got.max_rel_err(&want)
    );
}

#[test]
fn prefill_then_decode_matches_mono() {
    // chunk-aligned prefill (1 chunk) + ragged prefill tail (8 single-token
    // fallback steps) + explicit decode for the rest: one logits tensor,
    // every position checked against the oracle.
    let toks = tokens(2);
    let model = model_for(Variant::Gla, "0", 3);
    let vb = model.config().vocab;
    let mut s = model.session();
    let mut rows = vec![s.prefill(&toks[..40]).unwrap()]; // 32 + 8
    assert_eq!(rows[0].shape(), &[40, vb]);
    assert_eq!(s.pos(), 40);
    for &t in &toks[40..] {
        rows.push(s.decode(t).unwrap().reshape(&[1, vb]));
    }
    let got = Tensor::cat0(&rows);
    let want = mono(&model, &format!("forward_mono_gla_pure_N{N}"), &toks);
    assert!(
        got.allclose(&want, 1e-4),
        "gla prefill+decode: {}",
        got.max_rel_err(&want)
    );
}

#[test]
fn snapshot_restore_is_deterministic() {
    // hybrid pattern so BOTH state kinds (recurrent M and KV cache) are
    // snapshotted; replays must be bit-identical.
    let toks = tokens(3);
    let model = model_for(Variant::Basic, "1/2", 5);
    let mut s = model.session();
    s.prefill(&toks[..32]).unwrap();
    let snap = s.snapshot();
    let pos0 = s.pos();
    let first: Vec<Tensor> = (0..8).map(|i| s.decode(i * 3 + 1).unwrap()).collect();
    assert_eq!(s.pos(), pos0 + 8);
    s.restore(&snap);
    assert_eq!(s.pos(), pos0);
    let second: Vec<Tensor> = (0..8).map(|i| s.decode(i * 3 + 1).unwrap()).collect();
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a, b, "restored replay must be bit-identical");
    }
}

#[test]
fn batch_decode_matches_single_sessions() {
    // 3 sessions -> grouped as B=2 + B=1 through the batched kernels;
    // per-row math is independent of B, so results are bit-identical to
    // stepping each session alone.
    let model = model_for(Variant::Basic, "1/2", 13);
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|k| (0..32).map(|i| (i * 7 + 3 + k * 29) % 256).collect())
        .collect();
    let mut batch = Batch::new(&model);
    let mut singles = Vec::new();
    for p in &prompts {
        let mut s = model.session();
        s.prefill(p).unwrap();
        batch.push(s);
        let mut s2 = model.session();
        s2.prefill(p).unwrap();
        singles.push(s2);
    }
    assert_eq!(batch.len(), 3);
    for step in 0..4i32 {
        let toks: Vec<i32> = (0..3).map(|k| (step * 31 + k * 7 + 2) % 256).collect();
        let rows = batch.decode(&toks).unwrap();
        assert_eq!(rows.len(), 3);
        for (k, single) in singles.iter_mut().enumerate() {
            let want = single.decode(toks[k]).unwrap();
            assert_eq!(rows[k], want, "session {k} step {step}");
        }
    }
    for s in batch.sessions() {
        assert_eq!(s.pos(), 32 + 4);
    }
}

#[test]
fn linear_state_is_constant_memory_std_kv_grows() {
    // the decode-bench claim as a hard assertion
    let model = model_for(Variant::Retention, "0", 17);
    let mut s = model.session();
    for t in 0..48 {
        s.decode(t % 256).unwrap();
    }
    let at48 = s.state_bytes();
    for t in 0..48 {
        s.decode(t % 256).unwrap();
    }
    assert_eq!(s.state_bytes(), at48, "recurrent state must not grow");

    let model = model_for(Variant::Softmax, "all", 19);
    let mut s = model.session();
    for t in 0..48 {
        s.decode(t % 256).unwrap();
    }
    let at48 = s.state_bytes();
    for t in 0..48 {
        s.decode(t % 256).unwrap();
    }
    assert_eq!(
        s.state_bytes(),
        2 * at48,
        "std KV cache must grow linearly with position"
    );
}

#[test]
fn generate_greedy_matches_manual_prefill_decode_loop() {
    let model = model_for(Variant::Basic, "0", 29);
    let vb = model.config().vocab;
    let prompt: Vec<i32> = (0..32).map(|i| (i * 5 + 1) % 256).collect();
    let mut s1 = model.session();
    let got = s1.generate(&prompt, 8).unwrap();
    assert_eq!(got.len(), 8);
    let mut s2 = model.session();
    let logits = s2.prefill(&prompt).unwrap();
    let mut next = argmax(&logits.data()[(prompt.len() - 1) * vb..]);
    let mut want = vec![next];
    while want.len() < 8 {
        let row = s2.decode(next).unwrap();
        next = argmax(row.data());
        want.push(next);
    }
    assert_eq!(got, want);
    assert_eq!(s1.pos(), s2.pos());
}

#[test]
fn context_window_exhaustion_is_an_error() {
    // tiny max_seq = 512; position 512 must refuse, not corrupt state
    let model = model_for(Variant::Basic, "0", 23);
    let mut s = model.session();
    let c = model.config().chunk_len;
    let full: Vec<i32> = (0..model.config().max_seq as i32).map(|i| i % 256).collect();
    s.prefill(&full).unwrap();
    assert_eq!(s.pos(), model.config().max_seq);
    assert!(s.decode(1).is_err(), "decode past max_seq must error");
    assert!(s.prefill(&full[..c]).is_err(), "prefill past max_seq must error");
}
