//! Integration: every SP scheduler's distributed forward must reproduce
//! the monolithic single-device oracle (forward_mono_* artifacts) —
//! the rust analogue of "LASP-2 is an exact reorganization, not an
//! approximation".  Runs hermetically on the native backend; with
//! `--features pjrt` plus AOT artifacts it exercises the PJRT path too.

use std::sync::Arc;

use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono, Params};
use lasp2::runtime::Engine;

const TOL: f32 = 2e-3;

fn engine() -> Arc<Engine> {
    Engine::load_preset("tiny")
        .expect("tiny preset loads on the native backend (no artifacts needed)")
}

fn tokens(n: usize, vocab: usize) -> Vec<i32> {
    (0..n as i32).map(|i| (i * 31 + 7) % vocab as i32).collect()
}

fn run_config(sched: Scheduler, variant: Variant, layers: usize) -> RunConfig {
    RunConfig {
        world: 4,
        scheduler: sched,
        variant,
        pattern: Pattern("L".repeat(layers)),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    }
}

fn check_scheduler_vs_mono(sched: Scheduler, variant: Variant) {
    let e = engine();
    let cfg = e.model.clone();
    let run = run_config(sched, variant, cfg.n_layers);
    let params = Params::randn(&cfg, variant, &run.pattern, 11);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let mono = format!("forward_mono_{}_pure_N{}", variant.name(), n);
    let want = forward_mono(&e, &mono, &params, &toks).unwrap();
    let err = got.max_rel_err(&want);
    assert!(err < TOL, "{sched} {variant}: max rel err {err}");
}

#[test]
fn lasp2_matches_mono_all_variants() {
    for v in Variant::linear_variants() {
        check_scheduler_vs_mono(Scheduler::Lasp2, *v);
    }
}

#[test]
fn lasp2_overlap_matches_mono() {
    // the overlapped schedule must be numerically identical
    for v in [Variant::Basic, Variant::Gla, Variant::Based] {
        check_scheduler_vs_mono(Scheduler::Lasp2Overlap, v);
    }
}

#[test]
fn lasp1_matches_mono() {
    for v in [Variant::Basic, Variant::Retention, Variant::Gla] {
        check_scheduler_vs_mono(Scheduler::Lasp1, v);
    }
}

#[test]
fn ring_attention_matches_mono() {
    check_scheduler_vs_mono(Scheduler::RingAttention, Variant::Basic);
}

#[test]
fn megatron_sp_matches_mono() {
    check_scheduler_vs_mono(Scheduler::MegatronSp, Variant::Basic);
}

#[test]
fn split_gather_is_exact() {
    // Table 5's split gathers must not change the numbers at all
    let e = engine();
    let cfg = e.model.clone();
    let mut run = run_config(Scheduler::Lasp2, Variant::Basic, cfg.n_layers);
    let params = Params::randn(&cfg, Variant::Basic, &run.pattern, 3);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let base = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    for splits in [2usize, 4, 16] {
        run.gather_splits = splits;
        let world = World::new(run.world);
        let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
        assert!(got.allclose(&base, 1e-6), "splits={splits}");
    }
}

#[test]
fn scheduler_equivalence_at_world_two() {
    // SP schedulers must agree with each other at any world size
    // (W=2 here; the N=128 mono oracle covers W=4 elsewhere).
    let e = engine();
    let cfg = e.model.clone();
    let mut run = run_config(Scheduler::Lasp2, Variant::Basic, cfg.n_layers);
    run.world = 2;
    let params = Params::randn(&cfg, Variant::Basic, &run.pattern, 5);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(2);
    let a = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    for sched in [Scheduler::Lasp1, Scheduler::MegatronSp, Scheduler::RingAttention] {
        run.scheduler = sched;
        let world = World::new(2);
        let b = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
        assert!(a.allclose(&b, 1e-4), "{sched}");
    }
}

#[test]
fn all_schedulers_agree_pairwise_and_with_oracle_w4() {
    // Native-backend parity gate: LASP-2 / LASP-2(overlap) / LASP-1 /
    // Ring Attention / Megatron-SP / Ulysses / ZeCO / USP-2D must produce
    // identical logits on the tiny shape at W=4, and all must match the
    // single-device oracle — for the basic variant AND a decay-gated one
    // (gla), whose per-chunk carry `a` exercises the gated prefix-combine
    // on every scheduler.
    let e = engine();
    let cfg = e.model.clone();
    for variant in [Variant::Basic, Variant::Gla] {
        let mut run = run_config(Scheduler::Lasp2, variant, cfg.n_layers);
        let params = Params::randn(&cfg, variant, &run.pattern, 17);
        let n = run.world * cfg.chunk_len;
        let toks = tokens(n, cfg.vocab);
        let mono = format!("forward_mono_{}_pure_N{n}", variant.name());
        let want = forward_mono(&e, &mono, &params, &toks).unwrap();
        let schedulers = [
            Scheduler::Lasp2,
            Scheduler::Lasp2Overlap,
            Scheduler::Lasp1,
            Scheduler::RingAttention,
            Scheduler::MegatronSp,
            Scheduler::Ulysses,
            Scheduler::Zeco,
            Scheduler::Usp2d,
        ];
        let mut results = Vec::new();
        for sched in schedulers {
            run.scheduler = sched;
            // usp2d gets a 2x2 mesh from for_run; everyone else flat W=4
            let world = World::for_run(&run);
            let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
            let err = got.max_rel_err(&want);
            assert!(err < TOL, "{sched} {variant} vs oracle: {err}");
            results.push(got);
        }
        for (sched, got) in schedulers.iter().zip(&results).skip(1) {
            assert!(got.allclose(&results[0], 1e-4), "{sched} {variant} vs lasp2");
        }
    }
}

#[test]
fn new_schedulers_match_mono_on_hybrid_pattern_w4() {
    // The 2D mesh only pays off on hybrid models (its linear path IS
    // LASP-2); Ulysses repartitions both layer kinds.  Gate all three new
    // schedulers on the tiny "LN" hybrid against the monolithic oracle.
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern::from_ratio(cfg.n_layers, "1/2").unwrap();
    assert_eq!(pattern.0, "LN");
    let mut run = run_config(Scheduler::Lasp2, Variant::Basic, cfg.n_layers);
    run.pattern = pattern.clone();
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 23);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let want = forward_mono(&e, &format!("forward_mono_basic_h2_N{n}"), &params, &toks)
        .unwrap();
    for sched in [
        Scheduler::Lasp2,
        Scheduler::Ulysses,
        Scheduler::Zeco,
        Scheduler::Usp2d,
    ] {
        run.scheduler = sched;
        let world = World::for_run(&run);
        let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
        let err = got.max_rel_err(&want);
        assert!(err < TOL, "{sched} hybrid LN vs oracle: {err}");
    }
}

#[test]
fn comm_counters_match_cost_analysis() {
    // §3.4 on the REAL communicator: forward-only counts per iteration.
    let e = engine();
    let cfg = e.model.clone();
    let l = cfg.n_layers as u64;
    let w = 4u64;
    let params = Params::randn(
        &cfg,
        Variant::Basic,
        &Pattern("L".repeat(cfg.n_layers)),
        1,
    );
    let toks = tokens(4 * cfg.chunk_len, cfg.vocab);

    // LASP-2: 1 collective per linear layer per rank (forward)
    let run = run_config(Scheduler::Lasp2, Variant::Basic, cfg.n_layers);
    let world = World::new(run.world);
    forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let snap = world.counters();
    assert_eq!(snap.collective_ops, l * w, "LASP-2 collectives");
    assert_eq!(snap.p2p_ops, 0, "LASP-2 should use no P2P");

    // LASP-1: (W-1) sequential P2P sends per layer (forward)
    let run = run_config(Scheduler::Lasp1, Variant::Basic, cfg.n_layers);
    let world = World::new(run.world);
    forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let snap = world.counters();
    assert_eq!(snap.p2p_ops, l * (w - 1), "LASP-1 P2P steps");
    assert_eq!(snap.collective_ops, 0);

    // Ring Attention: (W-1) hops per rank per layer
    let run = run_config(Scheduler::RingAttention, Variant::Basic, cfg.n_layers);
    let world = World::new(run.world);
    forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let snap = world.counters();
    assert_eq!(snap.p2p_ops, l * w * (w - 1), "ring hops");
}

#[test]
fn lasp2_gather_bytes_are_state_sized() {
    // the AllGather payload must be exactly (W-1) x state size per rank,
    // independent of sequence length (the paper's headline property)
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern("L".into());
    let run = RunConfig {
        world: 4,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 2);
    let toks = tokens(run.world * cfg.chunk_len, cfg.vocab);
    let world = World::new(run.world);
    forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let snap = world.counters();
    // payload per rank = M [H, dh, dh] + a [H, dh], f32
    let state_bytes = (cfg.state_elems(Variant::Basic) + cfg.n_heads * cfg.head_dim) * 4;
    assert_eq!(
        snap.bytes,
        (run.world * (run.world - 1) * state_bytes) as u64
    );
}
