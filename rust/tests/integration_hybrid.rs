//! Integration: LASP-2H on hybrid models (Fig. 2) — linear layers gather
//! memory states, standard layers gather K/V (Alg. 7) — verified against
//! the monolithic hybrid oracle; plus the standard-attention-only model
//! under both AllGather-CP and Ring Attention.

use std::sync::Arc;

use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono, Params};
use lasp2::runtime::Engine;

const TOL: f32 = 2e-3;

fn engine() -> Arc<Engine> {
    Engine::load_preset("tiny")
        .expect("tiny preset loads on the native backend (no artifacts needed)")
}

fn tokens(n: usize, vocab: usize) -> Vec<i32> {
    (0..n as i32).map(|i| (i * 13 + 5) % vocab as i32).collect()
}

#[test]
fn lasp2h_hybrid_matches_mono() {
    // tiny has 2 layers; ratio 1/2 -> "LN": one linear + one standard.
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern::from_ratio(cfg.n_layers, "1/2").unwrap();
    assert_eq!(pattern.0, "LN");
    let run = RunConfig {
        world: 4,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 21);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let want = forward_mono(&e, &format!("forward_mono_basic_h2_N{n}"), &params, &toks)
        .unwrap();
    let err = got.max_rel_err(&want);
    assert!(err < TOL, "hybrid max rel err {err}");

    // comm structure: 1 state-gather (linear) + 1 KV-gather (std) per rank
    let snap = world.counters();
    assert_eq!(snap.collective_ops, 2 * run.world as u64);
}

#[test]
fn lasp2h_hybrid_overlap_matches_mono() {
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern::from_ratio(cfg.n_layers, "1/2").unwrap();
    let run = RunConfig {
        world: 4,
        scheduler: Scheduler::Lasp2Overlap,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 22);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let want = forward_mono(&e, &format!("forward_mono_basic_h2_N{n}"), &params, &toks)
        .unwrap();
    assert!(got.max_rel_err(&want) < TOL);
}

#[test]
fn std_only_model_allgather_cp_matches_mono() {
    // pure standard attention (the Llama3 baseline) under Alg. 7
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern("N".repeat(cfg.n_layers));
    let run = RunConfig {
        world: 4,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 23);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let want = forward_mono(&e, &format!("forward_mono_softmax_std_N{n}"), &params, &toks)
        .unwrap();
    let err = got.max_rel_err(&want);
    assert!(err < TOL, "std allgather-CP err {err}");
}

#[test]
fn std_only_model_ring_matches_mono() {
    // the same model under Ring Attention must agree (online softmax
    // telescopes exactly)
    let e = engine();
    let cfg = e.model.clone();
    let pattern = Pattern("N".repeat(cfg.n_layers));
    let run = RunConfig {
        world: 4,
        scheduler: Scheduler::RingAttention,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 23);
    let n = run.world * cfg.chunk_len;
    let toks = tokens(n, cfg.vocab);
    let world = World::new(run.world);
    let got = forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
    let want = forward_mono(&e, &format!("forward_mono_softmax_std_N{n}"), &params, &toks)
        .unwrap();
    let err = got.max_rel_err(&want);
    assert!(err < TOL, "std ring err {err}");
}

#[test]
fn hybrid_kv_gather_moves_more_bytes_than_state_gather() {
    // Fig. 2's asymmetry: linear layers move O(d^2)-sized states, std
    // layers move O(C*d)-sized K/V; with tiny dims C=32=dh the KV payload
    // (2 tensors C*H*dh) equals 2x the state payload (M + a) — check the
    // accounting distinguishes them.
    let e = engine();
    let cfg = e.model.clone();
    let kv_bytes = 2 * cfg.chunk_len * cfg.n_heads * cfg.head_dim * 4;
    let state_bytes = (cfg.state_elems(Variant::Basic) + cfg.n_heads * cfg.head_dim) * 4;

    let measure = |pattern: &str| {
        let pattern = Pattern(pattern.into());
        let run = RunConfig {
            world: 4,
            scheduler: Scheduler::Lasp2,
            variant: Variant::Basic,
            pattern: pattern.clone(),
            gather_splits: 1,
            usp_cols: 2,
            seed: 0,
        };
        let params = Params::randn(&cfg, Variant::Basic, &pattern, 2);
        let toks = tokens(4 * cfg.chunk_len, cfg.vocab);
        let world = World::new(run.world);
        forward_distributed(&e, &world, &run, &params, &toks, true).unwrap();
        world.counters().bytes
    };
    let linear_only = measure("L");
    let std_only = measure("N");
    assert_eq!(linear_only, (4 * 3 * state_bytes) as u64);
    assert_eq!(std_only, (4 * 3 * kv_bytes) as u64);
}
