//! Integration: the distributed BACKWARD pass (Alg. 3/4) — LASP-2's single
//! AllGather on dM_t and LASP-1's reverse sequential ring must produce
//! identical chunk gradients, matching a serial single-thread reference
//! built from the same artifacts.  (The jnp oracle equivalence to jax.grad
//! is proven in python/tests/test_model.py::test_bwd_phases_match_grad.)

use std::sync::Arc;

use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{
    lasp1_attention_backward, lasp2_attention_backward, LinearFwdCache,
};
use lasp2::runtime::Engine;
use lasp2::tensor::{suffix_dstates, Tensor};

fn engine() -> Arc<Engine> {
    Engine::load_preset("tiny")
        .expect("tiny preset loads on the native backend (no artifacts needed)")
}

/// Build per-rank forward caches for W chunks of synthetic q/k/v plus the
/// incoming gradient dO, with m_prefix computed serially (plain sums,
/// basic variant).
fn make_inputs(
    e: &Engine,
    w: usize,
) -> (Vec<LinearFwdCache>, Vec<Tensor>) {
    let cfg = &e.model;
    let (c, hh, dh) = (cfg.chunk_len, cfg.n_heads, cfg.head_dim);
    let shape = [c, hh, dh];
    let mut caches = Vec::new();
    let mut dos = Vec::new();
    let mut m_prefix = Tensor::zeros(&[hh, dh, dh]);
    for r in 0..w {
        let qt = Tensor::randn(&shape, 100 + r as u64).scale(0.3);
        let kt = Tensor::randn(&shape, 200 + r as u64).scale(0.3);
        let v = Tensor::randn(&shape, 300 + r as u64).scale(0.3);
        let do_t = Tensor::randn(&shape, 400 + r as u64).scale(0.3);
        // M_t = K_t^T V_t per head (basic variant, rust math)
        let mut m_t = Tensor::zeros(&[hh, dh, dh]);
        for h in 0..hh {
            for i in 0..c {
                for a in 0..dh {
                    let kv = kt.data()[(i * hh + h) * dh + a];
                    for b in 0..dh {
                        m_t.data_mut()[(h * dh + a) * dh + b] +=
                            kv * v.data()[(i * hh + h) * dh + b];
                    }
                }
            }
        }
        caches.push(LinearFwdCache { qt, kt, v, m_prefix: m_prefix.clone() });
        m_prefix.add_assign(&m_t);
        dos.push(do_t);
    }
    (caches, dos)
}

/// Serial reference: run bwd1 for every chunk in order, suffix-sum in rust,
/// then bwd2 per chunk — no communication involved.
fn serial_backward(
    e: &Engine,
    caches: &[LinearFwdCache],
    dos: &[Tensor],
) -> Vec<(Tensor, Tensor, Tensor)> {
    let bwd1 = e.artifact("l_bwd1_basic").unwrap();
    let bwd2 = e.artifact("l_bwd2_basic").unwrap();
    let dms: Vec<Tensor> = caches
        .iter()
        .zip(dos)
        .map(|(cch, d)| {
            bwd1.run1(&[cch.qt.clone().into(), d.clone().into()]).unwrap()
        })
        .collect();
    let suffix = suffix_dstates(&dms);
    caches
        .iter()
        .zip(dos)
        .zip(suffix)
        .map(|((cch, d), suf)| {
            let outs = bwd2
                .run(&[
                    cch.qt.clone().into(),
                    cch.kt.clone().into(),
                    cch.v.clone().into(),
                    d.clone().into(),
                    cch.m_prefix.clone().into(),
                    suf.into(),
                ])
                .unwrap();
            let mut it = outs.into_iter();
            (it.next().unwrap(), it.next().unwrap(), it.next().unwrap())
        })
        .collect()
}

#[test]
fn lasp2_distributed_backward_matches_serial() {
    let e = engine();
    let w = 4;
    let (caches, dos) = make_inputs(&e, w);
    let want = serial_backward(&e, &caches, &dos);

    let run = RunConfig {
        world: w,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: Pattern("L".into()),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let world = World::new(w);
    let e2 = e.clone();
    let caches_ref = &caches;
    let dos_ref = &dos;
    let got = world.run(move |comm| {
        let r = comm.rank();
        lasp2_attention_backward(&e2, &comm, &run, &caches_ref[r], &dos_ref[r])
            .unwrap()
    });
    for (r, ((dq, dk, dv), (wq, wk, wv))) in got.iter().zip(&want).enumerate() {
        assert!(dq.allclose(wq, 1e-4), "rank {r} dq");
        assert!(dk.allclose(wk, 1e-4), "rank {r} dk");
        assert!(dv.allclose(wv, 1e-4), "rank {r} dv");
    }
    // exactly one collective per rank in the backward (Alg. 4 line 4)
    assert_eq!(world.counters().collective_ops, w as u64);
}

#[test]
fn lasp1_backward_matches_lasp2() {
    let e = engine();
    let w = 4;
    let (caches, dos) = make_inputs(&e, w);
    let want = serial_backward(&e, &caches, &dos);

    let world = World::new(w);
    let e2 = e.clone();
    let caches_ref = &caches;
    let dos_ref = &dos;
    let got = world.run(move |comm| {
        let r = comm.rank();
        lasp1_attention_backward(&e2, &comm, &caches_ref[r], &dos_ref[r]).unwrap()
    });
    for (r, ((dq, dk, dv), (wq, wk, wv))) in got.iter().zip(&want).enumerate() {
        assert!(dq.allclose(wq, 1e-4), "rank {r} dq");
        assert!(dk.allclose(wk, 1e-4), "rank {r} dk");
        assert!(dv.allclose(wv, 1e-4), "rank {r} dv");
    }
    // LASP-1 backward: W-1 sequential P2P hops, no collectives
    let snap = world.counters();
    assert_eq!(snap.p2p_ops, (w - 1) as u64);
    assert_eq!(snap.collective_ops, 0);
}

#[test]
fn backward_split_gather_is_exact() {
    let e = engine();
    let w = 4;
    let (caches, dos) = make_inputs(&e, w);
    let want = serial_backward(&e, &caches, &dos);
    let run = RunConfig {
        world: w,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: Pattern("L".into()),
        gather_splits: 8,
        usp_cols: 2,
        seed: 0,
    };
    let world = World::new(w);
    let e2 = e.clone();
    let caches_ref = &caches;
    let dos_ref = &dos;
    let got = world.run(move |comm| {
        let r = comm.rank();
        lasp2_attention_backward(&e2, &comm, &run, &caches_ref[r], &dos_ref[r])
            .unwrap()
    });
    for ((dq, dk, dv), (wq, wk, wv)) in got.iter().zip(&want) {
        assert!(dq.allclose(wq, 1e-4));
        assert!(dk.allclose(wk, 1e-4));
        assert!(dv.allclose(wv, 1e-4));
    }
}
