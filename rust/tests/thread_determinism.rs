//! The compute core's determinism contract: `LASP2_THREADS` (or
//! `par::set_threads`) changes wall-clock only — every end-to-end output
//! is BIT-identical at any thread count.  Also pins the fused-transpose
//! and `_into` GEMM entry points against a naive reference, and the
//! zero-skip-removal regression (sparse inputs still produce identical
//! results).

use lasp2::config::{Pattern, Variant};
use lasp2::coordinator::{forward_mono, Params};
use lasp2::runtime::{Engine, Value};
use lasp2::serve::{Batch, Model};
use lasp2::tensor::{gemm, par, Tensor};

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

fn fbits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Reference naive triple loop (f64-free, ascending-p accumulation).
fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j];
            }
        }
    }
    out
}

fn close(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len());
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "elem {i}: {x} vs {y}"
        );
    }
}

#[test]
fn fused_transpose_and_into_match_naive_reference() {
    // rectangular shapes including the m=1 decode readout and the
    // k >> n backward shapes
    for &(m, k, n) in &[
        (7usize, 5usize, 9usize),
        (1, 64, 256),  // decode head readout
        (1, 8, 3),
        (12, 384, 4),  // k >> n
        (64, 2048, 32),
        (33, 2, 17),
    ] {
        let a = Tensor::randn(&[m, k], 1000 + m as u64);
        let b = Tensor::randn(&[k, n], 2000 + n as u64);
        let want = naive(m, k, n, a.data(), b.data());
        close(a.matmul(&b).data(), &want, 1e-4);
        // nt: B stored transposed
        let bt = b.t();
        close(a.matmul_nt(&bt).data(), &want, 1e-4);
        // tn: A stored transposed
        let at = a.t();
        close(at.matmul_tn(&b).data(), &want, 1e-4);
        // _into variants overwrite stale contents and match exactly
        let mut out = Tensor::full(&[m, n], 123.0);
        a.matmul_into(&b, &mut out);
        assert_eq!(bits(&out), bits(&a.matmul(&b)));
        a.matmul_nt_into(&bt, &mut out);
        assert_eq!(bits(&out), bits(&a.matmul_nt(&bt)));
        at.matmul_tn_into(&b, &mut out);
        assert_eq!(bits(&out), bits(&at.matmul_tn(&b)));
    }
}

#[test]
fn sparse_rows_bit_identical_to_zero_skip_reference() {
    // the old matmul skipped a == 0.0 contributions inside the p-loop (a
    // dense-input pessimization); the rewrite must keep sparse-ish inputs
    // (zero rows/entries) BIT-identical to that skipping reference
    let (m, k, n) = (9, 14, 11);
    let mut a = Tensor::randn(&[m, k], 7);
    for p in 0..k {
        a.data_mut()[3 * k + p] = 0.0; // a full zero row
        a.data_mut()[6 * k + p] = 0.0;
    }
    a.data_mut()[1] = 0.0; // scattered zero entries
    a.data_mut()[8 * k + 2] = 0.0;
    let b = Tensor::randn(&[k, n], 8);
    let mut skip_ref = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a.data()[i * k + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                skip_ref[i * n + j] += av * b.data()[p * n + j];
            }
        }
    }
    let got = a.matmul(&b);
    assert_eq!(
        bits(&got),
        skip_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    // zero rows in, zero rows out (exactly)
    for j in 0..n {
        assert_eq!(got.data()[3 * n + j].to_bits(), 0.0f32.to_bits());
    }
}

#[test]
fn simd_dispatch_bit_identical_to_scalar_oracle_across_thread_counts() {
    // The `simd` feature's contract: the runtime-dispatched microkernels
    // (AVX2/NEON) are bit-exact against the scalar oracle — not merely
    // close — on rectangular, m=1 decode, and k >> n shapes, at 1 AND 4
    // threads (banding must not change the per-element chains either).
    let shapes =
        [(5usize, 7usize, 9usize), (1, 512, 33), (12, 2048, 4), (64, 300, 48)];
    for threads in [1usize, 4] {
        par::set_threads(threads);
        for &(m, k, n) in &shapes {
            let a = Tensor::randn(&[m, k], 31 + m as u64);
            let b = Tensor::randn(&[k, n], 37 + n as u64);
            let (bt, at) = (b.t(), a.t());
            let mut got = vec![0.0f32; m * n];
            let mut want = vec![0.0f32; m * n];
            let tag = format!("{m}x{k}x{n} @{threads}t");
            gemm::nn(m, k, n, a.data(), k, b.data(), n, &mut got, n);
            gemm::nn_scalar(m, k, n, a.data(), k, b.data(), n, &mut want, n);
            assert_eq!(fbits(&got), fbits(&want), "nn {tag}");
            gemm::nt(m, k, n, a.data(), k, bt.data(), k, &mut got, n);
            gemm::nt_scalar(m, k, n, a.data(), k, bt.data(), k, &mut want, n);
            assert_eq!(fbits(&got), fbits(&want), "nt {tag}");
            gemm::tn(m, k, n, at.data(), m, b.data(), n, &mut got, n);
            gemm::tn_scalar(m, k, n, at.data(), m, b.data(), n, &mut want, n);
            assert_eq!(fbits(&got), fbits(&want), "tn {tag}");
        }

        // non-contiguous operands: every matrix lives inside a wider slab
        // (row stride > logical width), as head views do in native.rs
        let (m, k, n) = (9usize, 300usize, 13usize);
        let (lda, ldb, ldo) = (k + 3, n + 2, n + 5);
        let a = Tensor::randn(&[m, k], 91);
        let b = Tensor::randn(&[k, n], 92);
        let mut aw = vec![0.5f32; m * lda];
        let mut bw = vec![0.25f32; k * ldb];
        for i in 0..m {
            aw[i * lda..i * lda + k].copy_from_slice(&a.data()[i * k..(i + 1) * k]);
        }
        for p in 0..k {
            bw[p * ldb..p * ldb + n].copy_from_slice(&b.data()[p * n..(p + 1) * n]);
        }
        let mut got = vec![0.0f32; m * ldo];
        let mut want = vec![0.0f32; m * ldo];
        gemm::nn(m, k, n, &aw, lda, &bw, ldb, &mut got, ldo);
        gemm::nn_scalar(m, k, n, &aw, lda, &bw, ldb, &mut want, ldo);
        for i in 0..m {
            assert_eq!(
                fbits(&got[i * ldo..i * ldo + n]),
                fbits(&want[i * ldo..i * ldo + n]),
                "strided nn row {i} @{threads}t"
            );
        }
        // and the accumulate variant on the same strided layout
        gemm::nn_acc(m, k, n, &aw, lda, &bw, ldb, &mut got, ldo);
        gemm::nn_acc_scalar(m, k, n, &aw, lda, &bw, ldb, &mut want, ldo);
        for i in 0..m {
            assert_eq!(
                fbits(&got[i * ldo..i * ldo + n]),
                fbits(&want[i * ldo..i * ldo + n]),
                "strided nn_acc row {i} @{threads}t"
            );
        }
    }
    par::set_threads(0);
}

/// Run `f` under thread counts 1, 2, and 8 and assert every returned
/// tensor is bit-identical to the serial run.
fn assert_thread_invariant<F: Fn() -> Vec<Tensor>>(what: &str, f: F) {
    par::set_threads(1);
    let want: Vec<Vec<u32>> = f().iter().map(bits).collect();
    for t in [2usize, 8] {
        par::set_threads(t);
        let got: Vec<Vec<u32>> = f().iter().map(bits).collect();
        assert_eq!(got, want, "{what}: outputs changed at {t} threads");
    }
    par::set_threads(0);
}

#[test]
fn forward_train_and_batched_decode_bit_identical_across_thread_counts() {
    // one test (not three) so the global set_threads override never races

    // -- forward_mono on the small preset: big enough that chunk-level
    // par_map AND gemm row-banding genuinely fan out
    let small = Engine::load_preset("small").unwrap();
    let n = 4 * small.model.chunk_len;
    let pattern = Pattern("L".repeat(small.model.n_layers));
    let params = Params::randn(&small.model, Variant::Basic, &pattern, 11);
    let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 5 + 1) % small.model.vocab as i32).collect();
    let name = format!("forward_mono_basic_pure_N{n}");
    assert_thread_invariant("forward_mono(small)", || {
        vec![forward_mono(&small, &name, &params, &tokens).unwrap()]
    });

    // -- train_step on tiny (covers the sequence-parallel batch reduce +
    // the whole backward)
    let tiny = Engine::load_preset("tiny").unwrap();
    let cfg = tiny.model.clone();
    let init = tiny.artifact("init_basic_pure").unwrap();
    let params0 = init.run(&[Value::I32(vec![3], vec![1])]).unwrap();
    let p = params0.len();
    let step = tiny.artifact("train_step_basic_pure").unwrap();
    let (bs, sl) = (cfg.train_batch, cfg.train_seq);
    let toks: Vec<i32> = (0..(bs * sl) as i32).map(|i| (i * 7 + 2) % cfg.vocab as i32).collect();
    let tgts: Vec<i32> = (0..(bs * sl) as i32).map(|i| (i * 3 + 5) % cfg.vocab as i32).collect();
    assert_thread_invariant("train_step(tiny)", || {
        let mut ins: Vec<Value> = params0.iter().cloned().map(Value::F32).collect();
        for t in &params0 {
            ins.push(Value::F32(Tensor::zeros(t.shape())));
        }
        for t in &params0 {
            ins.push(Value::F32(Tensor::zeros(t.shape())));
        }
        ins.push(Value::I32(toks.clone(), vec![bs, sl]));
        ins.push(Value::I32(tgts.clone(), vec![bs, sl]));
        ins.push(Value::F32(Tensor::ones(&[bs, sl])));
        ins.push(Value::F32(Tensor::scalar1(1e-3)));
        ins.push(Value::F32(Tensor::scalar1(1.0)));
        let outs = step.run(&ins).unwrap();
        assert_eq!(outs.len(), 3 * p + 1);
        outs
    });

    // -- batched decode on a hybrid pattern (recurrent + KV-cache layers,
    // session-parallel kernels, B=1 zero-copy staging via the prefill)
    let model = Model::with_engine(tiny.clone(), Variant::Basic, "1/2", 1).unwrap();
    assert_thread_invariant("batched_decode(tiny h2)", || {
        let mut batch = Batch::new(&model);
        for i in 0..4 {
            let mut s = model.session();
            // stagger positions so per-session KV lens differ
            s.prefill(&(0..(i + 1) as i32).collect::<Vec<_>>()).unwrap();
            batch.push(s);
        }
        let mut out = Vec::new();
        for step in 0..3 {
            out.extend(batch.decode(&[step, step + 1, step + 2, step + 3]).unwrap());
        }
        out
    });
}
