//! Serve-loop correctness: the continuous-batching scheduler must be a
//! pure reordering of sequential `Session::generate` — every request's
//! token stream bit-identical through chunked prefill interleaving,
//! batched decode, prefix-cache hits, and evict/resume cycles — and
//! `state_bytes` must report allocated KV capacity honestly.

use lasp2::config::Variant;
use lasp2::serve::{
    decode_step, gen_trace, Model, ServeConfig, ServeLoop, ServeSummary, TraceConfig,
};

fn model(variant: Variant, ratio: &str) -> Model {
    Model::load("tiny", variant, ratio, 11).expect("native tiny preset")
}

/// Replay a trace through the loop and check every finished stream against
/// a fresh sequential `generate` of the same request.  Returns the summary
/// for extra assertions.
fn run_and_check(m: &Model, cfg: ServeConfig, sessions: usize, seed: u64) -> ServeSummary {
    let trace = gen_trace(&TraceConfig::for_model(m.config(), sessions, seed));
    let mut sl = ServeLoop::new(m, cfg);
    for req in trace.iter().cloned() {
        sl.enqueue(req);
    }
    let sum = sl.run().unwrap();
    assert_eq!(sum.sessions, sessions);
    let mut fin = sl.finished().to_vec();
    fin.sort_by_key(|f| f.id);
    for (req, f) in trace.iter().zip(&fin) {
        assert_eq!(req.id, f.id);
        let want = m.session().generate(&req.prompt, req.max_new).unwrap();
        assert_eq!(f.tokens, want, "request {} diverged from sequential generate", req.id);
    }
    sum
}

#[test]
fn loop_is_bit_identical_to_sequential_generate_hybrid() {
    // hybrid LN stack: recurrent state + growing KV cache in one model,
    // with the prefix cache on and default knobs
    let m = model(Variant::Basic, "1/2");
    let sum = run_and_check(&m, ServeConfig::default(), 8, 5);
    assert!(sum.generated_tokens >= 8 * 4);
}

#[test]
fn prefix_cache_hit_is_bit_identical_to_cold_prefill() {
    // the trace shares 4 system prompts across 10 requests, so the cached
    // run MUST hit; identical digests prove hits replay the cold path
    // bit-for-bit (run_and_check already pins each stream to generate)
    let m = model(Variant::Gla, "0");
    let cached = ServeConfig { prefix_cache_entries: 8, ..Default::default() };
    let cold = ServeConfig { prefix_cache_entries: 0, ..Default::default() };
    let a = run_and_check(&m, cached, 10, 3);
    let b = run_and_check(&m, cold, 10, 3);
    assert!(a.cache_hits > 0, "shared system prompts must hit the cache");
    assert_eq!(b.cache_hits, 0);
    assert_eq!(a.output_digest, b.output_digest);
}

#[test]
fn evict_then_resume_reproduces_streams_all_variants() {
    // a budget of ~2.5 active sessions forces evictions with max_active=4;
    // every linear variant plus one hybrid must replay bit-exactly through
    // the snapshot/park/resume cycle
    let mut cases: Vec<(Variant, &str)> =
        Variant::linear_variants().iter().map(|&v| (v, "0")).collect();
    cases.push((Variant::Basic, "1/2"));
    for (variant, ratio) in cases {
        let m = model(variant, ratio);
        let c = m.config().chunk_len;
        let mut probe = m.session();
        let prompt: Vec<i32> = (0..c as i32).map(|i| (i * 7 + 3) % 256).collect();
        probe.prefill(&prompt).unwrap();
        let per_session = probe.state_bytes();
        let cfg = ServeConfig {
            max_active: 4,
            mem_budget: per_session * 5 / 2,
            ..Default::default()
        };
        let sum = run_and_check(&m, cfg, 6, 9);
        assert!(
            sum.evictions > 0 && sum.resumes > 0,
            "{variant} {ratio}: budget {} must force evict/resume",
            per_session * 5 / 2
        );
    }
}

#[test]
fn state_bytes_reports_allocated_kv_capacity() {
    // std KV caches are capacity-managed: bytes stay FLAT between
    // power-of-two doublings and double exactly when capacity does
    let m = model(Variant::Softmax, "all");
    let mut s = m.session();
    for t in 0..10 {
        s.decode(t % 256).unwrap();
    }
    let at10 = s.state_bytes();
    assert!(at10 > 0);
    for t in 10..16 {
        s.decode(t % 256).unwrap();
    }
    assert_eq!(s.state_bytes(), at10, "no growth while len fits capacity 16");
    s.decode(17).unwrap();
    assert_eq!(s.state_bytes(), 2 * at10, "17th token doubles capacity");

    // linear state never grows, whatever the position
    let m = model(Variant::Basic, "0");
    let mut s = m.session();
    s.decode(1).unwrap();
    let b0 = s.state_bytes();
    for t in 0..40 {
        s.decode(t % 256).unwrap();
    }
    assert_eq!(s.state_bytes(), b0, "recurrent state is constant");
}

#[test]
fn decode_step_groups_mixed_length_std_sessions() {
    // three KV-cache sessions at DIFFERENT positions batched through the
    // shared decode entry point must match stepping each alone (the group
    // packs to the max live length, so per-row math is unchanged)
    let m = model(Variant::Softmax, "all");
    let lens = [7usize, 19, 33];
    let mut batched = Vec::new();
    let mut singles = Vec::new();
    for (k, &n) in lens.iter().enumerate() {
        let p: Vec<i32> = (0..n as i32).map(|i| (i * 5 + k as i32 * 17 + 1) % 256).collect();
        let mut a = m.session();
        a.prefill(&p).unwrap();
        batched.push(a);
        let mut b = m.session();
        b.prefill(&p).unwrap();
        singles.push(b);
    }
    for step in 0..3i32 {
        let toks: Vec<i32> = (0..3).map(|k| (step * 13 + k * 7 + 2) % 256).collect();
        let mut refs: Vec<&mut _> = batched.iter_mut().collect();
        let rows = decode_step(&mut refs, &toks).unwrap();
        for (k, single) in singles.iter_mut().enumerate() {
            let want = single.decode(toks[k]).unwrap();
            assert_eq!(rows[k], want, "session {k} (len {}) step {step}", lens[k]);
        }
    }
}
