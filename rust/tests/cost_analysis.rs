//! §3.4 theoretical cost analysis, reproduced as executable assertions,
//! plus calibration anchors for the simulator against Table 6.

use lasp2::config::Scheduler;
use lasp2::coordinator::plan::{build_plan, SimShape};
use lasp2::sim::{simulate, CostModel};

/// Paper §3.4: per-iteration communication steps.
///   LASP-1: 2(W-1)    LASP-2: 2        (per linear-attention layer)
#[test]
fn cost_analysis_steps() {
    for w in [2usize, 8, 64, 128] {
        let mut s = SimShape::linear_llama3_1b(w, w * 8192, 16);
        s.n_linear_layers = 1.0;
        let l2 = build_plan(&s, Scheduler::Lasp2, 1).account(w);
        assert_eq!(l2.collective_steps, 2);
        assert_eq!(l2.p2p_steps, 0);
        let l1 = build_plan(&s, Scheduler::Lasp1, 1).account(w);
        assert_eq!(l1.p2p_steps, 2 * (w - 1));
    }
}

/// Paper §3.4: communication traffic per step is BHd² for both methods,
/// so over I iterations LASP-1 moves 2(W-1)IBHd² and LASP-2 2IBHd² in
/// STEP-count terms (ring-allgather moves the same bytes but in one
/// pipelined collective).
#[test]
fn cost_analysis_traffic_model() {
    let w = 64;
    let mut s = SimShape::linear_llama3_1b(w, w * 8192, 16);
    s.n_linear_layers = 1.0;
    let state = s.state_bytes();
    let l1 = build_plan(&s, Scheduler::Lasp1, 1).account(w);
    let l2 = build_plan(&s, Scheduler::Lasp2, 1).account(w);
    // LASP-1: 2(W-1) hops x BHd² bytes each
    assert!((l1.bytes - 2.0 * (w as f64 - 1.0) * state).abs() < 1.0);
    // LASP-2: 2 ring-allgathers, each moving (W-1) x BHd² per rank
    assert!((l2.bytes - 2.0 * (w as f64 - 1.0) * state).abs() < 1.0);
}

/// Paper §3.4's worked example: Linear-Llama3-1B, B=16, H=16, d=2048
/// -> BHd² ≈ 1.07B elements ≈ 2.14 GB in FP16 (we carry f32 at runtime,
/// the element count is what's asserted).
#[test]
fn cost_analysis_state_size_example() {
    let s = SimShape {
        d_model: 2048.0,
        n_heads: 16.0,
        head_dim: 2048.0,
        feat_dim: 2048.0,
        ffn_dim: 5504.0,
        n_linear_layers: 16.0,
        n_std_layers: 0.0,
        batch: 16.0,
        world: 64,
        chunk: 1024.0,
        usp_cols: 8,
    };
    let elems = s.state_bytes() / 4.0;
    let fp16_gb = elems * 2.0 / 1e9;
    assert!((elems / 1.07e9 - 1.0).abs() < 0.01);
    assert!((fp16_gb / 2.14 - 1.0).abs() < 0.02);
}

/// The simulator's Fig.-3 ordering and gap growth (the paper's headline:
/// +17.8% over Ring at 512K -> +36.6% at 2048K; +7.3% -> +15.2% over
/// LASP-1).  We assert ordering, monotone growth, and that the gaps are in
/// a plausible band (5%..200%), not the exact percentages.
#[test]
fn fig3_shape_holds() {
    let cm = CostModel::default();
    let gaps: Vec<(f64, f64)> = [512usize, 1024, 2048]
        .iter()
        .map(|&k| {
            let s = SimShape::linear_llama3_1b(64, k * 1024, 1);
            let l2 = simulate(&s, Scheduler::Lasp2Overlap, 1, &cm).tokens_per_sec;
            let l1 = simulate(&s, Scheduler::Lasp1, 1, &cm).tokens_per_sec;
            let ra = simulate(&s, Scheduler::RingAttention, 1, &cm).tokens_per_sec;
            (l2 / ra - 1.0, l2 / l1 - 1.0)
        })
        .collect();
    for (g_ring, g_lasp1) in &gaps {
        // Ring moves O(C)-sized KV blocks with per-hop launches: our model
        // penalizes it more than the paper's testbed did (documented in
        // EXPERIMENTS.md); the SHAPE claims are the ordering + growth.
        assert!(*g_ring > 0.05 && *g_ring < 4.0, "ring gap {g_ring}");
        assert!(*g_lasp1 > 0.0 && *g_lasp1 < 1.0, "lasp1 gap {g_lasp1}");
    }
    assert!(gaps[2].0 > gaps[0].0, "ring gap must grow with seq len");
    assert!(gaps[2].1 > gaps[0].1, "lasp1 gap must grow with seq len");
}

/// Table 6 calibration anchor: LASP-2 at (16 GPUs, 16K tokens) reported
/// 9530 tokens/s.  The simulator must land within 2x (we claim shape, not
/// absolute numbers — but the anchor keeps the model honest).
#[test]
fn table6_throughput_anchor() {
    let cm = CostModel::default();
    let s = SimShape::linear_llama3_1b(16, 16 * 1024, 1);
    let r = simulate(&s, Scheduler::Lasp2Overlap, 1, &cm);
    assert!(
        r.tokens_per_sec > 9530.0 / 2.0 && r.tokens_per_sec < 9530.0 * 2.0,
        "anchor tokens/s {}",
        r.tokens_per_sec
    );
}

/// Table 6 memory anchor: the 1B model's per-GPU footprint at short
/// sequences is ~25.6 GB and grows with C; 512K on 16 GPUs OOMs.
#[test]
fn table6_memory_anchor() {
    let cm = CostModel::default();
    let base = simulate(
        &SimShape::linear_llama3_1b(16, 2 * 1024, 1), Scheduler::Lasp2, 1, &cm);
    assert!((base.mem_gb / 25.6 - 1.0).abs() < 0.15, "base mem {}", base.mem_gb);
    let m256 = simulate(
        &SimShape::linear_llama3_1b(16, 256 * 1024, 1), Scheduler::Lasp2, 1, &cm);
    assert!((m256.mem_gb / 57.8 - 1.0).abs() < 0.3, "256K mem {}", m256.mem_gb);
    assert!(!m256.oom);
    let m512 = simulate(
        &SimShape::linear_llama3_1b(16, 512 * 1024, 1), Scheduler::Lasp2, 1, &cm);
    assert!(m512.oom, "512K on 16 GPUs must OOM (Table 6)");
}

/// "LASP-2 performs best with long sequences, large clusters, slow links"
/// (§3.4's qualitative conclusion): the LASP-2/LASP-1 gap must widen when
/// the interconnect slows down.
#[test]
fn slow_links_favor_lasp2() {
    let s = SimShape::linear_llama3_1b(64, 512 * 1024, 1);
    let fast = CostModel::default();
    let slow = CostModel { beta_inter: 5e9, alpha_p2p: 60e-6, ..fast };
    let gap = |cm: &CostModel| {
        simulate(&s, Scheduler::Lasp2Overlap, 1, cm).tokens_per_sec
            / simulate(&s, Scheduler::Lasp1, 1, cm).tokens_per_sec
    };
    assert!(gap(&slow) > gap(&fast));
}
