//! Bench: Table 5 — throughput vs AllGather split size.
//!
//! SIM at the paper's scale (64 GPUs, 1024K) plus REAL-EXEC timing of the
//! split gathers through the instrumented communicator.
//!
//! Run via `cargo bench --bench table5_splits`.

use std::time::Instant;

use lasp2::bench;
use lasp2::comm::World;
use lasp2::sim::CostModel;
use lasp2::tensor::Tensor;

fn main() {
    println!("# Table 5 (sim, 64 GPUs, 1024K, state [1,16,2048,2048]-scaled)\n");
    println!("{}", bench::table5_splits(&CostModel::default()).to_markdown());

    // REAL: time W=4 split gathers of a Linear-Llama3-1B-shaped state
    // slice ([16, 256, 256] f32 = 4 MB) over the in-memory communicator.
    let w = 4;
    let iters = 20;
    println!("# Table 5 companion (REAL in-memory collectives, W={w}, 4MB state)\n");
    println!("| splits | median us/gather | collectives/iter |");
    println!("|---|---|---|");
    for splits in [1usize, 4, 16, 64] {
        let world = World::new(w);
        let times: Vec<f64> = (0..iters)
            .map(|_| {
                let t0 = Instant::now();
                world.run(|c| {
                    c.all_gather_split(
                        vec![Tensor::zeros(&[16, 256, 256])],
                        splits,
                    )
                    .unwrap();
                });
                t0.elapsed().as_secs_f64()
            })
            .collect();
        let mut ts = times.clone();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = ts[ts.len() / 2];
        let coll = world.counters().collective_ops / iters as u64;
        println!("| {splits} | {:.0} | {coll} |", med * 1e6);
    }
}
