//! Bench: communicator micro-benchmarks — AllGather vs P2P-ring latency
//! across world sizes and payload sizes.  This is the microscopic version
//! of the paper's §3.3 argument: one collective launch beats many
//! dependent P2P launches.
//!
//! Run via `cargo bench --bench collectives`.

use std::time::Instant;

use lasp2::comm::World;
use lasp2::tensor::Tensor;

fn bench_case(w: usize, elems: usize, iters: usize) -> (f64, f64) {
    // AllGather of `elems` f32 per rank
    let world = World::new(w);
    let t0 = Instant::now();
    for _ in 0..iters {
        world.run(|c| {
            c.all_gather(vec![Tensor::zeros(&[elems])]).unwrap();
        });
    }
    let ag = t0.elapsed().as_secs_f64() / iters as f64;

    // sequential ring of W-1 hops carrying the same payload (LASP-1 style)
    let world = World::new(w);
    let t0 = Instant::now();
    for _ in 0..iters {
        world.run(|c| {
            let r = c.rank();
            let m = if r == 0 {
                Tensor::zeros(&[elems])
            } else {
                c.recv(r - 1).unwrap().pop().unwrap()
            };
            if r + 1 < c.size() {
                c.send(r + 1, vec![m]).unwrap();
            }
        });
    }
    let ring = t0.elapsed().as_secs_f64() / iters as f64;
    (ag, ring)
}

fn main() {
    println!("| world | payload KB | allgather us | seq-ring us | ring/ag |");
    println!("|---|---|---|---|---|");
    for w in [2usize, 4, 8] {
        for elems in [1024usize, 65536, 1048576] {
            let (ag, ring) = bench_case(w, elems, 15);
            println!(
                "| {w} | {} | {:.0} | {:.0} | {:.2}x |",
                elems * 4 / 1024,
                ag * 1e6,
                ring * 1e6,
                ring / ag
            );
        }
    }
}
