//! Bench: Fig. 3 — speed comparison across SP schedulers.
//!
//! Part 1 (SIM): the calibrated cluster model at the paper's scale
//! (64 GPUs, 128K..2048K) — regenerates the figure's series.
//! Part 2 (REAL): the actual distributed pipeline over worker threads +
//! PJRT artifacts at tiny scale, median-of-k wall time per scheduler.
//!
//! Run via `cargo bench --bench fig3_speed` (harness = false).

use std::time::Instant;

use lasp2::bench;
use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, Params};
use lasp2::runtime::Engine;
use lasp2::sim::CostModel;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() -> anyhow::Result<()> {
    println!("# Fig. 3 (sim, 64 GPUs, Linear-Llama3-1B)\n");
    println!("{}", bench::fig3_speed(&CostModel::default()).to_markdown());

    let preset = std::env::var("LASP2_PRESET").unwrap_or_else(|_| "tiny".into());
    let Ok(engine) = Engine::load_preset(&preset) else {
        println!("(artifacts for {preset} missing; sim-only run)");
        return Ok(());
    };
    let cfg = engine.model.clone();
    let world_size = 4;
    let pattern = Pattern("L".repeat(cfg.n_layers));
    let params = Params::randn(&cfg, Variant::Basic, &pattern, 7);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % cfg.vocab as i32).collect();

    println!("# Fig. 3 companion (REAL exec, preset={preset}, W={world_size}, N={n})\n");
    println!("| scheduler | median ms/fwd | tokens/s | collectives | p2p |");
    println!("|---|---|---|---|---|");
    for sched in [
        Scheduler::MegatronSp,
        Scheduler::RingAttention,
        Scheduler::Lasp1,
        Scheduler::Lasp2,
        Scheduler::Lasp2Overlap,
    ] {
        let run = RunConfig {
            world: world_size,
            scheduler: sched,
            variant: Variant::Basic,
            pattern: pattern.clone(),
            gather_splits: 1,
            usp_cols: 2,
            seed: 0,
        };
        let world = World::new(world_size);
        forward_distributed(&engine, &world, &run, &params, &tokens, true)?; // warmup
        world.reset_counters();
        let mut times = Vec::new();
        for _ in 0..7 {
            let t0 = Instant::now();
            forward_distributed(&engine, &world, &run, &params, &tokens, true)?;
            times.push(t0.elapsed().as_secs_f64());
        }
        let med = median(times);
        let snap = world.counters();
        println!(
            "| {} | {:.2} | {:.0} | {} | {} |",
            sched.name(),
            med * 1e3,
            n as f64 / med,
            snap.collective_ops / 7,
            snap.p2p_ops / 7
        );
    }
    Ok(())
}
