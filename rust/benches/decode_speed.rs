//! Bench: serving decode — autoregressive tokens/s and the
//! state-bytes-vs-position table (constant for linear variants' recurrent
//! state, linear growth for the std KV cache), plus batched decode
//! scaling through the `serve::Batch` grouped kernels.
//!
//! Run via `cargo bench --bench decode_speed`.

use std::time::Instant;

use lasp2::bench;
use lasp2::config::Variant;
use lasp2::runtime::Engine;
use lasp2::serve::{Batch, Model};

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("LASP2_PRESET").unwrap_or_else(|_| "tiny".into());
    let engine = Engine::load_preset(&preset)?;
    let n = (engine.model.max_seq / 4).max(8);

    println!("# serving decode — constant-memory inference (preset={preset}, {n} tokens)\n");
    println!("{}", bench::decode_bench(&engine, n)?.to_markdown());

    // batched decode: sessions stepped per kernel call via serve::Batch
    println!("\n# batched decode scaling (basic pure, {n} steps per session)\n");
    println!("| batch | tokens/s (aggregate) |");
    println!("|---|---|");
    for b in [1usize, 2, 4, 8] {
        let model = Model::with_engine(engine.clone(), Variant::Basic, "0", 1)?;
        model.warmup_serving()?;
        let mut batch = Batch::new(&model);
        for _ in 0..b {
            batch.push(model.session());
        }
        let tokens = vec![1i32; b];
        // one untimed step instantiates the *_B{b} artifacts for this
        // batch size (warmup_serving only covers B=1)
        batch.decode(&tokens)?;
        let t0 = Instant::now();
        for _ in 0..n {
            batch.decode(&tokens)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("| {b} | {:.0} |", (b * n) as f64 / dt);
    }
    Ok(())
}
