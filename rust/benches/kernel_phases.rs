//! Bench: per-artifact execution latency of the LASP-2 phase kernels —
//! the real-exec hot-path profile (feeds the §Perf iteration log).
//!
//! Run via `cargo bench --bench kernel_phases`.

use std::time::Instant;

use lasp2::config::Variant;
use lasp2::runtime::{Engine, Value};
use lasp2::tensor::Tensor;

fn median_run(
    exe: &lasp2::runtime::Executable,
    ins: &[Value],
    iters: usize,
) -> f64 {
    let mut ts = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        exe.run(ins).unwrap();
        ts.push(t0.elapsed().as_secs_f64());
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn inputs_for(meta: &lasp2::runtime::ArtifactMeta) -> Vec<Value> {
    meta.inputs
        .iter()
        .map(|t| match t.dtype {
            lasp2::runtime::DType::F32 => {
                Value::F32(Tensor::randn(&t.shape, 7).scale(0.05))
            }
            lasp2::runtime::DType::I32 => {
                // token-ish ids stay small & non-negative
                Value::I32(vec![1; t.elems()], t.shape.clone())
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("LASP2_PRESET").unwrap_or_else(|_| "tiny".into());
    let engine = Engine::load_preset(&preset)?;
    let mut names: Vec<String> = vec![
        "embed".into(),
        "head".into(),
        "s_part1".into(),
        "post_attn".into(),
        "ring_step".into(),
        "ring_linear_step".into(),
        "l_bwd1_basic".into(),
        "l_bwd2_basic".into(),
    ];
    for v in Variant::linear_variants() {
        names.push(format!("l_part1_{}", v.name()));
        names.push(format!("l_part2_{}", v.name()));
        names.push(format!("l_intra_{}", v.name()));
    }
    println!("# per-artifact latency (preset={preset}, median of 9)\n");
    println!("| artifact | median us/call |");
    println!("|---|---|");
    for name in names {
        if !engine.has_artifact(&name) {
            println!("| {name} | SKIPPED (no artifact on this backend) |");
            continue;
        }
        let exe = engine.artifact(&name)?;
        let ins = inputs_for(&exe.meta);
        exe.run(&ins)?; // warmup
        let med = median_run(&exe, &ins, 9);
        println!("| {name} | {:.0} |", med * 1e6);
    }
    Ok(())
}
