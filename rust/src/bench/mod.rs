//! Benchmark harness: one function per table/figure of the paper's
//! evaluation section.  Each prints a paper-shaped markdown table and
//! returns it for the CLI / bench binaries to persist.
//!
//! Two kinds of evidence:
//!  * REAL-EXEC — the actual distributed pipeline over worker threads +
//!    PJRT artifacts (small scale; proves the system works end to end);
//!  * SIM — the calibrated discrete-event model evaluated at the paper's
//!    scale (64-128 GPUs, up to 4096K tokens; reproduces the SHAPE of
//!    Figs. 3-4 and Tables 5-6).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::comm::World;
use crate::config::{Pattern, RunConfig, Scheduler, Variant};
use crate::coordinator::{forward_distributed, Params};
use crate::metrics::{fmt_seq, Table};
use crate::runtime::Engine;
use crate::serve::{argmax, Model};
use crate::sim::{simulate, CostModel};
use crate::coordinator::plan::SimShape;
use crate::train::{train, TrainOpts};

pub const FIG3_SCHEDULERS: [Scheduler; 4] = [
    Scheduler::MegatronSp,
    Scheduler::RingAttention,
    Scheduler::Lasp1,
    Scheduler::Lasp2Overlap,
];

/// Fig. 3: tokens/s vs sequence length at W=64, all four SP methods (SIM).
pub fn fig3_speed(cm: &CostModel) -> Table {
    let mut t = Table::new(&[
        "seq_len", "megatron-sp", "ring", "lasp1", "lasp2",
        "lasp2/ring", "lasp2/lasp1",
    ]);
    for k in [128usize, 256, 512, 1024, 2048] {
        let shape = SimShape::linear_llama3_1b(64, k * 1024, 1);
        let tps: Vec<f64> = FIG3_SCHEDULERS
            .iter()
            .map(|s| simulate(&shape, *s, 1, cm).tokens_per_sec)
            .collect();
        t.row(&[
            fmt_seq(k * 1024),
            format!("{:.0}", tps[0]),
            format!("{:.0}", tps[1]),
            format!("{:.0}", tps[2]),
            format!("{:.0}", tps[3]),
            format!("{:+.1}%", (tps[3] / tps[1] - 1.0) * 100.0),
            format!("{:+.1}%", (tps[3] / tps[2] - 1.0) * 100.0),
        ]);
    }
    t
}

/// Fig. 3 companion at small scale: REAL execution of all schedulers over
/// worker threads + PJRT, verifying relative ordering end-to-end.
pub fn fig3_realexec(engine: &Arc<Engine>, world_size: usize, iters: usize) -> Result<Table> {
    let cfg = &engine.model;
    let pattern = Pattern("L".repeat(cfg.n_layers));
    let params = Params::randn(cfg, Variant::Basic, &pattern, 7);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % cfg.vocab as i32).collect();
    let mut t = Table::new(&["scheduler", "tokens/s", "collectives", "p2p_ops", "MB moved"]);
    for sched in [
        Scheduler::MegatronSp,
        Scheduler::RingAttention,
        Scheduler::Lasp1,
        Scheduler::Lasp2,
        Scheduler::Lasp2Overlap,
    ] {
        let run = RunConfig {
            world: world_size,
            scheduler: sched,
            variant: Variant::Basic,
            pattern: pattern.clone(),
            gather_splits: 1,
            seed: 0,
        };
        // warmup (compile artifacts)
        let world = World::new(world_size);
        forward_distributed(engine, &world, &run, &params, &tokens, true)?;
        world.reset_counters();
        let t0 = Instant::now();
        for _ in 0..iters {
            forward_distributed(engine, &world, &run, &params, &tokens, true)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = world.counters();
        t.row(&[
            sched.name().to_string(),
            format!("{:.0}", (iters * n) as f64 / dt),
            format!("{}", snap.collective_ops / iters as u64),
            format!("{}", snap.p2p_ops / iters as u64),
            format!("{:.2}", snap.bytes as f64 / 1e6 / iters as f64),
        ]);
    }
    Ok(t)
}

/// Fig. 4 / Table 6: scalability sweep — throughput + memory per GPU with
/// OOM frontier (SIM, LASP-2).
pub fn table6_scalability(cm: &CostModel) -> Table {
    let mut t = Table::new(&["seq_len", "gpus", "tokens/s", "mem_gb/gpu"]);
    for k in [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        for w in [16usize, 32, 64, 128] {
            let n = k * 1024;
            if n / w == 0 {
                continue;
            }
            let shape = SimShape::linear_llama3_1b(w, n, 1);
            let r = simulate(&shape, Scheduler::Lasp2Overlap, 1, cm);
            t.row(&[
                fmt_seq(n),
                w.to_string(),
                if r.oom { "OOM".into() } else { format!("{:.0}", r.tokens_per_sec) },
                if r.oom { "OOM".into() } else { format!("{:.1}", r.mem_gb) },
            ]);
        }
    }
    t
}

/// Table 5: throughput vs AllGather split size (SIM at paper scale + the
/// relative effect measured REAL-EXEC via comm counters in benches).
pub fn table5_splits(cm: &CostModel) -> Table {
    let mut t = Table::new(&["splits", "split_size", "tokens/s", "delta"]);
    let shape = SimShape::linear_llama3_1b(64, 1024 * 1024, 1);
    let base = simulate(&shape, Scheduler::Lasp2, 1, cm).tokens_per_sec;
    for splits in [1usize, 4, 16, 64] {
        let r = simulate(&shape, Scheduler::Lasp2, splits, cm);
        t.row(&[
            splits.to_string(),
            (2048 / splits).to_string(),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:+.2}%", (r.tokens_per_sec / base - 1.0) * 100.0),
        ]);
    }
    t
}

/// Serving decode (REAL-EXEC): autoregressive tokens/s plus per-request
/// state bytes sampled at N/4, N/2, and N decoded tokens.  This is the
/// paper's constant-memory-inference claim made measurable: the linear
/// variants' recurrent `ChunkState` is FLAT in position, while the std
/// softmax baseline's KV cache (and the KV half of a hybrid) grows
/// linearly.
pub fn decode_bench(engine: &Arc<Engine>, n_tokens: usize) -> Result<Table> {
    anyhow::ensure!(
        (4..=engine.model.max_seq).contains(&n_tokens),
        "n_tokens {n_tokens} must be in 4..=max_seq ({})",
        engine.model.max_seq
    );
    let mut t = Table::new(&[
        "model",
        "pattern",
        "decode tok/s",
        "state_bytes@N/4",
        "state_bytes@N/2",
        "state_bytes@N",
        "state growth",
    ]);
    let mut cases: Vec<(Variant, &str)> = Variant::linear_variants()
        .iter()
        .map(|v| (*v, "0"))
        .collect();
    cases.push((Variant::Basic, "1/2"));
    cases.push((Variant::Softmax, "all"));
    let marks = [n_tokens / 4, n_tokens / 2, n_tokens];
    for (variant, ratio) in cases {
        let model = Model::with_engine(engine.clone(), variant, ratio, 1)?;
        // instantiate the decode artifacts OUTSIDE the timed region (on
        // PJRT the first call would otherwise time an HLO compile)
        model.warmup_serving()?;
        let mut session = model.session();
        let mut bytes = [0usize; 3];
        let mut tok = 1i32;
        let t0 = Instant::now();
        for step in 1..=n_tokens {
            let row = session.decode(tok)?;
            tok = argmax(row.data());
            for (j, m) in marks.iter().enumerate() {
                if step == *m {
                    bytes[j] = session.state_bytes();
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let growth = if bytes[2] > bytes[0] {
            "linear (KV cache)"
        } else {
            "constant (recurrent state)"
        };
        t.row(&[
            variant.name().to_string(),
            model.pattern().0.clone(),
            format!("{:.0}", n_tokens as f64 / dt),
            bytes[0].to_string(),
            bytes[1].to_string(),
            bytes[2].to_string(),
            growth.to_string(),
        ]);
    }
    Ok(t)
}

/// Table 2: convergence (loss + throughput) for the attention-module zoo,
/// REAL training through the train_step artifacts.
pub fn table2_convergence(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["model", "attention", "pattern", "tokens/s", "loss"]);
    let mut run = |variant: Variant, ratio: &str, label: &str| -> Result<()> {
        let tag = format!("{}_{}", variant.name(), Pattern::tag(ratio));
        if !engine.has_artifact(&format!("train_step_{tag}")) {
            // never drop a paper row invisibly: say what was skipped and why
            t.row(&[
                label.to_string(),
                variant.name().to_string(),
                Pattern::tag(ratio).to_string(),
                "-".into(),
                format!("SKIPPED: {tag} (no train_step_{tag} artifact on this backend)"),
            ]);
            return Ok(());
        }
        let pattern = Pattern::from_ratio(cfg.n_layers, ratio)?;
        let rep = train(
            engine,
            variant,
            &pattern,
            &tag,
            &TrainOpts { steps, log_every: 0, ..Default::default() },
        )?;
        t.row(&[
            label.to_string(),
            variant.name().to_string(),
            Pattern::tag(ratio).to_string(),
            format!("{:.0}", rep.tokens_per_sec),
            format!("{:.3}", rep.tail_loss),
        ]);
        Ok(())
    };
    // Llama3 baseline (standard attention everywhere, Ring-Attention row)
    run(Variant::Softmax, "all", "Llama3")?;
    for v in Variant::linear_variants() {
        run(*v, "0", "Linear-Llama3")?;
        run(*v, "1/4", "Linear-Llama3")?;
    }
    Ok(t)
}

/// Table 3: bidirectional language modeling (MLM), LASP-2 w/o masking.
pub fn table3_bidirectional(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["model", "training_loss"]);
    // baseline: standard attention, causal==false not needed — the paper
    // compares RoBERTa-ish standard attention vs basic linear attention.
    let pattern = Pattern::from_ratio(cfg.n_layers, "0")?;
    let rep = train(
        engine,
        Variant::Basic,
        &pattern,
        &format!("basic_{}_nm", Pattern::tag("0")),
        &TrainOpts { steps, mlm: true, log_every: 0, ..Default::default() },
    )?;
    t.row(&["Bidirectional + Basic Linear Attention (LASP-2 w/o masking)".into(),
            format!("{:.3}", rep.tail_loss)]);
    if engine.has_artifact("train_step_softmax_std") {
        let pat = Pattern::from_ratio(cfg.n_layers, "all")?;
        let rep = train(
            engine,
            Variant::Softmax,
            &pat,
            "softmax_std",
            &TrainOpts { steps, mlm: true, log_every: 0, ..Default::default() },
        )?;
        t.row(&["Baseline standard attention (gather-based)".into(),
                format!("{:.3}", rep.tail_loss)]);
    } else {
        t.row(&["Baseline standard attention (gather-based)".into(),
                "SKIPPED: softmax_std (no train_step_softmax_std artifact on this backend)".into()]);
    }
    Ok(t)
}

/// Table 4: hybrid-ratio ablation (0, 1/8, 1/4, 1/2) — loss per ratio.
pub fn table4_hybrid_ratio(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["module", "0 (pure)", "1/8", "1/4", "1/2"]);
    for v in [Variant::Basic, Variant::Lightning, Variant::Retention, Variant::Gla] {
        let mut cells = vec![v.name().to_string()];
        for ratio in ["0", "1/8", "1/4", "1/2"] {
            let tag = format!("{}_{}", v.name(), Pattern::tag(ratio));
            if !engine.has_artifact(&format!("train_step_{tag}")) {
                cells.push(format!("SKIPPED: {tag} (no artifact)"));
                continue;
            }
            let pattern = Pattern::from_ratio(cfg.n_layers, ratio)?;
            let rep = train(
                engine,
                v,
                &pattern,
                &tag,
                &TrainOpts { steps, log_every: 0, ..Default::default() },
            )?;
            cells.push(format!("{:.3}", rep.tail_loss));
        }
        t.row(&cells);
    }
    Ok(t)
}

/// Fig. 4 (left): memory-per-GPU frontier rows for quick printing.
pub fn fig4_scalability(cm: &CostModel) -> Table {
    let mut t = Table::new(&["gpus", "max_seq_no_oom", "tokens/s@max"]);
    for w in [8usize, 16, 32, 64, 128] {
        let mut best = 0usize;
        let mut tps = 0.0;
        for k in [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
            let shape = SimShape::linear_llama3_1b(w, k * 1024, 1);
            let r = simulate(&shape, Scheduler::Lasp2Overlap, 1, cm);
            if !r.oom {
                best = k * 1024;
                tps = r.tokens_per_sec;
            }
        }
        t.row(&[w.to_string(), fmt_seq(best), format!("{tps:.0}")]);
    }
    t
}
