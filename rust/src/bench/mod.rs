//! Benchmark harness: one function per table/figure of the paper's
//! evaluation section.  Each prints a paper-shaped markdown table and
//! returns it for the CLI / bench binaries to persist.
//!
//! Two kinds of evidence:
//!  * REAL-EXEC — the actual distributed pipeline over worker threads +
//!    PJRT artifacts (small scale; proves the system works end to end);
//!  * SIM — the calibrated discrete-event model evaluated at the paper's
//!    scale (64-128 GPUs, up to 4096K tokens; reproduces the SHAPE of
//!    Figs. 3-4 and Tables 5-6).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::comm::World;
use crate::config::{Pattern, RunConfig, Scheduler, Variant};
use crate::coordinator::{forward_distributed, Params};
use crate::metrics::{fmt_seq, Table};
use crate::runtime::Engine;
use crate::serve::{argmax, gen_trace, Model, ServeConfig, ServeLoop, TraceConfig};
use crate::sim::{simulate, zero_shard, CostModel};
use crate::coordinator::plan::SimShape;
use crate::tensor::quant::DecodeDtype;
use crate::tensor::Tensor;
use crate::train::{train, TrainOpts};

pub const FIG3_SCHEDULERS: [Scheduler; 4] = [
    Scheduler::MegatronSp,
    Scheduler::RingAttention,
    Scheduler::Lasp1,
    Scheduler::Lasp2Overlap,
];

/// Fig. 3: tokens/s vs sequence length at W=64, all four SP methods (SIM).
pub fn fig3_speed(cm: &CostModel) -> Table {
    let mut t = Table::new(&[
        "seq_len", "megatron-sp", "ring", "lasp1", "lasp2",
        "lasp2/ring", "lasp2/lasp1",
    ]);
    for k in [128usize, 256, 512, 1024, 2048] {
        let shape = SimShape::linear_llama3_1b(64, k * 1024, 1);
        let tps: Vec<f64> = FIG3_SCHEDULERS
            .iter()
            .map(|s| simulate(&shape, *s, 1, cm).tokens_per_sec)
            .collect();
        t.row(&[
            fmt_seq(k * 1024),
            format!("{:.0}", tps[0]),
            format!("{:.0}", tps[1]),
            format!("{:.0}", tps[2]),
            format!("{:.0}", tps[3]),
            format!("{:+.1}%", (tps[3] / tps[1] - 1.0) * 100.0),
            format!("{:+.1}%", (tps[3] / tps[2] - 1.0) * 100.0),
        ]);
    }
    t
}

/// Fig. 3 companion at small scale: REAL execution of all schedulers over
/// worker threads + PJRT, verifying relative ordering end-to-end.
/// Returns the printable table plus (scheduler, tokens/s) rows for the
/// machine-readable snapshot.
pub fn fig3_realexec_rows(
    engine: &Arc<Engine>,
    world_size: usize,
    iters: usize,
) -> Result<(Table, Vec<(String, f64)>)> {
    let cfg = &engine.model;
    let pattern = Pattern("L".repeat(cfg.n_layers));
    let params = Params::randn(cfg, Variant::Basic, &pattern, 7);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % cfg.vocab as i32).collect();
    let mut t = Table::new(&["scheduler", "tokens/s", "collectives", "p2p_ops", "MB moved"]);
    let mut rows = Vec::new();
    let mut scheds = vec![
        Scheduler::MegatronSp,
        Scheduler::RingAttention,
        Scheduler::Lasp1,
        Scheduler::Lasp2,
        Scheduler::Lasp2Overlap,
        Scheduler::Ulysses,
        Scheduler::Zeco,
    ];
    if world_size % 2 == 0 {
        // usp2d runs on a rows x 2 mesh here; odd worlds can't form one
        scheds.push(Scheduler::Usp2d);
    }
    for sched in scheds {
        let run = RunConfig {
            world: world_size,
            scheduler: sched,
            variant: Variant::Basic,
            pattern: pattern.clone(),
            gather_splits: 1,
            usp_cols: 2,
            seed: 0,
        };
        // warmup (compile artifacts)
        let world = World::for_run(&run);
        forward_distributed(engine, &world, &run, &params, &tokens, true)?;
        world.reset_counters();
        let t0 = Instant::now();
        for _ in 0..iters {
            forward_distributed(engine, &world, &run, &params, &tokens, true)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let snap = world.counters();
        let tps = (iters * n) as f64 / dt;
        t.row(&[
            sched.name().to_string(),
            format!("{tps:.0}"),
            format!("{}", snap.collective_ops / iters as u64),
            format!("{}", snap.p2p_ops / iters as u64),
            format!("{:.2}", snap.bytes as f64 / 1e6 / iters as f64),
        ]);
        rows.push((sched.name().to_string(), tps));
    }
    Ok((t, rows))
}

/// `fig3_realexec_rows` without the machine-readable rows.
pub fn fig3_realexec(engine: &Arc<Engine>, world_size: usize, iters: usize) -> Result<Table> {
    Ok(fig3_realexec_rows(engine, world_size, iters)?.0)
}

/// Schedulers compared in the crossover sweep (`lasp2 bench-all`), in the
/// column order of the printed table and the JSON snapshot.
pub const CROSSOVER_SCHEDULERS: [Scheduler; 7] = [
    Scheduler::Lasp2Overlap,
    Scheduler::Lasp1,
    Scheduler::RingAttention,
    Scheduler::MegatronSp,
    Scheduler::Ulysses,
    Scheduler::Zeco,
    Scheduler::Usp2d,
];

/// One line of the scheduler crossover sweep: every scheduler's simulated
/// tokens/s at one (world, seq_len, layer-pattern) point.
pub struct CrossoverRow {
    pub world: usize,
    /// sequence length in units of 1024 tokens
    pub seq_k: usize,
    /// "pure" (all linear layers) or "hybrid" (1/4 standard attention)
    pub pattern: String,
    /// (scheduler name, tokens/s, hit the OOM frontier) per scheduler,
    /// in `CROSSOVER_SCHEDULERS` order
    pub toks: Vec<(String, f64, bool)>,
    /// fastest non-OOM scheduler at this point
    pub winner: String,
}

/// Scheduler crossover sweep (SIM): where does each sequence-parallel
/// strategy win?  Sweeps W in {8, 64, 128} x N in {8K .. 2048K} for the
/// pure-linear and 1/4-hybrid Linear-Llama3-1B, simulating every entry of
/// `CROSSOVER_SCHEDULERS` on the same cost model.  The table is also
/// persisted as the `crossover` section of BENCH_kernels.json and
/// discussed scheduler-by-scheduler in docs/SCHEDULERS.md.
pub fn crossover_table(cm: &CostModel) -> (Table, Vec<CrossoverRow>) {
    let mut header: Vec<String> = vec!["world".into(), "seq_len".into(), "pattern".into()];
    header.extend(CROSSOVER_SCHEDULERS.iter().map(|s| s.name().to_string()));
    header.push("winner".into());
    let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&cols);
    let mut rows = Vec::new();
    for &w in &[8usize, 64, 128] {
        for &k in &[8usize, 32, 128, 512, 2048] {
            for hybrid in [false, true] {
                let mut shape = SimShape::linear_llama3_1b(w, k * 1024, 1);
                if hybrid {
                    shape = shape.with_hybrid(0.25);
                }
                let pattern = if hybrid { "hybrid" } else { "pure" };
                let mut toks = Vec::new();
                let mut winner = ("-".to_string(), f64::NEG_INFINITY);
                for sched in CROSSOVER_SCHEDULERS {
                    let r = simulate(&shape, sched, 1, cm);
                    toks.push((sched.name().to_string(), r.tokens_per_sec, r.oom));
                    if !r.oom && r.tokens_per_sec > winner.1 {
                        winner = (sched.name().to_string(), r.tokens_per_sec);
                    }
                }
                let mut cells = vec![w.to_string(), fmt_seq(k * 1024), pattern.to_string()];
                cells.extend(toks.iter().map(|(_, tps, oom)| {
                    if *oom { "OOM".to_string() } else { format!("{tps:.0}") }
                }));
                cells.push(winner.0.clone());
                t.row(&cells);
                rows.push(CrossoverRow {
                    world: w,
                    seq_k: k,
                    pattern: pattern.to_string(),
                    toks,
                    winner: winner.0,
                });
            }
        }
    }
    (t, rows)
}

/// Fig. 4 / Table 6: scalability sweep — throughput + memory per GPU with
/// OOM frontier (SIM, LASP-2).
pub fn table6_scalability(cm: &CostModel) -> Table {
    let mut t = Table::new(&["seq_len", "gpus", "tokens/s", "mem_gb/gpu"]);
    for k in [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        for w in [16usize, 32, 64, 128] {
            let n = k * 1024;
            if n / w == 0 {
                continue;
            }
            let shape = SimShape::linear_llama3_1b(w, n, 1);
            let r = simulate(&shape, Scheduler::Lasp2Overlap, 1, cm);
            t.row(&[
                fmt_seq(n),
                w.to_string(),
                if r.oom { "OOM".into() } else { format!("{:.0}", r.tokens_per_sec) },
                if r.oom { "OOM".into() } else { format!("{:.1}", r.mem_gb) },
            ]);
        }
    }
    t
}

/// Table 5: throughput vs AllGather split size (SIM at paper scale + the
/// relative effect measured REAL-EXEC via comm counters in benches).
pub fn table5_splits(cm: &CostModel) -> Table {
    let mut t = Table::new(&["splits", "split_size", "tokens/s", "delta"]);
    let shape = SimShape::linear_llama3_1b(64, 1024 * 1024, 1);
    let base = simulate(&shape, Scheduler::Lasp2, 1, cm).tokens_per_sec;
    for splits in [1usize, 4, 16, 64] {
        let r = simulate(&shape, Scheduler::Lasp2, splits, cm);
        t.row(&[
            splits.to_string(),
            (2048 / splits).to_string(),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:+.2}%", (r.tokens_per_sec / base - 1.0) * 100.0),
        ]);
    }
    t
}

/// Serving decode (REAL-EXEC): autoregressive tokens/s plus per-request
/// state bytes sampled at N/4, N/2, and N decoded tokens.  This is the
/// paper's constant-memory-inference claim made measurable: the linear
/// variants' recurrent `ChunkState` is FLAT in position, while the std
/// softmax baseline's KV cache (and the KV half of a hybrid) grows
/// linearly.
pub fn decode_bench(engine: &Arc<Engine>, n_tokens: usize) -> Result<Table> {
    Ok(decode_bench_rows(engine, n_tokens)?.0)
}

/// One decode-bench measurement (`tag` = `{variant}_{pattern-tag}`, the
/// key the committed BENCH_floor.json floors are matched against).
#[derive(Clone)]
pub struct DecodeRow {
    pub tag: String,
    pub pattern: String,
    pub tokens_per_sec: f64,
    pub state_bytes: [usize; 3],
}

/// `decode_bench` plus the machine-readable per-model rows (f32 readout).
pub fn decode_bench_rows(engine: &Arc<Engine>, n_tokens: usize) -> Result<(Table, Vec<DecodeRow>)> {
    decode_bench_rows_with(engine, n_tokens, DecodeDtype::F32)
}

/// `decode_bench_rows` with an explicit readout dtype
/// (`bench-decode --decode-dtype bf16|int8`): the per-token logit readout
/// runs through the quantized path, everything else is unchanged.
pub fn decode_bench_rows_with(
    engine: &Arc<Engine>,
    n_tokens: usize,
    dtype: DecodeDtype,
) -> Result<(Table, Vec<DecodeRow>)> {
    anyhow::ensure!(
        (4..=engine.model.max_seq).contains(&n_tokens),
        "n_tokens {n_tokens} must be in 4..=max_seq ({})",
        engine.model.max_seq
    );
    let mut t = Table::new(&[
        "model",
        "pattern",
        "decode tok/s",
        "state_bytes@N/4",
        "state_bytes@N/2",
        "state_bytes@N",
        "state growth",
    ]);
    let mut rows = Vec::new();
    let mut cases: Vec<(Variant, &str)> = Variant::linear_variants()
        .iter()
        .map(|v| (*v, "0"))
        .collect();
    cases.push((Variant::Basic, "1/2"));
    cases.push((Variant::Softmax, "all"));
    let marks = [n_tokens / 4, n_tokens / 2, n_tokens];
    for (variant, ratio) in cases {
        let mut model = Model::with_engine(engine.clone(), variant, ratio, 1)?;
        model.set_decode_dtype(dtype)?;
        // instantiate the decode artifacts OUTSIDE the timed region (on
        // PJRT the first call would otherwise time an HLO compile)
        model.warmup_serving()?;
        let mut session = model.session();
        let mut bytes = [0usize; 3];
        let mut tok = 1i32;
        let t0 = Instant::now();
        for step in 1..=n_tokens {
            let row = session.decode(tok)?;
            tok = argmax(row.data());
            for (j, m) in marks.iter().enumerate() {
                if step == *m {
                    bytes[j] = session.state_bytes();
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let growth = if bytes[2] > bytes[0] {
            "linear (KV cache)"
        } else {
            "constant (recurrent state)"
        };
        let tps = n_tokens as f64 / dt;
        t.row(&[
            variant.name().to_string(),
            model.pattern().0.clone(),
            format!("{tps:.0}"),
            bytes[0].to_string(),
            bytes[1].to_string(),
            bytes[2].to_string(),
            growth.to_string(),
        ]);
        rows.push(DecodeRow {
            tag: format!("{}_{}", variant.name(), Pattern::tag(ratio)),
            pattern: model.pattern().0.clone(),
            tokens_per_sec: tps,
            state_bytes: bytes,
        });
    }
    Ok((t, rows))
}

/// One serve-bench measurement (`lasp2 bench-serve`): a full trace replay
/// through the continuous-batching loop for one model.  `tag` follows the
/// decode-bench convention (`{variant}_{pattern-tag}`), and the committed
/// BENCH_floor.json gates match on `serve_tps_{tag}` (floor) and
/// `serve_p99ttft_ms_{tag}` (ceiling).
#[derive(Clone)]
pub struct ServeRow {
    pub tag: String,
    pub pattern: String,
    pub sessions: usize,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub decode_tps: f64,
    pub sustained_tps: f64,
    pub bytes_per_session: f64,
    /// 1e9 / bytes_per_session — the headline serving-density contrast
    /// between constant-state linear variants and the std KV baseline.
    pub sessions_per_gb: f64,
    pub cache_hits: u64,
    pub evictions: u64,
}

/// `serve_bench_rows` without the machine-readable rows.
pub fn serve_bench(
    engine: &Arc<Engine>,
    sessions: usize,
    seed: u64,
    budget: usize,
    max_active: usize,
    full: bool,
) -> Result<Table> {
    Ok(serve_bench_rows(engine, sessions, seed, budget, max_active, full)?.0)
}

/// Serve-loop bench (REAL-EXEC): replay one synthetic multi-tenant trace
/// per model through [`ServeLoop`] and report TTFT percentiles, decode
/// and sustained tokens/s, and sessions-per-GB.  The headline contrast:
/// linear variants hold a CONSTANT per-session state, so their
/// sessions/GB is flat in context length, while the softmax baseline's
/// KV cache grows with every token.  `full` adds the remaining linear
/// variants to the four headline models.
pub fn serve_bench_rows(
    engine: &Arc<Engine>,
    sessions: usize,
    seed: u64,
    budget: usize,
    max_active: usize,
    full: bool,
) -> Result<(Table, Vec<ServeRow>)> {
    anyhow::ensure!(sessions > 0, "bench-serve: at least one session");
    let mut cases: Vec<(Variant, &str)> = vec![(Variant::Basic, "0"), (Variant::Gla, "0")];
    if full {
        for v in Variant::linear_variants() {
            if !cases.contains(&(*v, "0")) {
                cases.push((*v, "0"));
            }
        }
    }
    cases.push((Variant::Basic, "1/2"));
    cases.push((Variant::Softmax, "all"));
    let mut t = Table::new(&[
        "model",
        "pattern",
        "p50 TTFT ms",
        "p99 TTFT ms",
        "decode tok/s",
        "sustained tok/s",
        "KB/session",
        "sessions/GB",
        "cache hits",
        "evictions",
    ]);
    let mut rows = Vec::new();
    for (variant, ratio) in cases {
        let model = Model::with_engine(engine.clone(), variant, ratio, 1)?;
        model.warmup_serving()?;
        let cfg = ServeConfig {
            max_active,
            mem_budget: budget,
            ..Default::default()
        };
        let mut sl = ServeLoop::new(&model, cfg);
        for req in gen_trace(&TraceConfig::for_model(model.config(), sessions, seed)) {
            sl.enqueue(req);
        }
        let sum = sl.run()?;
        t.row(&[
            variant.name().to_string(),
            model.pattern().0.clone(),
            format!("{:.2}", sum.p50_ttft_ms),
            format!("{:.2}", sum.p99_ttft_ms),
            format!("{:.0}", sum.decode_tps),
            format!("{:.0}", sum.sustained_tps),
            format!("{:.1}", sum.mean_state_bytes / 1e3),
            format!("{:.0}", sum.sessions_per_gb),
            sum.cache_hits.to_string(),
            sum.evictions.to_string(),
        ]);
        rows.push(ServeRow {
            tag: format!("{}_{}", variant.name(), Pattern::tag(ratio)),
            pattern: model.pattern().0.clone(),
            sessions: sum.sessions,
            p50_ttft_ms: sum.p50_ttft_ms,
            p99_ttft_ms: sum.p99_ttft_ms,
            decode_tps: sum.decode_tps,
            sustained_tps: sum.sustained_tps,
            bytes_per_session: sum.mean_state_bytes,
            sessions_per_gb: sum.sessions_per_gb,
            cache_hits: sum.cache_hits,
            evictions: sum.evictions,
        });
    }
    Ok((t, rows))
}

/// Table 2: convergence (loss + throughput) for the attention-module zoo,
/// REAL training through the train_step artifacts.
pub fn table2_convergence(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["model", "attention", "pattern", "tokens/s", "loss"]);
    let mut run = |variant: Variant, ratio: &str, label: &str| -> Result<()> {
        let tag = format!("{}_{}", variant.name(), Pattern::tag(ratio));
        if !engine.has_artifact(&format!("train_step_{tag}")) {
            // never drop a paper row invisibly: say what was skipped and why
            t.row(&[
                label.to_string(),
                variant.name().to_string(),
                Pattern::tag(ratio).to_string(),
                "-".into(),
                format!("SKIPPED: {tag} (no train_step_{tag} artifact on this backend)"),
            ]);
            return Ok(());
        }
        let pattern = Pattern::from_ratio(cfg.n_layers, ratio)?;
        let rep = train(
            engine,
            variant,
            &pattern,
            &tag,
            &TrainOpts { steps, log_every: 0, ..Default::default() },
        )?;
        t.row(&[
            label.to_string(),
            variant.name().to_string(),
            Pattern::tag(ratio).to_string(),
            format!("{:.0}", rep.tokens_per_sec),
            format!("{:.3}", rep.tail_loss),
        ]);
        Ok(())
    };
    // Llama3 baseline (standard attention everywhere, Ring-Attention row)
    run(Variant::Softmax, "all", "Llama3")?;
    for v in Variant::linear_variants() {
        run(*v, "0", "Linear-Llama3")?;
        run(*v, "1/4", "Linear-Llama3")?;
    }
    Ok(t)
}

/// Table 3: bidirectional language modeling (MLM), LASP-2 w/o masking.
pub fn table3_bidirectional(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["model", "training_loss"]);
    // baseline: standard attention, causal==false not needed — the paper
    // compares RoBERTa-ish standard attention vs basic linear attention.
    let pattern = Pattern::from_ratio(cfg.n_layers, "0")?;
    let rep = train(
        engine,
        Variant::Basic,
        &pattern,
        &format!("basic_{}_nm", Pattern::tag("0")),
        &TrainOpts { steps, mlm: true, log_every: 0, ..Default::default() },
    )?;
    t.row(&["Bidirectional + Basic Linear Attention (LASP-2 w/o masking)".into(),
            format!("{:.3}", rep.tail_loss)]);
    if engine.has_artifact("train_step_softmax_std") {
        let pat = Pattern::from_ratio(cfg.n_layers, "all")?;
        let rep = train(
            engine,
            Variant::Softmax,
            &pat,
            "softmax_std",
            &TrainOpts { steps, mlm: true, log_every: 0, ..Default::default() },
        )?;
        t.row(&["Baseline standard attention (gather-based)".into(),
                format!("{:.3}", rep.tail_loss)]);
    } else {
        t.row(&["Baseline standard attention (gather-based)".into(),
                "SKIPPED: softmax_std (no train_step_softmax_std artifact on this backend)".into()]);
    }
    Ok(t)
}

/// Table 4: hybrid-ratio ablation (0, 1/8, 1/4, 1/2) — loss per ratio.
pub fn table4_hybrid_ratio(engine: &Arc<Engine>, steps: usize) -> Result<Table> {
    let cfg = &engine.model;
    let mut t = Table::new(&["module", "0 (pure)", "1/8", "1/4", "1/2"]);
    for v in [Variant::Basic, Variant::Lightning, Variant::Retention, Variant::Gla] {
        let mut cells = vec![v.name().to_string()];
        for ratio in ["0", "1/8", "1/4", "1/2"] {
            let tag = format!("{}_{}", v.name(), Pattern::tag(ratio));
            if !engine.has_artifact(&format!("train_step_{tag}")) {
                cells.push(format!("SKIPPED: {tag} (no artifact)"));
                continue;
            }
            let pattern = Pattern::from_ratio(cfg.n_layers, ratio)?;
            let rep = train(
                engine,
                v,
                &pattern,
                &tag,
                &TrainOpts { steps, log_every: 0, ..Default::default() },
            )?;
            cells.push(format!("{:.3}", rep.tail_loss));
        }
        t.row(&cells);
    }
    Ok(t)
}

// ===================================================== kernel-level bench

/// One measured GEMM data point (`lasp2 bench-kernels`).
pub struct GemmRow {
    pub op: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub gflops: f64,
}

/// Op-level GEMM throughput at the shapes the repo actually runs: the
/// tiny/small epilogue and projection `nn` products, the fused-transpose
/// `nt` logits/score shapes (including the m=1 decode readout), and the
/// `tn` weight-gradient shapes (k much larger than m/n).
pub fn gemm_bench() -> (Table, Vec<GemmRow>) {
    let shapes: &[(&'static str, usize, usize, usize)] = &[
        ("nn", 32, 64, 128),   // tiny epilogue swiglu
        ("nn", 128, 256, 512), // small swiglu
        ("nn", 512, 256, 512), // small train forward
        ("nt", 512, 256, 512), // small logits head (x · embᵀ)
        ("nt", 128, 64, 128),  // attention scores q·kᵀ
        ("nt", 1, 64, 256),    // tiny decode readout (m=1)
        ("nt", 1, 256, 512),   // small decode readout (m=1)
        ("tn", 256, 512, 256), // weight gradient xᵀ·dy
        ("tn", 64, 2048, 32),  // k >> n backward shape
    ];
    let mut t = Table::new(&["op", "m", "k", "n", "GFLOP/s"]);
    let mut rows = Vec::with_capacity(shapes.len());
    for &(op, m, k, n) in shapes {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        // ~0.1s per shape
        let iters = ((1.0e8 / flops) as usize).clamp(1, 2_000_000);
        let (a, b) = match op {
            "nn" => (Tensor::randn(&[m, k], 1), Tensor::randn(&[k, n], 2)),
            "nt" => (Tensor::randn(&[m, k], 1), Tensor::randn(&[n, k], 2)),
            _ => (Tensor::randn(&[k, m], 1), Tensor::randn(&[k, n], 2)),
        };
        // the `_into` entry points: kernel time only, no allocator noise
        let mut out = Tensor::zeros(&[m, n]);
        let step = |a: &Tensor, b: &Tensor, out: &mut Tensor| match op {
            "nn" => a.matmul_into(b, out),
            "nt" => a.matmul_nt_into(b, out),
            _ => a.matmul_tn_into(b, out),
        };
        step(&a, &b, &mut out); // warm up (scratch pool, caches)
        let t0 = Instant::now();
        for _ in 0..iters {
            step(&a, &b, &mut out);
            std::hint::black_box(&mut out);
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let gflops = flops * iters as f64 / dt / 1e9;
        t.row(&[
            op.to_string(),
            m.to_string(),
            k.to_string(),
            n.to_string(),
            format!("{gflops:.2}"),
        ]);
        rows.push(GemmRow { op, m, k, n, gflops });
    }
    (t, rows)
}

/// Time the real `train_step_basic_pure` artifact on this preset:
/// returns (tag, ms per step, tokens/s).
pub fn train_step_bench(engine: &Arc<Engine>, steps: usize) -> Result<(String, f64, f64)> {
    let cfg = &engine.model;
    let pattern = Pattern::from_ratio(cfg.n_layers, "0")?;
    let tag = "basic_pure".to_string();
    let rep = train(
        engine,
        Variant::Basic,
        &pattern,
        &tag,
        &TrainOpts { steps, log_every: 0, ..Default::default() },
    )?;
    let toks_per_step = (cfg.train_batch * cfg.train_seq) as f64;
    let step_ms = toks_per_step / rep.tokens_per_sec.max(1e-9) * 1e3;
    Ok((tag, step_ms, rep.tokens_per_sec))
}

/// One row of the ZeRO sharding table (machine-readable mirror of
/// `zero_sharding_table`).
pub struct ZeroRow {
    pub world: usize,
    pub params: f64,
    pub opt_replicated: f64,
    pub opt_sharded: f64,
    pub wire_bytes: f64,
    pub comm_ms: f64,
}

/// Replicated-vs-ZeRO optimizer memory and wire bytes per rank at the
/// paper's Fig.-3 anchor shape (Llama3-1B-linear, 2048K tokens), costed on
/// the α–β model at W ∈ {1, 4, 64}.  W=4 is the size the bit-parity tests
/// run for real; W=64 is the paper-scale extrapolation.
pub fn zero_sharding_table(cm: &CostModel) -> (Table, Vec<ZeroRow>) {
    let p = SimShape::linear_llama3_1b(64, 2048 * 1024, 1).param_count();
    let gb = 1e9;
    let mut t = Table::new(&[
        "world", "opt GB/rank (replicated)", "opt GB/rank (ZeRO)",
        "wire GB/rank/step", "comm ms/step",
    ]);
    let mut rows = Vec::new();
    for w in [1usize, 4, 64] {
        let z = zero_shard(p, w, cm);
        t.row(&[
            w.to_string(),
            format!("{:.2}", z.opt_bytes_replicated / gb),
            format!("{:.3}", z.opt_bytes_sharded / gb),
            format!("{:.2}", z.wire_bytes_per_rank / gb),
            format!("{:.1}", z.comm_time * 1e3),
        ]);
        rows.push(ZeroRow {
            world: w,
            params: p,
            opt_replicated: z.opt_bytes_replicated,
            opt_sharded: z.opt_bytes_sharded,
            wire_bytes: z.wire_bytes_per_rank,
            comm_ms: z.comm_time * 1e3,
        });
    }
    (t, rows)
}

/// The machine-readable benchmark snapshot `lasp2 bench-all --json` /
/// `bench-kernels --json` writes (committed as BENCH_kernels.json so the
/// repo's perf trajectory is tracked PR over PR).  Hand-rolled writer —
/// the repo is dependency-free by design.
pub struct KernelsReport {
    pub source: String,
    pub threads: usize,
    /// Active GEMM instruction set (`gemm::isa_name()`): records whether
    /// the snapshot was taken with the SIMD microkernels or the scalar
    /// fallback, so numbers are comparable PR over PR.
    pub isa: String,
    pub gemm: Vec<GemmRow>,
    /// (preset, tag, step_ms, tokens_per_sec)
    pub train: Option<(String, String, f64, f64)>,
    /// (preset, n_tokens, rows)
    pub decode: Option<(String, usize, Vec<DecodeRow>)>,
    /// (preset, world, [(scheduler, tokens_per_sec)])
    pub fig3: Option<(String, usize, Vec<(String, f64)>)>,
    /// simulated scheduler crossover sweep (`crossover_table`)
    pub crossover: Option<Vec<CrossoverRow>>,
    /// ZeRO replicated-vs-sharded memory/wire rows (`zero_sharding_table`)
    pub zero: Option<Vec<ZeroRow>>,
    /// (preset, sessions, rows) — serve-loop trace replay
    /// (`serve_bench_rows`); the gated metrics are emitted under FLAT
    /// per-tag keys (`serve_tps_<tag>`, `serve_p99ttft_ms_<tag>`) so the
    /// floor checker's flat-JSON scan can match them.
    pub serve: Option<(String, usize, Vec<ServeRow>)>,
    /// chaos-scenario recovery rows (`lasp2 chaos`)
    pub fault: Option<Vec<FaultRow>>,
    /// per-PR perf-trajectory array fragment (see [`append_history`])
    pub history: Option<String>,
}

/// One chaos-scenario row (`lasp2 chaos`): a seeded fault injected into
/// the elastic trainer or the serve loop, with recovery accounting.
pub struct FaultRow {
    pub scenario: String,
    /// World size before / after elastic recovery (equal when the fault
    /// was transient or serve-side).
    pub world_before: usize,
    pub world_after: usize,
    pub recoveries: usize,
    pub steps_lost: usize,
    pub recovery_ms: f64,
    /// Post-recovery result was bit-identical to the fault-free run.
    pub deterministic: bool,
}

// ================================== machine-readable snapshot sections
//
// Every section of BENCH_kernels.json has ONE fragment emitter, shared
// by [`KernelsReport::to_json`] (full rewrite, e.g. `bench-all --json`)
// and the [`splice_section`] path (update one section in place, e.g.
// `chaos --json`, `bench-serve --json`), so both emit byte-identical
// bodies and a splice after a full run is a no-op diff for the other
// sections (pinned by the tests below).

/// Format fault rows as the `"fault"` section body (a JSON array).
pub fn fault_fragment(rows: &[FaultRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"world_before\": {}, \
             \"world_after\": {}, \"recoveries\": {}, \"steps_lost\": {}, \
             \"recovery_ms\": {:.3}, \"deterministic\": {}}}{}\n",
            r.scenario,
            r.world_before,
            r.world_after,
            r.recoveries,
            r.steps_lost,
            r.recovery_ms,
            r.deterministic,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    s
}

/// `"gemm"` section body: one object per measured shape.
pub fn gemm_fragment(rows: &[GemmRow]) -> String {
    let mut s = String::from("[\n");
    for (i, g) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"op\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"gflops\": {:.3}}}{}\n",
            g.op,
            g.m,
            g.k,
            g.n,
            g.gflops,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    s
}

/// `"train"` section body.
pub fn train_fragment(preset: &str, tag: &str, step_ms: f64, tps: f64) -> String {
    format!(
        "{{\"preset\": \"{preset}\", \"tag\": \"{tag}\", \
         \"step_ms\": {step_ms:.3}, \"tokens_per_sec\": {tps:.1}}}"
    )
}

/// `"decode"` section body: flat `tag: tokens/s` rows (the floor keys).
pub fn decode_fragment(preset: &str, n: usize, rows: &[DecodeRow]) -> String {
    let mut s = format!("{{\"preset\": \"{preset}\", \"tokens\": {n}, \"rows\": {{\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    \"{}\": {:.1}{}\n",
            r.tag,
            r.tokens_per_sec,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  }}");
    s
}

/// `"fig3_realexec"` section body.
pub fn fig3_fragment(preset: &str, world: usize, rows: &[(String, f64)]) -> String {
    let mut s = format!("{{\"preset\": \"{preset}\", \"world\": {world}, \"rows\": {{\n");
    for (i, (name, tps)) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    \"{}\": {:.1}{}\n",
            name,
            tps,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  }}");
    s
}

/// `"crossover"` section body.
pub fn crossover_fragment(rows: &[CrossoverRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"world\": {}, \"seq_k\": {}, \"pattern\": \"{}\", \"winner\": \"{}\"",
            r.world, r.seq_k, r.pattern, r.winner
        ));
        for (name, tps, oom) in &r.toks {
            if *oom {
                s.push_str(&format!(", \"{name}\": null"));
            } else {
                s.push_str(&format!(", \"{name}\": {tps:.1}"));
            }
        }
        s.push_str(&format!("}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    s.push_str("  ]");
    s
}

/// `"serve"` section body: the gated metrics use flat per-tag keys
/// (`serve_tps_<tag>`, `serve_p99ttft_ms_<tag>`) for the floor scanner.
pub fn serve_fragment(preset: &str, sessions: usize, rows: &[ServeRow]) -> String {
    let mut s =
        format!("{{\"preset\": \"{preset}\", \"sessions\": {sessions}, \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"tag\": \"{}\", \"pattern\": \"{}\", \
             \"serve_tps_{}\": {:.1}, \"serve_p99ttft_ms_{}\": {:.2}, \
             \"p50_ttft_ms\": {:.2}, \"sustained_tps\": {:.1}, \
             \"bytes_per_session\": {:.0}, \"sessions_per_gb\": {:.0}, \
             \"cache_hits\": {}, \"evictions\": {}}}{}\n",
            r.tag,
            r.pattern,
            r.tag,
            r.decode_tps,
            r.tag,
            r.p99_ttft_ms,
            r.p50_ttft_ms,
            r.sustained_tps,
            r.bytes_per_session,
            r.sessions_per_gb,
            r.cache_hits,
            r.evictions,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]}");
    s
}

/// `"zero"` section body.
pub fn zero_fragment(rows: &[ZeroRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"world\": {}, \"params\": {:.0}, \
             \"opt_bytes_replicated\": {:.0}, \"opt_bytes_sharded\": {:.0}, \
             \"wire_bytes_per_rank\": {:.0}, \"comm_ms\": {:.3}}}{}\n",
            r.world,
            r.params,
            r.opt_replicated,
            r.opt_sharded,
            r.wire_bytes,
            r.comm_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    s
}

/// Index one past the balanced close of the `[`/`{` that `s` starts
/// with, string-aware (quotes and escapes inside the body are skipped).
fn balanced_end(s: &str) -> Option<usize> {
    let mut depth = 0i64;
    let mut in_str = false;
    let mut esc = false;
    for (i, ch) in s.char_indices() {
        if esc {
            esc = false;
            continue;
        }
        match ch {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => {
                depth -= 1;
                if depth == 0 {
                    return Some(i + 1);
                }
            }
            _ => {}
        }
    }
    None
}

/// The raw balanced body of top-level section `name` in `doc` (e.g. the
/// `[ ... ]` after `"gemm":`), if present.
pub fn extract_section<'a>(doc: &'a str, name: &str) -> Option<&'a str> {
    let k = doc.find(&format!("\"{name}\":"))?;
    let tail = &doc[k..];
    let open = tail.find(['[', '{'])?;
    let end = balanced_end(&tail[open..])?;
    Some(&tail[open..open + end])
}

/// Splice section `name` into an existing BENCH_kernels.json document,
/// replacing any previous copy and leaving every other section's bytes
/// untouched — the single helper behind `chaos --json` (fault),
/// `bench-serve --json` (serve), `bench-decode --json` (decode) and the
/// `history` trajectory, so partial bench runs update just their own
/// section without re-running everything else.  `fragment` is the
/// section body, e.g. `[ ... ]` from one of the `*_fragment` emitters.
pub fn splice_section(existing: &str, name: &str, fragment: &str) -> Result<String> {
    let mut doc = existing.trim_end().to_string();
    if let Some(k) = doc.find(&format!("\"{name}\":")) {
        // drop the old section: preceding comma through balanced close
        let start = doc[..k].rfind(',').unwrap_or(k);
        let tail = &doc[k..];
        let open = tail
            .find(['[', '{'])
            .ok_or_else(|| anyhow::anyhow!("malformed {name} section"))?;
        let end = balanced_end(&tail[open..])
            .ok_or_else(|| anyhow::anyhow!("unbalanced {name} section"))?;
        doc.replace_range(start..k + open + end, "");
    }
    let close = doc
        .rfind('}')
        .ok_or_else(|| anyhow::anyhow!("not a JSON object"))?;
    let head = doc[..close].trim_end();
    Ok(format!("{head},\n  \"{name}\": {fragment}\n}}\n"))
}

/// One `history` array entry: the headline numbers of one PR's bench run
/// (`pr`, `date`, then flat metric keys), the machine-readable perf
/// trajectory the kernels snapshot grows PR over PR.
pub fn history_entry(pr: &str, date: &str, headline: &[(&str, f64)]) -> String {
    let mut s = format!("{{\"pr\": \"{pr}\", \"date\": \"{date}\"");
    for (k, v) in headline {
        s.push_str(&format!(", \"{k}\": {v:.2}"));
    }
    s.push('}');
    s
}

/// Append `entry` to the `history` array carried by `old_doc` (the
/// previously committed snapshot, if any), preserving prior entries
/// verbatim.  Returns the new array fragment for [`splice_section`].
pub fn append_history(old_doc: Option<&str>, entry: &str) -> String {
    let prior = old_doc
        .and_then(|d| extract_section(d, "history"))
        .map(|frag| frag[1..frag.len() - 1].trim().trim_end_matches(',').to_string())
        .unwrap_or_default();
    if prior.is_empty() {
        format!("[\n    {entry}\n  ]")
    } else {
        format!("[\n    {prior},\n    {entry}\n  ]")
    }
}

impl KernelsReport {
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": 1,\n");
        s.push_str(&format!("  \"source\": \"{}\",\n", self.source));
        s.push_str(&format!("  \"threads\": {},\n", self.threads));
        s.push_str(&format!("  \"isa\": \"{}\",\n", self.isa));
        s.push_str("  \"gemm\": ");
        s.push_str(&gemm_fragment(&self.gemm));
        if let Some((preset, tag, step_ms, tps)) = &self.train {
            s.push_str(",\n  \"train\": ");
            s.push_str(&train_fragment(preset, tag, *step_ms, *tps));
        }
        if let Some((preset, n, rows)) = &self.decode {
            s.push_str(",\n  \"decode\": ");
            s.push_str(&decode_fragment(preset, *n, rows));
        }
        if let Some((preset, world, rows)) = &self.fig3 {
            s.push_str(",\n  \"fig3_realexec\": ");
            s.push_str(&fig3_fragment(preset, *world, rows));
        }
        if let Some(rows) = &self.crossover {
            s.push_str(",\n  \"crossover\": ");
            s.push_str(&crossover_fragment(rows));
        }
        if let Some((preset, sessions, rows)) = &self.serve {
            s.push_str(",\n  \"serve\": ");
            s.push_str(&serve_fragment(preset, *sessions, rows));
        }
        if let Some(rows) = &self.zero {
            s.push_str(",\n  \"zero\": ");
            s.push_str(&zero_fragment(rows));
        }
        if let Some(rows) = &self.fault {
            s.push_str(",\n  \"fault\": ");
            s.push_str(&fault_fragment(rows));
        }
        if let Some(h) = &self.history {
            s.push_str(",\n  \"history\": ");
            s.push_str(h);
        }
        s.push_str("\n}\n");
        s
    }
}

/// Fig. 4 (left): memory-per-GPU frontier rows for quick printing.
pub fn fig4_scalability(cm: &CostModel) -> Table {
    let mut t = Table::new(&["gpus", "max_seq_no_oom", "tokens/s@max"]);
    for w in [8usize, 16, 32, 64, 128] {
        let mut best = 0usize;
        let mut tps = 0.0;
        for k in [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
            let shape = SimShape::linear_llama3_1b(w, k * 1024, 1);
            let r = simulate(&shape, Scheduler::Lasp2Overlap, 1, cm);
            if !r.oom {
                best = k * 1024;
                tps = r.tokens_per_sec;
            }
        }
        t.row(&[w.to_string(), fmt_seq(best), format!("{tps:.0}")]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(fault: Option<Vec<FaultRow>>) -> KernelsReport {
        KernelsReport {
            source: "test".into(),
            threads: 1,
            isa: "scalar".into(),
            gemm: Vec::new(),
            train: None,
            decode: None,
            fig3: None,
            crossover: None,
            zero: None,
            serve: None,
            fault,
            history: None,
        }
    }

    /// A report with EVERY section populated — the bench-all shape.
    fn full_report() -> KernelsReport {
        KernelsReport {
            source: "test bench-all".into(),
            threads: 2,
            isa: "avx2".into(),
            gemm: vec![GemmRow { op: "nn", m: 4, k: 8, n: 4, gflops: 1.25 }],
            train: Some(("tiny".into(), "basic_pure".into(), 12.5, 4321.0)),
            decode: Some((
                "tiny".into(),
                16,
                vec![DecodeRow {
                    tag: "basic_pure".into(),
                    pattern: "LL".into(),
                    tokens_per_sec: 1000.0,
                    state_bytes: [64, 64, 64],
                }],
            )),
            fig3: Some(("tiny".into(), 4, vec![("lasp2".into(), 9000.0)])),
            crossover: Some(vec![CrossoverRow {
                world: 8,
                seq_k: 8,
                pattern: "pure".into(),
                toks: vec![("lasp2".into(), 100.0, false), ("ring".into(), 0.0, true)],
                winner: "lasp2".into(),
            }]),
            zero: Some(vec![ZeroRow {
                world: 4,
                params: 1e9,
                opt_replicated: 8e9,
                opt_sharded: 2e9,
                wire_bytes: 1e9,
                comm_ms: 3.5,
            }]),
            serve: Some((
                "tiny".into(),
                8,
                vec![ServeRow {
                    tag: "basic_pure".into(),
                    pattern: "LL".into(),
                    sessions: 8,
                    p50_ttft_ms: 1.0,
                    p99_ttft_ms: 2.0,
                    decode_tps: 900.0,
                    sustained_tps: 800.0,
                    bytes_per_session: 4096.0,
                    sessions_per_gb: 244140.0,
                    cache_hits: 3,
                    evictions: 1,
                }],
            )),
            fault: Some(vec![row("crash_w4")]),
            history: Some(append_history(None, &history_entry("pr5", "2026-01-01", &[]))),
        }
    }

    fn row(scenario: &str) -> FaultRow {
        FaultRow {
            scenario: scenario.into(),
            world_before: 4,
            world_after: 2,
            recoveries: 1,
            steps_lost: 1,
            recovery_ms: 3.25,
            deterministic: true,
        }
    }

    #[test]
    fn to_json_emits_fault_section_matching_the_fragment() {
        let doc = report_with(Some(vec![row("crash_w4")])).to_json();
        assert!(doc.contains("\"fault\": [\n"));
        assert!(doc.contains("\"scenario\": \"crash_w4\""));
        assert!(doc.contains(&fault_fragment(&[row("crash_w4")])));
        // balanced braces/brackets (hand-rolled writer sanity)
        let open = doc.matches(['{', '[']).count();
        let close = doc.matches(['}', ']']).count();
        assert_eq!(open, close);
    }

    #[test]
    fn splice_inserts_then_replaces_without_duplicating() {
        let base = report_with(None).to_json();
        let frag1 = fault_fragment(&[row("crash_w4")]);
        let d1 = splice_section(&base, "fault", &frag1).unwrap();
        assert_eq!(d1.matches("\"fault\"").count(), 1);
        assert!(d1.contains("crash_w4"));
        assert!(d1.ends_with("}\n"));
        // splicing again replaces the old section in place
        let frag2 = fault_fragment(&[row("straggler"), row("corrupt")]);
        let d2 = splice_section(&d1, "fault", &frag2).unwrap();
        assert_eq!(d2.matches("\"fault\"").count(), 1);
        assert!(!d2.contains("crash_w4"));
        assert!(d2.contains("straggler") && d2.contains("corrupt"));
        // and the result is byte-identical to emitting it directly
        assert_eq!(d2, splice_section(&base, "fault", &frag2).unwrap());
        let open = d2.matches(['{', '[']).count();
        let close = d2.matches(['}', ']']).count();
        assert_eq!(open, close);
    }

    /// The satellite guarantee: after a full bench-all write, splicing any
    /// one section (what chaos / bench-serve / bench-decode do) preserves
    /// every other section byte for byte.
    #[test]
    fn splicing_one_section_preserves_all_others() {
        let doc = full_report().to_json();
        let sections = [
            "gemm", "train", "decode", "fig3_realexec", "crossover",
            "serve", "zero", "fault", "history",
        ];
        // every section is present exactly once in the full document
        for name in sections {
            assert_eq!(doc.matches(&format!("\"{name}\":")).count(), 1, "{name}");
            assert!(extract_section(&doc, name).is_some(), "{name}");
        }
        // re-splice each section in turn with a fresh fragment; all other
        // sections' extracted bodies must be untouched
        let e6 = history_entry("pr6", "2026-02-02", &[("gemm_peak_gflops", 33.0)]);
        let cases: Vec<(&str, String)> = vec![
            ("fault", fault_fragment(&[row("straggler")])),
            ("serve", serve_fragment("tiny", 9, &full_report().serve.unwrap().2)),
            ("decode", decode_fragment("tiny", 32, &full_report().decode.unwrap().2)),
            ("gemm", gemm_fragment(&full_report().gemm)),
            ("history", append_history(Some(&doc), &e6)),
        ];
        for (name, frag) in cases {
            let spliced = splice_section(&doc, name, &frag).unwrap();
            assert_eq!(spliced.matches(&format!("\"{name}\":")).count(), 1);
            assert_eq!(extract_section(&spliced, name), Some(frag.as_str()));
            for other in sections.iter().filter(|s| **s != name) {
                assert_eq!(
                    extract_section(&spliced, other),
                    extract_section(&doc, other),
                    "splicing {name} must not disturb {other}"
                );
            }
            let open = spliced.matches(['{', '[']).count();
            let close = spliced.matches(['}', ']']).count();
            assert_eq!(open, close);
        }
    }

    #[test]
    fn history_appends_without_rewriting_prior_entries() {
        let e1 = history_entry("pr5", "2026-01-01", &[("decode_tps", 1000.0)]);
        let h1 = append_history(None, &e1);
        assert_eq!(h1, format!("[\n    {e1}\n  ]"));
        let doc = splice_section(&report_with(None).to_json(), "history", &h1).unwrap();
        // next PR appends; the first entry's bytes are carried verbatim
        let e2 = history_entry("pr6", "2026-02-02", &[("decode_tps", 1250.0)]);
        let h2 = append_history(Some(&doc), &e2);
        assert!(h2.contains(&e1) && h2.contains(&e2));
        assert!(h2.find(&e1).unwrap() < h2.find(&e2).unwrap());
        let doc2 = splice_section(&doc, "history", &h2).unwrap();
        assert_eq!(doc2.matches("\"pr\"").count(), 2);
        // and a third round keeps all prior entries in order
        let e3 = history_entry("pr7", "2026-03-03", &[]);
        let doc3 =
            splice_section(&doc2, "history", &append_history(Some(&doc2), &e3)).unwrap();
        for pr in ["pr5", "pr6", "pr7"] {
            assert_eq!(doc3.matches(&format!("\"{pr}\"")).count(), 1);
        }
    }
}
