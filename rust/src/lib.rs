//! # lasp2 — reproduction of *LASP-2: Rethinking Sequence Parallelism for
//! # Linear Attention and Its Hybrid* (Sun et al., 2025)
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the rust coordinator: SP schedulers (LASP-2,
//!   LASP-1, Ring Attention, Megatron-SP, LASP-2H hybrid dispatch), an
//!   in-memory multi-device world with instrumented collectives, a
//!   discrete-event cluster simulator for paper-scale extrapolation, a
//!   training loop, the serving layer (`serve::Model`/`serve::Session`:
//!   constant-memory autoregressive decode on the recurrent state, plus
//!   the `serve::ServeLoop` continuous-batching scheduler with prefix
//!   caching and evict/resume), and the benchmark harness for every
//!   table/figure.
//! * **L2 (python/compile, build-time)** — Linear-Llama3 in JAX, lowered
//!   once to HLO-text artifacts.
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   chunked linear-attention hot spots.
//!
//! Python never runs on the request path.  The runtime is pluggable
//! (see DESIGN.md §Backends): by default every artifact executes on the
//! hermetic pure-rust NATIVE backend (`runtime/native.rs`); with the
//! `pjrt` cargo feature the engine instead loads
//! `artifacts/<preset>/*.hlo.txt` through the PJRT C API (`xla` crate).

pub mod bench;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod train;

pub use config::{ModelConfig, Pattern, RunConfig, Scheduler, Variant};
pub use runtime::Engine;
pub use serve::{decode_step, Batch, Model, ServeConfig, ServeLoop, Session};
pub use tensor::Tensor;
