//! Synthetic training data (the documented SlimPajama substitution).
//!
//! The convergence experiments need a LEARNABLE token distribution, not a
//! specific corpus: we generate a deterministic order-2 Markov chain over
//! the vocabulary with a sparse transition structure plus embedded
//! repeating "phrases", which gives a smoothly decreasing LM loss and a
//! non-trivial gap between weak and strong models — enough to preserve the
//! paper's relative convergence ordering (Table 2/3/4 shapes).

/// Deterministic xorshift64* PRNG (std-only).
#[derive(Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }
}

/// Order-2 Markov corpus generator with phrase insertions.
pub struct SynthCorpus {
    vocab: usize,
    rng: Rng,
    /// current bigram context
    ctx: (usize, usize),
    /// per-context candidate successors (sparse, derived procedurally)
    branch: usize,
    /// repeating phrases injected with probability `phrase_p`
    phrases: Vec<Vec<usize>>,
    phrase_p: f32,
    pending: Vec<usize>,
}

impl SynthCorpus {
    pub fn new(vocab: usize, seed: u64) -> SynthCorpus {
        assert!(vocab >= 16);
        let mut rng = Rng::new(seed);
        let n_phrases = 32;
        let head = (vocab / 4).max(8);
        let phrases = (0..n_phrases)
            .map(|_| {
                let len = 4 + rng.below(8);
                (0..len).map(|_| rng.below(head)).collect()
            })
            .collect();
        SynthCorpus {
            vocab,
            rng,
            ctx: (0, 1),
            branch: 2,
            phrases,
            phrase_p: 0.05,
            pending: Vec::new(),
        }
    }

    /// Deterministic successor set of a bigram context: a hash selects
    /// `branch` candidates; the chain mixes them with mild noise.
    ///
    /// The chain's mass concentrates on the first vocab/4 token ids (a
    /// crude Zipf-like skew): a model learns the unigram head within a few
    /// steps (fast initial loss drop) and the bigram structure over longer
    /// runs — mirroring how real-corpus LM curves behave.
    fn successor(&mut self, a: usize, b: usize) -> usize {
        let h = (a as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((b as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        // 10% uniform noise keeps entropy > 0 so loss plateaus, not zeroes
        if self.rng.f32() < 0.1 {
            return self.rng.below(self.vocab);
        }
        let head = (self.vocab / 4).max(8);
        let pick = self.rng.below(self.branch) as u64;
        ((h >> (8 + pick * 7)) % head as u64) as usize
    }

    pub fn next_token(&mut self) -> usize {
        if let Some(t) = self.pending.pop() {
            self.ctx = (self.ctx.1, t);
            return t;
        }
        if self.rng.f32() < self.phrase_p {
            let p = self.phrases[self.rng.below(self.phrases.len())].clone();
            self.pending = p.into_iter().rev().collect();
            return self.next_token();
        }
        let t = self.successor(self.ctx.0, self.ctx.1);
        self.ctx = (self.ctx.1, t);
        t
    }

    /// Generate `n` tokens as i32 (the runtime token dtype).
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_token() as i32).collect()
    }
}

/// A [B, S] batch of LM training data: inputs, next-token targets, and a
/// loss mask (all-ones for causal LM; MLM-style for bidirectional).
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Streaming batch iterator over the synthetic corpus.
pub struct BatchIter {
    corpus: SynthCorpus,
    batch: usize,
    seq: usize,
    /// None = causal LM; Some(p) = bidirectional MLM with mask prob p
    mlm: Option<f32>,
    mask_token: i32,
    rng: Rng,
    /// batches drawn so far — the resumable-checkpoint data cursor
    cursor: usize,
}

impl BatchIter {
    pub fn causal(vocab: usize, batch: usize, seq: usize, seed: u64) -> BatchIter {
        BatchIter {
            corpus: SynthCorpus::new(vocab, seed),
            batch,
            seq,
            mlm: None,
            mask_token: 0,
            rng: Rng::new(seed ^ 0xABCD),
            cursor: 0,
        }
    }

    /// Bidirectional task (paper A.5.1): mask 15% of inputs, predict them.
    pub fn mlm(vocab: usize, batch: usize, seq: usize, seed: u64) -> BatchIter {
        BatchIter {
            corpus: SynthCorpus::new(vocab, seed),
            batch,
            seq,
            mlm: Some(0.15),
            mask_token: (vocab - 1) as i32,
            rng: Rng::new(seed ^ 0xABCD),
            cursor: 0,
        }
    }

    /// Number of batches drawn so far (stored in training checkpoints).
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Fast-forward to batch `n` by drawing and discarding, which is the
    /// only bit-exact way to advance the corpus + mask RNG state (their
    /// draws per batch are data-dependent, so no closed-form jump exists).
    pub fn skip_to(&mut self, n: usize) {
        assert!(
            n >= self.cursor,
            "skip_to({n}) cannot rewind past cursor {}",
            self.cursor
        );
        while self.cursor < n {
            self.next_batch();
        }
    }

    pub fn next_batch(&mut self) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        let mut loss_mask = Vec::with_capacity(b * s);
        for _ in 0..b {
            // generate S+1 so targets are the shifted sequence
            let seq = self.corpus.tokens(s + 1);
            match self.mlm {
                None => {
                    tokens.extend_from_slice(&seq[..s]);
                    targets.extend_from_slice(&seq[1..]);
                    loss_mask.extend(std::iter::repeat(1.0f32).take(s));
                }
                Some(p) => {
                    for i in 0..s {
                        let masked = self.rng.f32() < p;
                        tokens.push(if masked { self.mask_token } else { seq[i] });
                        targets.push(seq[i]);
                        loss_mask.push(if masked { 1.0 } else { 0.0 });
                    }
                }
            }
        }
        self.cursor += 1;
        Batch { tokens, targets, loss_mask, batch: b, seq: s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic() {
        let a = SynthCorpus::new(256, 7).tokens(100);
        let b = SynthCorpus::new(256, 7).tokens(100);
        assert_eq!(a, b);
        let c = SynthCorpus::new(256, 8).tokens(100);
        assert_ne!(a, c);
    }

    #[test]
    fn corpus_in_vocab() {
        let toks = SynthCorpus::new(64, 1).tokens(1000);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn corpus_is_learnable_not_uniform() {
        // unigram mass must concentrate on the head (learnable skew)
        let toks = SynthCorpus::new(64, 2).tokens(20000);
        let head_mass = toks.iter().filter(|&&t| t < 16).count() as f64
            / toks.len() as f64;
        assert!(head_mass > 0.7, "head mass {head_mass}");
        // and the bigram support must stay sparse vs uniform
        let mut counts = vec![0usize; 64 * 64];
        for w in toks.windows(2) {
            counts[w[0] as usize * 64 + w[1] as usize] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero < 3000, "bigram support {nonzero}");
    }

    #[test]
    fn causal_batch_shift() {
        let mut it = BatchIter::causal(128, 2, 16, 3);
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), 32);
        assert_eq!(b.targets.len(), 32);
        assert!(b.loss_mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn skip_to_matches_sequential_draws() {
        // resume correctness: fast-forwarding a fresh iterator must land
        // on the exact batch a continuously-run iterator produces
        for mlm in [false, true] {
            let mk = |seed| if mlm {
                BatchIter::mlm(128, 2, 32, seed)
            } else {
                BatchIter::causal(128, 2, 32, seed)
            };
            let mut a = mk(9);
            for _ in 0..5 {
                a.next_batch();
            }
            assert_eq!(a.cursor(), 5);
            let mut b = mk(9);
            b.skip_to(5);
            let (ba, bb) = (a.next_batch(), b.next_batch());
            assert_eq!(ba.tokens, bb.tokens, "mlm={mlm}");
            assert_eq!(ba.targets, bb.targets, "mlm={mlm}");
            assert_eq!(ba.loss_mask, bb.loss_mask, "mlm={mlm}");
        }
    }

    #[test]
    fn mlm_batch_masks() {
        let mut it = BatchIter::mlm(128, 2, 256, 3);
        let b = it.next_batch();
        let masked: usize = b.loss_mask.iter().map(|&m| m as usize).sum();
        // ~15% +- slack
        assert!(masked > 30 && masked < 130, "{masked}");
        for i in 0..b.tokens.len() {
            if b.loss_mask[i] == 1.0 {
                assert_eq!(b.tokens[i], 127);
            } else {
                assert_eq!(b.tokens[i], b.targets[i]);
            }
        }
    }
}
