//! Training loop driver: runs the `train_step_*` artifact (full forward +
//! backward + Adam) from rust, feeding synthetic batches and logging the
//! loss curve.  Every linear variant trains on the native backend —
//! including the decay-gated ones (backward-through-gates) — so no tag is
//! skipped here; a missing artifact is a hard error, not a silent no-op.
//! Used by the convergence experiments (Tables 2/3/4) and the end-to-end
//! example.

use std::io::Write;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Pattern, Variant};
use crate::coordinator::{param_specs, Params};
use crate::data::BatchIter;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

/// Cosine LR schedule with linear warmup (paper Sec. 4.1 hyperparameters).
pub fn lr_schedule(step: usize, total: usize, peak: f32, min_lr: f32) -> f32 {
    let warmup = (total / 10).max(1);
    if step < warmup {
        return peak * (step + 1) as f32 / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    min_lr + 0.5 * (peak - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
}

#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub peak_lr: f32,
    pub min_lr: f32,
    pub seed: u64,
    /// bidirectional (MLM) task — Table 3
    pub mlm: bool,
    pub log_every: usize,
    /// optional CSV path for the loss curve
    pub csv: Option<String>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 100,
            peak_lr: 3e-3,
            min_lr: 1e-6,
            seed: 0,
            mlm: false,
            log_every: 10,
            csv: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub final_loss: f32,
    /// mean loss over the last 10% of steps (the "converged" metric)
    pub tail_loss: f32,
    pub tokens_per_sec: f64,
    pub params: usize,
    pub steps: usize,
}

/// Train a (variant, pattern) model with the given train-step artifact.
///
/// `artifact_tag` example: "basic_pure" -> uses `init_basic_pure` +
/// `train_step_basic_pure`.
pub fn train(
    engine: &Arc<Engine>,
    variant: Variant,
    pattern: &Pattern,
    artifact_tag: &str,
    opts: &TrainOpts,
) -> Result<TrainReport> {
    let cfg = &engine.model;
    let init_name = format!("init_{artifact_tag}");
    let step_name = format!("train_step_{artifact_tag}");
    let params = Params::from_init_artifact(
        engine, variant, pattern, &init_name, opts.seed as i32,
    )
    .with_context(|| format!("init artifact {init_name}"))?;
    let n_params = params.len();
    let total_elems = params.n_elems();
    let specs = param_specs(cfg, variant, pattern);

    let step_exe = engine.artifact(&step_name)?;
    let (bsz, seq) = (cfg.train_batch, cfg.train_seq);
    let mut data = if opts.mlm {
        BatchIter::mlm(cfg.vocab, bsz, seq, opts.seed)
    } else {
        BatchIter::causal(cfg.vocab, bsz, seq, opts.seed)
    };

    // state: flat params + adam moments
    let mut flat: Vec<Tensor> = specs
        .iter()
        .map(|(n, _, _)| params.get(n).unwrap().clone())
        .collect();
    let mut mom: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
    let mut vel: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();

    let mut csv = match &opts.csv {
        Some(p) => {
            if let Some(dir) = Path::new(p).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            let mut f = std::fs::File::create(p)?;
            writeln!(f, "step,loss,lr,tokens_per_sec")?;
            Some(f)
        }
        None => None,
    };

    let mut losses = Vec::with_capacity(opts.steps);
    let t0 = Instant::now();
    let mut tokens_seen = 0usize;
    for it in 0..opts.steps {
        let b = data.next_batch();
        let lr = lr_schedule(it, opts.steps, opts.peak_lr, opts.min_lr);
        let mut ins: Vec<Value> = Vec::with_capacity(3 * n_params + 5);
        ins.extend(flat.iter().map(|t| Value::F32(t.clone())));
        ins.extend(mom.iter().map(|t| Value::F32(t.clone())));
        ins.extend(vel.iter().map(|t| Value::F32(t.clone())));
        ins.push(Value::I32(b.tokens.clone(), vec![bsz, seq]));
        ins.push(Value::I32(b.targets.clone(), vec![bsz, seq]));
        ins.push(Value::F32(Tensor::new(vec![bsz, seq], b.loss_mask.clone())));
        ins.push(Value::F32(Tensor::scalar1(lr)));
        ins.push(Value::F32(Tensor::scalar1((it + 1) as f32)));
        let mut outs = step_exe.run(&ins)?;
        let loss_t = outs.pop().unwrap();
        let loss = loss_t.data()[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {it}: {loss}");
        vel = outs.split_off(2 * n_params);
        mom = outs.split_off(n_params);
        flat = outs;
        tokens_seen += bsz * seq;
        losses.push(loss);
        let elapsed = t0.elapsed().as_secs_f64();
        let tps = tokens_seen as f64 / elapsed;
        if let Some(f) = csv.as_mut() {
            writeln!(f, "{it},{loss},{lr},{tps:.1}")?;
        }
        if opts.log_every > 0 && (it % opts.log_every == 0 || it + 1 == opts.steps) {
            eprintln!(
                "[train {artifact_tag}] step {it:>4} loss {loss:.4} lr {lr:.2e} ({tps:.0} tok/s)"
            );
        }
    }
    let tail_n = (opts.steps / 10).max(1);
    let tail_loss =
        losses[opts.steps - tail_n..].iter().sum::<f32>() / tail_n as f32;
    Ok(TrainReport {
        final_loss: *losses.last().unwrap(),
        tail_loss,
        tokens_per_sec: tokens_seen as f64 / t0.elapsed().as_secs_f64(),
        losses,
        params: total_elems,
        steps: opts.steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 100;
        // warmup rises
        assert!(lr_schedule(0, total, 1e-3, 1e-6) < lr_schedule(5, total, 1e-3, 1e-6));
        // peak near end of warmup
        let peak = lr_schedule(10, total, 1e-3, 1e-6);
        assert!((peak - 1e-3).abs() < 1e-4);
        // decays to ~min
        assert!(lr_schedule(99, total, 1e-3, 1e-6) < 1e-4);
    }

    #[test]
    fn tiny_training_reduces_loss() {
        let engine = Engine::load_preset("tiny").expect("tiny artifacts");
        let pattern = Pattern("LL".into());
        let opts = TrainOpts {
            steps: 20,
            peak_lr: 3e-3,
            log_every: 0,
            ..Default::default()
        };
        let rep = train(&engine, Variant::Basic, &pattern, "basic_pure", &opts)
            .unwrap();
        assert!(rep.losses.iter().all(|l| l.is_finite()));
        assert!(
            rep.tail_loss < rep.losses[0],
            "no learning: {:?}",
            rep.losses
        );
    }

    #[test]
    fn tiny_gated_training_reduces_loss() {
        // gated-variant training end-to-end through the native
        // backward-through-gates train_step artifacts (the Table-2/4 rows
        // that used to be PJRT-only).
        let engine = Engine::load_preset("tiny").expect("tiny artifacts");
        let pattern = Pattern("LL".into());
        let opts = TrainOpts {
            steps: 16,
            peak_lr: 3e-3,
            log_every: 0,
            ..Default::default()
        };
        for (variant, tag) in [
            (Variant::Gla, "gla_pure"),
            (Variant::Retention, "retention_pure"),
        ] {
            let rep = train(&engine, variant, &pattern, tag, &opts).unwrap();
            assert!(rep.losses.iter().all(|l| l.is_finite()), "{tag}");
            assert!(
                rep.tail_loss < rep.losses[0],
                "{tag} no learning: {:?}",
                rep.losses
            );
        }
    }
}
