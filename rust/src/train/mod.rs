//! Distributed, resumable training driver.
//!
//! The loop is SPMD over the in-memory `comm::World`: every rank runs the
//! optimizer-free `grad_step_*` artifact on its contiguous slice of the
//! batch, combines gradients with a rank-ordered `reduce_scatter`, applies
//! ZeRO-sharded AdamW on its own parameter shard (`optimizer::ShardedAdam`),
//! and rejoins the updated shards with an `all_gather` — LASP-2's
//! data-parallel companion (ZeRO-1: optimizer state per rank is 2·P·4/W
//! bytes).  `world = 1` is the replicated degenerate case (no collectives),
//! and W=4 reproduces its loss curve BIT-FOR-BIT because each rank's
//! partial gradient is summed in the same fixed order the serial path uses
//! (see `grad_step_impl` / `tests/train_distributed.rs`).
//!
//! Training state (params, both Adam moments, step counter, lr-schedule
//! position, data cursor) snapshots to a versioned binary `Checkpoint`;
//! a killed run resumes to a bit-identical loss curve, and the loss CSV
//! appends on resume instead of truncating.  Every linear variant trains
//! natively — including the decay-gated ones (backward-through-gates) —
//! so no tag is skipped here; a missing artifact is a hard error, not a
//! silent no-op.  Used by the convergence experiments (Tables 2/3/4), the
//! end-to-end example, and the `train` CLI.
//!
//! The driver is also ELASTIC: ranks return typed [`StepError`]s instead
//! of panicking, and when an attempt fails on a [`CommError`] the driver
//! discards the partial step, reloads the last good checkpoint (falling
//! back to the rotated `.prev` copy if the newest is damaged), rebuilds a
//! possibly smaller `World` — crashed ranks shrink it to the largest
//! power of two the survivors fill — and continues.  Because checkpoints
//! are world-size independent and the loss curve is world-size invariant,
//! a W=4 run that loses a rank resumes at W=2 with a loss CSV
//! byte-identical to an uninterrupted run (`tests/fault_injection.rs`).

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::comm::{CommError, Communicator, FaultPlan, World};
use crate::config::{Pattern, Variant};
use crate::coordinator::{param_specs, FlatLayout, Params};
use crate::data::BatchIter;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

pub mod checkpoint;
pub mod optimizer;

pub use checkpoint::{Checkpoint, CKPT_VERSION};
pub use optimizer::ShardedAdam;

/// Cosine LR schedule with linear warmup (paper Sec. 4.1 hyperparameters).
pub fn lr_schedule(step: usize, total: usize, peak: f32, min_lr: f32) -> f32 {
    let warmup = (total / 10).max(1);
    if step < warmup {
        return peak * (step + 1) as f32 / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    min_lr + 0.5 * (peak - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
}

#[derive(Clone, Debug)]
pub struct TrainOpts {
    /// TOTAL lr-schedule horizon; a resumed run continues toward the same
    /// total, it does not add steps
    pub steps: usize,
    pub peak_lr: f32,
    pub min_lr: f32,
    pub seed: u64,
    /// bidirectional (MLM) task — Table 3
    pub mlm: bool,
    pub log_every: usize,
    /// optional CSV path for the loss curve (appends on resume)
    pub csv: Option<String>,
    /// ZeRO data-parallel world size (1 = single-rank replicated)
    pub world: usize,
    /// checkpoint file to resume from
    pub resume: Option<String>,
    /// checkpoint file to snapshot to
    pub save: Option<String>,
    /// snapshot every K steps (0 = only at the end / halt point)
    pub save_every: usize,
    /// stop after K optimizer steps THIS invocation (a simulated kill for
    /// the resume gate; requires `save`) — 0 = run to `steps`
    pub halt_after: usize,
    /// fault plan installed on every world this run builds (chaos/testing)
    pub faults: Option<Arc<FaultPlan>>,
    /// elastic-recovery budget: how many comm failures to roll back from
    /// before giving up
    pub max_recoveries: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 100,
            peak_lr: 3e-3,
            min_lr: 1e-6,
            seed: 0,
            mlm: false,
            log_every: 10,
            csv: None,
            world: 1,
            resume: None,
            save: None,
            save_every: 0,
            halt_after: 0,
            faults: None,
            max_recoveries: 2,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// losses of the steps executed THIS invocation (`start_step..`)
    pub losses: Vec<f32>,
    pub final_loss: f32,
    /// mean loss over the last 10% of executed steps (the "converged" metric)
    pub tail_loss: f32,
    pub tokens_per_sec: f64,
    pub params: usize,
    /// total schedule steps (`TrainOpts::steps`)
    pub steps: usize,
    pub world: usize,
    /// first step executed this invocation (0 unless resumed)
    pub start_step: usize,
    /// Adam-moment bytes each rank actually held (ZeRO-sharded)
    pub opt_bytes_per_rank: usize,
    /// Adam-moment bytes a replicated rank would hold (2·P·4)
    pub opt_bytes_replicated: usize,
    /// wire bytes moved by the training collectives this invocation
    /// (summed over every elastic attempt)
    pub wire_bytes: u64,
    pub collective_ops: u64,
    /// elastic recoveries taken (0 = no comm failure)
    pub recoveries: usize,
    /// completed steps discarded by rollbacks and re-executed
    pub steps_lost: usize,
    /// wall milliseconds spent reloading/rebuilding during recoveries
    pub recovery_ms: f64,
}

/// Per-rank step failure.  Split into comm vs. everything-else so the
/// elastic driver can tell a recoverable communication fault (roll back,
/// maybe shrink the world, retry) from a fatal one — necessary because
/// the vendored `anyhow` shim is string-backed and cannot downcast.
#[derive(Debug)]
pub enum StepError {
    /// a collective or p2p op failed; the step did not commit anywhere
    Comm(CommError),
    /// artifact/IO/divergence failure — re-running will not help
    Other(anyhow::Error),
}

impl StepError {
    fn into_anyhow(self) -> anyhow::Error {
        match self {
            StepError::Comm(e) => anyhow::Error::msg(e),
            StepError::Other(e) => e,
        }
    }
}

impl From<CommError> for StepError {
    fn from(e: CommError) -> StepError {
        StepError::Comm(e)
    }
}

impl From<anyhow::Error> for StepError {
    fn from(e: anyhow::Error) -> StepError {
        StepError::Other(e)
    }
}

/// Communicator-op index of the FIRST collective of absolute step `step`
/// for an invocation that started at `start_step`: each step issues 3 ops
/// per rank (gradient `reduce_scatter`, parameter `all_gather`, loss
/// `all_gather`) plus one `gather_state` all_gather after every snapshot
/// step.  Lets chaos scenarios and tests aim a [`FaultPlan`] event at an
/// exact training step.
pub fn fault_op_for_step(
    start_step: usize,
    step: usize,
    save_every: usize,
    end_step: usize,
) -> u64 {
    let mut ops = 0u64;
    for it in start_step..step {
        ops += 3;
        if it + 1 == end_step || (save_every > 0 && (it + 1) % save_every == 0) {
            ops += 1;
        }
    }
    ops
}

/// Rank-0 side effects, shared across worker threads.  IO failures are
/// RECORDED rather than returned mid-loop: an early return from one rank
/// would strand the others at the next collective.
struct DriverIo {
    csv: Option<File>,
    err: Option<anyhow::Error>,
}

impl DriverIo {
    fn record(&mut self, e: anyhow::Error) {
        if self.err.is_none() {
            self.err = Some(e);
        }
    }
}

/// Everything a rank needs, bundled so the SPMD closure stays one call.
struct RankCtx<'a> {
    engine: &'a Engine,
    tag: &'a str,
    opts: &'a TrainOpts,
    layout: &'a FlatLayout,
    /// unpadded flat parameters at `start_step`
    init_flat: &'a [f32],
    /// unpadded Adam moments from a checkpoint (fresh zeros when None)
    init_moments: Option<(&'a [f32], &'a [f32])>,
    start_step: usize,
    end_step: usize,
    total: usize,
    io: &'a Mutex<DriverIo>,
    /// rank-0 loss per step, indexed by `step - curve_base`; outlives the
    /// attempt so the final report covers steps executed BEFORE a rollback
    curve: &'a Mutex<Vec<f32>>,
    curve_base: usize,
    /// highest step count rank 0 completed (for steps-lost accounting)
    progress: &'a AtomicU64,
    t0: Instant,
}

struct RankOut {
    opt_bytes: usize,
}

fn rank_loop(ctx: &RankCtx, comm: Option<&Communicator>) -> Result<RankOut, StepError> {
    let cfg = &ctx.engine.model;
    let opts = ctx.opts;
    let (world, rank) = match comm {
        Some(c) => (c.size(), c.rank()),
        None => (1, 0),
    };
    let layout = ctx.layout;
    let e_pad = layout.padded(world);
    let mut flat = vec![0.0f32; e_pad];
    flat[..layout.total()].copy_from_slice(ctx.init_flat);
    let mut opt = match ctx.init_moments {
        Some((m, v)) => ShardedAdam::restore(layout, world, rank, m, v),
        None => ShardedAdam::new(layout, world, rank),
    };
    let (bsz, seq) = (cfg.train_batch, cfg.train_seq);
    // contiguous batch shard: rank r owns sequences [lo, hi); a ceil split,
    // so trailing ranks may own none — they contribute exact-zero partial
    // gradients and still join every collective
    let per = bsz.div_ceil(world);
    let lo = (rank * per).min(bsz);
    let hi = ((rank + 1) * per).min(bsz);

    let mut data = if opts.mlm {
        BatchIter::mlm(cfg.vocab, bsz, seq, opts.seed)
    } else {
        BatchIter::causal(cfg.vocab, bsz, seq, opts.seed)
    };
    // one batch per step: fast-forward the stream to the resume point
    data.skip_to(ctx.start_step);

    let exe = ctx.engine.artifact(&format!("grad_step_{}", ctx.tag))?;
    let mut tokens_seen = 0usize;
    for it in ctx.start_step..ctx.end_step {
        let b = data.next_batch();
        let lr = lr_schedule(it, ctx.total, opts.peak_lr, opts.min_lr);
        let mut ins: Vec<Value> =
            layout.unflatten(&flat).into_iter().map(Value::F32).collect();
        ins.push(Value::I32(b.tokens, vec![bsz, seq]));
        ins.push(Value::I32(b.targets, vec![bsz, seq]));
        ins.push(Value::F32(Tensor::new(vec![bsz, seq], b.loss_mask)));
        ins.push(Value::I32(vec![lo as i32, hi as i32], vec![2]));
        let mut outs = exe.run(&ins)?;
        let local_loss = outs.pop().unwrap().data()[0];
        let grads = layout.flatten(&outs, e_pad);
        opt.step(comm, &mut flat, grads, lr, (it + 1) as f32)?;
        // logging loss: rank-ordered sum of per-rank contributions; with
        // contiguous batch shards this IS the batch-ordered sum the W=1
        // path produces, so the logged curve is identical bit-for-bit
        let loss = match comm {
            Some(c) => c
                .all_gather(vec![Tensor::scalar1(local_loss)])?
                .iter()
                .map(|m| m[0].data()[0])
                .fold(0.0f32, |a, x| a + x),
            None => local_loss,
        };
        if !loss.is_finite() {
            return Err(StepError::Other(anyhow::anyhow!(
                "loss diverged at step {it}: {loss}"
            )));
        }
        tokens_seen += bsz * seq;

        // deterministic snapshot schedule: EVERY rank evaluates the same
        // condition and joins the state-gather collective; only rank 0
        // touches the filesystem
        let snapshot_due = opts.save.is_some()
            && (it + 1 == ctx.end_step
                || (opts.save_every > 0 && (it + 1) % opts.save_every == 0));
        if snapshot_due {
            let (mf, vf) = opt.gather_state(comm, layout.total())?;
            if rank == 0 {
                let ck = Checkpoint {
                    tag: ctx.tag.to_string(),
                    mlm: opts.mlm,
                    seed: opts.seed,
                    total_steps: ctx.total as u64,
                    steps_done: (it + 1) as u64,
                    data_cursor: data.cursor() as u64,
                    peak_lr: opts.peak_lr,
                    min_lr: opts.min_lr,
                    params: flat[..layout.total()].to_vec(),
                    m: mf,
                    v: vf,
                };
                let path = opts.save.as_deref().unwrap();
                if let Err(e) = ck.save(path) {
                    ctx.io.lock().unwrap().record(e);
                }
            }
        }
        if rank == 0 {
            ctx.curve.lock().unwrap()[it - ctx.curve_base] = loss;
            ctx.progress.store((it + 1) as u64, Ordering::Relaxed);
            let mut io = ctx.io.lock().unwrap();
            if let Some(f) = io.csv.as_mut() {
                if let Err(e) = writeln!(f, "{it},{loss},{lr}") {
                    io.record(e.into());
                }
            }
            if opts.log_every > 0 && (it % opts.log_every == 0 || it + 1 == ctx.end_step) {
                let tps = tokens_seen as f64 / ctx.t0.elapsed().as_secs_f64();
                eprintln!(
                    "[train {} w{world}] step {it:>4} loss {loss:.4} lr {lr:.2e} ({tps:.0} tok/s)",
                    ctx.tag
                );
            }
        }
    }
    Ok(RankOut { opt_bytes: opt.state_bytes() })
}

/// Everything that must match for a resumed curve to be a CONTINUATION
/// of the checkpointed one: model size, data stream, lr-schedule
/// position.  Shared by `--resume` and elastic rollback.
fn validate_resume(
    ck: &Checkpoint,
    path: &str,
    artifact_tag: &str,
    layout: &FlatLayout,
    opts: &TrainOpts,
    total: usize,
) -> Result<()> {
    anyhow::ensure!(
        ck.tag == artifact_tag,
        "checkpoint {path} was written by tag {} (resuming {artifact_tag})",
        ck.tag
    );
    anyhow::ensure!(
        ck.n_elems() == layout.total(),
        "checkpoint has {} parameter elements, model has {}",
        ck.n_elems(),
        layout.total()
    );
    anyhow::ensure!(
        ck.seed == opts.seed && ck.mlm == opts.mlm,
        "checkpoint data stream (seed {}, mlm {}) != run (seed {}, mlm {})",
        ck.seed,
        ck.mlm,
        opts.seed,
        opts.mlm
    );
    anyhow::ensure!(
        ck.total_steps as usize == total
            && ck.peak_lr == opts.peak_lr
            && ck.min_lr == opts.min_lr,
        "lr schedule mismatch: checkpoint ({} steps, peak {:e}, min {:e}) \
         vs run ({total} steps, peak {:e}, min {:e})",
        ck.total_steps,
        ck.peak_lr,
        ck.min_lr,
        opts.peak_lr,
        opts.min_lr
    );
    anyhow::ensure!(
        ck.data_cursor == ck.steps_done,
        "checkpoint data cursor {} != steps done {}",
        ck.data_cursor,
        ck.steps_done
    );
    Ok(())
}

/// Drop loss-CSV rows at/after `resume_step`: they log steps the
/// rolled-back state never executed (or will re-execute), and would
/// otherwise appear twice.  Header and earlier rows are kept byte-for-byte.
fn sanitize_csv(path: &str, resume_step: usize) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let mut kept = String::with_capacity(text.len());
    for line in text.lines() {
        let keep = match line.split(',').next().and_then(|f| f.parse::<usize>().ok()) {
            Some(step) => step < resume_step,
            None => true, // header
        };
        if keep {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    std::fs::write(path, kept)?;
    Ok(())
}

/// Open the loss CSV: a fresh run truncates and writes the header; a
/// resume (both `--resume` and elastic rollback) first sanitizes rows
/// at/after the resume step, then appends.
fn open_csv(path: &str, resume_step: Option<usize>) -> Result<File> {
    if let Some(dir) = Path::new(path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    if let Some(s) = resume_step {
        if Path::new(path).exists() {
            sanitize_csv(path, s)?;
            return Ok(OpenOptions::new().append(true).open(path)?);
        }
    }
    let mut f = File::create(path)?;
    writeln!(f, "step,loss,lr")?;
    Ok(f)
}

/// Outcome of one elastic attempt (one `World` lifetime) in [`train`].
enum Attempt {
    Done(RankOut),
    Fatal(anyhow::Error),
    /// at least one rank failed on comms or panicked: roll back and retry
    Recover { crashed: Vec<usize>, cause: String },
}

/// Train a (variant, pattern) model with the given artifact tag.
///
/// `artifact_tag` example: "basic_pure" -> uses `init_basic_pure` +
/// `grad_step_basic_pure`.  `opts.world > 1` runs ZeRO-sharded over an
/// in-memory SPMD world; `opts.resume`/`opts.save` make the run
/// checkpointed and resumable (see the module docs).
pub fn train(
    engine: &Arc<Engine>,
    variant: Variant,
    pattern: &Pattern,
    artifact_tag: &str,
    opts: &TrainOpts,
) -> Result<TrainReport> {
    let cfg = &engine.model;
    let world = opts.world.max(1);
    anyhow::ensure!(
        opts.halt_after == 0 || opts.save.is_some(),
        "halt_after requires a save path (a halted run must be resumable)"
    );
    let specs = param_specs(cfg, variant, pattern);
    let layout = FlatLayout::new(&specs);
    let total = opts.steps;

    // start state: fresh init artifact, or checkpoint restore.  Restore
    // validates everything that must match for the resumed curve to be a
    // continuation: model size, data stream, and lr-schedule position.
    let (start_step, init_flat, moments) = match &opts.resume {
        Some(path) => {
            // a damaged newest file falls back to the rotated .prev copy
            let (ck, _fell_back) = Checkpoint::load_with_fallback(path)?;
            validate_resume(&ck, path, artifact_tag, &layout, opts, total)?;
            (ck.steps_done as usize, ck.params, Some((ck.m, ck.v)))
        }
        None => {
            let init_name = format!("init_{artifact_tag}");
            let params = Params::from_init_artifact(
                engine, variant, pattern, &init_name, opts.seed as i32,
            )
            .with_context(|| format!("init artifact {init_name}"))?;
            let tensors: Vec<Tensor> = specs
                .iter()
                .map(|(n, _, _)| params.get(n).unwrap().clone())
                .collect();
            (0usize, layout.flatten(&tensors, layout.total()), None)
        }
    };
    anyhow::ensure!(
        start_step < total,
        "checkpoint is already at step {start_step} of {total}; nothing to train"
    );
    let end_step = if opts.halt_after > 0 {
        (start_step + opts.halt_after).min(total)
    } else {
        total
    };

    // loss CSV: a resumed run APPENDS to the existing curve (no second
    // header, stale rows sanitized away); a fresh run truncates and
    // writes the header
    let mut csv_file = match &opts.csv {
        Some(p) => Some(open_csv(
            p,
            if opts.resume.is_some() { Some(start_step) } else { None },
        )?),
        None => None,
    };
    let t0 = Instant::now();

    // elastic attempt loop: run the SPMD world; on a comm failure roll
    // back to the last good checkpoint, rebuild a (possibly smaller)
    // world, and go again.  State for the CURRENT attempt lives in the
    // *_now bindings; `init0` keeps the launch state for the no-snapshot
    // rollback path.
    let curve = Mutex::new(vec![f32::NAN; end_step - start_step]);
    let progress = AtomicU64::new(start_step as u64);
    let init0 = (init_flat.clone(), moments.clone());
    let mut flat_now = init_flat;
    let mut moments_now = moments;
    let mut world_now = world;
    let mut start_now = start_step;
    let mut recoveries = 0usize;
    let mut steps_lost = 0usize;
    let mut recovery_ms = 0.0f64;
    let mut wire_bytes = 0u64;
    let mut collective_ops = 0u64;
    let rank0 = loop {
        let io = Mutex::new(DriverIo { csv: csv_file.take(), err: None });
        let ctx = RankCtx {
            engine: engine.as_ref(),
            tag: artifact_tag,
            opts,
            layout: &layout,
            init_flat: &flat_now,
            init_moments: moments_now.as_ref().map(|(m, v)| (m.as_slice(), v.as_slice())),
            start_step: start_now,
            end_step,
            total,
            io: &io,
            curve: &curve,
            curve_base: start_step,
            progress: &progress,
            t0,
        };
        let attempt = if world_now == 1 {
            match rank_loop(&ctx, None) {
                Ok(out) => Attempt::Done(out),
                Err(e) => Attempt::Fatal(e.into_anyhow()),
            }
        } else {
            let w = World::new(world_now);
            if let Some(plan) = &opts.faults {
                w.install_faults(plan.clone());
            }
            let results = w.run_catch(|c| {
                let out = rank_loop(&ctx, Some(&c));
                if out.is_err() {
                    // release peers already blocked on this rank
                    c.poison();
                }
                out
            });
            let snap = w.counters();
            wire_bytes += snap.bytes;
            collective_ops += snap.collective_ops;
            let mut r0 = None;
            let mut fatal: Option<anyhow::Error> = None;
            let mut crashed: Vec<usize> = Vec::new();
            let mut cause = String::new();
            let mut comm_failed = false;
            for (r, res) in results.into_iter().enumerate() {
                match res {
                    Ok(Ok(out)) => {
                        if r == 0 {
                            r0 = Some(out);
                        }
                    }
                    Ok(Err(StepError::Comm(e))) => {
                        comm_failed = true;
                        if let Some(cr) = e.crashed_rank() {
                            if !crashed.contains(&cr) {
                                crashed.push(cr);
                            }
                        }
                        if cause.is_empty() {
                            cause = format!("rank {r}: {e}");
                        }
                    }
                    Ok(Err(StepError::Other(e))) => {
                        if fatal.is_none() {
                            fatal = Some(e.context(format!("rank {r}")));
                        }
                    }
                    Err(p) => {
                        comm_failed = true;
                        if !crashed.contains(&p.rank) {
                            crashed.push(p.rank);
                        }
                        if cause.is_empty() {
                            cause = p.to_string();
                        }
                    }
                }
            }
            if let Some(e) = fatal {
                Attempt::Fatal(e)
            } else if comm_failed {
                Attempt::Recover { crashed, cause }
            } else {
                Attempt::Done(r0.expect("rank 0 completed"))
            }
        };
        match attempt {
            Attempt::Done(out) => {
                if let Some(e) = io.into_inner().unwrap().err {
                    return Err(e);
                }
                break out;
            }
            Attempt::Fatal(e) => return Err(e),
            Attempt::Recover { crashed, cause } => {
                drop(io);
                anyhow::ensure!(
                    recoveries < opts.max_recoveries,
                    "giving up after {recoveries} recoveries: {cause}"
                );
                recoveries += 1;
                let rt = Instant::now();
                // a crashed rank is gone for good: shrink to the largest
                // power of two the survivors fill (keeps batch shards and
                // reduce_scatter splits balanced).  Timeouts and exhausted
                // retries keep the size — every rank is still alive.
                if !crashed.is_empty() {
                    let survivors = world_now.saturating_sub(crashed.len()).max(1);
                    let mut p = 1;
                    while p * 2 <= survivors {
                        p *= 2;
                    }
                    world_now = p;
                }
                // roll back to the last good snapshot (fall back to the
                // rotated .prev if the newest file is damaged); without
                // any snapshot, restart this invocation's range
                let have_ck = opts.save.as_deref().is_some_and(|p| {
                    Path::new(p).exists() || Path::new(&checkpoint::prev_path(p)).exists()
                });
                let resume_at = if have_ck {
                    let path = opts.save.as_deref().unwrap();
                    let (ck, _) = Checkpoint::load_with_fallback(path)?;
                    validate_resume(&ck, path, artifact_tag, &layout, opts, total)?;
                    flat_now = ck.params;
                    moments_now = Some((ck.m, ck.v));
                    ck.steps_done as usize
                } else {
                    flat_now = init0.0.clone();
                    moments_now = init0.1.clone();
                    start_step
                };
                let reached = progress.load(Ordering::Relaxed) as usize;
                steps_lost += reached.saturating_sub(resume_at);
                progress.store(resume_at as u64, Ordering::Relaxed);
                start_now = resume_at;
                if let Some(p) = &opts.csv {
                    csv_file = Some(open_csv(p, Some(resume_at))?);
                }
                recovery_ms += rt.elapsed().as_secs_f64() * 1e3;
                eprintln!(
                    "[train {artifact_tag}] {cause}; recovery {recoveries}: \
                     world -> {world_now}, rolled back to step {resume_at} \
                     ({} steps re-run)",
                    reached.saturating_sub(resume_at)
                );
            }
        }
    };

    let losses = curve.into_inner().unwrap();
    debug_assert!(losses.iter().all(|l| !l.is_nan()), "gap in the loss curve");
    let executed = end_step - start_step;
    let tail_n = (executed / 10).max(1);
    let tail_loss = losses[executed - tail_n..].iter().sum::<f32>() / tail_n as f32;
    let tokens_seen = executed * cfg.train_batch * cfg.train_seq;
    Ok(TrainReport {
        final_loss: *losses.last().unwrap(),
        tail_loss,
        tokens_per_sec: tokens_seen as f64 / t0.elapsed().as_secs_f64(),
        losses,
        params: layout.total(),
        steps: total,
        world: world_now,
        start_step,
        opt_bytes_per_rank: rank0.opt_bytes,
        opt_bytes_replicated: layout.total() * 8,
        wire_bytes,
        collective_ops,
        recoveries,
        steps_lost,
        recovery_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 100;
        // warmup rises
        assert!(lr_schedule(0, total, 1e-3, 1e-6) < lr_schedule(5, total, 1e-3, 1e-6));
        // peak near end of warmup
        let peak = lr_schedule(10, total, 1e-3, 1e-6);
        assert!((peak - 1e-3).abs() < 1e-4);
        // decays to ~min
        assert!(lr_schedule(99, total, 1e-3, 1e-6) < 1e-4);
    }

    #[test]
    fn lr_schedule_continuity_under_resume() {
        // the driver recomputes lr from the ABSOLUTE step and the
        // checkpointed schedule horizon, so the lr at step k must not
        // depend on where the run was cut — in any phase
        let (total, peak, min_lr) = (100usize, 3e-3f32, 1e-6f32);
        let uninterrupted: Vec<f32> =
            (0..total).map(|k| lr_schedule(k, total, peak, min_lr)).collect();
        // halt inside warmup (3), at the peak (10), mid-decay (55, 80)
        for halt in [3usize, 10, 55, 80] {
            for (k, &want) in uninterrupted.iter().enumerate().skip(halt) {
                let resumed = lr_schedule(k, total, peak, min_lr);
                assert_eq!(
                    resumed.to_bits(),
                    want.to_bits(),
                    "step {k} after halt at {halt}"
                );
            }
        }
        // phase sanity: 5 is warmup (rising), 10 the peak, 80 decaying
        assert!(uninterrupted[5] > uninterrupted[4]);
        assert!(uninterrupted[80] < uninterrupted[40]);
        assert!(uninterrupted[99] >= min_lr);
    }

    #[test]
    fn tiny_training_reduces_loss() {
        let engine = Engine::load_preset("tiny").expect("tiny artifacts");
        let pattern = Pattern("LL".into());
        let opts = TrainOpts {
            steps: 20,
            peak_lr: 3e-3,
            log_every: 0,
            ..Default::default()
        };
        let rep = train(&engine, Variant::Basic, &pattern, "basic_pure", &opts)
            .unwrap();
        assert!(rep.losses.iter().all(|l| l.is_finite()));
        assert!(
            rep.tail_loss < rep.losses[0],
            "no learning: {:?}",
            rep.losses
        );
        // W=1 holds the full replicated optimizer state and moves nothing
        assert_eq!(rep.world, 1);
        assert_eq!(rep.opt_bytes_per_rank, rep.opt_bytes_replicated);
        assert_eq!(rep.wire_bytes, 0);
    }

    #[test]
    fn tiny_gated_training_reduces_loss() {
        // gated-variant training end-to-end through the native
        // backward-through-gates gradient artifacts (the Table-2/4 rows
        // that used to be PJRT-only).
        let engine = Engine::load_preset("tiny").expect("tiny artifacts");
        let pattern = Pattern("LL".into());
        let opts = TrainOpts {
            steps: 16,
            peak_lr: 3e-3,
            log_every: 0,
            ..Default::default()
        };
        for (variant, tag) in [
            (Variant::Gla, "gla_pure"),
            (Variant::Retention, "retention_pure"),
        ] {
            let rep = train(&engine, variant, &pattern, tag, &opts).unwrap();
            assert!(rep.losses.iter().all(|l| l.is_finite()), "{tag}");
            assert!(
                rep.tail_loss < rep.losses[0],
                "{tag} no learning: {:?}",
                rep.losses
            );
        }
    }
}
