//! Deterministic binary training checkpoints.
//!
//! A checkpoint captures the COMPLETE training state — flat parameters,
//! both Adam moments, the step counter, the lr-schedule position
//! (total_steps + peak/min lr), and the `BatchIter` cursor — so a killed
//! run resumes to a bit-identical loss curve (`tests/train_distributed.rs`
//! diffs the CSVs byte-for-byte).  The format is fixed-layout
//! little-endian with a magic, a version field, and an FNV-1a checksum;
//! writes go through a tmp file + rename so a crash mid-save never
//! corrupts the previous snapshot.  Moments are stored UNSHARDED
//! (gathered, unpadded), which makes the file world-size independent: a
//! checkpoint written at W=1 resumes at W=4 and vice versa.
//!
//! Saves also ROTATE: the previous `<path>` is renamed to `<path>.prev`
//! before the new file lands, so the last TWO snapshots are always on
//! disk.  [`Checkpoint::load_with_fallback`] uses that: if the newest
//! file fails validation (bit rot, truncation, a crash at exactly the
//! wrong moment), it logs and falls back to `.prev` instead of refusing
//! to resume.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic   8  b"LASP2CKP"
//! version u32  = 1
//! tag     u32 len + utf8 bytes      (train artifact tag, e.g. basic_pure)
//! mlm     u8                        (0 causal / 1 bidirectional)
//! seed    u64
//! total_steps / steps_done / data_cursor   u64 each
//! peak_lr / min_lr                  f32 each
//! n_elems u64
//! params / m / v                    n_elems f32 each
//! checksum u64   FNV-1a over everything before it
//! ```

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Current checkpoint format version (bump on any layout change).
pub const CKPT_VERSION: u32 = 1;

const MAGIC: &[u8; 8] = b"LASP2CKP";

/// Complete training state; see the module docs for the wire layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub tag: String,
    pub mlm: bool,
    pub seed: u64,
    /// lr-schedule horizon the run was launched with (`--steps`)
    pub total_steps: u64,
    /// optimizer steps already applied to `params`/`m`/`v`
    pub steps_done: u64,
    /// `BatchIter::cursor()` — batches consumed so far
    pub data_cursor: u64,
    pub peak_lr: f32,
    pub min_lr: f32,
    /// flat parameters in `FlatLayout` order (unpadded)
    pub params: Vec<f32>,
    /// first Adam moment, same layout as `params`
    pub m: Vec<f32>,
    /// second Adam moment, same layout as `params`
    pub v: Vec<f32>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "checkpoint truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

impl Checkpoint {
    /// Number of flat parameter elements.
    pub fn n_elems(&self) -> usize {
        self.params.len()
    }

    /// Serialize to the versioned byte layout (deterministic: identical
    /// state produces identical bytes — the kill-and-resume gate relies
    /// on comparing these files directly).
    pub fn to_bytes(&self) -> Vec<u8> {
        assert_eq!(self.params.len(), self.m.len());
        assert_eq!(self.params.len(), self.v.len());
        let mut out = Vec::with_capacity(64 + self.tag.len() + 12 * self.params.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tag.len() as u32).to_le_bytes());
        out.extend_from_slice(self.tag.as_bytes());
        out.push(self.mlm as u8);
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.total_steps.to_le_bytes());
        out.extend_from_slice(&self.steps_done.to_le_bytes());
        out.extend_from_slice(&self.data_cursor.to_le_bytes());
        out.extend_from_slice(&self.peak_lr.to_le_bytes());
        out.extend_from_slice(&self.min_lr.to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        push_f32s(&mut out, &self.params);
        push_f32s(&mut out, &self.m);
        push_f32s(&mut out, &self.v);
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse + validate (magic, version, length, checksum).
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        anyhow::ensure!(buf.len() > MAGIC.len() + 8, "checkpoint truncated");
        let (body, tail) = buf.split_at(buf.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().unwrap());
        anyhow::ensure!(
            fnv1a(body) == want,
            "checkpoint checksum mismatch (corrupt or partially written file)"
        );
        let mut r = Reader { buf: body, pos: 0 };
        anyhow::ensure!(r.take(8)? == MAGIC, "not a LASP2 checkpoint (bad magic)");
        let version = r.u32()?;
        anyhow::ensure!(
            version == CKPT_VERSION,
            "checkpoint version {version} unsupported (this build reads {CKPT_VERSION})"
        );
        let tag_len = r.u32()? as usize;
        let tag = String::from_utf8(r.take(tag_len)?.to_vec())
            .context("checkpoint tag is not utf8")?;
        let mlm = r.take(1)?[0] != 0;
        let seed = r.u64()?;
        let total_steps = r.u64()?;
        let steps_done = r.u64()?;
        let data_cursor = r.u64()?;
        let peak_lr = r.f32()?;
        let min_lr = r.f32()?;
        let n = r.u64()? as usize;
        let params = r.f32s(n)?;
        let m = r.f32s(n)?;
        let v = r.f32s(n)?;
        anyhow::ensure!(r.pos == body.len(), "checkpoint has trailing bytes");
        Ok(Checkpoint {
            tag,
            mlm,
            seed,
            total_steps,
            steps_done,
            data_cursor,
            peak_lr,
            min_lr,
            params,
            m,
            v,
        })
    }

    /// Atomic save with rotation: write `<path>.tmp`, move any existing
    /// `path` to `<path>.prev`, then rename the tmp over `path`.  Every
    /// transition is a rename, so at any crash point either `path` or
    /// `<path>.prev` holds a complete, checksummed snapshot.
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).ok();
            }
        }
        let tmp = format!("{path}.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {tmp}"))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all().ok();
        }
        if Path::new(path).exists() {
            std::fs::rename(path, prev_path(path))
                .with_context(|| format!("rotating {path} -> {path}.prev"))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp} -> {path}"))
    }

    /// Load + validate a checkpoint file.
    pub fn load(path: &str) -> Result<Checkpoint> {
        let buf = std::fs::read(path).with_context(|| format!("reading checkpoint {path}"))?;
        Self::from_bytes(&buf).with_context(|| format!("parsing checkpoint {path}"))
    }

    /// Load `path`; if it is missing/corrupt/truncated, fall back to the
    /// rotated `<path>.prev`.  Returns the checkpoint and whether the
    /// fallback was taken (so the driver can log how many steps were
    /// lost).  Errors only when BOTH copies are unusable.
    pub fn load_with_fallback(path: &str) -> Result<(Checkpoint, bool)> {
        let newest = Self::load(path);
        match newest {
            Ok(ck) => Ok((ck, false)),
            Err(primary) => {
                let prev = prev_path(path);
                match Self::load(&prev) {
                    Ok(ck) => {
                        eprintln!(
                            "warning: checkpoint {path} unusable ({primary:#}); \
                             falling back to {prev} at step {}",
                            ck.steps_done
                        );
                        Ok((ck, true))
                    }
                    Err(fallback) => Err(primary.context(format!(
                        "and the rotated fallback {prev} is also unusable: {fallback:#}"
                    ))),
                }
            }
        }
    }
}

/// Path of the rotated previous snapshot kept alongside `path`.
pub fn prev_path(path: &str) -> String {
    format!("{path}.prev")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            tag: "basic_pure".into(),
            mlm: false,
            seed: 7,
            total_steps: 100,
            steps_done: 42,
            data_cursor: 42,
            peak_lr: 3e-3,
            min_lr: 1e-6,
            params: (0..97).map(|i| i as f32 * 0.25 - 3.0).collect(),
            m: (0..97).map(|i| (i as f32).sin()).collect(),
            v: (0..97).map(|i| (i as f32).cos().abs()).collect(),
        }
    }

    #[test]
    fn roundtrip_is_exact_and_deterministic() {
        let ck = sample();
        let bytes = ck.to_bytes();
        // determinism: same state -> same bytes (the resume gate diffs files)
        assert_eq!(bytes, ck.to_bytes());
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        // f32 payloads roundtrip bit-exactly, including negative zero
        let mut z = sample();
        z.params[0] = -0.0;
        let back = Checkpoint::from_bytes(&z.to_bytes()).unwrap();
        assert_eq!(back.params[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn corruption_and_truncation_are_detected() {
        let bytes = sample().to_bytes();
        for flip in [0usize, 9, 20, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[flip] ^= 0x40;
            assert!(Checkpoint::from_bytes(&bad).is_err(), "flip at {flip}");
        }
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(b"short").is_err());
    }

    #[test]
    fn future_version_is_rejected() {
        let mut bytes = sample().to_bytes();
        // bump the version field (bytes 8..12) and re-sign the checksum so
        // ONLY the version check can reject it
        bytes[8] = CKPT_VERSION as u8 + 1;
        let n = bytes.len();
        let sum = fnv1a(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn save_load_via_file() {
        let dir = std::env::temp_dir().join("lasp2_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let path = path.to_str().unwrap();
        let ck = sample();
        ck.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        // no tmp file left behind
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        std::fs::remove_file(path).ok();
        std::fs::remove_file(prev_path(path)).ok();
    }

    #[test]
    fn save_rotates_and_keeps_the_previous_snapshot() {
        let dir = std::env::temp_dir().join("lasp2_ckpt_rotate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rot.ckpt");
        let path = path.to_str().unwrap();
        std::fs::remove_file(path).ok();
        std::fs::remove_file(prev_path(path)).ok();

        let first = sample();
        first.save(path).unwrap();
        // one snapshot on disk: no .prev yet
        assert!(!Path::new(&prev_path(path)).exists());

        let mut second = sample();
        second.steps_done = 43;
        second.data_cursor = 43;
        second.save(path).unwrap();
        assert_eq!(Checkpoint::load(path).unwrap(), second);
        assert_eq!(Checkpoint::load(&prev_path(path)).unwrap(), first);

        std::fs::remove_file(path).ok();
        std::fs::remove_file(prev_path(path)).ok();
    }

    #[test]
    fn fallback_survives_bit_flip_and_truncation_of_the_newest() {
        let dir = std::env::temp_dir().join("lasp2_ckpt_fallback_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fb.ckpt");
        let path = path.to_str().unwrap();
        std::fs::remove_file(path).ok();
        std::fs::remove_file(prev_path(path)).ok();

        let first = sample();
        first.save(path).unwrap();
        let mut second = sample();
        second.steps_done = 43;
        second.save(path).unwrap();

        // healthy: newest wins, no fallback
        let (ck, fell_back) = Checkpoint::load_with_fallback(path).unwrap();
        assert_eq!(ck, second);
        assert!(!fell_back);

        // bit-flip the newest: checksum rejects it, .prev takes over
        let good = std::fs::read(path).unwrap();
        let mut bad = good.clone();
        bad[good.len() / 2] ^= 0x01;
        std::fs::write(path, &bad).unwrap();
        let (ck, fell_back) = Checkpoint::load_with_fallback(path).unwrap();
        assert_eq!(ck, first);
        assert!(fell_back);

        // truncate the newest: same story
        std::fs::write(path, &good[..good.len() / 3]).unwrap();
        let (ck, fell_back) = Checkpoint::load_with_fallback(path).unwrap();
        assert_eq!(ck, first);
        assert!(fell_back);

        // both unusable -> a real error naming both files
        std::fs::write(prev_path(path), b"junk").unwrap();
        let err = Checkpoint::load_with_fallback(path).unwrap_err().to_string();
        assert!(err.contains("fallback"), "{err}");

        std::fs::remove_file(path).ok();
        std::fs::remove_file(prev_path(path)).ok();
    }
}
