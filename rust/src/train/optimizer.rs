//! ZeRO-1 sharded AdamW over the `FlatLayout` parameter space.
//!
//! Each rank owns the contiguous shard `[r*S, (r+1)*S)` of the padded
//! flat parameter vector (S = padded/W) and keeps the Adam moments ONLY
//! for that shard — the ZeRO memory win: optimizer state per rank drops
//! from `2·P·4` bytes to `2·P·4/W`.  One step is
//!
//! 1. `reduce_scatter` the per-rank partial gradients (rank-ordered sum,
//!    each rank receives its own shard of the combined gradient),
//! 2. AdamW elementwise on the shard (identical arithmetic, constants,
//!    and op order to the fused `train_step_*` artifacts — this is what
//!    makes the W=1 path bit-match the legacy artifact, and the W=4 path
//!    bit-match W=1 when the rank-ordered gradient sum matches the batch
//!    order, see `grad_step_impl`),
//! 3. `all_gather` the updated shards so every rank holds the full
//!    parameter vector again.
//!
//! `comm = None` is the W=1 degenerate case: no collectives, the "shard"
//! is the whole vector, and the update reduces to plain replicated AdamW.
//!
//! Both collectives are fallible: `step` and `gather_state` surface
//! [`CommError`] TYPED (not stringified) so the elastic driver in
//! `train::train` can tell a communication failure — roll back to the
//! checkpoint, maybe shrink the world — from a math/IO error.

use crate::comm::{CommError, Communicator};
use crate::coordinator::FlatLayout;
use crate::tensor::Tensor;

/// AdamW hyperparameters (paper Sec. 4.1; must stay equal to the
/// constants hard-wired in `train_step_impl` for bit-parity).
pub const ADAM_BETA1: f32 = 0.9;
pub const ADAM_BETA2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
pub const ADAM_WD: f32 = 0.1;

/// Sharded AdamW state for one rank.
pub struct ShardedAdam {
    world: usize,
    /// own shard bounds in the padded flat space
    lo: usize,
    hi: usize,
    e_pad: usize,
    /// Adam moments, shard-only (the per-rank memory that ZeRO bounds)
    m: Vec<f32>,
    v: Vec<f32>,
    /// per-element decay coefficient (wd or 0.0), shard-only
    decay: Vec<f32>,
}

impl ShardedAdam {
    /// Fresh (zero-moment) state for `rank` of `world`.
    pub fn new(layout: &FlatLayout, world: usize, rank: usize) -> ShardedAdam {
        assert!(rank < world && world >= 1);
        let e_pad = layout.padded(world);
        let s = e_pad / world;
        let (lo, hi) = (rank * s, (rank + 1) * s);
        ShardedAdam {
            world,
            lo,
            hi,
            e_pad,
            m: vec![0.0; s],
            v: vec![0.0; s],
            decay: layout.decay_coeff(ADAM_WD, lo, hi),
        }
    }

    /// State restored from a checkpoint's full (unpadded) moment vectors:
    /// each rank slices out its own shard, so the file is world-agnostic.
    pub fn restore(
        layout: &FlatLayout,
        world: usize,
        rank: usize,
        m_full: &[f32],
        v_full: &[f32],
    ) -> ShardedAdam {
        assert_eq!(m_full.len(), layout.total());
        assert_eq!(v_full.len(), layout.total());
        let mut opt = ShardedAdam::new(layout, world, rank);
        let n = layout.total();
        let hi = opt.hi.min(n);
        if opt.lo < hi {
            opt.m[..hi - opt.lo].copy_from_slice(&m_full[opt.lo..hi]);
            opt.v[..hi - opt.lo].copy_from_slice(&v_full[opt.lo..hi]);
        }
        opt
    }

    /// Own shard bounds `[lo, hi)` in the padded flat space.
    pub fn shard_range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Optimizer-state bytes THIS rank holds (both moments, f32).
    pub fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    /// One ZeRO step.  `grads` is this rank's padded partial gradient sum
    /// (length `padded(world)`); `flat` is the full padded parameter
    /// vector, updated in place on every rank; `t` is the 1-based Adam
    /// step counter (bias correction).  A `CommError` means the step did
    /// NOT complete — parameters and moments may be mid-update, so the
    /// caller must discard this replica's state and reload a checkpoint.
    pub fn step(
        &mut self,
        comm: Option<&Communicator>,
        flat: &mut [f32],
        grads: Vec<f32>,
        lr: f32,
        t: f32,
    ) -> Result<(), CommError> {
        assert_eq!(flat.len(), self.e_pad, "param vector length");
        assert_eq!(grads.len(), self.e_pad, "grad vector length");
        let s = self.hi - self.lo;
        // 1. combine partial grads; keep own shard (rank-ordered sum)
        let gshard: Vec<f32> = match comm {
            Some(c) => {
                debug_assert_eq!(c.size(), self.world);
                let out = c.reduce_scatter(vec![Tensor::new(vec![self.e_pad], grads)])?;
                out.into_iter().next().unwrap().into_data()
            }
            None => grads,
        };
        assert_eq!(gshard.len(), s, "grad shard length");
        // 2. AdamW on the shard — op-for-op the train_step_impl update
        let (b1, b2, eps) = (ADAM_BETA1, ADAM_BETA2, ADAM_EPS);
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        let mut new_shard = Vec::with_capacity(s);
        for j in 0..s {
            let g = gshard[j];
            self.m[j] = b1 * self.m[j] + (1.0 - b1) * g;
            self.v[j] = b2 * self.v[j] + (1.0 - b2) * g * g;
            let upd = (self.m[j] / bc1) / ((self.v[j] / bc2).sqrt() + eps);
            let pj = flat[self.lo + j];
            new_shard.push(pj - lr * (upd + self.decay[j] * pj));
        }
        // 3. rejoin the updated shards on every rank
        match comm {
            Some(c) => {
                let got = c.all_gather(vec![Tensor::new(vec![s], new_shard)])?;
                for (r, msg) in got.iter().enumerate() {
                    flat[r * s..(r + 1) * s].copy_from_slice(msg[0].data());
                }
            }
            None => flat.copy_from_slice(&new_shard),
        }
        Ok(())
    }

    /// Gather the full (unpadded) moment vectors for checkpointing; a
    /// collective on W>1, so EVERY rank must call it at the same step.
    pub fn gather_state(
        &self,
        comm: Option<&Communicator>,
        total: usize,
    ) -> Result<(Vec<f32>, Vec<f32>), CommError> {
        Ok(match comm {
            Some(c) => {
                let s = self.hi - self.lo;
                let got = c.all_gather(vec![
                    Tensor::new(vec![s], self.m.clone()),
                    Tensor::new(vec![s], self.v.clone()),
                ])?;
                let mut m = Vec::with_capacity(self.e_pad);
                let mut v = Vec::with_capacity(self.e_pad);
                for msg in &got {
                    m.extend_from_slice(msg[0].data());
                    v.extend_from_slice(msg[1].data());
                }
                m.truncate(total);
                v.truncate(total);
                (m, v)
            }
            None => (self.m[..total].to_vec(), self.v[..total].to_vec()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::config::{ModelConfig, Pattern, Variant};
    use crate::coordinator::param_specs;
    use crate::data::Rng;

    fn layout() -> FlatLayout {
        let cfg = ModelConfig::preset("tiny").unwrap();
        FlatLayout::new(&param_specs(&cfg, Variant::Basic, &Pattern("LL".into())))
    }

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.f32() - 0.5).collect()
    }

    #[test]
    fn sharded_w4_matches_replicated_w1_bitwise() {
        // the tentpole parity gate in miniature: per-rank partial grads
        // combined by rank-ordered reduce_scatter drive the exact update
        // the W=1 path computes from the pre-summed gradient
        let layout = layout();
        let world = 4;
        let e1 = layout.padded(1);
        let e4 = layout.padded(world);
        let p0 = randvec(e1, 1);
        // per-rank partials; zero padding tail like the real driver
        let partials: Vec<Vec<f32>> = (0..world)
            .map(|r| {
                let mut g = randvec(e1, 10 + r as u64);
                g.resize(e4, 0.0);
                g
            })
            .collect();
        // rank-ordered sum == what reduce_scatter computes per element
        let mut gsum = vec![0.0f32; e1];
        for p in &partials {
            for (a, b) in gsum.iter_mut().zip(p) {
                *a += *b;
            }
        }

        // W=1 reference over three steps (moments must accumulate)
        let mut flat1 = p0.clone();
        let mut opt1 = ShardedAdam::new(&layout, 1, 0);
        for t in 1..=3 {
            opt1.step(None, &mut flat1, gsum.clone(), 1e-3, t as f32).unwrap();
        }

        let w = World::new(world);
        let flats = w.run(|c| {
            let mut flat = p0.clone();
            flat.resize(e4, 0.0);
            let mut opt = ShardedAdam::new(&layout, world, c.rank());
            for t in 1..=3 {
                opt.step(Some(&c), &mut flat, partials[c.rank()].clone(), 1e-3, t as f32)
                    .unwrap();
            }
            flat
        });
        for (r, f) in flats.iter().enumerate() {
            for j in 0..e1 {
                assert_eq!(
                    f[j].to_bits(),
                    flat1[j].to_bits(),
                    "rank {r} element {j}: {} != {}",
                    f[j],
                    flat1[j]
                );
            }
        }
    }

    #[test]
    fn state_bytes_shrink_with_world() {
        let layout = layout();
        let full = ShardedAdam::new(&layout, 1, 0).state_bytes();
        let quarter = ShardedAdam::new(&layout, 4, 0).state_bytes();
        assert_eq!(full, layout.padded(1) * 8);
        // 2 moments * 4 bytes / 4 ranks, up to padding
        assert!(quarter <= full / 4 + 8, "{quarter} vs {full}");
    }

    #[test]
    fn moments_restore_then_gather_roundtrip() {
        let layout = layout();
        let total = layout.total();
        let m: Vec<f32> = randvec(total, 3);
        let v: Vec<f32> = randvec(total, 4).iter().map(|x| x.abs()).collect();
        // W=1: restore/gather are plain copies
        let opt = ShardedAdam::restore(&layout, 1, 0, &m, &v);
        let (m1, v1) = opt.gather_state(None, total).unwrap();
        assert_eq!(m1, m);
        assert_eq!(v1, v);
        // W=4: every rank slices its shard; the gather collective rejoins
        // them (this is the checkpoint save path at W>1)
        let w = World::new(4);
        let outs = w.run(|c| {
            let opt = ShardedAdam::restore(&layout, 4, c.rank(), &m, &v);
            opt.gather_state(Some(&c), total).unwrap()
        });
        for (r, (mg, vg)) in outs.iter().enumerate() {
            assert_eq!(mg, &m, "rank {r}");
            assert_eq!(vg, &v, "rank {r}");
        }
    }
}
