//! `lasp2` CLI launcher.
//!
//! Subcommands (see `lasp2 help`):
//!   run           distributed forward + SP-vs-mono verification
//!   train         train a model via the train_step artifact
//!   bench-fig3    Fig. 3 speed comparison (sim @ 64 GPUs + real-exec)
//!   bench-fig4    Fig. 4 scalability summary (sim)
//!   bench-table2  Table 2 convergence (real training)
//!   bench-table3  Table 3 bidirectional (real training)
//!   bench-table4  Table 4 hybrid-ratio ablation (real training)
//!   bench-table5  Table 5 gather-split ablation (sim)
//!   bench-table6  Table 6 quantitative scalability (sim)
//!   serve-sim     continuous-batching serve loop over a synthetic trace
//!   bench-serve   serve-loop bench: TTFT percentiles + sessions/GB
//!   chaos         seeded fault-injection scenarios + recovery metrics
//!   bench-all     everything above

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use lasp2::bench;
use lasp2::comm::{FaultPlan, World};
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono, Params};
use lasp2::metrics::Table;
use lasp2::runtime::Engine;
use lasp2::serve::{
    argmax, gen_trace, Model, Request, ServeConfig, ServeLoop, ServeSummary, TraceConfig,
};
use lasp2::sim::CostModel;
use lasp2::tensor::quant::DecodeDtype;
use lasp2::tensor::{gemm, par, Tensor};
use lasp2::train::{fault_op_for_step, train, TrainOpts};

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse `--key value`, `--key=value`, and bare `--flag` (-> "true").
    /// Everything after the FIRST `=` is the value, so values may contain
    /// `=` themselves (e.g. `--csv=run=1.csv`).
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn is_set(&self, key: &str) -> bool {
        self.get(key, "false") == "true"
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }
}

const HELP: &str = "lasp2 — LASP-2 sequence parallelism reproduction

USAGE: lasp2 <command> [--flags]

COMMANDS
  run           distributed forward, verified against the monolithic oracle
                  --preset tiny|small  --world N
                  --scheduler lasp2|lasp2-overlap|lasp1|ring|megatron-sp|
                              ulysses|zeco|usp2d   (see docs/SCHEDULERS.md)
                  --variant basic|gla|...  --splits K
                  --usp-cols C  (mesh columns for usp2d; must divide --world)
                  --strict  (error out if the verification oracle is missing)
  train         real training: ZeRO-sharded, checkpointed, resumable driver
                (grad_step artifact + reduce-scatter + sharded Adam; see
                DESIGN.md \"Distributed training\")
                  --preset tiny|small|medium  --variant basic --ratio 0|1/4
                  --steps N  (TOTAL schedule steps, also after --resume)
                  --lr 3e-3  --mlm  --csv path.csv  (appends on resume)
                  --world W  (ZeRO data-parallel ranks; W=4 bit-matches W=1)
                  --save path.ckpt  --save-every K  (0 = only at the end)
                  --resume path.ckpt  (continue a killed run bit-exactly)
                  --halt-after K  (stop after K steps; simulated kill)
  generate      serving demo: prefill a prompt, then autoregressive decode
                on the recurrent state (constant memory for linear layers)
                  --preset tiny|small  --variant basic|gla|...  --ratio 0|1/2
                  --tokens N  --prompt 1,2,3  --seed S
                  --decode-dtype f32|bf16|int8  (readout weight storage;
                  f32 is bit-exact, bf16/int8 trade <=1e-2 logit error for
                  2-4x less readout bandwidth; see DESIGN.md)
  bench-fig3    speed comparison tokens/s (sim @64 GPUs) + real-exec table
  bench-fig4    scalability frontier (sim)
  bench-table2  convergence zoo (real training; needs small bench artifacts)
  bench-table3  bidirectional LM (real training)
  bench-table4  hybrid-ratio ablation (real training)
  bench-table5  AllGather split-size ablation (sim)
  bench-table6  quantitative scalability table (sim)
  serve-sim     continuous-batching serve loop: admit/prefill/decode/evict
                a synthetic multi-tenant trace through ONE model, printing
                TTFT percentiles, tokens/s, and the schedule output digest
                (bit-identical at any LASP2_THREADS — the CI cross-thread
                determinism check compares the digest line)
                  --preset tiny|small  --variant basic|gla|...  --ratio 0|1/2
                  --sessions N  --seed S  --budget-mb MB (0 = unbounded)
                  --max-active K  --cache-entries E (0 disables the cache)
  bench-serve   serve-loop bench across the headline models (basic/gla
                pure-linear, basic 1/2 hybrid, softmax std baseline;
                --full adds the remaining linear variants)
                  --preset tiny|small  --sessions N  --seed S
                  --budget-mb MB  --max-active K
                  --json path.json  (adds the \"serve\" section)
                  --floor BENCH_floor.json  (fail if decode tok/s drops
                  >30% below the serve_tps_* floor, or p99 TTFT rises
                  >30% above the serve_p99ttft_ms_* ceiling)
  bench-decode  serving decode: tokens/s + state-bytes-vs-seqlen table
                  --preset tiny|small  --tokens N
                  --decode-dtype f32|bf16|int8  (readout weight storage)
                  --json path.json  (splices the \"decode\" section into an
                  existing snapshot, other sections untouched)
                  --floor BENCH_floor.json  (fail if tokens/s drops >30%
                  below the committed floor — the CI perf smoke gate)
  bench-kernels op-level GEMM GFLOP/s + train-step ms + decode tokens/s
                  --preset tiny|small  --steps N  --tokens N
                  --json BENCH_kernels.json  (also appends a \"history\"
                  perf-trajectory entry; --pr names it)
                  --floor BENCH_floor.json  (gemm + train + decode gate)
  bench-all     all of the above, plus the scheduler crossover table
                (sim, W in {8,64,128}, N up to 2048K) and the ZeRO
                replicated-vs-sharded memory/wire table; --json path.json
                writes the full machine-readable
                kernel/train/decode/fig3/crossover/zero snapshot
  chaos         seeded fault-injection scenarios through the REAL stack:
                a rank crash (elastic W=4 -> W=2 resume, loss curve
                bit-identical), transient drop/corruption (checksum +
                bounded-backoff retry, bit-exact), a straggler rank
                (fenced collectives stay bit-identical), and a poison
                serve request (survivors unperturbed); see DESIGN.md
                \"Fault tolerance\"
                  --preset tiny  --steps N (>= 4)  --seed S
                  --json BENCH_kernels.json  (splices the \"fault\"
                  section in place, leaving other sections untouched)

Flags accept both `--key value` and `--key=value`.  `run`, `train`, and
`generate` also take `--profile` to print the per-artifact execution time
table after the run.  `LASP2_THREADS` controls compute-core threading
(unset/0 = all cores, 1 = serial; outputs are bit-identical either way).
The scheduler atlas in docs/SCHEDULERS.md explains which --scheduler to
pick for a given world size, sequence length, and hybrid pattern.
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "train" => cmd_train(&args),
        "generate" => cmd_generate(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "bench-decode" => cmd_decode_bench(&args),
        "bench-kernels" => cmd_bench_kernels(&args),
        "bench-fig3" => cmd_fig3(&args),
        "bench-fig4" => {
            println!("# Fig. 4 — scalability frontier (sim)\n");
            println!("{}", bench::fig4_scalability(&CostModel::default()).to_markdown());
            Ok(())
        }
        "bench-table2" => cmd_table2(&args),
        "bench-table3" => cmd_table3(&args),
        "bench-table4" => cmd_table4(&args),
        "bench-table5" => {
            println!("# Table 5 — AllGather split-size ablation (sim)\n");
            println!("{}", bench::table5_splits(&CostModel::default()).to_markdown());
            Ok(())
        }
        "bench-table6" => {
            println!("# Table 6 — quantitative scalability (sim)\n");
            println!("{}", bench::table6_scalability(&CostModel::default()).to_markdown());
            Ok(())
        }
        "bench-all" => cmd_bench_all(&args),
        "chaos" => cmd_chaos(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other}\n\n{HELP}"),
    }
}

/// `--profile`: the per-artifact execution time table (Engine stats).
fn print_profile(engine: &Engine) {
    let mut t = Table::new(&["artifact", "calls", "total_ms", "mean_us/call"]);
    for (name, st) in engine.stats_report() {
        if st.calls == 0 {
            continue;
        }
        t.row(&[
            name,
            st.calls.to_string(),
            format!("{:.2}", st.nanos as f64 / 1e6),
            format!("{:.1}", st.nanos as f64 / 1e3 / st.calls as f64),
        ]);
    }
    println!("\n# per-artifact execution profile\n\n{}", t.to_markdown());
}

fn cmd_generate(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let variant = Variant::parse(&args.get("variant", "basic"))?;
    let ratio = args.get("ratio", "0");
    let n_tokens = args.usize("tokens", 32)?;
    anyhow::ensure!(n_tokens >= 1, "--tokens must be >= 1");
    let seed = args.usize("seed", 0)? as i32;
    let dtype = DecodeDtype::parse(&args.get("decode-dtype", "f32"))?;
    let mut model = Model::load(&preset, variant, &ratio, seed)?;
    model.set_decode_dtype(dtype)?;
    let model = model;
    let cfg = model.config().clone();
    let prompt: Vec<i32> = match args.flags.get("prompt") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<i32>().with_context(|| format!("--prompt token {t:?}")))
            .collect::<Result<_>>()?,
        None => (0..cfg.chunk_len as i32)
            .map(|i| (i * 7 + 3) % cfg.vocab as i32)
            .collect(),
    };
    println!(
        "preset={preset} variant={variant} pattern={} prompt_len={} decode_tokens={n_tokens} \
         decode_dtype={}",
        model.pattern().0,
        prompt.len(),
        dtype.name()
    );
    model.warmup_serving()?;
    let mut session = model.session();

    let t0 = std::time::Instant::now();
    let logits = session.prefill(&prompt)?;
    let prefill_s = t0.elapsed().as_secs_f64();
    println!(
        "prefill: {} tokens in {:.1} ms ({:.0} tokens/s), state {} bytes",
        prompt.len(),
        prefill_s * 1e3,
        prompt.len() as f64 / prefill_s,
        session.state_bytes()
    );

    let vb = cfg.vocab;
    let last = &logits.data()[(logits.shape()[0] - 1) * vb..];
    let mut next = argmax(last);
    let mut generated = Vec::with_capacity(n_tokens);
    generated.push(next);
    let t1 = std::time::Instant::now();
    while generated.len() < n_tokens {
        let row = session.decode(next)?;
        next = argmax(row.data());
        generated.push(next);
    }
    let decode_s = t1.elapsed().as_secs_f64().max(1e-9);
    println!(
        "decode: {} tokens in {:.1} ms ({:.0} tokens/s), state {} bytes at pos {}",
        generated.len() - 1,
        decode_s * 1e3,
        (generated.len() - 1) as f64 / decode_s,
        session.state_bytes(),
        session.pos()
    );
    println!("generated token ids: {generated:?}");
    if args.is_set("profile") {
        print_profile(model.engine());
    }
    Ok(())
}

fn cmd_decode_bench(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let dtype = DecodeDtype::parse(&args.get("decode-dtype", "f32"))?;
    let engine = Engine::load_preset(&preset)?;
    let n = args.usize("tokens", (engine.model.max_seq / 4).max(8))?;
    println!(
        "# Serving decode — constant-memory inference \
         ({preset}, {n} tokens, {} readout)\n",
        dtype.name()
    );
    let (table, rows) = bench::decode_bench_rows_with(&engine, n, dtype)?;
    println!("{}", table.to_markdown());
    if let Some(path) = args.flags.get("json") {
        // splice into an existing snapshot (keeping its other sections);
        // write a fresh single-section document only if none exists
        let frag = bench::decode_fragment(&preset, n, &rows);
        let doc = match std::fs::read_to_string(path) {
            Ok(existing) => bench::splice_section(&existing, "decode", &frag)
                .with_context(|| format!("splicing decode section into {path}"))?,
            Err(_) => bench::KernelsReport {
                source: "lasp2 bench-decode".into(),
                threads: par::num_threads(),
                isa: gemm::isa_name().into(),
                gemm: Vec::new(),
                train: None,
                decode: Some((preset.clone(), n, rows.clone())),
                fig3: None,
                crossover: None,
                zero: None,
                serve: None,
                fault: None,
                history: None,
            }
            .to_json(),
        };
        std::fs::write(path, doc).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if let Some(floor_path) = args.flags.get("floor") {
        let text = std::fs::read_to_string(floor_path)
            .with_context(|| format!("reading floor file {floor_path}"))?;
        check_decode_floor(&rows, &text)?;
        println!("decode floor check passed ({floor_path})");
    }
    Ok(())
}

fn cmd_serve_sim(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let variant = Variant::parse(&args.get("variant", "basic"))?;
    let ratio = args.get("ratio", "0");
    let sessions = args.usize("sessions", 8)?;
    let seed = args.usize("seed", 1)? as u64;
    let budget_mb = args.usize("budget-mb", 0)?;
    let cfg = ServeConfig {
        max_active: args.usize("max-active", 8)?,
        mem_budget: budget_mb << 20,
        prefix_cache_entries: args.usize("cache-entries", 8)?,
        ..Default::default()
    };
    anyhow::ensure!(cfg.max_active >= 1, "--max-active must be >= 1");
    anyhow::ensure!(sessions >= 1, "--sessions must be >= 1");
    let model = Model::load(&preset, variant, &ratio, 1)?;
    model.warmup_serving()?;
    println!(
        "preset={preset} variant={variant} pattern={} sessions={sessions} seed={seed} \
         max_active={} budget_mb={budget_mb}",
        model.pattern().0,
        cfg.max_active,
    );
    let mut sl = ServeLoop::new(&model, cfg);
    for req in gen_trace(&TraceConfig::for_model(model.config(), sessions, seed)) {
        sl.enqueue(req);
    }
    let sum = sl.run()?;
    println!(
        "served {} sessions in {} ticks ({:.1} ms): {} tokens generated",
        sum.sessions,
        sum.total_ticks,
        sum.elapsed_s * 1e3,
        sum.generated_tokens
    );
    println!(
        "latency/throughput: p50 TTFT {:.2} ms, p99 TTFT {:.2} ms, \
         decode {:.0} tok/s, sustained {:.0} tok/s",
        sum.p50_ttft_ms, sum.p99_ttft_ms, sum.decode_tps, sum.sustained_tps
    );
    println!(
        "state: {:.0} bytes/session mean ({:.0} sessions/GB) | cache {} hits \
         / {} misses / {} inserts | {} evictions, {} resumes",
        sum.mean_state_bytes,
        sum.sessions_per_gb,
        sum.cache_hits,
        sum.cache_misses,
        sum.cache_insertions,
        sum.evictions,
        sum.resumes
    );
    if sum.rejected_requests + sum.failed_requests > 0 {
        println!(
            "degraded: {} rejected at admission, {} failed at runtime (culled alone)",
            sum.rejected_requests, sum.failed_requests
        );
    }
    // the CI determinism smoke compares this line across LASP2_THREADS
    println!("output_digest=0x{:016x}", sum.output_digest);
    if args.is_set("profile") {
        print_profile(model.engine());
    }
    Ok(())
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let sessions = args.usize("sessions", 256)?;
    let seed = args.usize("seed", 1)? as u64;
    let budget = args.usize("budget-mb", 0)? << 20;
    let max_active = args.usize("max-active", 8)?;
    let full = args.is_set("full");
    let engine = Engine::load_preset(&preset)?;
    println!("# Serve loop — continuous batching ({preset}, {sessions} sessions)\n");
    let (table, rows) =
        bench::serve_bench_rows(&engine, sessions, seed, budget, max_active, full)?;
    println!("{}", table.to_markdown());
    if let Some(path) = args.flags.get("json") {
        // "adds the serve section": splice into an existing snapshot,
        // only falling back to a fresh document when none exists
        let frag = bench::serve_fragment(&preset, sessions, &rows);
        let doc = match std::fs::read_to_string(path) {
            Ok(existing) => bench::splice_section(&existing, "serve", &frag)
                .with_context(|| format!("splicing serve section into {path}"))?,
            Err(_) => bench::KernelsReport {
                source: "lasp2 bench-serve".into(),
                threads: par::num_threads(),
                isa: gemm::isa_name().into(),
                gemm: Vec::new(),
                train: None,
                decode: None,
                fig3: None,
                crossover: None,
                zero: None,
                serve: Some((preset.clone(), sessions, rows.clone())),
                fault: None,
                history: None,
            }
            .to_json(),
        };
        std::fs::write(path, doc).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if let Some(floor_path) = args.flags.get("floor") {
        let text = std::fs::read_to_string(floor_path)
            .with_context(|| format!("reading floor file {floor_path}"))?;
        check_serve_floor(&rows, &text)?;
        println!("serve floor check passed ({floor_path})");
    }
    Ok(())
}

/// Scan our own flat bench JSON for `"key": <number>` (the repo is
/// dependency-free by design, so no JSON parser — this reads only the
/// files the bench writer itself emits).
fn json_lookup_f64(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let rest = &text[text.find(&pat)? + pat.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || ".-+eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// CI perf smoke: every measured decode row with a committed floor must
/// stay above floor * 0.7 (i.e. fail on a >30% regression).
fn check_decode_floor(rows: &[bench::DecodeRow], floor_text: &str) -> Result<()> {
    let mut failures = Vec::new();
    let mut checked = 0;
    for r in rows {
        if let Some(floor) = json_lookup_f64(floor_text, &r.tag) {
            checked += 1;
            if r.tokens_per_sec < floor * 0.7 {
                failures.push(format!(
                    "{}: {:.0} tok/s < 70% of committed floor {:.0}",
                    r.tag, r.tokens_per_sec, floor
                ));
            }
        }
    }
    anyhow::ensure!(checked > 0, "floor file matched no decode rows");
    if !failures.is_empty() {
        bail!("decode perf regression:\n  {}", failures.join("\n  "));
    }
    Ok(())
}

/// CI perf smoke for the serve loop: decode tokens/s must stay above
/// `serve_tps_{tag}` * 0.7 (a >30% throughput regression fails), and p99
/// TTFT must stay below `serve_p99ttft_ms_{tag}` * 1.3 (a >30% latency
/// regression fails).  Rows without committed entries are skipped, but at
/// least one metric must match or the floor file is misconfigured.
fn check_serve_floor(rows: &[bench::ServeRow], floor_text: &str) -> Result<()> {
    let mut failures = Vec::new();
    let mut checked = 0;
    for r in rows {
        if let Some(floor) = json_lookup_f64(floor_text, &format!("serve_tps_{}", r.tag)) {
            checked += 1;
            if r.decode_tps < floor * 0.7 {
                failures.push(format!(
                    "serve_tps_{}: {:.0} tok/s < 70% of committed floor {:.0}",
                    r.tag, r.decode_tps, floor
                ));
            }
        }
        let ceil_key = format!("serve_p99ttft_ms_{}", r.tag);
        if let Some(ceil) = json_lookup_f64(floor_text, &ceil_key) {
            checked += 1;
            if r.p99_ttft_ms > ceil * 1.3 {
                failures.push(format!(
                    "{ceil_key}: {:.2} ms > 130% of committed ceiling {ceil:.2}",
                    r.p99_ttft_ms
                ));
            }
        }
    }
    anyhow::ensure!(checked > 0, "floor file matched no serve rows");
    if !failures.is_empty() {
        bail!("serve perf regression:\n  {}", failures.join("\n  "));
    }
    Ok(())
}

/// CI perf smoke for the GEMM microkernels: every measured shape with a
/// committed `gemm_{op}_{m}x{k}x{n}` floor must stay above floor * 0.7,
/// mirroring the decode gate.  The committed floors sit above the
/// pre-SIMD kernels' throughput (losing the microkernels fails CI) with
/// several-fold headroom under the snapshot numbers for noisy runners;
/// the scalar-fallback CI leg runs without `--floor` and skips them.
fn check_gemm_floor(rows: &[bench::GemmRow], floor_text: &str) -> Result<()> {
    let mut failures = Vec::new();
    let mut checked = 0;
    for r in rows {
        let key = format!("gemm_{}_{}x{}x{}", r.op, r.m, r.k, r.n);
        if let Some(floor) = json_lookup_f64(floor_text, &key) {
            checked += 1;
            if r.gflops < floor * 0.7 {
                failures.push(format!(
                    "{key}: {:.2} GFLOP/s < 70% of committed floor {floor:.2}",
                    r.gflops
                ));
            }
        }
    }
    anyhow::ensure!(checked > 0, "floor file matched no gemm rows");
    if !failures.is_empty() {
        bail!("gemm perf regression:\n  {}", failures.join("\n  "));
    }
    Ok(())
}

/// CI perf smoke for the train-step row: tokens/s must stay above
/// floor * 0.7, mirroring the decode gate.
fn check_train_floor(tag: &str, tps: f64, floor_text: &str) -> Result<()> {
    let key = format!("train_step_{tag}");
    let Some(floor) = json_lookup_f64(floor_text, &key) else {
        bail!("floor file has no {key} entry");
    };
    anyhow::ensure!(
        tps >= floor * 0.7,
        "train perf regression: {key} {tps:.0} tok/s < 70% of committed floor {floor:.0}"
    );
    Ok(())
}

/// Headline numbers for one bench-kernels run, as `history` entry keys.
fn history_headline(
    gemm: &[bench::GemmRow],
    tps: f64,
    rows: &[bench::DecodeRow],
) -> Vec<(&'static str, f64)> {
    let peak = |pred: &dyn Fn(&&bench::GemmRow) -> bool| {
        gemm.iter().filter(pred).map(|g| g.gflops).fold(0.0, f64::max)
    };
    let mut h = vec![
        ("gemm_nn_peak_gflops", peak(&|g| g.op == "nn")),
        ("gemm_nt_m1_gflops", peak(&|g| g.op == "nt" && g.m == 1)),
        ("gemm_tn_peak_gflops", peak(&|g| g.op == "tn")),
        ("train_tps", tps),
    ];
    if let Some(r) = rows.iter().find(|r| r.tag == "basic_pure") {
        h.push(("decode_tps_basic_pure", r.tokens_per_sec));
    }
    h
}

/// Today's UTC date as `YYYY-MM-DD` (civil-from-days, dependency-free).
fn utc_date() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}

fn cmd_bench_kernels(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let engine = Engine::load_preset(&preset)?;
    let (gt, gemm_rows) = bench::gemm_bench();
    println!(
        "# Kernel-level GEMM throughput ({} threads, {} kernels)\n\n{}",
        par::num_threads(),
        gemm::isa_name(),
        gt.to_markdown()
    );
    let steps = args.usize("steps", 8)?;
    let (tag, step_ms, tps) = bench::train_step_bench(&engine, steps)?;
    println!("train_step_{tag} ({preset}): {step_ms:.1} ms/step ({tps:.0} tokens/s)\n");
    let n = args.usize("tokens", (engine.model.max_seq / 4).max(8))?;
    let (dt, rows) = bench::decode_bench_rows(&engine, n)?;
    println!("# Serving decode ({preset}, {n} tokens)\n\n{}", dt.to_markdown());
    if let Some(path) = args.flags.get("json") {
        // the perf trajectory: carry the committed snapshot's history
        // forward and append this run's headline numbers (--pr names the
        // entry; CI passes the actual PR/branch, local runs default)
        let old = std::fs::read_to_string(path).ok();
        let entry = bench::history_entry(
            &args.get("pr", "local"),
            &utc_date(),
            &history_headline(&gemm_rows, tps, &rows),
        );
        let history = bench::append_history(old.as_deref(), &entry);
        let report = bench::KernelsReport {
            source: "lasp2 bench-kernels".into(),
            threads: par::num_threads(),
            isa: gemm::isa_name().into(),
            gemm: gemm_rows.clone(),
            train: Some((preset.clone(), tag.clone(), step_ms, tps)),
            decode: Some((preset.clone(), n, rows.clone())),
            fig3: None,
            crossover: None,
            zero: None,
            serve: None,
            fault: None,
            history: Some(history),
        };
        std::fs::write(path, report.to_json()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    if let Some(floor_path) = args.flags.get("floor") {
        let text = std::fs::read_to_string(floor_path)
            .with_context(|| format!("reading floor file {floor_path}"))?;
        check_gemm_floor(&gemm_rows, &text)?;
        check_train_floor(&tag, tps, &text)?;
        check_decode_floor(&rows, &text)?;
        println!("gemm + train + decode floor check passed ({floor_path})");
    }
    Ok(())
}

fn cmd_bench_all(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let world = args.usize("world", 4)?;
    let iters = args.usize("iters", 3)?;
    let engine = Engine::load_preset(&preset)?;
    println!("# Fig. 3 — speed comparison, tokens/s (sim, 64 GPUs, Linear-Llama3-1B)\n");
    println!("{}", bench::fig3_speed(&CostModel::default()).to_markdown());
    println!(
        "# Fig. 3 companion — REAL execution ({preset}, W={world}, {} layers)\n",
        engine.model.n_layers
    );
    let (t, rows) = bench::fig3_realexec_rows(&engine, world, iters)?;
    println!("{}", t.to_markdown());
    let fig3_rows = Some((preset.clone(), world, rows));
    println!("# Fig. 4\n\n{}", bench::fig4_scalability(&CostModel::default()).to_markdown());
    cmd_table2(args)?;
    cmd_table3(args)?;
    cmd_table4(args)?;
    println!("# Table 5\n\n{}", bench::table5_splits(&CostModel::default()).to_markdown());
    println!("# Table 6\n\n{}", bench::table6_scalability(&CostModel::default()).to_markdown());
    println!("# Scheduler crossover sweep (sim; see docs/SCHEDULERS.md)\n");
    let (xtable, xrows) = bench::crossover_table(&CostModel::default());
    println!("{}", xtable.to_markdown());
    println!("# ZeRO optimizer sharding — replicated vs sharded per rank (sim, Linear-Llama3-1B @2048K)\n");
    let (ztable, zrows) = bench::zero_sharding_table(&CostModel::default());
    println!("{}", ztable.to_markdown());
    let (gt, gemm) = bench::gemm_bench();
    println!(
        "# Kernel-level GEMM throughput ({} threads)\n\n{}",
        par::num_threads(),
        gt.to_markdown()
    );
    let (tag, step_ms, tps) = bench::train_step_bench(&engine, args.usize("train-steps", 8)?)?;
    println!("train_step_{tag} ({preset}): {step_ms:.1} ms/step ({tps:.0} tokens/s)\n");
    let n = args.usize("tokens", (engine.model.max_seq / 4).max(8))?;
    println!("# Serving decode — constant-memory inference ({preset}, {n} tokens)\n");
    let (dtable, drows) = bench::decode_bench_rows(&engine, n)?;
    println!("{}", dtable.to_markdown());
    let sessions = args.usize("serve-sessions", 64)?;
    println!("# Serve loop — continuous batching ({preset}, {sessions} sessions)\n");
    let (stable, srows) = bench::serve_bench_rows(&engine, sessions, 1, 0, 8, false)?;
    println!("{}", stable.to_markdown());
    if let Some(path) = args.flags.get("json") {
        let old = std::fs::read_to_string(path).ok();
        let entry = bench::history_entry(
            &args.get("pr", "local"),
            &utc_date(),
            &history_headline(&gemm, tps, &drows),
        );
        let report = bench::KernelsReport {
            source: "lasp2 bench-all".into(),
            threads: par::num_threads(),
            isa: gemm::isa_name().into(),
            gemm,
            train: Some((preset.clone(), tag, step_ms, tps)),
            decode: Some((preset.clone(), n, drows)),
            fig3: fig3_rows,
            crossover: Some(xrows),
            zero: Some(zrows),
            serve: Some((preset, sessions, srows)),
            fault: None,
            history: Some(bench::append_history(old.as_deref(), &entry)),
        };
        std::fs::write(path, report.to_json()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `lasp2 chaos`: replay the seeded fault scenarios end to end — a rank
/// crash with elastic resume, transient message loss/corruption, a
/// straggler rank, and a poison serve request — and report recovery-time
/// and steps-lost metrics.  Every scenario also ASSERTS its recovery
/// guarantee (bit-identical results), so this doubles as the CI chaos
/// smoke.  `--json` splices a `"fault"` section into an existing
/// BENCH_kernels.json without touching the other sections.
fn cmd_chaos(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let steps = args.usize("steps", 8)?;
    anyhow::ensure!(steps >= 4, "chaos needs --steps >= 4 (the crash lands at steps-3)");
    let seed = args.usize("seed", 0)? as u64;
    let engine = Engine::load_preset(&preset)?;
    let pattern = Pattern::from_ratio(engine.model.n_layers, "0")?;
    let tag = format!("{}_{}", Variant::Basic.name(), Pattern::tag("0"));
    println!("# Chaos — seeded fault injection ({preset}, {steps} steps, seed {seed})\n");
    let mut rows: Vec<bench::FaultRow> = Vec::new();

    // 1. rank crash mid-run: W=4 loses rank 3, rolls back to the last
    // snapshot, resumes at W=2 — the loss curve must match the clean run
    let base = TrainOpts { steps, seed, world: 4, log_every: 0, ..Default::default() };
    let clean = train(&engine, Variant::Basic, &pattern, &tag, &base)?;
    let save_every = 2;
    let crash_step = steps - 3;
    let crash_op = fault_op_for_step(0, crash_step, save_every, steps);
    let ckpt = std::env::temp_dir().join("lasp2_chaos.ckpt");
    let ckpt = ckpt.to_string_lossy().into_owned();
    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_file(format!("{ckpt}.prev"));
    let rep = train(
        &engine,
        Variant::Basic,
        &pattern,
        &tag,
        &TrainOpts {
            save: Some(ckpt.clone()),
            save_every,
            faults: Some(Arc::new(FaultPlan::new().crash(3, crash_op))),
            ..base.clone()
        },
    )?;
    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_file(format!("{ckpt}.prev"));
    let bitwise = rep.losses.len() == clean.losses.len()
        && rep.losses.iter().zip(&clean.losses).all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "crash_w4_elastic_resume: rank 3 died at step {crash_step} (op {crash_op}); \
         W 4 -> {}, {} recovery(ies), {} step(s) re-run, {:.1} ms recovering; \
         loss curve bit-identical to the clean run: {bitwise}",
        rep.world, rep.recoveries, rep.steps_lost, rep.recovery_ms
    );
    anyhow::ensure!(
        rep.recoveries == 1 && rep.world == 2,
        "chaos: expected one recovery shrinking W=4 to W=2, got {} at W={}",
        rep.recoveries,
        rep.world
    );
    anyhow::ensure!(bitwise, "chaos: recovered loss curve diverged from the clean run");
    rows.push(bench::FaultRow {
        scenario: "crash_w4_elastic_resume".into(),
        world_before: 4,
        world_after: rep.world,
        recoveries: rep.recoveries,
        steps_lost: rep.steps_lost,
        recovery_ms: rep.recovery_ms,
        deterministic: bitwise,
    });

    // 2. transient loss + corruption: the sealed checksum catches the bit
    // flip, bounded-backoff retries deliver the true bytes — results are
    // bit-exact everywhere, never silently wrong
    let plan = Arc::new(
        FaultPlan::new().corrupt(1, 0, 0, 2).drop_msg(2, 0, 3, 1).with_retry(4, 50),
    );
    let world = World::new(4);
    world.install_faults(plan.clone());
    let t0 = std::time::Instant::now();
    let per_rank = world.run_catch(|c| {
        c.all_gather(vec![Tensor::randn(&[64], seed * 31 + 1000 + c.rank() as u64)])
    });
    let retry_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut exact = true;
    for (r, res) in per_rank.into_iter().enumerate() {
        let gathered = match res {
            Ok(Ok(g)) => g,
            Ok(Err(e)) => bail!("chaos transient scenario: rank {r}: {e}"),
            Err(p) => bail!("chaos transient scenario: rank {r} panicked: {}", p.message),
        };
        for (src, m) in gathered.iter().enumerate() {
            exact &= m[0] == Tensor::randn(&[64], seed * 31 + 1000 + src as u64);
        }
    }
    println!(
        "transient_corrupt_drop: {} event(s) injected, {} retry(ies), {retry_ms:.1} ms; \
         gathered payloads bit-exact on every rank: {exact}",
        plan.injected(),
        plan.retries()
    );
    anyhow::ensure!(
        exact && plan.injected() >= 2,
        "chaos: transient faults did not inject and recover bit-exactly"
    );
    rows.push(bench::FaultRow {
        scenario: "transient_corrupt_drop".into(),
        world_before: 4,
        world_after: 4,
        recoveries: plan.retries() as usize,
        steps_lost: 0,
        recovery_ms: retry_ms,
        deterministic: exact,
    });

    // 3. straggler: one rank sleeps 25 ms entering the collective; the
    // two-barrier generation fence keeps the gather bit-identical and
    // rank-ordered on every rank
    let plan = Arc::new(FaultPlan::new().delay(2, 0, 25_000));
    let world = World::new(4);
    world.install_faults(plan.clone());
    let t0 = std::time::Instant::now();
    let per_rank = world.run_catch(|c| {
        c.all_gather(vec![Tensor::randn(&[32], seed * 17 + 7 + c.rank() as u64)])
    });
    let delay_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut fenced = true;
    for (r, res) in per_rank.into_iter().enumerate() {
        let gathered = match res {
            Ok(Ok(g)) => g,
            Ok(Err(e)) => bail!("chaos straggler scenario: rank {r}: {e}"),
            Err(p) => bail!("chaos straggler scenario: rank {r} panicked: {}", p.message),
        };
        for (src, m) in gathered.iter().enumerate() {
            fenced &= m[0] == Tensor::randn(&[32], seed * 17 + 7 + src as u64);
        }
    }
    println!(
        "straggler_fence: {} delay(s) injected, {delay_ms:.1} ms wall; \
         gather bit-identical and rank-ordered under the straggler: {fenced}",
        plan.injected()
    );
    anyhow::ensure!(
        fenced && plan.injected() == 1,
        "chaos: straggler delay perturbed the fenced collective"
    );
    rows.push(bench::FaultRow {
        scenario: "straggler_fence".into(),
        world_before: 4,
        world_after: 4,
        recoveries: 0,
        steps_lost: 0,
        recovery_ms: delay_ms,
        deterministic: fenced,
    });

    // 4. poison serve request: a generation budget that overruns the
    // context window fails ALONE; the survivors' digest is unchanged
    let model = Model::load(&preset, Variant::Basic, "0", 1)?;
    model.warmup_serving()?;
    let window = model.config().max_seq;
    let prompts: Vec<Vec<i32>> = (0..3)
        .map(|k| (0..40).map(|i| ((i * 7 + k * 13 + 5) % 256) as i32).collect())
        .collect();
    let run_trace = |poison: bool| -> Result<ServeSummary> {
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        for (k, p) in prompts.iter().enumerate() {
            sl.enqueue(Request {
                id: k as u64,
                arrival_tick: k as u64,
                prompt: p.clone(),
                prefix_len: 0,
                max_new: 6,
                deadline_tick: k as u64 + 64,
            });
        }
        if poison {
            // prompt fills the window exactly: decode has nowhere to go
            sl.enqueue(Request {
                id: 9,
                arrival_tick: 0,
                prompt: vec![3; window],
                prefix_len: 0,
                max_new: 4,
                deadline_tick: 64,
            });
        }
        sl.run()
    };
    let clean_sum = run_trace(false)?;
    let t0 = std::time::Instant::now();
    let sum = run_trace(true)?;
    let serve_ms = t0.elapsed().as_secs_f64() * 1e3;
    let survived = sum.sessions == 3
        && sum.failed_requests == 1
        && sum.output_digest == clean_sum.output_digest;
    println!(
        "serve_poison_request: {} survivor(s) finished, {} failed, {serve_ms:.1} ms; \
         survivor digest matches the clean run: {survived}",
        sum.sessions, sum.failed_requests
    );
    anyhow::ensure!(survived, "chaos: poison serve request perturbed the survivors");
    rows.push(bench::FaultRow {
        scenario: "serve_poison_request".into(),
        world_before: 1,
        world_after: 1,
        recoveries: 0,
        steps_lost: 0,
        recovery_ms: serve_ms,
        deterministic: survived,
    });

    if let Some(path) = args.flags.get("json") {
        let frag = bench::fault_fragment(&rows);
        let doc = match std::fs::read_to_string(path) {
            Ok(existing) => bench::splice_section(&existing, "fault", &frag)
                .with_context(|| format!("splicing fault section into {path}"))?,
            Err(_) => bench::KernelsReport {
                source: "lasp2 chaos".into(),
                threads: par::num_threads(),
                isa: gemm::isa_name().into(),
                gemm: Vec::new(),
                train: None,
                decode: None,
                fig3: None,
                crossover: None,
                zero: None,
                serve: None,
                fault: Some(rows),
                history: None,
            }
            .to_json(),
        };
        std::fs::write(path, doc).with_context(|| format!("writing {path}"))?;
        println!("wrote fault section to {path}");
    }
    println!("\nall chaos scenarios recovered with bit-identical results");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let world_size = args.usize("world", 4)?;
    let scheduler = Scheduler::parse(&args.get("scheduler", "lasp2"))?;
    let variant = Variant::parse(&args.get("variant", "basic"))?;
    let splits = args.usize("splits", 1)?;
    let cols = args.usize("usp-cols", 2)?;
    let strict = args.get("strict", "false") == "true";
    let engine = Engine::load_preset(&preset)?;
    let cfg = engine.model.clone();
    let pattern = Pattern("L".repeat(cfg.n_layers));
    let run = RunConfig {
        world: world_size,
        scheduler,
        variant,
        pattern: pattern.clone(),
        gather_splits: splits,
        usp_cols: cols,
        seed: 0,
    };
    let params = Params::randn(&cfg, variant, &pattern, 42);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();
    println!(
        "preset={preset} world={world_size} scheduler={scheduler} variant={variant} N={n}"
    );
    // usp2d needs a 2D mesh world; every flat scheduler gets a plain one
    let world = World::for_run(&run);
    let t0 = std::time::Instant::now();
    let logits = forward_distributed(&engine, &world, &run, &params, &tokens, true)?;
    let dt = t0.elapsed().as_secs_f64();
    let snap = world.counters();
    println!(
        "forward: {:.1} ms, {:.0} tokens/s | collectives={} p2p={} bytes={}",
        dt * 1e3,
        n as f64 / dt,
        snap.collective_ops,
        snap.p2p_ops,
        snap.bytes,
    );
    // verify against the monolithic oracle if it was compiled
    let mono_name = format!("forward_mono_{}_pure_N{}", variant.name(), n);
    if engine.has_artifact(&mono_name) {
        let want = forward_mono(&engine, &mono_name, &params, &tokens)?;
        let err = logits.max_rel_err(&want);
        println!("verified vs {mono_name}: max rel err {err:.2e}");
        anyhow::ensure!(err < 2e-3, "mismatch vs monolithic oracle");
    } else if strict {
        bail!(
            "--strict: verification oracle artifact {mono_name} is missing \
             for preset {preset}; refusing to report an unverified run"
        );
    } else {
        println!("(no {mono_name} artifact; skipping verification)");
    }
    if args.is_set("profile") {
        print_profile(&engine);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.get("preset", "tiny");
    let variant = Variant::parse(&args.get("variant", "basic"))?;
    let ratio = args.get("ratio", "0");
    let mlm = args.get("mlm", "false") == "true";
    let engine = Engine::load_preset(&preset)?;
    let pattern = Pattern::from_ratio(engine.model.n_layers, &ratio)?;
    let tag = format!(
        "{}_{}{}",
        variant.name(),
        Pattern::tag(&ratio),
        if mlm { "_nm" } else { "" }
    );
    let opts = TrainOpts {
        steps: args.usize("steps", 50)?,
        peak_lr: args.get("lr", "3e-3").parse()?,
        mlm,
        csv: args.flags.get("csv").cloned(),
        seed: args.usize("seed", 0)? as u64,
        world: args.usize("world", 1)?,
        resume: args.flags.get("resume").cloned(),
        save: args.flags.get("save").cloned(),
        save_every: args.usize("save-every", 0)?,
        halt_after: args.usize("halt-after", 0)?,
        ..Default::default()
    };
    let rep = train(&engine, variant, &pattern, &tag, &opts)?;
    println!(
        "trained {tag}: {} params, steps {}..{} of {}, final loss {:.4}, tail loss {:.4}, {:.0} tokens/s",
        rep.params,
        rep.start_step,
        rep.start_step + rep.losses.len(),
        rep.steps,
        rep.final_loss,
        rep.tail_loss,
        rep.tokens_per_sec
    );
    if rep.world > 1 {
        println!(
            "zero-sharding (W={}): opt state {} B/rank vs {} B replicated, \
             {} wire bytes over {} collectives",
            rep.world,
            rep.opt_bytes_per_rank,
            rep.opt_bytes_replicated,
            rep.wire_bytes,
            rep.collective_ops
        );
    }
    if args.is_set("profile") {
        print_profile(&engine);
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    println!("# Fig. 3 — speed comparison, tokens/s (sim, 64 GPUs, Linear-Llama3-1B)\n");
    println!("{}", bench::fig3_speed(&CostModel::default()).to_markdown());
    let preset = args.get("preset", "tiny");
    let world = args.usize("world", 4)?;
    if let Ok(engine) = Engine::load_preset(&preset) {
        println!(
            "# Fig. 3 companion — REAL execution ({preset}, W={world}, {} layers)\n",
            engine.model.n_layers
        );
        let iters = args.usize("iters", 3)?;
        println!("{}", bench::fig3_realexec(&engine, world, iters)?.to_markdown());
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let preset = args.get("preset", "small");
    let steps = args.usize("steps", 40)?;
    let engine = Engine::load_preset(&preset)?;
    println!("# Table 2 — convergence ({preset}, {steps} steps, synthetic corpus)\n");
    println!("{}", bench::table2_convergence(&engine, steps)?.to_markdown());
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let preset = args.get("preset", "small");
    let steps = args.usize("steps", 40)?;
    let engine = Engine::load_preset(&preset)?;
    println!("# Table 3 — bidirectional LM ({preset}, {steps} steps)\n");
    println!("{}", bench::table3_bidirectional(&engine, steps)?.to_markdown());
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    let preset = args.get("preset", "small");
    let steps = args.usize("steps", 40)?;
    let engine = Engine::load_preset(&preset)?;
    println!("# Table 4 — hybrid-ratio ablation ({preset}, {steps} steps)\n");
    println!("{}", bench::table4_hybrid_ratio(&engine, steps)?.to_markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn args_space_separated_and_bare_flags() {
        let a = parse(&["--preset", "small", "--strict", "--world", "2"]);
        assert_eq!(a.get("preset", "tiny"), "small");
        assert_eq!(a.usize("world", 4).unwrap(), 2);
        assert!(a.is_set("strict"));
        assert!(!a.is_set("profile"));
    }

    #[test]
    fn args_key_equals_value() {
        let a = parse(&["--preset=small", "--lr=3e-4", "--world=8", "--profile"]);
        assert_eq!(a.get("preset", "tiny"), "small");
        assert_eq!(a.get("lr", "0"), "3e-4");
        assert_eq!(a.usize("world", 4).unwrap(), 8);
        assert!(a.is_set("profile"));
    }

    #[test]
    fn args_equals_value_may_contain_equals_and_mixes_with_space_form() {
        let a = parse(&["--csv=run=1.csv", "--steps", "10", "--ratio=1/2"]);
        assert_eq!(a.get("csv", ""), "run=1.csv");
        assert_eq!(a.usize("steps", 0).unwrap(), 10);
        assert_eq!(a.get("ratio", "0"), "1/2");
    }

    #[test]
    fn args_empty_equals_value_is_empty_string_not_true() {
        let a = parse(&["--prompt=", "--tokens=4"]);
        assert_eq!(a.get("prompt", "x"), "");
        assert_eq!(a.usize("tokens", 0).unwrap(), 4);
    }

    #[test]
    fn floor_lookup_and_regression_check() {
        let text = r#"{"floors": {"basic_pure": 300.0, "softmax_std": 100}}"#;
        assert_eq!(super::json_lookup_f64(text, "basic_pure"), Some(300.0));
        assert_eq!(super::json_lookup_f64(text, "softmax_std"), Some(100.0));
        assert_eq!(super::json_lookup_f64(text, "missing"), None);
        let row = |tps: f64| lasp2::bench::DecodeRow {
            tag: "basic_pure".into(),
            pattern: "LL".into(),
            tokens_per_sec: tps,
            state_bytes: [0; 3],
        };
        // 250 >= 300 * 0.7 -> within the 30% regression budget
        assert!(super::check_decode_floor(&[row(250.0)], text).is_ok());
        // 100 < 210 -> regression
        assert!(super::check_decode_floor(&[row(100.0)], text).is_err());
        // a floor file matching no rows is a configuration error
        assert!(super::check_decode_floor(&[row(250.0)], "{}").is_err());
    }

    #[test]
    fn serve_floor_check() {
        let text = r#"{"floors": {"serve_tps_basic_pure": 100.0,
                       "serve_p99ttft_ms_basic_pure": 50.0}}"#;
        let row = |tps: f64, p99: f64| lasp2::bench::ServeRow {
            tag: "basic_pure".into(),
            pattern: "LL".into(),
            sessions: 8,
            p50_ttft_ms: p99 / 2.0,
            p99_ttft_ms: p99,
            decode_tps: tps,
            sustained_tps: tps / 2.0,
            bytes_per_session: 1e4,
            sessions_per_gb: 1e5,
            cache_hits: 0,
            evictions: 0,
        };
        // 80 tok/s >= 70 and 60 ms <= 65: both inside the 30% budgets
        assert!(super::check_serve_floor(&[row(80.0, 60.0)], text).is_ok());
        // throughput regression: 50 < 100 * 0.7
        assert!(super::check_serve_floor(&[row(50.0, 60.0)], text).is_err());
        // latency regression: 70 ms > 50 * 1.3
        assert!(super::check_serve_floor(&[row(80.0, 70.0)], text).is_err());
        // a floor file matching no rows is a configuration error
        assert!(super::check_serve_floor(&[row(80.0, 60.0)], "{}").is_err());
    }

    #[test]
    fn gemm_floor_check() {
        let text = r#"{"floors": {"gemm_nn_512x256x512": 10.0}}"#;
        let row = |gflops: f64| lasp2::bench::GemmRow {
            op: "nn",
            m: 512,
            k: 256,
            n: 512,
            gflops,
        };
        // 8 >= 10 * 0.7 -> inside the 30% regression budget
        assert!(super::check_gemm_floor(&[row(8.0)], text).is_ok());
        // 6 < 7 -> regression
        assert!(super::check_gemm_floor(&[row(6.0)], text).is_err());
        // shapes without floors are skipped, but matching none is an error
        assert!(super::check_gemm_floor(&[row(8.0)], "{}").is_err());
    }

    #[test]
    fn utc_date_is_well_formed() {
        let d = super::utc_date();
        assert_eq!(d.len(), 10);
        assert_eq!(d.as_bytes()[4], b'-');
        assert_eq!(d.as_bytes()[7], b'-');
        assert!(d[..4].parse::<i64>().unwrap() >= 2024);
    }

    #[test]
    fn train_floor_check() {
        let text = r#"{"floors": {"train_step_basic_pure": 200.0, "basic_pure": 300.0}}"#;
        // the train key is the full artifact name, so it never collides
        // with the decode row of the same tag
        assert!(super::check_train_floor("basic_pure", 150.0, text).is_ok());
        assert!(super::check_train_floor("basic_pure", 120.0, text).is_err());
        assert!(super::check_train_floor("basic_pure", 1e6, "{}").is_err());
    }
}
