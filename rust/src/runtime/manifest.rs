//! Flat-text manifest parser (`artifacts/<preset>/manifest.txt`).
//!
//! Format (written by `python/compile/aot.py`):
//! ```text
//! lasp2-manifest 1
//! preset tiny
//! field d_model 64
//! artifact l_part1_basic l_part1_basic.hlo.txt
//! in x f32 32,64
//! out qt f32 32,2,32
//! end
//! ```
//! Chosen over JSON so the runtime stays std-only (the offline registry
//! carries no serde).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            _ => bail!("unknown dtype {s}"),
        })
    }
}

/// Declared tensor signature.  A `shape` dim of 0 is a wildcard: the
/// runtime accepts any extent there (used for capacity-sized KV caches
/// that grow between calls; kernels read the live extent off the input).
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ArtifactMeta {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub fields: HashMap<String, usize>,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn parse_file(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut lines = text.lines();
        match lines.next() {
            Some("lasp2-manifest 1") => {}
            other => bail!("bad manifest header {other:?}"),
        }
        let mut preset = String::new();
        let mut fields = HashMap::new();
        let mut artifacts = HashMap::new();
        let mut cur: Option<ArtifactMeta> = None;
        for (ln, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let kw = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            match kw {
                "preset" => preset = rest.first().context("preset")?.to_string(),
                "field" => {
                    let (k, v) = (rest[0], rest[1]);
                    fields.insert(k.to_string(), v.parse::<usize>()?);
                }
                "artifact" => {
                    if cur.is_some() {
                        bail!("line {ln}: nested artifact");
                    }
                    cur = Some(ArtifactMeta {
                        name: rest[0].to_string(),
                        file: rest[1].to_string(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" | "out" => {
                    let a = cur.as_mut().with_context(|| format!("line {ln}: {kw} outside artifact"))?;
                    let meta = TensorMeta {
                        name: rest[0].to_string(),
                        dtype: DType::parse(rest[1])?,
                        shape: rest[2]
                            .split(',')
                            .filter(|s| !s.is_empty())
                            .map(|s| s.parse::<usize>())
                            .collect::<std::result::Result<_, _>>()?,
                    };
                    if kw == "in" {
                        a.inputs.push(meta);
                    } else {
                        a.outputs.push(meta);
                    }
                }
                "end" => {
                    let a = cur.take().with_context(|| format!("line {ln}: end outside artifact"))?;
                    artifacts.insert(a.name.clone(), a);
                }
                other => bail!("line {ln}: unknown keyword {other}"),
            }
        }
        if cur.is_some() {
            bail!("unterminated artifact");
        }
        Ok(Manifest { preset, fields, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "lasp2-manifest 1\npreset tiny\nfield d_model 64\n\
artifact foo foo.hlo.txt\nin x f32 32,64\nin t i32 1\nout y f32 32,64\nend\n";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.fields["d_model"], 64);
        let a = &m.artifacts["foo"];
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![32, 64]);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].name, "y");
        assert_eq!(a.input_index("t"), Some(1));
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("nope\n").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Manifest::parse("lasp2-manifest 1\nartifact a b\n").is_err());
    }

    #[test]
    fn rejects_orphan_in() {
        assert!(Manifest::parse("lasp2-manifest 1\nin x f32 1\n").is_err());
    }
}
