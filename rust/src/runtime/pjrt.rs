//! PJRT execution backend (cargo feature `pjrt`): loads the AOT-compiled
//! HLO-text artifacts written by `python -m compile.aot` and executes them
//! through the PJRT C API (`xla` crate).  Python is never involved at
//! runtime.
//!
//! Wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! All computations are lowered with `return_tuple=True`, so every
//! execution returns a tuple literal that we decompose.
//!
//! NOTE: the `xla` crate is not on crates.io; enabling this feature
//! requires adding it as a path/git dependency (see DESIGN.md §Backends).

use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::{ArtifactMeta, BufferInner, Value};
use crate::tensor::Tensor;

/// The `xla` crate's PJRT handles are `Rc`-based (`!Send`/`!Sync`) and
/// `execute()` clones the client `Rc` per output buffer, so concurrent use
/// from worker threads would race on the non-atomic refcount.  We make the
/// handles shareable with an unsafe wrapper and route EVERY PJRT call
/// (compile, execute, buffer->literal, buffer drop) through one global
/// lock: all `Rc` refcount traffic is serialized, which makes the wrapper
/// sound.  XLA's CPU executor parallelizes inside a single execute call, so
/// simulated devices still use the machine's cores; the simulator (not
/// wall-clock real-exec) is what carries the paper-scale performance claims.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

struct SendWrap<T>(T);
// SAFETY: see PJRT_LOCK — all access to the wrapped values is serialized.
unsafe impl<T> Send for SendWrap<T> {}
unsafe impl<T> Sync for SendWrap<T> {}

/// A device-resident constant buffer (weights staged once; also sidesteps
/// a host-buffer leak in the C wrapper's literal-based `execute`).
/// Safety: all PJRT access is serialized by PJRT_LOCK.
pub struct DeviceBuffer {
    buf: SendWrap<xla::PjRtBuffer>,
}

/// One CPU PJRT client, shared by every executable of an engine.
pub struct Client {
    client: SendWrap<xla::PjRtClient>,
}

impl Client {
    pub fn new() -> Result<Client> {
        let _guard = PJRT_LOCK.lock().unwrap();
        Ok(Client {
            client: SendWrap(xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?),
        })
    }

    /// Stage a constant tensor (weights) onto the device once.
    pub fn stage(&self, t: &Tensor) -> Result<DeviceBuffer> {
        let _guard = PJRT_LOCK.lock().unwrap();
        let buf = self
            .client
            .0
            .buffer_from_host_buffer(t.data(), t.shape(), None)?;
        Ok(DeviceBuffer { buf: SendWrap(buf) })
    }

    /// Compile one HLO-text artifact file.
    pub fn compile(&self, path: &Path, name: &str) -> Result<LoadedExec> {
        let _guard = PJRT_LOCK.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("bad path")?)
            .map_err(|e| anyhow!("loading {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(LoadedExec {
            exe: SendWrap(exe),
            client: SendWrap(self.client.0.clone()),
        })
    }
}

/// A compiled XLA executable plus the client handle it runs on.
pub struct LoadedExec {
    exe: SendWrap<xla::PjRtLoadedExecutable>,
    client: SendWrap<xla::PjRtClient>,
}

impl LoadedExec {
    /// Execute with positional inputs (shape checks happen in the caller).
    ///
    /// NOTE: inputs are staged as PjRtBuffers and run through `execute_b`
    /// instead of the literal-based `execute`: the C wrapper behind
    /// `execute` copies every input host->device and never frees those
    /// staging buffers (measured ~inputs-sized leak per call); with
    /// `execute_b` rust owns every buffer and drops it here.
    pub fn execute(&self, meta: &ArtifactMeta, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let parts = {
            let _guard = PJRT_LOCK.lock().unwrap();
            // stage the non-cached inputs; borrow cached weight buffers
            let owned: Vec<Option<xla::PjRtBuffer>> = inputs
                .iter()
                .map(|v| self.to_buffer(v))
                .collect::<Result<_>>()?;
            let refs: Vec<&xla::PjRtBuffer> = inputs
                .iter()
                .zip(&owned)
                .map(|(v, o)| match (v, o) {
                    (Value::Buf(c), _) => match &c.inner {
                        BufferInner::Device(d) => Ok(&d.buf.0),
                        BufferInner::Host(_) => {
                            bail!("host buffer passed to the PJRT backend")
                        }
                    },
                    (_, Some(b)) => Ok(b),
                    _ => unreachable!(),
                })
                .collect::<Result<_>>()?;
            let bufs = self.exe.0.execute_b::<&xla::PjRtBuffer>(&refs)?;
            let out = bufs[0][0].to_literal_sync()?;
            out.to_tuple()?
            // input + output device buffers drop here, still under the lock
        };
        let mut res = Vec::with_capacity(parts.len());
        for (lit, m) in parts.into_iter().zip(&meta.outputs) {
            let data: Vec<f32> = lit
                .to_vec::<f32>()
                .with_context(|| format!("{}: output {} not f32", meta.name, m.name))?;
            res.push(Tensor::new(m.shape.clone(), data));
        }
        Ok(res)
    }

    /// Stage one input onto the device unless already cached
    /// (must hold PJRT_LOCK).
    fn to_buffer(&self, v: &Value) -> Result<Option<xla::PjRtBuffer>> {
        Ok(match v {
            Value::F32(t) => Some(
                self.client
                    .0
                    .buffer_from_host_buffer(t.data(), t.shape(), None)?,
            ),
            Value::I32(vals, shape) => {
                Some(self.client.0.buffer_from_host_buffer(vals, shape, None)?)
            }
            Value::Buf(_) => None,
        })
    }
}
