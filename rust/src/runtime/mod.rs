//! Execution runtime: pluggable backends behind one `Engine`/artifact API.
//!
//! Every scheduler/pipeline/train call site asks the engine for a named
//! artifact (`l_part1_basic`, `s_part2_T4`, `train_step_basic_pure`, ...)
//! and executes it positionally.  Two backends provide those artifacts:
//!
//! * **native** (default, `runtime/native.rs`) — every artifact implemented
//!   in pure rust on the coordinator `Tensor`, with shapes derived from the
//!   built-in `ModelConfig` presets.  Hermetic: no python, no XLA, no
//!   artifact files.  This is what `cargo test` exercises.
//! * **pjrt** (cargo feature `pjrt`, `runtime/pjrt.rs`) — loads the
//!   AOT-compiled HLO-text artifacts produced by `python -m compile.aot`
//!   and executes them through the PJRT C API (`xla` crate).  Selected
//!   automatically when `artifacts/<preset>/manifest.txt` exists.
//!
//! See DESIGN.md §Backends for the feature matrix.

mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{ArtifactMeta, DType, Manifest, TensorMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::Tensor;

/// A constant input (weights) staged once and reused across calls.  On the
/// native backend this is simply a host tensor; on PJRT it is a
/// device-resident buffer (the serving-style "weights live on the device"
/// optimization).
pub struct CachedBuffer {
    shape: Vec<usize>,
    inner: BufferInner,
}

enum BufferInner {
    Host(Tensor),
    #[cfg(feature = "pjrt")]
    Device(pjrt::DeviceBuffer),
}

impl std::fmt::Debug for CachedBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CachedBuffer{:?}", self.shape)
    }
}

/// A runtime input value: f32 tensor, i32 tensor (token ids, offsets), or
/// a pre-staged constant buffer (weights).
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
    Buf(Arc<CachedBuffer>),
}

impl Value {
    pub fn i32_scalar(v: i32) -> Value {
        Value::I32(vec![v], vec![1])
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(_, s) => s,
            Value::Buf(c) => &c.shape,
        }
    }

    /// Borrow as a host-resident f32 tensor (native-backend execution).
    pub(crate) fn host_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::Buf(b) => match &b.inner {
                BufferInner::Host(t) => Ok(t),
                #[cfg(feature = "pjrt")]
                BufferInner::Device(_) => {
                    bail!("device buffer passed to the native backend")
                }
            },
            Value::I32(..) => bail!("expected f32, got i32"),
        }
    }

    /// Borrow as host i32 data (native-backend execution).
    pub(crate) fn host_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v, _) => Ok(v),
            _ => bail!("expected i32 value"),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// One executable artifact: the manifest signature plus a backend kernel.
pub struct Executable {
    pub meta: ArtifactMeta,
    kind: ExecKind,
    /// cumulative execution stats (hot-path profiling)
    pub stats: Mutex<ExecStats>,
}

enum ExecKind {
    Native { model: ModelConfig, f: native::KernelFn },
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::LoadedExec),
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub nanos: u64,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple
    /// as f32 tensors (integer outputs are not used by any artifact).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        for (v, m) in inputs.iter().zip(&self.meta.inputs) {
            // a manifest dim of 0 is a wildcard: the artifact accepts any
            // extent there (capacity-sized KV caches grow between calls)
            let vs = v.shape();
            let ok = vs.len() == m.shape.len()
                && vs.iter().zip(&m.shape).all(|(&a, &b)| b == 0 || a == b);
            if !ok {
                bail!(
                    "{}: input {} shape {:?} != manifest {:?}",
                    self.meta.name,
                    m.name,
                    vs,
                    m.shape
                );
            }
        }
        let parts = match &self.kind {
            ExecKind::Native { model, f } => f.as_ref()(model, inputs)
                .with_context(|| format!("native kernel {}", self.meta.name))?,
            #[cfg(feature = "pjrt")]
            ExecKind::Pjrt(exe) => exe.execute(&self.meta, inputs)?,
        };
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        for (t, m) in parts.iter().zip(&self.meta.outputs) {
            if t.shape() != m.shape.as_slice() {
                bail!(
                    "{}: output {} shape {:?} != manifest {:?}",
                    self.meta.name,
                    m.name,
                    t.shape(),
                    m.shape
                );
            }
        }
        let dt = t0.elapsed().as_nanos() as u64;
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.nanos += dt;
        Ok(parts)
    }

    /// Single-output convenience.
    pub fn run1(&self, inputs: &[Value]) -> Result<Tensor> {
        let mut out = self.run(inputs)?;
        if out.len() != 1 {
            bail!("{}: expected 1 output, got {}", self.meta.name, out.len());
        }
        Ok(out.pop().unwrap())
    }
}

enum Backend {
    Native(native::Registry),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::Client),
}

/// Per-artifact cache slot: the outer map lock is only held long enough
/// to fetch/create the slot; instantiation happens under the slot's own
/// lock, so racing threads on the SAME name do the work exactly once
/// while lookups of other (cached or compiling) artifacts never block.
type CacheSlot = Arc<Mutex<Option<Arc<Executable>>>>;

/// The engine: a preset's artifact registry plus the executable cache.
/// Shared (`Arc`) by all worker threads.
pub struct Engine {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub model: ModelConfig,
    backend: Backend,
    cache: Mutex<HashMap<String, CacheSlot>>,
}

impl Engine {
    /// Load a preset from `artifacts/<preset>/` when PJRT artifacts exist
    /// there (and the `pjrt` feature is on); otherwise fall back to the
    /// native backend driven by the built-in preset shapes.
    pub fn load(artifacts_root: &Path, preset: &str) -> Result<Arc<Engine>> {
        let dir = artifacts_root.join(preset);
        #[cfg(feature = "pjrt")]
        if dir.join("manifest.txt").exists() {
            return Self::load_pjrt(dir);
        }
        Self::native(preset, dir)
    }

    /// Default artifacts root: $LASP2_ARTIFACTS or ./artifacts.
    pub fn load_preset(preset: &str) -> Result<Arc<Engine>> {
        let root = std::env::var("LASP2_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&root), preset)
    }

    /// Construct the pure-rust native backend for a built-in preset.
    pub fn native(preset: &str, dir: PathBuf) -> Result<Arc<Engine>> {
        let model = ModelConfig::preset(preset)
            .with_context(|| format!("native backend for preset {preset}"))?;
        let registry = native::Registry::build(&model);
        let manifest = registry.manifest(&model);
        Ok(Arc::new(Engine {
            dir,
            manifest,
            model,
            backend: Backend::Native(registry),
            cache: Mutex::new(HashMap::new()),
        }))
    }

    #[cfg(feature = "pjrt")]
    fn load_pjrt(dir: PathBuf) -> Result<Arc<Engine>> {
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))?;
        let model = ModelConfig::from_fields(&manifest.preset, &manifest.fields)?;
        let client = pjrt::Client::new()?;
        Ok(Arc::new(Engine {
            dir,
            manifest,
            model,
            backend: Backend::Pjrt(client),
            cache: Mutex::new(HashMap::new()),
        }))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Stage a constant tensor (weights) once for reuse across calls.
    pub fn cache_buffer(&self, t: &Tensor) -> Result<Arc<CachedBuffer>> {
        let inner = match &self.backend {
            Backend::Native(_) => BufferInner::Host(t.clone()),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(client) => BufferInner::Device(client.stage(t)?),
        };
        Ok(Arc::new(CachedBuffer { shape: t.shape().to_vec(), inner }))
    }

    /// Get (instantiate-on-first-use) an executable by artifact name.
    ///
    /// Instantiation happens under a per-name slot lock (see `CacheSlot`):
    /// two threads racing on the same uncached artifact compile it exactly
    /// once (the loser blocks on the slot, then reads the winner's entry),
    /// while artifacts with other names — cached or mid-compile — are
    /// never blocked.  A failed instantiation leaves the slot empty, so a
    /// later call retries cleanly.
    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        let slot: CacheSlot = self
            .cache
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone();
        let mut slot = slot.lock().unwrap();
        if let Some(e) = &*slot {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))?
            .clone();
        let t0 = Instant::now();
        let kind = match &self.backend {
            Backend::Native(reg) => ExecKind::Native {
                model: self.model.clone(),
                f: reg.kernel(name)?,
            },
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(client) => {
                ExecKind::Pjrt(client.compile(&self.dir.join(&meta.file), name)?)
            }
        };
        let exec = Arc::new(Executable {
            meta,
            kind,
            stats: Mutex::new(ExecStats::default()),
        });
        *slot = Some(exec.clone());
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            eprintln!("[runtime] compiled {name} in {:.2}s", dt.as_secs_f64());
        }
        Ok(exec)
    }

    /// Pre-instantiate a set of artifacts (avoids first-call jitter in
    /// benches; a no-op cost on the native backend).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.artifact(n)?;
        }
        Ok(())
    }

    /// Snapshot of per-artifact execution stats, sorted by total time.
    pub fn stats_report(&self) -> Vec<(String, ExecStats)> {
        let slots: Vec<(String, CacheSlot)> = {
            let cache = self.cache.lock().unwrap();
            cache.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut rows: Vec<(String, ExecStats)> = slots
            .into_iter()
            .filter_map(|(k, slot)| {
                let guard = slot.lock().unwrap();
                guard.as_ref().map(|e| (k, *e.stats.lock().unwrap()))
            })
            .collect();
        rows.sort_by_key(|(_, s)| std::cmp::Reverse(s.nanos));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Arc<Engine> {
        Engine::load_preset("tiny").expect("native tiny preset")
    }

    #[test]
    fn manifest_loads_and_has_core_artifacts() {
        let e = engine();
        for a in [
            "embed",
            "head",
            "l_part1_basic",
            "l_part2_basic",
            "l_intra_basic",
            "l_part2b_basic",
            "l_bwd1_basic",
            "l_bwd2_basic",
            "s_part1",
            "ring_step",
            "ring_linear_step",
            "train_step_basic_pure",
        ] {
            assert!(e.has_artifact(a), "{a}");
        }
        assert_eq!(e.model.d_model, 64);
        assert_eq!(e.model.chunk_len, 32);
    }

    #[test]
    fn execute_embed_shapes() {
        let e = engine();
        let m = &e.model;
        let emb = Tensor::randn(&[m.vocab, m.d_model], 1);
        let pos = Tensor::randn(&[m.max_seq, m.d_model], 2);
        let tokens: Vec<i32> = (0..m.chunk_len as i32).collect();
        let exe = e.artifact("embed").unwrap();
        let out = exe
            .run(&[
                Value::I32(tokens, vec![m.chunk_len]),
                Value::i32_scalar(0),
                emb.clone().into(),
                pos.clone().into(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[m.chunk_len, m.d_model]);
        // embed(tokens, 0) = emb[tokens] + pos[0..C]
        let want0 = emb.data()[0] + pos.data()[0];
        assert!((out[0].data()[0] - want0).abs() < 1e-6);
    }

    #[test]
    fn artifact_is_a_single_shared_instance_across_threads() {
        // all racers must observe the SAME executable (the per-name slot
        // lock makes the instantiation happen exactly once)
        let e = engine();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let e = e.clone();
                std::thread::spawn(move || e.artifact("head").unwrap())
            })
            .collect();
        let execs: Vec<Arc<Executable>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for other in &execs[1..] {
            assert!(Arc::ptr_eq(&execs[0], other));
        }
    }

    #[test]
    fn input_shape_mismatch_is_error() {
        let e = engine();
        let exe = e.artifact("head").unwrap();
        let bad = Tensor::zeros(&[1, 1]);
        assert!(exe.run(&[bad.into()]).is_err());
    }

    #[test]
    fn cached_buffer_round_trips_through_artifacts() {
        // weights staged via cache_buffer must behave exactly like F32 values
        let e = engine();
        let m = &e.model;
        let x = Tensor::randn(&[m.chunk_len, m.d_model], 3);
        let ln = Tensor::ones(&[m.d_model]);
        let exe = e.artifact("head").unwrap();
        let emb = Tensor::randn(&[m.vocab, m.d_model], 4).scale(0.1);
        let a = exe
            .run(&[x.clone().into(), ln.clone().into(), emb.clone().into()])
            .unwrap();
        let cached = e.cache_buffer(&emb).unwrap();
        let b = exe
            .run(&[x.into(), ln.into(), Value::Buf(cached)])
            .unwrap();
        assert!(a[0].allclose(&b[0], 1e-7));
    }

    #[test]
    fn chunk_state_matches_rust_math() {
        // l_part1_basic's m output must equal K~^T V computed in rust.
        let e = engine();
        let m = &e.model;
        let exe = e.artifact("l_part1_basic").unwrap();
        let x = Tensor::randn(&[m.chunk_len, m.d_model], 3);
        let ln1 = Tensor::ones(&[m.d_model]);
        let wq = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 4).scale(0.1);
        let wk = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 5).scale(0.1);
        let wv = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 6).scale(0.1);
        let out = exe
            .run(&[
                x.into(),
                ln1.into(),
                wq.into(),
                wk.into(),
                wv.into(),
            ])
            .unwrap();
        let (qt, kt, v, mstate, a) = (&out[0], &out[1], &out[2], &out[3], &out[4]);
        assert_eq!(qt.shape(), &[m.chunk_len, m.n_heads, m.head_dim]);
        assert_eq!(mstate.shape(), &[m.n_heads, m.head_dim, m.head_dim]);
        // a == 1 for basic
        assert!(a.allclose(&Tensor::ones(a.shape()), 1e-6));
        // recompute M per head in rust: M_h = K_h^T V_h
        let c = m.chunk_len;
        let (hh, dh) = (m.n_heads, m.head_dim);
        for h in 0..hh {
            let mut kh = Vec::with_capacity(c * dh);
            let mut vh = Vec::with_capacity(c * dh);
            for i in 0..c {
                kh.extend_from_slice(&kt.data()[(i * hh + h) * dh..(i * hh + h + 1) * dh]);
                vh.extend_from_slice(&v.data()[(i * hh + h) * dh..(i * hh + h + 1) * dh]);
            }
            let kh = Tensor::new(vec![c, dh], kh);
            let vh = Tensor::new(vec![c, dh], vh);
            let want = kh.t().matmul(&vh);
            let got = Tensor::new(
                vec![dh, dh],
                mstate.data()[h * dh * dh..(h + 1) * dh * dh].to_vec(),
            );
            assert!(got.allclose(&want, 1e-4), "head {h}");
        }
    }
}
