//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them from the rust hot path.  Python is never involved at runtime.
//!
//! Wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! All computations are lowered with `return_tuple=True`, so every
//! execution returns a tuple literal that we decompose.

mod manifest;

pub use manifest::{ArtifactMeta, DType, Manifest, TensorMeta};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::Tensor;

/// The `xla` crate's PJRT handles are `Rc`-based (`!Send`/`!Sync`) and
/// `execute()` clones the client `Rc` per output buffer, so concurrent use
/// from worker threads would race on the non-atomic refcount.  We make the
/// handles shareable with an unsafe wrapper and route EVERY PJRT call
/// (compile, execute, buffer->literal, buffer drop) through one global
/// lock: all `Rc` refcount traffic is serialized, which makes the wrapper
/// sound.  XLA's CPU executor parallelizes inside a single execute call, so
/// simulated devices still use the machine's cores; the simulator (not
/// wall-clock real-exec) is what carries the paper-scale performance claims.
static PJRT_LOCK: Mutex<()> = Mutex::new(());

struct SendWrap<T>(T);
// SAFETY: see PJRT_LOCK — all access to the wrapped values is serialized.
unsafe impl<T> Send for SendWrap<T> {}
unsafe impl<T> Sync for SendWrap<T> {}

/// A device-resident input buffer staged once and reused across calls (for
/// constant parameters — weights — the serving-style "weights live on the
/// device" optimization; also sidesteps a host-buffer leak in the C
/// wrapper's literal-based `execute`, see Executable::run).
/// Safety: all PJRT access is serialized by PJRT_LOCK.
pub struct CachedBuffer {
    buf: SendWrap<xla::PjRtBuffer>,
    shape: Vec<usize>,
}

impl std::fmt::Debug for CachedBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CachedBuffer{:?}", self.shape)
    }
}

/// A runtime input value: f32 tensor, i32 tensor (token ids, offsets), or
/// a pre-staged device buffer (constant weights).
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
    Buf(Arc<CachedBuffer>),
}

impl Value {
    pub fn i32_scalar(v: i32) -> Value {
        Value::I32(vec![v], vec![1])
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(_, s) => s,
            Value::Buf(c) => &c.shape,
        }
    }

    /// Stage onto the device unless already cached (must hold PJRT_LOCK).
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<Option<xla::PjRtBuffer>> {
        let buf = match self {
            Value::F32(t) => {
                Some(client.buffer_from_host_buffer(t.data(), t.shape(), None)?)
            }
            Value::I32(v, shape) => {
                Some(client.buffer_from_host_buffer(v, shape, None)?)
            }
            Value::Buf(_) => None,
        };
        Ok(buf)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// One compiled artifact (an XLA executable plus its manifest signature).
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: SendWrap<xla::PjRtLoadedExecutable>,
    client: SendWrap<xla::PjRtClient>,
    /// cumulative execution stats (hot-path profiling)
    pub stats: Mutex<ExecStats>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub nanos: u64,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple
    /// as f32 tensors (integer outputs are not used by any artifact).
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        for (v, m) in inputs.iter().zip(&self.meta.inputs) {
            if v.shape() != m.shape.as_slice() {
                bail!(
                    "{}: input {} shape {:?} != manifest {:?}",
                    self.meta.name,
                    m.name,
                    v.shape(),
                    m.shape
                );
            }
        }
        // All PJRT interaction happens under the global lock (see PJRT_LOCK).
        //
        // NOTE: we stage inputs as PjRtBuffers ourselves and call
        // `execute_b` instead of the literal-based `execute`: the C wrapper
        // behind `execute` copies every input host->device and never frees
        // those staging buffers (measured ~inputs-sized leak per call);
        // with `execute_b` rust owns every buffer and drops it here.
        let parts = {
            let _guard = PJRT_LOCK.lock().unwrap();
            // stage the non-cached inputs; borrow cached weight buffers
            let owned: Vec<Option<xla::PjRtBuffer>> = inputs
                .iter()
                .map(|v| v.to_buffer(&self.client.0))
                .collect::<Result<_>>()?;
            let refs: Vec<&xla::PjRtBuffer> = inputs
                .iter()
                .zip(&owned)
                .map(|(v, o)| match (v, o) {
                    (Value::Buf(c), _) => &c.buf.0,
                    (_, Some(b)) => b,
                    _ => unreachable!(),
                })
                .collect();
            let bufs = self.exe.0.execute_b::<&xla::PjRtBuffer>(&refs)?;
            let out = bufs[0][0].to_literal_sync()?;
            out.to_tuple()?
            // input + output device buffers drop here, still under the lock
        };
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        let mut res = Vec::with_capacity(parts.len());
        for (lit, m) in parts.into_iter().zip(&self.meta.outputs) {
            let data: Vec<f32> = lit.to_vec::<f32>().with_context(|| {
                format!("{}: output {} not f32", self.meta.name, m.name)
            })?;
            res.push(Tensor::new(m.shape.clone(), data));
        }
        let dt = t0.elapsed().as_nanos() as u64;
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.nanos += dt;
        Ok(res)
    }

    /// Single-output convenience.
    pub fn run1(&self, inputs: &[Value]) -> Result<Tensor> {
        let mut out = self.run(inputs)?;
        if out.len() != 1 {
            bail!("{}: expected 1 output, got {}", self.meta.name, out.len());
        }
        Ok(out.pop().unwrap())
    }
}

/// The PJRT engine: one CPU client + the compiled artifact registry of a
/// preset.  Artifacts compile lazily on first use and are cached; the
/// engine is shared (`Arc`) by all worker threads.
pub struct Engine {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub model: ModelConfig,
    client: SendWrap<xla::PjRtClient>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Engine {
    /// Load the manifest for a preset from `artifacts/<preset>/`.
    pub fn load(artifacts_root: &Path, preset: &str) -> Result<Arc<Engine>> {
        let dir = artifacts_root.join(preset);
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))?;
        let model = ModelConfig::from_fields(&manifest.preset, &manifest.fields)?;
        let client = {
            let _guard = PJRT_LOCK.lock().unwrap();
            SendWrap(xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?)
        };
        Ok(Arc::new(Engine {
            dir,
            manifest,
            model,
            client,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    /// Default artifacts root: $LASP2_ARTIFACTS or ./artifacts.
    pub fn load_preset(preset: &str) -> Result<Arc<Engine>> {
        let root = std::env::var("LASP2_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&root), preset)
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Stage a constant tensor (weights) onto the device once.
    pub fn cache_buffer(&self, t: &Tensor) -> Result<Arc<CachedBuffer>> {
        let _guard = PJRT_LOCK.lock().unwrap();
        let buf = self.client.0.buffer_from_host_buffer(t.data(), t.shape(), None)?;
        Ok(Arc::new(CachedBuffer {
            buf: SendWrap(buf),
            shape: t.shape().to_vec(),
        }))
    }

    /// Get (compile-on-first-use) an executable by artifact name.
    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let t0 = Instant::now();
        let exe = {
            let _guard = PJRT_LOCK.lock().unwrap();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("bad path")?,
            )
            .map_err(|e| anyhow!("loading {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            SendWrap(
                self.client
                    .0
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
            )
        };
        let exec = Arc::new(Executable {
            meta,
            exe,
            client: {
                let _guard = PJRT_LOCK.lock().unwrap();
                SendWrap(self.client.0.clone())
            },
            stats: Mutex::new(ExecStats::default()),
        });
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(name.to_string()).or_insert_with(|| exec);
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            eprintln!("[runtime] compiled {name} in {:.2}s", dt.as_secs_f64());
        }
        Ok(entry.clone())
    }

    /// Pre-compile a set of artifacts (avoids first-call jitter in benches).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.artifact(n)?;
        }
        Ok(())
    }

    /// Snapshot of per-artifact execution stats, sorted by total time.
    pub fn stats_report(&self) -> Vec<(String, ExecStats)> {
        let cache = self.cache.lock().unwrap();
        let mut rows: Vec<(String, ExecStats)> = cache
            .iter()
            .map(|(k, v)| (k.clone(), *v.stats.lock().unwrap()))
            .collect();
        rows.sort_by_key(|(_, s)| std::cmp::Reverse(s.nanos));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Arc<Engine> {
        Engine::load_preset("tiny").expect("tiny artifacts built?")
    }

    #[test]
    fn manifest_loads_and_has_core_artifacts() {
        let e = engine();
        for a in [
            "embed",
            "head",
            "l_part1_basic",
            "l_part2_basic",
            "l_intra_basic",
            "l_part2b_basic",
            "l_bwd1_basic",
            "l_bwd2_basic",
            "s_part1",
            "ring_step",
            "ring_linear_step",
            "train_step_basic_pure",
        ] {
            assert!(e.has_artifact(a), "{a}");
        }
        assert_eq!(e.model.d_model, 64);
        assert_eq!(e.model.chunk_len, 32);
    }

    #[test]
    fn execute_embed_shapes() {
        let e = engine();
        let m = &e.model;
        let emb = Tensor::randn(&[m.vocab, m.d_model], 1);
        let pos = Tensor::randn(&[m.max_seq, m.d_model], 2);
        let tokens: Vec<i32> = (0..m.chunk_len as i32).collect();
        let exe = e.artifact("embed").unwrap();
        let out = exe
            .run(&[
                Value::I32(tokens, vec![m.chunk_len]),
                Value::i32_scalar(0),
                emb.clone().into(),
                pos.clone().into(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[m.chunk_len, m.d_model]);
        // embed(tokens, 0) = emb[tokens] + pos[0..C]
        let want0 = emb.data()[0] + pos.data()[0];
        assert!((out[0].data()[0] - want0).abs() < 1e-6);
    }

    #[test]
    fn input_shape_mismatch_is_error() {
        let e = engine();
        let exe = e.artifact("head").unwrap();
        let bad = Tensor::zeros(&[1, 1]);
        assert!(exe.run(&[bad.into()]).is_err());
    }

    #[test]
    fn chunk_state_matches_rust_math() {
        // l_part1_basic's m output must equal K~^T V computed in rust.
        let e = engine();
        let m = &e.model;
        let exe = e.artifact("l_part1_basic").unwrap();
        let x = Tensor::randn(&[m.chunk_len, m.d_model], 3);
        let ln1 = Tensor::ones(&[m.d_model]);
        let wq = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 4).scale(0.1);
        let wk = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 5).scale(0.1);
        let wv = Tensor::randn(&[m.d_model, m.n_heads * m.head_dim], 6).scale(0.1);
        let out = exe
            .run(&[
                x.into(),
                ln1.into(),
                wq.into(),
                wk.into(),
                wv.into(),
            ])
            .unwrap();
        let (qt, kt, v, mstate, a) = (&out[0], &out[1], &out[2], &out[3], &out[4]);
        assert_eq!(qt.shape(), &[m.chunk_len, m.n_heads, m.head_dim]);
        assert_eq!(mstate.shape(), &[m.n_heads, m.head_dim, m.head_dim]);
        // a == 1 for basic
        assert!(a.allclose(&Tensor::ones(a.shape()), 1e-6));
        // recompute M per head in rust: M_h = K_h^T V_h
        let c = m.chunk_len;
        let (hh, dh) = (m.n_heads, m.head_dim);
        for h in 0..hh {
            let mut kh = Vec::with_capacity(c * dh);
            let mut vh = Vec::with_capacity(c * dh);
            for i in 0..c {
                kh.extend_from_slice(&kt.data()[(i * hh + h) * dh..(i * hh + h + 1) * dh]);
                vh.extend_from_slice(&v.data()[(i * hh + h) * dh..(i * hh + h + 1) * dh]);
            }
            let kh = Tensor::new(vec![c, dh], kh);
            let vh = Tensor::new(vec![c, dh], vh);
            let want = kh.t().matmul(&vh);
            let got = Tensor::new(
                vec![dh, dh],
                mstate.data()[h * dh * dh..(h + 1) * dh * dh].to_vec(),
            );
            assert!(got.allclose(&want, 1e-4), "head {h}");
        }
    }
}
