//! Native execution backend: every AOT artifact the schedulers, pipeline,
//! and training loop request, implemented in pure rust on the coordinator
//! `Tensor` and driven by the built-in `ModelConfig` preset shapes.
//!
//! The math mirrors `python/compile/model.py` (and its Pallas kernels)
//! formula-for-formula — gate prefactor folding (q~ = q*B, k~ = k/B),
//! Based/ReBased feature maps, the chunk state M_t = K~^T V, the masked
//! intra product, online softmax for the standard layers, and a
//! hand-written backward (validated against `jax.grad`) for the
//! `train_step_*` artifacts.  No python, XLA, or artifact files are
//! involved: `cargo test` runs hermetically from a bare checkout.
//!
//! Registered artifact set (per preset): embed/head, the per-variant
//! linear phases (`l_part1/l_part2/l_intra/l_part2b`), the basic backward
//! phases, the standard-attention phases (`s_part1`, `s_part2_T{w}`),
//! the Ring/Megatron baselines, the `forward_mono_*` oracles, and
//! `init_*` / `train_step_*` for ALL SIX linear variants (basic,
//! lightning, retention, gla, based, rebased) at every hybrid ratio the
//! preset genuinely realizes (a ratio whose truncated pattern has no
//! std layer is left out, so the bench reports it as explicitly
//! SKIPPED), plus the softmax and unmasked-basic tags.  Every train tag
//! also registers a `grad_step_*` artifact — forward + backward only over
//! a contiguous `seq_range` slice of the batch, no optimizer — which is
//! what the ZeRO-sharded distributed driver consumes (`train::optimizer`
//! owns the Adam update there; the monolithic `train_step_*` keeps the
//! fused in-artifact Adam for the W=1 legacy path).  Gated-variant
//! training is
//! native: the backward differentiates through the decay prefactor
//! folding (q~ = q*B, k~ = k/B, B = cumprod(g)) including the
//! data-dependent GLA gate projection, and through the Based/ReBased
//! feature maps (see DESIGN.md §Native training).
//!
//! The serving layer (`serve::Model`/`serve::Session`) adds the decode
//! artifact family: `l_decode_{variant}_B{b}` (one autoregressive step on
//! the per-head recurrent state, M <- diag(g) M + k^T v, o = q~ M — the
//! constant-memory inference form), `s_decode_B{b}` (KV-cache softmax
//! step), `s_prefill` (chunk-sized KV-cache attention for hybrid
//! prefill), and the decode-shaped `embed_dec_B{b}` / `head_dec_B{b}`,
//! each registered at every batch size in `DECODE_BATCH_SIZES`.
//!
//! ## Fused intra+inter attention kernel
//!
//! Every masked chunked path computes its attention output through
//! [`attn_heads_fused`] — one pass over Q~ per chunk evaluating
//! `[(Q~ K~ᵀ) · tril] V + Q~ M` head by head (the Lightning-Attention-2
//! fusion, arXiv:2401.04658).  Artifact families on the fused kernel:
//! the `forward_mono_*` oracles (via `linear_layer_chunked`), `l_part2_*`
//! (chunked forward + prefill), the scheduler hidden-state path
//! (`l_chunk_hs_*`), and the batched decode steps (`l_decode_*_B{b}`).
//! The split path kept for overlap scheduling — `l_intra_*` followed by
//! `l_part2b_*` — accumulates the inter readout in place on top of
//! `o_intra` (`inter_acc_heads`), reproducing the fused kernel's
//! accumulation chain bit for bit.  Only the unmasked bidirectional
//! `l_part2nm_basic` still materializes a standalone `inter_heads`
//! product (it has no intra term).
//!
//! ## Compute parallelism (`LASP2_THREADS`, bit-identical at any setting)
//!
//! All dense math routes through the strided `tensor::gemm` core
//! (SIMD-dispatched k-blocked panel microkernels, fused-transpose,
//! row-band threaded for large shapes, per-head views addressed in
//! place).  On top of that, the embarrassingly-parallel
//! loops fan out deterministically via `tensor::par` — exactly the
//! computation-parallelism the paper's single AllGather unlocks:
//!
//! * **chunk-parallel** — the whole-sequence oracle path
//!   (`forward_mono_*` / `linear_layer_chunked`): after part1, every
//!   chunk's intra-attention and epilogue are independent (Alg. 2's
//!   per-device concurrency, realized across threads);
//! * **head-parallel** — the std/ring/mega softmax-attention kernels
//!   (`s_part2_T*`, `mega_attn_*`, the oracle `std_layer_full`);
//! * **sequence-parallel** — `train_step_*` runs its batch's sequences
//!   concurrently, reducing gradients in fixed batch order;
//! * **session-parallel** — the batched decode artifacts
//!   (`l_decode_*_B{b}`, `s_decode_B{b}`) step their per-session rows
//!   concurrently.
//!
//! Thresholds depend only on problem shape, never on the thread count,
//! and every worker writes a disjoint output region in a fixed order —
//! so outputs are bit-identical across `LASP2_THREADS` settings
//! (`tests/thread_determinism.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{ArtifactMeta, DType, Manifest, TensorMeta, Value};
use crate::config::{ModelConfig, Pattern, Variant};
use crate::coordinator::params::{param_specs, Init};
use crate::coordinator::schedulers::head_partition;
use crate::tensor::{gemm, par, prefix_states, scratch, state_combine, ChunkState, Tensor};

/// Batch sizes the serving decode artifacts are registered for.  The
/// `serve::Batch` wrapper groups sessions greedily into the largest
/// registered size (B=1 always exists, so any group count decomposes).
pub const DECODE_BATCH_SIZES: &[usize] = &[1, 2, 4, 8];

/// A native artifact kernel: positional `Value` inputs -> output tensors.
pub type KernelFn = Arc<dyn Fn(&ModelConfig, &[Value]) -> Result<Vec<Tensor>> + Send + Sync>;

const EPS: f32 = 1e-5;
const GATE_FLOOR: f32 = 0.95;
const GLA_TAU: f32 = 16.0;
const NEG_INF: f32 = -1e30;

// ================================================================ helpers

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// RMSNorm over the last axis: y = x * rsqrt(mean(x^2) + eps) * w.
///
/// `pub(crate)`: the serve quantized-readout path (`serve::QuantReadout`)
/// applies this exact normalization before its quantized `matmul_nt`, so
/// the only deviation from the `head_dec_B{b}` artifact is weight rounding.
pub(crate) fn rmsnorm(x: &Tensor, w: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let wd = w.data();
    let mut out = Vec::with_capacity(x.len());
    for i in 0..rows {
        let row = &x.data()[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + EPS).sqrt();
        for j in 0..d {
            out.push(row[j] * r * wd[j]);
        }
    }
    Tensor::new(x.shape().to_vec(), out)
}

/// Backward of `rmsnorm`: returns (dx, dw).
fn rmsnorm_bwd(x: &Tensor, w: &Tensor, dy: &Tensor) -> (Tensor, Tensor) {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let wd = w.data();
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; d];
    for i in 0..rows {
        let xr = &x.data()[i * d..(i + 1) * d];
        let dyr = &dy.data()[i * d..(i + 1) * d];
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + EPS).sqrt();
        let s: f32 = (0..d).map(|j| dyr[j] * wd[j] * xr[j]).sum();
        let r3s = r * r * r * s / d as f32;
        for j in 0..d {
            dx[i * d + j] = r * wd[j] * dyr[j] - xr[j] * r3s;
            dw[j] += dyr[j] * xr[j] * r;
        }
    }
    (
        Tensor::new(x.shape().to_vec(), dx),
        Tensor::new(vec![d], dw),
    )
}

/// SwiGLU MLP: (silu(x w1) * (x w3)) w2.
fn swiglu(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    let u = x.matmul(w1);
    let tg = x.matmul(w3);
    let gated: Vec<f32> = u
        .data()
        .iter()
        .zip(tg.data())
        .map(|(a, b)| silu(*a) * b)
        .collect();
    Tensor::new(u.shape().to_vec(), gated).matmul(w2)
}

/// Row `i` of a tensor along axis 0, keeping the leading axis (shape
/// `[1, rest...]`) — batch-row extraction for the decode kernels.
fn row0(t: &Tensor, i: usize) -> Tensor {
    let stride: usize = t.shape()[1..].iter().product();
    let mut shape = t.shape().to_vec();
    shape[0] = 1;
    Tensor::new(shape, t.data()[i * stride..(i + 1) * stride].to_vec())
}

/// Write a packed `[C, F]` buffer into head `h` of a `[C, H, F]` tensor
/// (scatter step of the head-parallel kernels).
fn scatter_head(dst: &mut Tensor, h: usize, src: &[f32]) {
    let (heads, f) = (dst.shape()[1], dst.shape()[2]);
    let c = dst.shape()[0];
    for i in 0..c {
        let base = (i * heads + h) * f;
        dst.data_mut()[base..base + f].copy_from_slice(&src[i * f..(i + 1) * f]);
    }
}

/// Zero the strictly-upper triangle of a square [c, c] score buffer.
fn tril_raw(s: &mut [f32], c: usize) {
    for i in 0..c {
        for v in &mut s[i * c + i + 1..(i + 1) * c] {
            *v = 0.0;
        }
    }
}

/// Zero entries of a [cq, ck] score buffer where global qpos < kpos.
fn offset_causal_zero_raw(s: &mut [f32], cq: usize, ck: usize, qoff: i32, koff: i32) {
    for i in 0..cq {
        // columns j with koff + j > qoff + i are masked
        let cut = (qoff + i as i32 - koff + 1).clamp(0, ck as i32) as usize;
        for v in &mut s[i * ck + cut..(i + 1) * ck] {
            *v = 0.0;
        }
    }
}

/// Row-wise stable softmax over a [cq, ck] score buffer: scores are
/// scaled by `scale`, entries with global qpos < kpos get -inf, rows are
/// max-subtracted, exponentiated, and normalized.
fn softmax_causal_scaled_raw(
    s: &mut [f32],
    cq: usize,
    ck: usize,
    scale: f32,
    qoff: i32,
    koff: i32,
) {
    for i in 0..cq {
        let row = &mut s[i * ck..(i + 1) * ck];
        for (j, v) in row.iter_mut().enumerate() {
            if qoff + i as i32 < koff + j as i32 {
                *v = NEG_INF;
            } else {
                *v *= scale;
            }
        }
        let m = row.iter().fold(NEG_INF, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// Shared layer epilogue: y = x + attn wo; z = y + swiglu(rmsnorm(y)).
fn epilogue(
    x: &Tensor,
    attn: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
) -> Tensor {
    let c = x.shape()[0];
    let hd = attn.len() / c;
    let attn2 = attn.clone().reshape(&[c, hd]);
    let y = x.add(&attn2.matmul(wo));
    y.add(&swiglu(&rmsnorm(&y, ln2), w1, w3, w2))
}

// ================================================ linear-attention kernels

/// Based feature map phi(x) = [1, x, vec(x x^T)/sqrt(2)] over the last axis.
fn phi_based(x: &Tensor) -> Tensor {
    let s = x.shape();
    let (c, hh, r) = (s[0], s[1], s[2]);
    let fk = 1 + r + r * r;
    let sqrt2 = 2.0f32.sqrt();
    let mut out = Vec::with_capacity(c * hh * fk);
    for i in 0..c {
        for h in 0..hh {
            let v = &x.data()[(i * hh + h) * r..(i * hh + h + 1) * r];
            out.push(1.0);
            out.extend_from_slice(v);
            for a in 0..r {
                for b in 0..r {
                    out.push(v[a] * v[b] / sqrt2);
                }
            }
        }
    }
    Tensor::new(vec![c, hh, fk], out)
}

/// ReBased feature map phi(x) = (x * gamma + beta)^2 over the last axis.
fn phi_rebased(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> Tensor {
    let r = *x.shape().last().unwrap();
    let (g, b) = (gamma.data(), beta.data());
    let out = x
        .data()
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let t = v * g[i % r] + b[i % r];
            t * t
        })
        .collect();
    Tensor::new(x.shape().to_vec(), out)
}

/// Backward of `phi_based`: dphi [C, H, 1+r+r^2] -> dx [C, H, r].
/// phi = [1, x_a, x_a x_b / sqrt(2)], so
/// dx_a = dphi[1+a] + sum_b (dphi[1+r+a*r+b] + dphi[1+r+b*r+a]) x_b / sqrt(2).
fn phi_based_bwd(x: &Tensor, dphi: &Tensor) -> Tensor {
    let s = x.shape();
    let (c, hh, r) = (s[0], s[1], s[2]);
    let fk = 1 + r + r * r;
    let sqrt2 = 2.0f32.sqrt();
    let mut out = vec![0.0f32; c * hh * r];
    for i in 0..c {
        for h in 0..hh {
            let xv = &x.data()[(i * hh + h) * r..(i * hh + h + 1) * r];
            let dp = &dphi.data()[(i * hh + h) * fk..(i * hh + h + 1) * fk];
            let o = &mut out[(i * hh + h) * r..(i * hh + h + 1) * r];
            for a in 0..r {
                let mut acc = dp[1 + a];
                for b in 0..r {
                    acc += (dp[1 + r + a * r + b] + dp[1 + r + b * r + a]) * xv[b] / sqrt2;
                }
                o[a] = acc;
            }
        }
    }
    Tensor::new(vec![c, hh, r], out)
}

/// Backward of `phi_rebased`: returns (dx, dgamma, dbeta).
/// t = x*gamma + beta, phi = t^2 -> dt = 2 t dphi.
fn phi_rebased_bwd(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    dphi: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let r = *x.shape().last().unwrap();
    let (g, b) = (gamma.data(), beta.data());
    let mut dx = vec![0.0f32; x.len()];
    let mut dgamma = vec![0.0f32; r];
    let mut dbeta = vec![0.0f32; r];
    for (i, (xv, dp)) in x.data().iter().zip(dphi.data()).enumerate() {
        let f = i % r;
        let t = xv * g[f] + b[f];
        let dt = 2.0 * t * dp;
        dx[i] = dt * g[f];
        dgamma[f] += dt * xv;
        dbeta[f] += dt;
    }
    (
        Tensor::new(x.shape().to_vec(), dx),
        Tensor::new(vec![r], dgamma),
        Tensor::new(vec![r], dbeta),
    )
}

/// Per-token decay gates g: [C, H, fk] (ones for non-decay variants).
fn decay_gates(
    cfg: &ModelConfig,
    variant: Variant,
    hn: &Tensor,
    extra: &[&Tensor],
    c: usize,
    fk: usize,
) -> Tensor {
    let hh = cfg.n_heads;
    match variant {
        Variant::Retention => {
            // RetNet-style per-head lambda = max(1 - 2^(-5-h), floor)
            let mut data = Vec::with_capacity(c * hh * fk);
            for _ in 0..c {
                for h in 0..hh {
                    let lam = (1.0 - (-(5.0 + h as f32)).exp2()).max(GATE_FLOOR);
                    data.extend(std::iter::repeat(lam).take(fk));
                }
            }
            Tensor::new(vec![c, hh, fk], data)
        }
        Variant::Gla => {
            let raw = hn.matmul(extra[0]); // [c, hh*fk]
            let data = raw
                .data()
                .iter()
                .map(|r| GATE_FLOOR + (1.0 - GATE_FLOOR) * sigmoid(*r).powf(1.0 / GLA_TAU))
                .collect();
            Tensor::new(vec![c, hh, fk], data)
        }
        _ => Tensor::ones(&[c, hh, fk]),
    }
}

/// Cumulative product along axis 0 (time), in place on the moved tensor.
fn cumprod0(g: Tensor) -> Tensor {
    let n = g.shape()[0];
    let stride: usize = g.shape()[1..].iter().product();
    let mut b = g;
    let bd = b.data_mut();
    for i in 1..n {
        for j in 0..stride {
            let prev = bd[(i - 1) * stride + j];
            bd[i * stride + j] *= prev;
        }
    }
    b
}

/// Fold decay gates into q/k (prefactor trick) and form the chunk state:
/// B = cumprod(g), a = B[-1], q~ = q*B, k~ = k/B, M = (k~ * a)^T v per head.
fn fold_gates(q: &Tensor, k: &Tensor, v: &Tensor, g: Tensor) -> (Tensor, Tensor, Tensor, Tensor) {
    let s = q.shape();
    let (c, hh, fk) = (s[0], s[1], s[2]);
    let dh = v.shape()[2];
    let stride = hh * fk;
    let b = cumprod0(g);
    let a = Tensor::new(vec![hh, fk], b.data()[(c - 1) * stride..c * stride].to_vec());
    let qt = q.mul(&b);
    let kt = k.div(&b);
    // scale k~ by the carry once for the whole [C, H, fk] block, then form
    // M_h = (k~ * a)_hᵀ · V_h with a strided tn — no per-head copies
    let mut kts = scratch::take(c * stride);
    let (ktd, ad) = (kt.data(), a.data());
    for (i, vmut) in kts.iter_mut().enumerate() {
        *vmut = ktd[i] * ad[i % stride];
    }
    let mut m = Tensor::zeros(&[hh, fk, dh]);
    for h in 0..hh {
        gemm::tn(
            fk,
            c,
            dh,
            &kts[h * fk..],
            stride,
            &v.data()[h * dh..],
            hh * dh,
            &mut m.data_mut()[h * fk * dh..(h + 1) * fk * dh],
            dh,
        );
    }
    scratch::recycle(kts);
    (qt, kt, m, a)
}

struct Part1 {
    qt: Tensor,
    kt: Tensor,
    v: Tensor,
    m: Tensor,
    a: Tensor,
}

/// Alg. 2 lines 5-6 for one chunk (all variants).
fn linear_part1(
    cfg: &ModelConfig,
    variant: Variant,
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    extra: &[&Tensor],
) -> Part1 {
    let c = x.shape()[0];
    let (hh, dh) = (cfg.n_heads, cfg.head_dim);
    let rq = cfg.qk_dim(variant);
    let fk = cfg.feat_dim(variant);
    let hn = rmsnorm(x, ln1);
    let q = hn.matmul(wq).reshape(&[c, hh, rq]);
    let k = hn.matmul(wk).reshape(&[c, hh, rq]);
    let v = hn.matmul(wv).reshape(&[c, hh, dh]);
    let (q, k) = match variant {
        Variant::Based => (phi_based(&q), phi_based(&k)),
        Variant::Rebased => (
            phi_rebased(&q, extra[0], extra[1]),
            phi_rebased(&k, extra[0], extra[1]),
        ),
        _ => (q, k),
    };
    let g = decay_gates(cfg, variant, &hn, extra, c, fk);
    let (qt, kt, m, a) = fold_gates(&q, &k, &v, g);
    Part1 { qt, kt, v, m, a }
}

/// One head of O_intra = [(Q~ K~^T) . tril] V, written to `out` rows at
/// stride `ldo` (identical bits whether `out` is a packed [C, dh] buffer
/// or an in-place [C, H, dh] head view).
fn intra_one_head(
    qt: &Tensor,
    kt: &Tensor,
    v: &Tensor,
    h: usize,
    s: &mut [f32],
    out: &mut [f32],
    ldo: usize,
) {
    let (c, hh, fk) = (qt.shape()[0], qt.shape()[1], qt.shape()[2]);
    let dh = v.shape()[2];
    gemm::nt(c, fk, c, &qt.data()[h * fk..], hh * fk, &kt.data()[h * fk..], hh * fk, s, c);
    tril_raw(s, c);
    gemm::nn(c, c, dh, s, c, &v.data()[h * dh..], hh * dh, out, ldo);
}

/// O_intra = [(Q~ K~^T) . tril] V per head -> [C, H, dh].  Strided in
/// place (no head copies); head-parallel when the work is large.
fn intra_heads(qt: &Tensor, kt: &Tensor, v: &Tensor) -> Tensor {
    let (c, hh, fk) = (qt.shape()[0], qt.shape()[1], qt.shape()[2]);
    let dh = v.shape()[2];
    let mut out = Tensor::zeros(&[c, hh, dh]);
    let flops = 2 * c * c * (fk + dh) * hh;
    if par::would_parallelize(hh, flops) {
        let heads: Vec<Vec<f32>> = par::par_map(hh, flops, |h| {
            let mut s = scratch::take(c * c);
            let mut oh = scratch::take(c * dh);
            intra_one_head(qt, kt, v, h, &mut s, &mut oh, dh);
            scratch::recycle(s);
            oh
        });
        // scatter, then recycle on THIS thread (worker pools die with the
        // scoped threads, so the coordinator keeps the buffers alive)
        for (h, oh) in heads.into_iter().enumerate() {
            scatter_head(&mut out, h, &oh);
            scratch::recycle(oh);
        }
    } else {
        let mut s = scratch::take(c * c);
        for h in 0..hh {
            intra_one_head(qt, kt, v, h, &mut s, &mut out.data_mut()[h * dh..], hh * dh);
        }
        scratch::recycle(s);
    }
    out
}

/// O_inter = Q~ M per head -> [C, H, dh].  m: [H, fk, dh].  Strided nn
/// per head, no copies.
fn inter_heads(qt: &Tensor, m: &Tensor) -> Tensor {
    let (c, hh) = (qt.shape()[0], qt.shape()[1]);
    let (fk, dh) = (m.shape()[1], m.shape()[2]);
    let mut out = Tensor::zeros(&[c, hh, dh]);
    for h in 0..hh {
        gemm::nn(
            c,
            fk,
            dh,
            &qt.data()[h * fk..],
            hh * fk,
            &m.data()[h * fk * dh..(h + 1) * fk * dh],
            dh,
            &mut out.data_mut()[h * dh..],
            hh * dh,
        );
    }
    out
}

/// One head of O += Q~ M_h accumulated into `out` rows at stride `ldo`
/// (`gemm::nn_acc` on the strided head view — no copies).
fn inter_acc_one_head(qt: &Tensor, m: &Tensor, h: usize, out: &mut [f32], ldo: usize) {
    let (c, hh, fk) = (qt.shape()[0], qt.shape()[1], qt.shape()[2]);
    let dh = m.shape()[2];
    gemm::nn_acc(
        c,
        fk,
        dh,
        &qt.data()[h * fk..],
        hh * fk,
        &m.data()[h * fk * dh..(h + 1) * fk * dh],
        dh,
        out,
        ldo,
    );
}

/// O += Q~ M per head, accumulated in place into `out` ([C, H, dh]).
/// The accumulation-chain twin of the fused kernel: `o_intra` + this is
/// bit-identical to [`attn_heads_fused`], which is how the split
/// `l_intra`/`l_part2b` scheduler path keeps exact parity with the fused
/// `l_part2` path.
fn inter_acc_heads(qt: &Tensor, m: &Tensor, out: &mut Tensor) {
    let hh = qt.shape()[1];
    let dh = m.shape()[2];
    for h in 0..hh {
        let ldo = hh * dh;
        inter_acc_one_head(qt, m, h, &mut out.data_mut()[h * dh..], ldo);
    }
}

/// Fused O = [(Q~ K~ᵀ) · tril] V + Q~ M per head -> [C, H, dh] — the
/// Lightning-Attention-2-style single pass over Q~ (arXiv:2401.04658):
/// each head computes its intra product into `out` and immediately
/// accumulates the inter readout on top while Q~_h and the output tile
/// are still cache-hot.  Replaces `intra_heads(..).add(&inter_heads(..))`
/// in every chunked forward, decode, and scheduler path, eliminating the
/// full [C, H, dh] intermediate and a second traversal of Q~.
/// Head-parallel when the work is large; bit-identical at any thread
/// count (banding and head fan-out never reorder accumulation).
fn attn_heads_fused(qt: &Tensor, kt: &Tensor, v: &Tensor, m: &Tensor) -> Tensor {
    let (c, hh, fk) = (qt.shape()[0], qt.shape()[1], qt.shape()[2]);
    let dh = v.shape()[2];
    let mut out = Tensor::zeros(&[c, hh, dh]);
    let flops = 2 * c * hh * (c * (fk + dh) + fk * dh);
    if par::would_parallelize(hh, flops) {
        let heads: Vec<Vec<f32>> = par::par_map(hh, flops, |h| {
            let mut s = scratch::take(c * c);
            let mut oh = scratch::take(c * dh);
            intra_one_head(qt, kt, v, h, &mut s, &mut oh, dh);
            inter_acc_one_head(qt, m, h, &mut oh, dh);
            scratch::recycle(s);
            oh
        });
        for (h, oh) in heads.into_iter().enumerate() {
            scatter_head(&mut out, h, &oh);
            scratch::recycle(oh);
        }
    } else {
        let mut s = scratch::take(c * c);
        for h in 0..hh {
            let ldo = hh * dh;
            intra_one_head(qt, kt, v, h, &mut s, &mut out.data_mut()[h * dh..], ldo);
            inter_acc_one_head(qt, m, h, &mut out.data_mut()[h * dh..], ldo);
        }
        scratch::recycle(s);
    }
    out
}

/// One head of causal softmax attention against a gathered K/V sequence,
/// written to `out` rows at stride `ldo`.
fn softmax_one_head(
    q: &Tensor,
    k_all: &Tensor,
    v_all: &Tensor,
    qoff: i32,
    h: usize,
    s: &mut [f32],
    out: &mut [f32],
    ldo: usize,
) {
    let (c, hh, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let n_all = k_all.shape()[0];
    let scale = 1.0 / (dh as f32).sqrt();
    gemm::nt(
        c,
        dh,
        n_all,
        &q.data()[h * dh..],
        hh * dh,
        &k_all.data()[h * dh..],
        hh * dh,
        s,
        n_all,
    );
    softmax_causal_scaled_raw(s, c, n_all, scale, qoff, 0);
    gemm::nn(c, n_all, dh, s, n_all, &v_all.data()[h * dh..], hh * dh, out, ldo);
}

/// Standard softmax attention per head against a gathered K/V sequence.
/// q: [C, H, dh] at global positions qoff+[0..C); k/v: [N, H, dh] at
/// [0..N).  Head-parallel when the work is large.
fn softmax_attn_heads(q: &Tensor, k_all: &Tensor, v_all: &Tensor, qoff: i32) -> Tensor {
    let (c, hh, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let n_all = k_all.shape()[0];
    let mut out = Tensor::zeros(&[c, hh, dh]);
    let flops = 4 * c * n_all * dh * hh;
    if par::would_parallelize(hh, flops) {
        let heads: Vec<Vec<f32>> = par::par_map(hh, flops, |h| {
            let mut s = scratch::take(c * n_all);
            let mut oh = scratch::take(c * dh);
            softmax_one_head(q, k_all, v_all, qoff, h, &mut s, &mut oh, dh);
            scratch::recycle(s);
            oh
        });
        for (h, oh) in heads.into_iter().enumerate() {
            scatter_head(&mut out, h, &oh);
            scratch::recycle(oh);
        }
    } else {
        let mut s = scratch::take(c * n_all);
        for h in 0..hh {
            let ldo = hh * dh;
            softmax_one_head(q, k_all, v_all, qoff, h, &mut s, &mut out.data_mut()[h * dh..], ldo);
        }
        scratch::recycle(s);
    }
    out
}

// ======================================================= mono / train model

/// Read-only parameter view in `param_specs` order, indexed by name.
struct ParamView<'a> {
    vals: Vec<&'a Tensor>,
    index: HashMap<String, usize>,
}

impl<'a> ParamView<'a> {
    fn new(specs: &[(String, Vec<usize>, Init)], ins: &'a [Value]) -> Result<ParamView<'a>> {
        let mut vals = Vec::with_capacity(specs.len());
        for (i, (name, shape, _)) in specs.iter().enumerate() {
            let t = ins[i]
                .host_f32()
                .with_context(|| format!("param {name}"))?;
            anyhow::ensure!(t.shape() == shape.as_slice(), "param {name} shape");
            vals.push(t);
        }
        let index = specs
            .iter()
            .enumerate()
            .map(|(i, (n, _, _))| (n.clone(), i))
            .collect();
        Ok(ParamView { vals, index })
    }

    fn get(&self, name: &str) -> Result<&'a Tensor> {
        let i = *self
            .index
            .get(name)
            .with_context(|| format!("param {name}"))?;
        Ok(self.vals[i])
    }

    fn layer(&self, i: usize, name: &str) -> Result<&'a Tensor> {
        self.get(&format!("layer{i}.{name}"))
    }
}

/// x = `emb[tokens]` + `pos[offset..offset+n]` (embed at a global position).
fn embed_tokens(
    cfg: &ModelConfig,
    emb: &Tensor,
    pos: &Tensor,
    tokens: &[i32],
    offset: usize,
) -> Result<Tensor> {
    let d = cfg.d_model;
    anyhow::ensure!(
        offset + tokens.len() <= cfg.max_seq,
        "positions {}..{} exceed the pos table (max_seq {})",
        offset,
        offset + tokens.len(),
        cfg.max_seq
    );
    let mut out = Vec::with_capacity(tokens.len() * d);
    for (i, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        anyhow::ensure!(t < cfg.vocab, "token id {t} out of vocab");
        let e = &emb.data()[t * d..(t + 1) * d];
        let p = &pos.data()[(offset + i) * d..(offset + i + 1) * d];
        out.extend(e.iter().zip(p).map(|(a, b)| a + b));
    }
    Ok(Tensor::new(vec![tokens.len(), d], out))
}

/// Whole-sequence linear layer via the chunked LASP-2 math (oracle path).
fn linear_layer_chunked(
    cfg: &ModelConfig,
    variant: Variant,
    pv: &ParamView,
    layer: usize,
    x: &Tensor,
    masked: bool,
) -> Result<Tensor> {
    let n = x.shape()[0];
    let c = cfg.chunk_len;
    anyhow::ensure!(n % c == 0, "N={n} not divisible by chunk {c}");
    let ln1 = pv.layer(layer, "ln1")?;
    let wq = pv.layer(layer, "wq")?;
    let wk = pv.layer(layer, "wk")?;
    let wv = pv.layer(layer, "wv")?;
    let extra: Vec<&Tensor> = match variant {
        Variant::Gla => vec![pv.layer(layer, "wg")?],
        Variant::Rebased => vec![pv.layer(layer, "gamma")?, pv.layer(layer, "beta")?],
        _ => vec![],
    };
    let (wo, ln2) = (pv.layer(layer, "wo")?, pv.layer(layer, "ln2")?);
    let (w1, w3, w2) = (
        pv.layer(layer, "w1")?,
        pv.layer(layer, "w3")?,
        pv.layer(layer, "w2")?,
    );
    let chunks = x.chunk0(n / c);
    // chunk-parallel part1: each chunk's projections/feature maps/state
    // are independent (the compute side of the paper's single-AllGather
    // claim), so they fan out across threads deterministically
    let d = cfg.d_model;
    let (hh, dh, fk) = (cfg.n_heads, cfg.head_dim, cfg.feat_dim(variant));
    let chunk_flops =
        2 * c * (d * (3 * hh * fk + hh * dh + 3 * cfg.ffn_dim) + c * hh * (fk + dh));
    let total_flops = chunk_flops * chunks.len();
    let parts: Vec<Part1> = par::par_map(chunks.len(), total_flops, |t| {
        linear_part1(cfg, variant, &chunks[t], ln1, wq, wk, wv, &extra)
    });
    let states: Vec<ChunkState> = parts
        .iter()
        .map(|p| ChunkState { m: p.m.clone(), a: p.a.clone() })
        .collect();
    // the serial prefix combine is O(W) on seq-len-independent states ...
    let (prefixes, total) = prefix_states(&states);
    // ... after which every chunk's intra-attention + epilogue is again
    // embarrassingly parallel
    let outs: Vec<Tensor> = par::par_map(chunks.len(), total_flops, |t| {
        let p = &parts[t];
        let attn = if masked {
            attn_heads_fused(&p.qt, &p.kt, &p.v, &prefixes[t].m)
        } else {
            inter_heads(&p.qt, &total.m)
        };
        epilogue(&chunks[t], &attn, wo, ln2, w1, w3, w2)
    });
    Ok(Tensor::cat0(&outs))
}

/// Whole-sequence standard-attention layer (causal softmax, offset 0).
fn std_layer_full(cfg: &ModelConfig, pv: &ParamView, layer: usize, x: &Tensor) -> Result<Tensor> {
    let n = x.shape()[0];
    let (hh, dh) = (cfg.n_heads, cfg.head_dim);
    let hn = rmsnorm(x, pv.layer(layer, "ln1")?);
    let q = hn.matmul(pv.layer(layer, "wq")?).reshape(&[n, hh, dh]);
    let k = hn.matmul(pv.layer(layer, "wk")?).reshape(&[n, hh, dh]);
    let v = hn.matmul(pv.layer(layer, "wv")?).reshape(&[n, hh, dh]);
    let attn = softmax_attn_heads(&q, &k, &v, 0);
    Ok(epilogue(
        x,
        &attn,
        pv.layer(layer, "wo")?,
        pv.layer(layer, "ln2")?,
        pv.layer(layer, "w1")?,
        pv.layer(layer, "w3")?,
        pv.layer(layer, "w2")?,
    ))
}

/// Single-device oracle forward: tokens -> logits (the `forward_mono_*`
/// artifacts; the distributed pipeline is tested against this).
fn forward_tokens(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    pv: &ParamView,
    tokens: &[i32],
    masked: bool,
) -> Result<Tensor> {
    let mut x = embed_tokens(cfg, pv.get("embed")?, pv.get("pos")?, tokens, 0)?;
    for (i, is_linear) in pattern.layers() {
        x = if is_linear {
            linear_layer_chunked(cfg, variant, pv, i, &x, masked)?
        } else {
            std_layer_full(cfg, pv, i, &x)?
        };
    }
    let zn = rmsnorm(&x, pv.get("final_ln")?);
    Ok(zn.matmul_nt(pv.get("embed")?))
}

// ===================================================== train step backward

/// Per-sequence loss + parameter gradients for one (variant, pattern)
/// model, hand-written backward (derived per variant against a float64
/// finite-difference prototype; re-checked in-repo by the f32 gradcheck
/// below — see DESIGN.md §Native math fidelity).  Linear layers
/// run the whole-sequence prefactor-folded math: feature maps
/// (Based/ReBased), decay gates (Retention's fixed per-head lambda, GLA's
/// learned projection), B = cumprod(g), q~ = q*B, k~ = k/B, masked
/// product — with gradients flowing back through the folding, the
/// cumprod, and the data-dependent GLA gate projection.  Accumulates
/// into `grads` (spec order).
#[allow(clippy::too_many_lines)]
fn seq_loss_grads(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    pv: &ParamView,
    grads: &mut [Tensor],
    tokens: &[i32],
    targets: &[i32],
    mask: &[f32],
    denom: f32,
    masked: bool,
) -> Result<f32> {
    let n = tokens.len();
    let (hh, dh, vb) = (cfg.n_heads, cfg.head_dim, cfg.vocab);
    let scale = 1.0 / (dh as f32).sqrt();
    let gidx = |name: &str| -> usize { pv.index[name] };
    anyhow::ensure!(
        masked || !variant.has_decay(),
        "unmasked (bidirectional) training is undefined for decay-gated variant {variant}"
    );

    // ---- forward with caches ----
    struct LayerCache {
        x_in: Tensor,
        hn: Tensor,
        /// post-feature-map q/k ([N, H, fk]; raw [N, H, dh] on std layers)
        q: Tensor,
        k: Tensor,
        /// pre-feature-map projections (cached only for Based/ReBased)
        qr: Option<Tensor>,
        kr: Option<Tensor>,
        /// decay gates and their cumulative product B (decay variants only)
        g: Option<Tensor>,
        b: Option<Tensor>,
        /// prefactor-folded (q~, k~) = (q*B, k/B) (decay variants only;
        /// ungated layers read q/k directly — no fold, nothing to cache)
        folded: Option<(Tensor, Tensor)>,
        v: Tensor,
        attn: Tensor,
        y: Tensor,
        yn: Tensor,
        u: Tensor,
        tg: Tensor,
        is_linear: bool,
    }
    let emb = pv.get("embed")?;
    let pos = pv.get("pos")?;
    let mut x = embed_tokens(cfg, emb, pos, tokens, 0)?;
    let mut caches: Vec<LayerCache> = Vec::with_capacity(pattern.len());
    for (i, is_linear) in pattern.layers() {
        let hn = rmsnorm(&x, pv.layer(i, "ln1")?);
        let rq = if is_linear { cfg.qk_dim(variant) } else { dh };
        let qr = hn.matmul(pv.layer(i, "wq")?).reshape(&[n, hh, rq]);
        let kr = hn.matmul(pv.layer(i, "wk")?).reshape(&[n, hh, rq]);
        let v = hn.matmul(pv.layer(i, "wv")?).reshape(&[n, hh, dh]);
        let (q, k, qr, kr) = match variant {
            Variant::Based if is_linear => (phi_based(&qr), phi_based(&kr), Some(qr), Some(kr)),
            Variant::Rebased if is_linear => {
                let ga = pv.layer(i, "gamma")?;
                let be = pv.layer(i, "beta")?;
                (
                    phi_rebased(&qr, ga, be),
                    phi_rebased(&kr, ga, be),
                    Some(qr),
                    Some(kr),
                )
            }
            _ => (qr, kr, None, None),
        };
        let g = if is_linear && variant.has_decay() {
            let fk = cfg.feat_dim(variant);
            let extra: Vec<&Tensor> = if variant == Variant::Gla {
                vec![pv.layer(i, "wg")?]
            } else {
                vec![]
            };
            Some(decay_gates(cfg, variant, &hn, &extra, n, fk))
        } else {
            None
        };
        let b = g.clone().map(cumprod0);
        let folded = b.as_ref().map(|b| (q.mul(b), k.div(b)));
        let (qt, kt): (&Tensor, &Tensor) = match &folded {
            Some((qt, kt)) => (qt, kt),
            None => (&q, &k),
        };
        let mut attn = Tensor::zeros(&[n, hh, dh]);
        let fkl = qt.shape()[2];
        let mut sbuf = scratch::take(n * n);
        for h in 0..hh {
            if is_linear {
                gemm::nt(
                    n,
                    fkl,
                    n,
                    &qt.data()[h * fkl..],
                    hh * fkl,
                    &kt.data()[h * fkl..],
                    hh * fkl,
                    &mut sbuf,
                    n,
                );
                if masked {
                    tril_raw(&mut sbuf, n);
                }
                gemm::nn(
                    n,
                    n,
                    dh,
                    &sbuf,
                    n,
                    &v.data()[h * dh..],
                    hh * dh,
                    &mut attn.data_mut()[h * dh..],
                    hh * dh,
                );
            } else {
                let ldo = hh * dh;
                softmax_one_head(qt, kt, &v, 0, h, &mut sbuf, &mut attn.data_mut()[h * dh..], ldo);
            }
        }
        scratch::recycle(sbuf);
        let y = x.add(
            &attn
                .clone()
                .reshape(&[n, hh * dh])
                .matmul(pv.layer(i, "wo")?),
        );
        let yn = rmsnorm(&y, pv.layer(i, "ln2")?);
        let u = yn.matmul(pv.layer(i, "w1")?);
        let tg = yn.matmul(pv.layer(i, "w3")?);
        let gated: Vec<f32> = u
            .data()
            .iter()
            .zip(tg.data())
            .map(|(a, b)| silu(*a) * b)
            .collect();
        let z = y.add(&Tensor::new(u.shape().to_vec(), gated).matmul(pv.layer(i, "w2")?));
        caches.push(LayerCache {
            x_in: x,
            hn,
            q,
            k,
            qr,
            kr,
            g,
            b,
            folded,
            v,
            attn,
            y,
            yn,
            u,
            tg,
            is_linear,
        });
        x = z;
    }
    let xl = x;
    let zn = rmsnorm(&xl, pv.get("final_ln")?);
    let logits = zn.matmul_nt(emb);

    // ---- loss + dlogits ----
    let mut loss = 0.0f32;
    let mut dlogits = Tensor::zeros(&[n, vb]);
    for i in 0..n {
        let row = &logits.data()[i * vb..(i + 1) * vb];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
        let logz = z.ln() + mx;
        let tgt = targets[i] as usize;
        anyhow::ensure!(tgt < vb, "target id {tgt} out of vocab");
        let w = mask[i] / denom;
        loss += mask[i] * (logz - row[tgt]) / denom;
        let dr = &mut dlogits.data_mut()[i * vb..(i + 1) * vb];
        for j in 0..vb {
            dr[j] = ((row[j] - mx).exp() / z) * w;
        }
        dr[tgt] -= w;
    }

    // ---- backward: head (tied embedding) ----
    grads[gidx("embed")].add_assign(&dlogits.matmul_tn(&zn));
    let dz = dlogits.matmul(emb);
    let (mut dx, dfl) = rmsnorm_bwd(&xl, pv.get("final_ln")?, &dz);
    grads[gidx("final_ln")].add_assign(&dfl);

    // ---- backward: layers in reverse ----
    for (i, _) in pattern.layers().collect::<Vec<_>>().into_iter().rev() {
        let lc = &caches[i];
        let dzl = dx;
        // MLP: z = y + (silu(u) * tg) w2
        let w2 = pv.layer(i, "w2")?;
        let ds = dzl.matmul_nt(w2);
        let gated: Vec<f32> = lc
            .u
            .data()
            .iter()
            .zip(lc.tg.data())
            .map(|(a, b)| silu(*a) * b)
            .collect();
        grads[gidx(&format!("layer{i}.w2"))]
            .add_assign(&Tensor::new(lc.u.shape().to_vec(), gated).matmul_tn(&dzl));
        let mut dtg = ds.clone();
        let mut du = ds;
        for (j, (dt, dd)) in dtg.data_mut().iter_mut().zip(du.data_mut()).enumerate() {
            let uu = lc.u.data()[j];
            let sg = sigmoid(uu);
            let t = lc.tg.data()[j];
            let dsj = *dt; // ds value
            *dt = dsj * silu(uu);
            *dd = dsj * t * (sg * (1.0 + uu * (1.0 - sg)));
        }
        let dyn_ = du
            .matmul_nt(pv.layer(i, "w1")?)
            .add(&dtg.matmul_nt(pv.layer(i, "w3")?));
        grads[gidx(&format!("layer{i}.w1"))].add_assign(&lc.yn.matmul_tn(&du));
        grads[gidx(&format!("layer{i}.w3"))].add_assign(&lc.yn.matmul_tn(&dtg));
        let (dy_norm, dln2) = rmsnorm_bwd(&lc.y, pv.layer(i, "ln2")?, &dyn_);
        grads[gidx(&format!("layer{i}.ln2"))].add_assign(&dln2);
        let dy = dzl.add(&dy_norm);
        // attention projection: y = x + attn_flat wo
        let dattn = dy
            .matmul_nt(pv.layer(i, "wo")?)
            .reshape(&[n, hh, dh]);
        grads[gidx(&format!("layer{i}.wo"))]
            .add_assign(&lc.attn.clone().reshape(&[n, hh * dh]).matmul_tn(&dy));
        // attention core backward (through the cached folded q~/k~ on
        // decay-gated linear layers)
        let (qt, kt): (&Tensor, &Tensor) = match &lc.folded {
            Some((qt, kt)) => (qt, kt),
            None => (&lc.q, &lc.k),
        };
        let fkl = lc.q.shape()[2];
        let mut dqt = Tensor::zeros(&[n, hh, fkl]);
        let mut dkt = Tensor::zeros(&[n, hh, fkl]);
        let mut dv = Tensor::zeros(&[n, hh, dh]);
        let mut s1 = scratch::take(n * n);
        let mut s2 = scratch::take(n * n);
        for h in 0..hh {
            let qs = &qt.data()[h * fkl..];
            let ks = &kt.data()[h * fkl..];
            let vs = &lc.v.data()[h * dh..];
            let dos = &dattn.data()[h * dh..];
            if lc.is_linear {
                // a = q·kᵀ (masked) -> s1; dv_h = aᵀ·do
                gemm::nt(n, fkl, n, qs, hh * fkl, ks, hh * fkl, &mut s1, n);
                if masked {
                    tril_raw(&mut s1, n);
                }
                gemm::tn(n, n, dh, &s1, n, dos, hh * dh, &mut dv.data_mut()[h * dh..], hh * dh);
                // da = do·vᵀ (masked) -> s2; dq = da·k; dk = daᵀ·q
                gemm::nt(n, dh, n, dos, hh * dh, vs, hh * dh, &mut s2, n);
                if masked {
                    tril_raw(&mut s2, n);
                }
                gemm::nn(n, n, fkl, &s2, n, ks, hh * fkl, &mut dqt.data_mut()[h * fkl..], hh * fkl);
                gemm::tn(n, n, fkl, &s2, n, qs, hh * fkl, &mut dkt.data_mut()[h * fkl..], hh * fkl);
            } else {
                // p = softmax(scale q·kᵀ) -> s1; dv_h = pᵀ·do
                gemm::nt(n, dh, n, qs, hh * dh, ks, hh * dh, &mut s1, n);
                softmax_causal_scaled_raw(&mut s1, n, n, scale, 0, 0);
                gemm::tn(n, n, dh, &s1, n, dos, hh * dh, &mut dv.data_mut()[h * dh..], hh * dh);
                // dp = do·vᵀ -> s2; dS = P*(dP - rowsum(dP*P))*scale in s2
                gemm::nt(n, dh, n, dos, hh * dh, vs, hh * dh, &mut s2, n);
                for r in 0..n {
                    let pr = &s1[r * n..(r + 1) * n];
                    let dpr = &mut s2[r * n..(r + 1) * n];
                    let rs: f32 = pr.iter().zip(dpr.iter()).map(|(a, b)| a * b).sum();
                    for (pe, de) in pr.iter().zip(dpr.iter_mut()) {
                        *de = pe * (*de - rs) * scale;
                    }
                }
                gemm::nn(n, n, fkl, &s2, n, ks, hh * fkl, &mut dqt.data_mut()[h * fkl..], hh * fkl);
                gemm::tn(n, n, fkl, &s2, n, qs, hh * fkl, &mut dkt.data_mut()[h * fkl..], hh * fkl);
            }
        }
        scratch::recycle(s1);
        scratch::recycle(s2);
        // decay gates: q~ = q*B, k~ = k/B with B = cumprod(g)
        let mut dhn_gate: Option<Tensor> = None;
        let (dq, dk) = if let (Some(g), Some(b)) = (&lc.g, &lc.b) {
            let dq = dqt.mul(b);
            let dk = dkt.div(b);
            if variant == Variant::Gla {
                // dB = dq~*q - dk~*k/B^2, then the cumprod backward
                // dg_s = (sum_{i>=s} dB_i * B_i) / g_s (g >= floor > 0).
                let wg = pv.layer(i, "wg")?;
                let db = dqt.mul(&lc.q).sub(&dk.mul(&lc.k).div(b));
                let stride = hh * fkl;
                let mut dg = vec![0.0f32; n * stride];
                let (bd, gd, dbd) = (b.data(), g.data(), db.data());
                for j in 0..stride {
                    let mut acc = 0.0f32;
                    for s in (0..n).rev() {
                        acc += dbd[s * stride + j] * bd[s * stride + j];
                        dg[s * stride + j] = acc / gd[s * stride + j];
                    }
                }
                // gate = floor + (1-floor)*sig^(1/tau) with sig=sigmoid(raw):
                // draw = dg * (1-floor)/tau * sig^(1/tau) * (1 - sig).  Both
                // factors are recoverable from the cached gate itself via
                // u = (g-floor)/(1-floor) = sig^(1/tau), so no matmul to
                // rebuild raw: draw = dg * (1-floor)/tau * u * (1 - u^tau).
                let mut draw = Tensor::new(vec![n, stride], dg);
                for (dr, gv) in draw.data_mut().iter_mut().zip(g.data()) {
                    let u = (gv - GATE_FLOOR) / (1.0 - GATE_FLOOR);
                    *dr *= (1.0 - GATE_FLOOR) / GLA_TAU * u * (1.0 - u.powf(GLA_TAU));
                }
                grads[gidx(&format!("layer{i}.wg"))].add_assign(&lc.hn.matmul_tn(&draw));
                dhn_gate = Some(draw.matmul_nt(wg));
            }
            // Retention's lambda is a fixed per-head constant: no gate params.
            (dq, dk)
        } else {
            (dqt, dkt)
        };
        // feature maps (Based/ReBased) on linear layers
        let (dqr, dkr) = match variant {
            Variant::Based if lc.is_linear => (
                phi_based_bwd(lc.qr.as_ref().unwrap(), &dq),
                phi_based_bwd(lc.kr.as_ref().unwrap(), &dk),
            ),
            Variant::Rebased if lc.is_linear => {
                let ga = pv.layer(i, "gamma")?;
                let be = pv.layer(i, "beta")?;
                let (dqr, dga_q, dbe_q) = phi_rebased_bwd(lc.qr.as_ref().unwrap(), ga, be, &dq);
                let (dkr, dga_k, dbe_k) = phi_rebased_bwd(lc.kr.as_ref().unwrap(), ga, be, &dk);
                grads[gidx(&format!("layer{i}.gamma"))].add_assign(&dga_q.add(&dga_k));
                grads[gidx(&format!("layer{i}.beta"))].add_assign(&dbe_q.add(&dbe_k));
                (dqr, dkr)
            }
            _ => (dq, dk),
        };
        let rql = dqr.shape()[2];
        let dqf = dqr.reshape(&[n, hh * rql]);
        let dkf = dkr.reshape(&[n, hh * rql]);
        let dvf = dv.reshape(&[n, hh * dh]);
        let mut dhn = dqf
            .matmul_nt(pv.layer(i, "wq")?)
            .add(&dkf.matmul_nt(pv.layer(i, "wk")?))
            .add(&dvf.matmul_nt(pv.layer(i, "wv")?));
        if let Some(e) = dhn_gate {
            dhn.add_assign(&e);
        }
        grads[gidx(&format!("layer{i}.wq"))].add_assign(&lc.hn.matmul_tn(&dqf));
        grads[gidx(&format!("layer{i}.wk"))].add_assign(&lc.hn.matmul_tn(&dkf));
        grads[gidx(&format!("layer{i}.wv"))].add_assign(&lc.hn.matmul_tn(&dvf));
        let (dx_norm, dln1) = rmsnorm_bwd(&lc.x_in, pv.layer(i, "ln1")?, &dhn);
        grads[gidx(&format!("layer{i}.ln1"))].add_assign(&dln1);
        dx = dy.add(&dx_norm);
    }

    // ---- backward: embedding + positions ----
    let d = cfg.d_model;
    let gemb = gidx("embed");
    let gpos = gidx("pos");
    for (i, &t) in tokens.iter().enumerate() {
        let row = dx.data()[i * d..(i + 1) * d].to_vec();
        let t = t as usize;
        for j in 0..d {
            grads[gemb].data_mut()[t * d + j] += row[j];
            grads[gpos].data_mut()[i * d + j] += row[j];
        }
    }
    Ok(loss)
}

/// Forward + backward over the contiguous batch slice `[lo, hi)`:
/// per-sequence gradients accumulate into their own buffers (even when
/// serial, so the reduction structure — and therefore every bit of the
/// result — is independent of the thread count), then they are summed in
/// fixed batch order starting from zeros.  `denom` must be the GLOBAL
/// loss-mask sum so that a partial slice's loss/grads are exactly the
/// full-batch contribution of those sequences — a ZeRO rank's partial
/// sum, combinable bit-exactly by rank-ordered reduce_scatter.
fn batch_loss_grads(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    masked: bool,
    specs: &[(String, Vec<usize>, Init)],
    pv: &ParamView,
    tokens: &[i32],
    targets: &[i32],
    mask: &Tensor,
    denom: f32,
    lo: usize,
    hi: usize,
) -> Result<(f32, Vec<Tensor>)> {
    let seq = cfg.train_seq;
    let nseq = hi - lo;
    let seq_flops = 8 * seq * cfg.d_model * (cfg.d_model + cfg.ffn_dim) * pattern.len();
    let per_seq: Vec<Result<(f32, Vec<Tensor>)>> =
        par::par_map(nseq, nseq * seq_flops, |i| {
            let b = lo + i;
            let mut g: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
            let l = seq_loss_grads(
                cfg,
                variant,
                pattern,
                pv,
                &mut g,
                &tokens[b * seq..(b + 1) * seq],
                &targets[b * seq..(b + 1) * seq],
                &mask.data()[b * seq..(b + 1) * seq],
                denom,
                masked,
            )?;
            Ok((l, g))
        });
    let mut grads: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
    let mut loss = 0.0f32;
    for r in per_seq {
        let (l, g) = r?;
        loss += l;
        for (acc, gt) in grads.iter_mut().zip(&g) {
            acc.add_assign(gt);
        }
    }
    Ok((loss, grads))
}

/// The flat-signature Adam train step (`train_step_*` artifacts).
fn train_step_impl(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    masked: bool,
    ins: &[Value],
) -> Result<Vec<Tensor>> {
    let specs = param_specs(cfg, variant, pattern);
    let p = specs.len();
    anyhow::ensure!(ins.len() == 3 * p + 5, "train step arity");
    let pv = ParamView::new(&specs, &ins[..p])?;
    let mom: Vec<&Tensor> = ins[p..2 * p]
        .iter()
        .map(|v| v.host_f32())
        .collect::<Result<_>>()?;
    let vel: Vec<&Tensor> = ins[2 * p..3 * p]
        .iter()
        .map(|v| v.host_f32())
        .collect::<Result<_>>()?;
    let tokens = ins[3 * p].host_i32()?;
    let targets = ins[3 * p + 1].host_i32()?;
    let mask = ins[3 * p + 2].host_f32()?;
    let lr = ins[3 * p + 3].host_f32()?.data()[0];
    let step = ins[3 * p + 4].host_f32()?.data()[0];
    let bsz = cfg.train_batch;

    let denom = mask.data().iter().sum::<f32>().max(1.0);
    let (loss, grads) = batch_loss_grads(
        cfg, variant, pattern, masked, &specs, &pv, tokens, targets, mask, denom, 0, bsz,
    )?;

    // AdamW (paper Sec. 4.1 hyperparameters; no decay on norm gains/biases)
    let (b1, b2, eps, wd) = (0.9f32, 0.95f32, 1e-8f32, 0.1f32);
    let bc1 = 1.0 - b1.powf(step);
    let bc2 = 1.0 - b2.powf(step);
    let mut out = Vec::with_capacity(3 * p + 1);
    let mut new_m = Vec::with_capacity(p);
    let mut new_v = Vec::with_capacity(p);
    for i in 0..p {
        let decay = match specs[i].2 {
            Init::Ones | Init::Zeros => 0.0,
            _ => wd,
        };
        let pd = pv.vals[i].data();
        let g = grads[i].data();
        let mut m2 = mom[i].data().to_vec();
        let mut v2 = vel[i].data().to_vec();
        let mut pnew = Vec::with_capacity(pd.len());
        for j in 0..pd.len() {
            m2[j] = b1 * m2[j] + (1.0 - b1) * g[j];
            v2[j] = b2 * v2[j] + (1.0 - b2) * g[j] * g[j];
            let upd = (m2[j] / bc1) / ((v2[j] / bc2).sqrt() + eps);
            pnew.push(pd[j] - lr * (upd + decay * pd[j]));
        }
        let shape = specs[i].1.clone();
        out.push(Tensor::new(shape.clone(), pnew));
        new_m.push(Tensor::new(shape.clone(), m2));
        new_v.push(Tensor::new(shape, v2));
    }
    out.extend(new_m);
    out.extend(new_v);
    out.push(Tensor::scalar1(loss));
    Ok(out)
}

/// The optimizer-free gradient step (`grad_step_*` artifacts): forward +
/// backward over the contiguous `seq_range = [lo, hi)` slice of the batch,
/// returning spec-ordered gradients plus the slice's loss contribution.
/// The loss denominator comes from the FULL batch mask, so a rank that
/// owns `[lo, hi)` produces exactly its additive share of the global
/// gradient: summing the per-rank outputs in rank order (reduce_scatter's
/// contract) reproduces the `train_step_*` gradient bit-for-bit whenever
/// each rank owns at most one sequence, and to fp-rounding otherwise.
/// An empty range (`lo == hi`) is valid and returns exact zeros — idle
/// high ranks when W exceeds the batch size.
fn grad_step_impl(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    masked: bool,
    ins: &[Value],
) -> Result<Vec<Tensor>> {
    let specs = param_specs(cfg, variant, pattern);
    let p = specs.len();
    anyhow::ensure!(ins.len() == p + 4, "grad step arity");
    let pv = ParamView::new(&specs, &ins[..p])?;
    let tokens = ins[p].host_i32()?;
    let targets = ins[p + 1].host_i32()?;
    let mask = ins[p + 2].host_f32()?;
    let range = ins[p + 3].host_i32()?;
    let bsz = cfg.train_batch;
    let (lo, hi) = (range[0] as usize, range[1] as usize);
    anyhow::ensure!(
        range[0] >= 0 && lo <= hi && hi <= bsz,
        "grad step seq_range [{}, {}) outside batch 0..{bsz}",
        range[0],
        range[1]
    );
    let denom = mask.data().iter().sum::<f32>().max(1.0);
    let (loss, mut out) = batch_loss_grads(
        cfg, variant, pattern, masked, &specs, &pv, tokens, targets, mask, denom, lo, hi,
    )?;
    out.push(Tensor::scalar1(loss));
    Ok(out)
}

/// Deterministic parameter init (`init_*` artifacts): rust-side RNG with
/// the python init LAWS (0.02 normal / xavier / ones / zeros).  The exact
/// draws differ from jax.random — only the law matters to callers.
fn init_impl(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
    ins: &[Value],
) -> Result<Vec<Tensor>> {
    let seed = ins[0].host_i32()?[0] as u64;
    let specs = param_specs(cfg, variant, pattern);
    let mut out = Vec::with_capacity(specs.len());
    for (i, (_, shape, init)) in specs.iter().enumerate() {
        let s = seed
            .wrapping_mul(0x9E37_79B9)
            .wrapping_add(i as u64 * 7919 + 1);
        out.push(match init {
            Init::Ones => Tensor::ones(shape),
            Init::Zeros => Tensor::zeros(shape),
            Init::Normal => Tensor::randn(shape, s).scale(0.02),
            Init::Xavier => {
                let fan_in = shape[0];
                let fan_out = *shape.last().unwrap();
                let std = (2.0 / (fan_in + fan_out) as f32).sqrt();
                Tensor::randn(shape, s).scale(std)
            }
        });
    }
    Ok(out)
}

// ================================================================ registry

/// The native artifact registry: name -> (manifest signature, kernel).
pub struct Registry {
    metas: HashMap<String, ArtifactMeta>,
    kernels: HashMap<String, KernelFn>,
}

fn f32m(name: &str, shape: &[usize]) -> TensorMeta {
    TensorMeta { name: name.to_string(), dtype: DType::F32, shape: shape.to_vec() }
}

fn i32m(name: &str, shape: &[usize]) -> TensorMeta {
    TensorMeta { name: name.to_string(), dtype: DType::I32, shape: shape.to_vec() }
}

impl Registry {
    pub fn kernel(&self, name: &str) -> Result<KernelFn> {
        self.kernels
            .get(name)
            .cloned()
            .with_context(|| format!("no native kernel for artifact {name}"))
    }

    /// Synthesize the manifest the rest of the runtime expects (same data
    /// the AOT step would have written, minus the .hlo.txt files).
    pub fn manifest(&self, cfg: &ModelConfig) -> Manifest {
        let mut fields = HashMap::new();
        for (k, v) in [
            ("d_model", cfg.d_model),
            ("n_heads", cfg.n_heads),
            ("n_layers", cfg.n_layers),
            ("vocab", cfg.vocab),
            ("chunk_len", cfg.chunk_len),
            ("max_seq", cfg.max_seq),
            ("head_dim", cfg.head_dim),
            ("ffn_dim", cfg.ffn_dim),
            ("qk_reduced", cfg.qk_reduced),
            ("train_batch", cfg.train_batch),
            ("train_seq", cfg.train_seq),
        ] {
            fields.insert(k.to_string(), v);
        }
        Manifest {
            preset: cfg.preset.clone(),
            fields,
            artifacts: self.metas.clone(),
        }
    }

    fn add(&mut self, name: &str, ins: Vec<TensorMeta>, outs: Vec<TensorMeta>, f: KernelFn) {
        let meta = ArtifactMeta {
            name: name.to_string(),
            file: format!("{name}.native"),
            inputs: ins,
            outputs: outs,
        };
        self.metas.insert(name.to_string(), meta);
        self.kernels.insert(name.to_string(), f);
    }

    /// Build the full registry for one preset (mirrors
    /// `python/compile/aot.py::build_registry`).
    pub fn build(cfg: &ModelConfig) -> Registry {
        let mut reg = Registry { metas: HashMap::new(), kernels: HashMap::new() };
        let (c, d, hh, dh) = (cfg.chunk_len, cfg.d_model, cfg.n_heads, cfg.head_dim);
        let (f, vb, ms) = (cfg.ffn_dim, cfg.vocab, cfg.max_seq);
        let epi_ins = |v: &mut Vec<TensorMeta>| {
            v.push(f32m("wo", &[hh * dh, d]));
            v.push(f32m("ln2", &[d]));
            v.push(f32m("w1", &[d, f]));
            v.push(f32m("w3", &[d, f]));
            v.push(f32m("w2", &[f, d]));
        };

        // ---- embed / head ----
        reg.add(
            "embed",
            vec![
                i32m("tokens", &[c]),
                i32m("offset", &[1]),
                f32m("emb", &[vb, d]),
                f32m("pos", &[ms, d]),
            ],
            vec![f32m("x", &[c, d])],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let toks = ins[0].host_i32()?;
                let off = ins[1].host_i32()?[0];
                anyhow::ensure!(off >= 0, "negative position offset {off}");
                let emb = ins[2].host_f32()?;
                let pos = ins[3].host_f32()?;
                Ok(vec![embed_tokens(cfg, emb, pos, toks, off as usize)?])
            }),
        );
        reg.add(
            "head",
            vec![f32m("x", &[c, d]), f32m("final_ln", &[d]), f32m("emb", &[vb, d])],
            vec![f32m("logits", &[c, vb])],
            Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                let x = ins[0].host_f32()?;
                let ln = ins[1].host_f32()?;
                let emb = ins[2].host_f32()?;
                Ok(vec![rmsnorm(x, ln).matmul_nt(emb)])
            }),
        );

        // ---- linear phases, per variant ----
        for &variant in Variant::linear_variants() {
            let v = variant.name();
            let rq = cfg.qk_dim(variant);
            let fk = cfg.feat_dim(variant);
            let mut p1_ins = vec![
                f32m("x", &[c, d]),
                f32m("ln1", &[d]),
                f32m("wq", &[d, hh * rq]),
                f32m("wk", &[d, hh * rq]),
                f32m("wv", &[d, hh * dh]),
            ];
            match variant {
                Variant::Gla => p1_ins.push(f32m("wg", &[d, hh * rq])),
                Variant::Rebased => {
                    p1_ins.push(f32m("gamma", &[rq]));
                    p1_ins.push(f32m("beta", &[rq]));
                }
                _ => {}
            }
            reg.add(
                &format!("l_part1_{v}"),
                p1_ins,
                vec![
                    f32m("qt", &[c, hh, fk]),
                    f32m("kt", &[c, hh, fk]),
                    f32m("v", &[c, hh, dh]),
                    f32m("m", &[hh, fk, dh]),
                    f32m("a", &[hh, fk]),
                ],
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let ln1 = ins[1].host_f32()?;
                    let wq = ins[2].host_f32()?;
                    let wk = ins[3].host_f32()?;
                    let wv = ins[4].host_f32()?;
                    let extra: Vec<&Tensor> = ins[5..]
                        .iter()
                        .map(|e| e.host_f32())
                        .collect::<Result<_>>()?;
                    let p = linear_part1(cfg, variant, x, ln1, wq, wk, wv, &extra);
                    Ok(vec![p.qt, p.kt, p.v, p.m, p.a])
                }),
            );
            let mut p2_ins = vec![
                f32m("x", &[c, d]),
                f32m("qt", &[c, hh, fk]),
                f32m("kt", &[c, hh, fk]),
                f32m("v", &[c, hh, dh]),
                f32m("m_prefix", &[hh, fk, dh]),
            ];
            epi_ins(&mut p2_ins);
            reg.add(
                &format!("l_part2_{v}"),
                p2_ins,
                vec![f32m("y", &[c, d])],
                Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let qt = ins[1].host_f32()?;
                    let kt = ins[2].host_f32()?;
                    let v = ins[3].host_f32()?;
                    let mp = ins[4].host_f32()?;
                    let attn = attn_heads_fused(qt, kt, v, mp);
                    Ok(vec![epilogue(
                        x,
                        &attn,
                        ins[5].host_f32()?,
                        ins[6].host_f32()?,
                        ins[7].host_f32()?,
                        ins[8].host_f32()?,
                        ins[9].host_f32()?,
                    )])
                }),
            );
            reg.add(
                &format!("l_intra_{v}"),
                vec![
                    f32m("qt", &[c, hh, fk]),
                    f32m("kt", &[c, hh, fk]),
                    f32m("v", &[c, hh, dh]),
                ],
                vec![f32m("o_intra", &[c, hh, dh])],
                Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                    Ok(vec![intra_heads(
                        ins[0].host_f32()?,
                        ins[1].host_f32()?,
                        ins[2].host_f32()?,
                    )])
                }),
            );
            let mut p2b_ins = vec![
                f32m("x", &[c, d]),
                f32m("qt", &[c, hh, fk]),
                f32m("o_intra", &[c, hh, dh]),
                f32m("m_prefix", &[hh, fk, dh]),
            ];
            epi_ins(&mut p2b_ins);
            reg.add(
                &format!("l_part2b_{v}"),
                p2b_ins,
                vec![f32m("y", &[c, d])],
                Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let qt = ins[1].host_f32()?;
                    let o_intra = ins[2].host_f32()?;
                    let mp = ins[3].host_f32()?;
                    // clone-then-accumulate keeps the per-element chain
                    // identical to the fused l_part2 kernel (o_intra +
                    // panel partials, NOT o_intra + a separately
                    // materialized inter total)
                    let mut attn = o_intra.clone();
                    inter_acc_heads(qt, mp, &mut attn);
                    Ok(vec![epilogue(
                        x,
                        &attn,
                        ins[4].host_f32()?,
                        ins[5].host_f32()?,
                        ins[6].host_f32()?,
                        ins[7].host_f32()?,
                        ins[8].host_f32()?,
                    )])
                }),
            );
        }

        // ---- bidirectional (Alg. 1) part2, basic ----
        let mut nm_ins = vec![
            f32m("x", &[c, d]),
            f32m("qt", &[c, hh, dh]),
            f32m("v", &[c, hh, dh]),
            f32m("m_total", &[hh, dh, dh]),
        ];
        epi_ins(&mut nm_ins);
        reg.add(
            "l_part2nm_basic",
            nm_ins,
            vec![f32m("y", &[c, d])],
            Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                let x = ins[0].host_f32()?;
                let qt = ins[1].host_f32()?;
                // ins[2] (v) is unused: Alg. 1 line 8 is O = Q M_{1:T} only.
                let mt = ins[3].host_f32()?;
                let attn = inter_heads(qt, mt);
                Ok(vec![epilogue(
                    x,
                    &attn,
                    ins[4].host_f32()?,
                    ins[5].host_f32()?,
                    ins[6].host_f32()?,
                    ins[7].host_f32()?,
                    ins[8].host_f32()?,
                )])
            }),
        );

        // ---- backward phases (basic variant, Alg. 3/4) ----
        reg.add(
            "l_bwd1_basic",
            vec![f32m("qt", &[c, hh, dh]), f32m("do", &[c, hh, dh])],
            vec![f32m("dm", &[hh, dh, dh])],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let qt = ins[0].host_f32()?;
                let do_t = ins[1].host_f32()?;
                let (hh, dh) = (cfg.n_heads, cfg.head_dim);
                let c = qt.shape()[0];
                let mut dm = Tensor::zeros(&[hh, dh, dh]);
                for h in 0..hh {
                    // dM_h = Q_hᵀ · dO_h, strided in place
                    gemm::tn(
                        dh,
                        c,
                        dh,
                        &qt.data()[h * dh..],
                        hh * dh,
                        &do_t.data()[h * dh..],
                        hh * dh,
                        &mut dm.data_mut()[h * dh * dh..(h + 1) * dh * dh],
                        dh,
                    );
                }
                Ok(vec![dm])
            }),
        );
        reg.add(
            "l_bwd2_basic",
            vec![
                f32m("qt", &[c, hh, dh]),
                f32m("kt", &[c, hh, dh]),
                f32m("v", &[c, hh, dh]),
                f32m("do", &[c, hh, dh]),
                f32m("m_prefix", &[hh, dh, dh]),
                f32m("dm_suffix", &[hh, dh, dh]),
            ],
            vec![
                f32m("dq", &[c, hh, dh]),
                f32m("dk", &[c, hh, dh]),
                f32m("dv", &[c, hh, dh]),
            ],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let qt = ins[0].host_f32()?;
                let kt = ins[1].host_f32()?;
                let v = ins[2].host_f32()?;
                let do_t = ins[3].host_f32()?;
                let mp = ins[4].host_f32()?;
                let suf = ins[5].host_f32()?;
                let (cc, hh, dh) = (cfg.chunk_len, cfg.n_heads, cfg.head_dim);
                let ld = hh * dh;
                let mut dq = Tensor::zeros(&[cc, hh, dh]);
                let mut dk = Tensor::zeros(&[cc, hh, dh]);
                let mut dv = Tensor::zeros(&[cc, hh, dh]);
                let mut dov = scratch::take(cc * cc);
                let mut qk = scratch::take(cc * cc);
                for h in 0..hh {
                    let qs = &qt.data()[h * dh..];
                    let ks = &kt.data()[h * dh..];
                    let vs = &v.data()[h * dh..];
                    let dos = &do_t.data()[h * dh..];
                    let mph = &mp.data()[h * dh * dh..(h + 1) * dh * dh];
                    let sufh = &suf.data()[h * dh * dh..(h + 1) * dh * dh];
                    gemm::nt(cc, dh, cc, dos, ld, vs, ld, &mut dov, cc);
                    tril_raw(&mut dov, cc);
                    gemm::nt(cc, dh, cc, qs, ld, ks, ld, &mut qk, cc);
                    tril_raw(&mut qk, cc);
                    // dQ_h = dOV·K + dO·M_prefixᵀ
                    gemm::nn(cc, cc, dh, &dov, cc, ks, ld, &mut dq.data_mut()[h * dh..], ld);
                    gemm::nt_acc(cc, dh, dh, dos, ld, mph, dh, &mut dq.data_mut()[h * dh..], ld);
                    // dK_h = dOVᵀ·Q + V·dM_suffixᵀ
                    gemm::tn(cc, cc, dh, &dov, cc, qs, ld, &mut dk.data_mut()[h * dh..], ld);
                    gemm::nt_acc(cc, dh, dh, vs, ld, sufh, dh, &mut dk.data_mut()[h * dh..], ld);
                    // dV_h = QKᵀ·dO + K·dM_suffix
                    gemm::tn(cc, cc, dh, &qk, cc, dos, ld, &mut dv.data_mut()[h * dh..], ld);
                    gemm::nn_acc(cc, dh, dh, ks, ld, sufh, dh, &mut dv.data_mut()[h * dh..], ld);
                }
                scratch::recycle(dov);
                scratch::recycle(qk);
                Ok(vec![dq, dk, dv])
            }),
        );

        // ---- standard-attention phases + baselines ----
        reg.add(
            "s_part1",
            vec![
                f32m("x", &[c, d]),
                f32m("ln1", &[d]),
                f32m("wq", &[d, hh * dh]),
                f32m("wk", &[d, hh * dh]),
                f32m("wv", &[d, hh * dh]),
            ],
            vec![
                f32m("q", &[c, hh, dh]),
                f32m("k", &[c, hh, dh]),
                f32m("v", &[c, hh, dh]),
            ],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let x = ins[0].host_f32()?;
                let hn = rmsnorm(x, ins[1].host_f32()?);
                let cc = x.shape()[0];
                let (hh, dh) = (cfg.n_heads, cfg.head_dim);
                Ok(vec![
                    hn.matmul(ins[2].host_f32()?).reshape(&[cc, hh, dh]),
                    hn.matmul(ins[3].host_f32()?).reshape(&[cc, hh, dh]),
                    hn.matmul(ins[4].host_f32()?).reshape(&[cc, hh, dh]),
                ])
            }),
        );
        for &w in cfg.sp_world_sizes() {
            let n_all = w * c;
            let mut sp2_ins = vec![
                f32m("x", &[c, d]),
                f32m("q", &[c, hh, dh]),
                f32m("k_all", &[n_all, hh, dh]),
                f32m("v_all", &[n_all, hh, dh]),
                i32m("offset", &[1]),
            ];
            epi_ins(&mut sp2_ins);
            reg.add(
                &format!("s_part2_T{w}"),
                sp2_ins,
                vec![f32m("y", &[c, d])],
                Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let q = ins[1].host_f32()?;
                    let k_all = ins[2].host_f32()?;
                    let v_all = ins[3].host_f32()?;
                    let off = ins[4].host_i32()?[0];
                    let attn = softmax_attn_heads(q, k_all, v_all, off);
                    Ok(vec![epilogue(
                        x,
                        &attn,
                        ins[5].host_f32()?,
                        ins[6].host_f32()?,
                        ins[7].host_f32()?,
                        ins[8].host_f32()?,
                        ins[9].host_f32()?,
                    )])
                }),
            );
            reg.add(
                &format!("mega_attn_basic_T{w}"),
                vec![
                    f32m("qt", &[c, hh, dh]),
                    f32m("k_all", &[n_all, hh, dh]),
                    f32m("v_all", &[n_all, hh, dh]),
                    i32m("offset", &[1]),
                ],
                vec![f32m("attn", &[c, hh, dh])],
                Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                    let qt = ins[0].host_f32()?;
                    let k_all = ins[1].host_f32()?;
                    let v_all = ins[2].host_f32()?;
                    let off = ins[3].host_i32()?[0];
                    let (cc, hh, dh) = (cfg.chunk_len, cfg.n_heads, cfg.head_dim);
                    let n_all = k_all.shape()[0];
                    let ld = hh * dh;
                    let mut out = Tensor::zeros(&[cc, hh, dh]);
                    let one_head = |h: usize, s: &mut [f32], o: &mut [f32], ldo: usize| {
                        let (qs, ks) = (&qt.data()[h * dh..], &k_all.data()[h * dh..]);
                        gemm::nt(cc, dh, n_all, qs, ld, ks, ld, s, n_all);
                        offset_causal_zero_raw(s, cc, n_all, off, 0);
                        gemm::nn(cc, n_all, dh, s, n_all, &v_all.data()[h * dh..], ld, o, ldo);
                    };
                    let flops = 4 * cc * n_all * dh * hh;
                    if par::would_parallelize(hh, flops) {
                        let heads: Vec<Vec<f32>> = par::par_map(hh, flops, |h| {
                            let mut s = scratch::take(cc * n_all);
                            let mut oh = scratch::take(cc * dh);
                            one_head(h, &mut s, &mut oh, dh);
                            scratch::recycle(s);
                            oh
                        });
                        for (h, oh) in heads.into_iter().enumerate() {
                            scatter_head(&mut out, h, &oh);
                            scratch::recycle(oh);
                        }
                    } else {
                        let mut s = scratch::take(cc * n_all);
                        for h in 0..hh {
                            one_head(h, &mut s, &mut out.data_mut()[h * dh..], ld);
                        }
                        scratch::recycle(s);
                    }
                    Ok(vec![out])
                }),
            );
        }
        let mut post_ins = vec![f32m("x", &[c, d]), f32m("attn", &[c, hh, dh])];
        epi_ins(&mut post_ins);
        reg.add(
            "post_attn",
            post_ins,
            vec![f32m("y", &[c, d])],
            Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                Ok(vec![epilogue(
                    ins[0].host_f32()?,
                    ins[1].host_f32()?,
                    ins[2].host_f32()?,
                    ins[3].host_f32()?,
                    ins[4].host_f32()?,
                    ins[5].host_f32()?,
                    ins[6].host_f32()?,
                )])
            }),
        );
        // ---- Ulysses / USP head-sharded phases ----
        // After an All-to-All repartition a rank owns `hl` heads of a
        // longer span: the full sequence (Ulysses, row size = W) or a mesh
        // row's segment (USP, row size u | W).  Register one kernel per
        // (query len, gathered len, owned heads) combination reachable
        // from `sp_world_sizes` and its divisors.
        for &w in cfg.sp_world_sizes() {
            let n_all = w * c;
            for u in 1..=w {
                if w % u != 0 {
                    continue;
                }
                let qlen = u * c;
                let mut hls: Vec<usize> = head_partition(hh, u)
                    .into_iter()
                    .map(|(_, n)| n)
                    .filter(|&n| n > 0)
                    .collect();
                hls.sort_unstable();
                hls.dedup();
                for hl in hls {
                    let name = format!("s_attn_hs_Q{qlen}_N{n_all}_H{hl}");
                    if reg.metas.contains_key(&name) {
                        continue;
                    }
                    reg.add(
                        &name,
                        vec![
                            f32m("q", &[qlen, hl, dh]),
                            f32m("k_all", &[n_all, hl, dh]),
                            f32m("v_all", &[n_all, hl, dh]),
                            i32m("offset", &[1]),
                        ],
                        vec![f32m("attn", &[qlen, hl, dh])],
                        Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                            Ok(vec![softmax_attn_heads(
                                ins[0].host_f32()?,
                                ins[1].host_f32()?,
                                ins[2].host_f32()?,
                                ins[3].host_i32()?[0],
                            )])
                        }),
                    );
                }
            }
        }
        // Ulysses linear path: the full-sequence chunkwise scan over the
        // rank's owned heads — the same Alg. 2 recurrence LASP-2 evaluates
        // after its AllGather (intra + inter with the exclusive gated
        // prefix), run T = W chunks deep on one device, so it is
        // bit-identical to `l_part2` per head.
        for &variant in Variant::linear_variants() {
            let v = variant.name();
            let fk = cfg.feat_dim(variant);
            for &w in cfg.sp_world_sizes() {
                let mut hls: Vec<usize> = head_partition(hh, w)
                    .into_iter()
                    .map(|(_, n)| n)
                    .filter(|&n| n > 0)
                    .collect();
                hls.sort_unstable();
                hls.dedup();
                for hl in hls {
                    let name = format!("l_chunk_hs_{v}_T{w}_H{hl}");
                    if reg.metas.contains_key(&name) {
                        continue;
                    }
                    reg.add(
                        &name,
                        vec![
                            f32m("qt", &[w * c, hl, fk]),
                            f32m("kt", &[w * c, hl, fk]),
                            f32m("v", &[w * c, hl, dh]),
                            f32m("m", &[w * hl, fk, dh]),
                            f32m("a", &[w * hl, fk]),
                        ],
                        vec![f32m("o", &[w * c, hl, dh])],
                        Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                            let qt = ins[0].host_f32()?;
                            let kt = ins[1].host_f32()?;
                            let v = ins[2].host_f32()?;
                            let m = ins[3].host_f32()?;
                            let a = ins[4].host_f32()?;
                            let t_chunks = qt.shape()[0] / cfg.chunk_len;
                            let qts = qt.chunk0(t_chunks);
                            let kts = kt.chunk0(t_chunks);
                            let vs = v.chunk0(t_chunks);
                            let ms = m.chunk0(t_chunks);
                            let as_ = a.chunk0(t_chunks);
                            let mut prefix = ChunkState {
                                m: Tensor::zeros(ms[0].shape()),
                                a: Tensor::ones(as_[0].shape()),
                            };
                            let mut outs = Vec::with_capacity(t_chunks);
                            for t in 0..t_chunks {
                                let o = attn_heads_fused(&qts[t], &kts[t], &vs[t], &prefix.m);
                                outs.push(o);
                                prefix = state_combine(
                                    &prefix,
                                    &ChunkState { m: ms[t].clone(), a: as_[t].clone() },
                                );
                            }
                            Ok(vec![Tensor::cat0(&outs)])
                        }),
                    );
                }
            }
        }
        reg.add(
            "ring_linear_step",
            vec![
                f32m("qt", &[c, hh, dh]),
                f32m("k_j", &[c, hh, dh]),
                f32m("v_j", &[c, hh, dh]),
                f32m("acc", &[c, hh, dh]),
                i32m("qoff", &[1]),
                i32m("koff", &[1]),
            ],
            vec![f32m("acc", &[c, hh, dh])],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let qt = ins[0].host_f32()?;
                let kj = ins[1].host_f32()?;
                let vj = ins[2].host_f32()?;
                let acc = ins[3].host_f32()?;
                let qoff = ins[4].host_i32()?[0];
                let koff = ins[5].host_i32()?[0];
                let (hh, dh) = (cfg.n_heads, cfg.head_dim);
                let cc = qt.shape()[0];
                let ld = hh * dh;
                let mut out = acc.clone();
                let mut s = scratch::take(cc * cc);
                for h in 0..hh {
                    let (qs, ks) = (&qt.data()[h * dh..], &kj.data()[h * dh..]);
                    gemm::nt(cc, dh, cc, qs, ld, ks, ld, &mut s, cc);
                    offset_causal_zero_raw(&mut s, cc, cc, qoff, koff);
                    let o = &mut out.data_mut()[h * dh..];
                    gemm::nn_acc(cc, cc, dh, &s, cc, &vj.data()[h * dh..], ld, o, ld);
                }
                scratch::recycle(s);
                Ok(vec![out])
            }),
        );
        reg.add(
            "ring_step",
            vec![
                f32m("q", &[c, hh, dh]),
                f32m("k", &[c, hh, dh]),
                f32m("v", &[c, hh, dh]),
                f32m("m", &[c, hh]),
                f32m("l", &[c, hh]),
                f32m("acc", &[c, hh, dh]),
                i32m("qoff", &[1]),
                i32m("koff", &[1]),
            ],
            vec![
                f32m("m", &[c, hh]),
                f32m("l", &[c, hh]),
                f32m("acc", &[c, hh, dh]),
            ],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let q = ins[0].host_f32()?;
                let k = ins[1].host_f32()?;
                let v = ins[2].host_f32()?;
                let m_prev = ins[3].host_f32()?;
                let l_prev = ins[4].host_f32()?;
                let acc_prev = ins[5].host_f32()?;
                let qoff = ins[6].host_i32()?[0];
                let koff = ins[7].host_i32()?[0];
                let (cc, hh, dh) = (cfg.chunk_len, cfg.n_heads, cfg.head_dim);
                let ld = hh * dh;
                let scale = 1.0 / (dh as f32).sqrt();
                let mut m_out = m_prev.clone();
                let mut l_out = l_prev.clone();
                let mut acc_out = acc_prev.clone();
                let mut s = scratch::take(cc * cc);
                let mut pv = scratch::take(dh);
                let vd = v.data();
                for h in 0..hh {
                    let (qs, ks) = (&q.data()[h * dh..], &k.data()[h * dh..]);
                    gemm::nt(cc, dh, cc, qs, ld, ks, ld, &mut s, cc);
                    for i in 0..cc {
                        let row = &mut s[i * cc..(i + 1) * cc];
                        for (j, sv) in row.iter_mut().enumerate() {
                            if qoff + i as i32 < koff + j as i32 {
                                *sv = NEG_INF;
                            } else {
                                *sv *= scale;
                            }
                        }
                        let row = &s[i * cc..(i + 1) * cc];
                        let mp = m_prev.data()[i * hh + h];
                        let rowmax = row.iter().fold(NEG_INF, |a, &b| a.max(b));
                        let mn = mp.max(rowmax);
                        let alpha = (mp - mn).exp();
                        let mut psum = 0.0f32;
                        pv.fill(0.0);
                        for (j, &sv) in row.iter().enumerate() {
                            let p = (sv - mn).exp();
                            psum += p;
                            let vr = &vd[(j * hh + h) * dh..(j * hh + h + 1) * dh];
                            for (acc_j, &vv) in pv.iter_mut().zip(vr) {
                                *acc_j += p * vv;
                            }
                        }
                        m_out.data_mut()[i * hh + h] = mn;
                        l_out.data_mut()[i * hh + h] = alpha * l_prev.data()[i * hh + h] + psum;
                        for jd in 0..dh {
                            let idx = (i * hh + h) * dh + jd;
                            acc_out.data_mut()[idx] = acc_prev.data()[idx] * alpha + pv[jd];
                        }
                    }
                }
                scratch::recycle(s);
                scratch::recycle(pv);
                Ok(vec![m_out, l_out, acc_out])
            }),
        );
        reg.add(
            "ring_finalize",
            vec![f32m("l", &[c, hh]), f32m("acc", &[c, hh, dh])],
            vec![f32m("attn", &[c, hh, dh])],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let l = ins[0].host_f32()?;
                let acc = ins[1].host_f32()?;
                let dh = cfg.head_dim;
                let mut out = acc.clone();
                for (i, v) in out.data_mut().iter_mut().enumerate() {
                    // acc index (row*H + h)*dh + j  ->  l index row*H + h
                    *v /= l.data()[i / dh];
                }
                Ok(vec![out])
            }),
        );

        // ---- monolithic oracles ----
        let mono_set: Vec<(&str, &str)> = {
            let mut s: Vec<(&str, &str)> = Variant::linear_variants()
                .iter()
                .map(|v| (v.name(), "0"))
                .collect();
            s.push(("basic", "1/4"));
            s.push(("basic", "1/2"));
            s.push(("softmax", "all"));
            s
        };
        for &w in cfg.sp_world_sizes() {
            let n = w * c;
            for &(vname, ratio) in &mono_set {
                let variant = if vname == "softmax" {
                    Variant::Basic
                } else {
                    Variant::parse(vname).unwrap()
                };
                let pattern = Pattern::from_ratio(cfg.n_layers, ratio).unwrap();
                let tag = Pattern::tag(ratio);
                let specs = param_specs(cfg, variant, &pattern);
                let mut ins: Vec<TensorMeta> = specs
                    .iter()
                    .map(|(nm, sh, _)| f32m(&format!("p.{nm}"), sh))
                    .collect();
                ins.push(i32m("tokens", &[n]));
                let pat = pattern.clone();
                reg.add(
                    &format!("forward_mono_{vname}_{tag}_N{n}"),
                    ins,
                    vec![f32m("logits", &[n, vb])],
                    Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                        let specs = param_specs(cfg, variant, &pat);
                        let p = specs.len();
                        let pv = ParamView::new(&specs, &ins[..p])?;
                        let tokens = ins[p].host_i32()?;
                        Ok(vec![forward_tokens(cfg, variant, &pat, &pv, tokens, true)?])
                    }),
                );
            }
        }

        // ---- serving decode artifacts (serve::Session / serve::Batch) ----
        // One autoregressive step at batch size B: the linear layers fold
        // the whole chunked LASP-2 machinery into the per-head recurrent
        // state update M <- diag(g) M + k^T v with readout o = q~ M (the
        // Lightning-Attention-2 decode form — O(1) memory in position);
        // the std layers attend against an explicit KV cache (O(pos)
        // memory), which is exactly the contrast the decode bench shows.
        reg.add(
            "s_prefill",
            {
                let mut v = vec![
                    f32m("x", &[c, d]),
                    f32m("ln1", &[d]),
                    f32m("wq", &[d, hh * dh]),
                    f32m("wk", &[d, hh * dh]),
                    f32m("wv", &[d, hh * dh]),
                    // capacity-sized caches: dim 0 is a wildcard (the
                    // serve layer grows them; only `len` rows are live)
                    f32m("k_cache", &[0, hh, dh]),
                    f32m("v_cache", &[0, hh, dh]),
                    i32m("len", &[1]),
                ];
                epi_ins(&mut v);
                v
            },
            vec![
                f32m("y", &[c, d]),
                f32m("k_new", &[c, hh, dh]),
                f32m("v_new", &[c, hh, dh]),
            ],
            Arc::new(|cfg: &ModelConfig, ins: &[Value]| {
                let x = ins[0].host_f32()?;
                let ln1 = ins[1].host_f32()?;
                let kc = ins[5].host_f32()?;
                let vc = ins[6].host_f32()?;
                let len = ins[7].host_i32()?[0];
                let cc = x.shape()[0];
                let (hh, dh, ms) = (cfg.n_heads, cfg.head_dim, cfg.max_seq);
                anyhow::ensure!(
                    len >= 0 && len as usize + cc <= ms,
                    "s_prefill: kv len {len} + chunk {cc} exceeds max_seq {ms}"
                );
                anyhow::ensure!(
                    len as usize <= kc.shape()[0] && len as usize <= vc.shape()[0],
                    "s_prefill: kv len {len} exceeds cache capacity {}",
                    kc.shape()[0]
                );
                let qoff = len;
                let len = len as usize;
                let hn = rmsnorm(x, ln1);
                let q = hn.matmul(ins[2].host_f32()?).reshape(&[cc, hh, dh]);
                let k = hn.matmul(ins[3].host_f32()?).reshape(&[cc, hh, dh]);
                let v = hn.matmul(ins[4].host_f32()?).reshape(&[cc, hh, dh]);
                // attend directly over the live cache rows + the new chunk
                // (no gathered K/V copy): scores [cc, len + cc] per head,
                // cache columns then new columns
                let stride = hh * dh;
                let scale = 1.0 / (dh as f32).sqrt();
                let w = len + cc;
                let mut attn = Tensor::zeros(&[cc, hh, dh]);
                let mut s = scratch::take(cc * w);
                for h in 0..hh {
                    let qs = &q.data()[h * dh..];
                    // len == 0 also means the cache may still be capacity
                    // 0 (a fresh session) — don't even slice it then
                    if len > 0 {
                        let ks = &kc.data()[h * dh..];
                        gemm::nt(cc, dh, len, qs, stride, ks, stride, &mut s, w);
                    }
                    let new_cols = &mut s[len..];
                    gemm::nt(cc, dh, cc, qs, stride, &k.data()[h * dh..], stride, new_cols, w);
                    softmax_causal_scaled_raw(&mut s, cc, w, scale, qoff, 0);
                    let out = &mut attn.data_mut()[h * dh..];
                    if len > 0 {
                        let vrows = &vc.data()[h * dh..];
                        gemm::nn(cc, len, dh, &s, w, vrows, stride, out, stride);
                    }
                    let vs = &v.data()[h * dh..];
                    gemm::nn_acc(cc, cc, dh, &s[len..], w, vs, stride, out, stride);
                }
                scratch::recycle(s);
                let y = epilogue(
                    x,
                    &attn,
                    ins[8].host_f32()?,
                    ins[9].host_f32()?,
                    ins[10].host_f32()?,
                    ins[11].host_f32()?,
                    ins[12].host_f32()?,
                );
                Ok(vec![y, k, v])
            }),
        );
        for &b in DECODE_BATCH_SIZES {
            reg.add(
                &format!("embed_dec_B{b}"),
                vec![
                    i32m("tokens", &[b]),
                    i32m("offsets", &[b]),
                    f32m("emb", &[vb, d]),
                    f32m("pos", &[ms, d]),
                ],
                vec![f32m("x", &[b, d])],
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    let toks = ins[0].host_i32()?;
                    let offs = ins[1].host_i32()?;
                    let emb = ins[2].host_f32()?;
                    let pos = ins[3].host_f32()?;
                    let mut rows = Vec::with_capacity(b);
                    for bi in 0..b {
                        anyhow::ensure!(
                            offs[bi] >= 0,
                            "negative position offset {}",
                            offs[bi]
                        );
                        rows.push(embed_tokens(
                            cfg,
                            emb,
                            pos,
                            &toks[bi..bi + 1],
                            offs[bi] as usize,
                        )?);
                    }
                    Ok(vec![Tensor::cat0(&rows)])
                }),
            );
            reg.add(
                &format!("head_dec_B{b}"),
                vec![
                    f32m("x", &[b, d]),
                    f32m("final_ln", &[d]),
                    f32m("emb", &[vb, d]),
                ],
                vec![f32m("logits", &[b, vb])],
                Arc::new(|_cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let ln = ins[1].host_f32()?;
                    let emb = ins[2].host_f32()?;
                    Ok(vec![rmsnorm(x, ln).matmul_nt(emb)])
                }),
            );
            reg.add(
                &format!("s_decode_B{b}"),
                {
                    let mut v = vec![
                        f32m("x", &[b, d]),
                        f32m("ln1", &[d]),
                        f32m("wq", &[d, hh * dh]),
                        f32m("wk", &[d, hh * dh]),
                        f32m("wv", &[d, hh * dh]),
                        // per-session capacity in dim 1 is a wildcard; the
                        // kernel reads the live extent off the tensor
                        f32m("k_cache", &[b, 0, hh, dh]),
                        f32m("v_cache", &[b, 0, hh, dh]),
                        i32m("len", &[b]),
                    ];
                    epi_ins(&mut v);
                    v
                },
                vec![
                    f32m("y", &[b, d]),
                    f32m("k_new", &[b, hh, dh]),
                    f32m("v_new", &[b, hh, dh]),
                ],
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    let x = ins[0].host_f32()?;
                    let ln1 = ins[1].host_f32()?;
                    let wq = ins[2].host_f32()?;
                    let wk = ins[3].host_f32()?;
                    let wv = ins[4].host_f32()?;
                    let kc = ins[5].host_f32()?;
                    let vc = ins[6].host_f32()?;
                    let lens = ins[7].host_i32()?;
                    let epi: Vec<&Tensor> = ins[8..13]
                        .iter()
                        .map(|e| e.host_f32())
                        .collect::<Result<_>>()?;
                    let (hh, dh, ms) = (cfg.n_heads, cfg.head_dim, cfg.max_seq);
                    let cap = kc.shape()[1];
                    let stride = hh * dh;
                    let scale = 1.0 / (dh as f32).sqrt();
                    let d = cfg.d_model;
                    let mut flops = 0usize;
                    for bi in 0..b {
                        let len = lens[bi];
                        anyhow::ensure!(
                            len >= 0 && (len as usize) < ms,
                            "s_decode: kv len {len} out of range (max_seq {ms})"
                        );
                        anyhow::ensure!(
                            len as usize <= cap,
                            "s_decode: kv len {len} exceeds cache capacity {cap}"
                        );
                        flops += 8 * d * stride + 6 * d * cfg.ffn_dim + 4 * len as usize * stride;
                    }
                    // session-parallel: each batch row attends over its own
                    // LIVE cache rows (no per-step gathered K/V copy)
                    let rows: Vec<Result<(Tensor, Tensor, Tensor)>> =
                        par::par_map(b, flops, |bi| {
                            let xb = row0(x, bi);
                            let hn = rmsnorm(&xb, ln1);
                            let q = hn.matmul(wq).reshape(&[1, hh, dh]);
                            let k = hn.matmul(wk).reshape(&[1, hh, dh]);
                            let v = hn.matmul(wv).reshape(&[1, hh, dh]);
                            let len = lens[bi] as usize;
                            let base = bi * cap * stride;
                            let mut attn = Tensor::zeros(&[1, hh, dh]);
                            let mut s = scratch::take(len + 1);
                            for h in 0..hh {
                                let qh = &q.data()[h * dh..(h + 1) * dh];
                                // len == 0 can mean a capacity-0 fresh
                                // cache — don't slice it then
                                if len > 0 {
                                    gemm::nt(
                                        1,
                                        dh,
                                        len,
                                        qh,
                                        dh,
                                        &kc.data()[base + h * dh..],
                                        stride,
                                        &mut s,
                                        len + 1,
                                    );
                                }
                                let kh = &k.data()[h * dh..(h + 1) * dh];
                                s[len] = qh.iter().zip(kh).map(|(a, b2)| a * b2).sum();
                                // q sits at position len: every entry visible
                                softmax_causal_scaled_raw(&mut s, 1, len + 1, scale, len as i32, 0);
                                let out = &mut attn.data_mut()[h * dh..(h + 1) * dh];
                                if len > 0 {
                                    let vrows = &vc.data()[base + h * dh..];
                                    gemm::nn(1, len, dh, &s, len + 1, vrows, stride, out, dh);
                                }
                                let pl = s[len];
                                let vh = &v.data()[h * dh..(h + 1) * dh];
                                for (o, &vv) in out.iter_mut().zip(vh) {
                                    *o += pl * vv;
                                }
                            }
                            scratch::recycle(s);
                            let y = epilogue(&xb, &attn, epi[0], epi[1], epi[2], epi[3], epi[4]);
                            Ok((y, k, v))
                        });
                    let mut ys = Vec::with_capacity(b);
                    let mut kn = Vec::with_capacity(b);
                    let mut vn = Vec::with_capacity(b);
                    for r in rows {
                        let (y, k, v) = r?;
                        ys.push(y);
                        kn.push(k);
                        vn.push(v);
                    }
                    Ok(vec![
                        Tensor::cat0(&ys),
                        Tensor::cat0(&kn),
                        Tensor::cat0(&vn),
                    ])
                }),
            );
            for &variant in Variant::linear_variants() {
                let v = variant.name();
                let rq = cfg.qk_dim(variant);
                let fk = cfg.feat_dim(variant);
                let mut ld_ins = vec![
                    f32m("x", &[b, d]),
                    f32m("ln1", &[d]),
                    f32m("wq", &[d, hh * rq]),
                    f32m("wk", &[d, hh * rq]),
                    f32m("wv", &[d, hh * dh]),
                ];
                match variant {
                    Variant::Gla => ld_ins.push(f32m("wg", &[d, hh * rq])),
                    Variant::Rebased => {
                        ld_ins.push(f32m("gamma", &[rq]));
                        ld_ins.push(f32m("beta", &[rq]));
                    }
                    _ => {}
                }
                ld_ins.push(f32m("m", &[b, hh, fk, dh]));
                epi_ins(&mut ld_ins);
                reg.add(
                    &format!("l_decode_{v}_B{b}"),
                    ld_ins,
                    vec![
                        f32m("y", &[b, d]),
                        f32m("m_new", &[b, hh, fk, dh]),
                        f32m("a", &[b, hh, fk]),
                    ],
                    Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                        let x = ins[0].host_f32()?;
                        let ln1 = ins[1].host_f32()?;
                        let wq = ins[2].host_f32()?;
                        let wk = ins[3].host_f32()?;
                        let wv = ins[4].host_f32()?;
                        let ex_n = match variant {
                            Variant::Gla => 1,
                            Variant::Rebased => 2,
                            _ => 0,
                        };
                        let extra: Vec<&Tensor> = ins[5..5 + ex_n]
                            .iter()
                            .map(|e| e.host_f32())
                            .collect::<Result<_>>()?;
                        let m_in = ins[5 + ex_n].host_f32()?;
                        let epi: Vec<&Tensor> = ins[6 + ex_n..11 + ex_n]
                            .iter()
                            .map(|e| e.host_f32())
                            .collect::<Result<_>>()?;
                        let (hh, dh, d) = (cfg.n_heads, cfg.head_dim, cfg.d_model);
                        let fk = cfg.feat_dim(variant);
                        let mstride = hh * fk * dh;
                        // session-parallel: every batch row's recurrent-state
                        // step is independent
                        let proj = 3 * hh * fk + hh * dh + 3 * cfg.ffn_dim;
                        let flops = b * (2 * d * proj + 4 * hh * fk * dh);
                        let rows: Vec<(Tensor, Tensor, Tensor)> = par::par_map(b, flops, |bi| {
                            let xb = row0(x, bi);
                            // c=1 chunk through the validated part1 path:
                            // qt = q*g, kt = k/g, p.m = k^T v, p.a = g
                            let p = linear_part1(cfg, variant, &xb, ln1, wq, wk, wv, &extra);
                            let m_prev = Tensor::new(
                                vec![hh, fk, dh],
                                m_in.data()[bi * mstride..(bi + 1) * mstride].to_vec(),
                            );
                            let attn = attn_heads_fused(&p.qt, &p.kt, &p.v, &m_prev);
                            let y = epilogue(&xb, &attn, epi[0], epi[1], epi[2], epi[3], epi[4]);
                            // M_new = diag(g) M_prev + k^T v (Eq. 4, one step)
                            let st = state_combine(
                                &ChunkState {
                                    m: m_prev,
                                    a: Tensor::ones(&[hh, fk]),
                                },
                                &ChunkState { m: p.m, a: p.a.clone() },
                            );
                            (
                                y,
                                st.m.reshape(&[1, hh, fk, dh]),
                                p.a.reshape(&[1, hh, fk]),
                            )
                        });
                        let mut ys = Vec::with_capacity(b);
                        let mut ms_out = Vec::with_capacity(b);
                        let mut as_out = Vec::with_capacity(b);
                        for (y, m2, a2) in rows {
                            ys.push(y);
                            ms_out.push(m2);
                            as_out.push(a2);
                        }
                        Ok(vec![
                            Tensor::cat0(&ys),
                            Tensor::cat0(&ms_out),
                            Tensor::cat0(&as_out),
                        ])
                    }),
                );
            }
        }

        // ---- init + train steps: every linear variant at every hybrid
        // ratio (Table 2/4 coverage), plus the softmax baseline and the
        // unmasked (bidirectional, Table 3) basic tag ----
        let mut train_set: Vec<(Variant, &str, bool)> = Vec::new();
        for &v in Variant::linear_variants() {
            for ratio in ["0", "1/8", "1/4", "1/2"] {
                train_set.push((v, ratio, true));
            }
        }
        train_set.push((Variant::Softmax, "all", true));
        train_set.push((Variant::Basic, "0", false));
        let (bs, sl) = (cfg.train_batch, cfg.train_seq);
        for (variant, ratio, masked) in train_set {
            let pattern = Pattern::from_ratio(cfg.n_layers, ratio).unwrap();
            // a hybrid tag must BE hybrid: on small presets the pattern
            // cycle truncates "1/8"/"1/4" to all-L (e.g. tiny's 2 layers),
            // and registering those would let a pure-linear model
            // masquerade as a hybrid row in Tables 2/4 — leave them out so
            // the bench prints its explicit SKIPPED row instead.
            if Pattern::tag(ratio).starts_with('h') && pattern.n_std() == 0 {
                continue;
            }
            let tag = format!(
                "{}_{}{}",
                variant.name(),
                Pattern::tag(ratio),
                if masked { "" } else { "_nm" }
            );
            let specs = param_specs(cfg, variant, &pattern);
            let pmetas: Vec<TensorMeta> = specs
                .iter()
                .map(|(nm, sh, _)| f32m(&format!("p.{nm}"), sh))
                .collect();
            let mmetas: Vec<TensorMeta> = specs
                .iter()
                .map(|(nm, sh, _)| f32m(&format!("m.{nm}"), sh))
                .collect();
            let vmetas: Vec<TensorMeta> = specs
                .iter()
                .map(|(nm, sh, _)| f32m(&format!("v.{nm}"), sh))
                .collect();
            let pat = pattern.clone();
            reg.add(
                &format!("init_{tag}"),
                vec![i32m("seed", &[1])],
                pmetas.clone(),
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    init_impl(cfg, variant, &pat, ins)
                }),
            );
            let mut tins = pmetas.clone();
            tins.extend(mmetas.clone());
            tins.extend(vmetas.clone());
            tins.push(i32m("tokens", &[bs, sl]));
            tins.push(i32m("targets", &[bs, sl]));
            tins.push(f32m("loss_mask", &[bs, sl]));
            tins.push(f32m("lr", &[1]));
            tins.push(f32m("step", &[1]));
            let mut touts = pmetas;
            touts.extend(mmetas);
            touts.extend(vmetas);
            touts.push(f32m("loss", &[1]));
            let pat = pattern.clone();
            reg.add(
                &format!("train_step_{tag}"),
                tins,
                touts,
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    train_step_impl(cfg, variant, &pat, masked, ins)
                }),
            );
            // optimizer-free gradient step for the ZeRO-sharded driver:
            // params + batch + seq_range -> spec-ordered grads + loss
            let mut gins: Vec<TensorMeta> = specs
                .iter()
                .map(|(nm, sh, _)| f32m(&format!("p.{nm}"), sh))
                .collect();
            gins.push(i32m("tokens", &[bs, sl]));
            gins.push(i32m("targets", &[bs, sl]));
            gins.push(f32m("loss_mask", &[bs, sl]));
            gins.push(i32m("seq_range", &[2]));
            let mut gouts: Vec<TensorMeta> = specs
                .iter()
                .map(|(nm, sh, _)| f32m(&format!("g.{nm}"), sh))
                .collect();
            gouts.push(f32m("loss", &[1]));
            let pat = pattern.clone();
            reg.add(
                &format!("grad_step_{tag}"),
                gins,
                gouts,
                Arc::new(move |cfg: &ModelConfig, ins: &[Value]| {
                    grad_step_impl(cfg, variant, &pat, masked, ins)
                }),
            );
        }

        reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::preset("tiny").unwrap()
    }

    /// Extract head `h` of a `[C, H, F]` tensor as `[C, F]` (test-side
    /// reference; the kernels themselves address heads in place).
    fn head_of(t: &Tensor, h: usize) -> Tensor {
        let s = t.shape();
        let (c, heads, f) = (s[0], s[1], s[2]);
        let mut out = Vec::with_capacity(c * f);
        for i in 0..c {
            let base = (i * heads + h) * f;
            out.extend_from_slice(&t.data()[base..base + f]);
        }
        Tensor::new(vec![c, f], out)
    }

    /// Token-by-token gated recurrence oracle (ref.py::recurrent_linear_attn):
    /// M_s = diag(g_s) M_{s-1} + k_s^T v_s, o_s = q_s M_s.
    fn recurrent_oracle(q: &Tensor, k: &Tensor, v: &Tensor, g: &Tensor) -> Tensor {
        let (n, fk) = (q.shape()[0], q.shape()[1]);
        let dv = v.shape()[1];
        let mut m = vec![0.0f32; fk * dv];
        let mut out = Vec::with_capacity(n * dv);
        for s in 0..n {
            for a in 0..fk {
                let gs = g.data()[s * fk + a];
                let ks = k.data()[s * fk + a];
                for b in 0..dv {
                    m[a * dv + b] = gs * m[a * dv + b] + ks * v.data()[s * dv + b];
                }
            }
            for b in 0..dv {
                let mut acc = 0.0;
                for a in 0..fk {
                    acc += q.data()[s * fk + a] * m[a * dv + b];
                }
                out.push(acc);
            }
        }
        Tensor::new(vec![n, dv], out)
    }

    #[test]
    fn fold_gates_chunked_matches_token_recurrence() {
        // 4 chunks of C=8 through fold_gates + intra/inter + prefix combine
        // must equal the token-level gated recurrence (Eq. 4).
        let (t, c, fk, dv) = (4, 8, 5, 6);
        let n = t * c;
        let q = Tensor::randn(&[n, 1, fk], 1).scale(0.5);
        let k = Tensor::randn(&[n, 1, fk], 2).scale(0.5);
        let v = Tensor::randn(&[n, 1, dv], 3).scale(0.5);
        let g = Tensor::new(
            vec![n, 1, fk],
            Tensor::randn(&[n, 1, fk], 4)
                .data()
                .iter()
                .map(|x| 0.9 + 0.1 * (x.tanh() * 0.5 + 0.5))
                .collect(),
        );
        let flat = |t: &Tensor, last: usize| t.clone().reshape(&[n, last]);
        let want = recurrent_oracle(&flat(&q, fk), &flat(&k, fk), &flat(&v, dv), &flat(&g, fk));
        let mut outs = Vec::new();
        let mut states = Vec::new();
        for i in 0..t {
            let sl = |x: &Tensor, last: usize| {
                Tensor::new(
                    vec![c, 1, last],
                    x.data()[i * c * last..(i + 1) * c * last].to_vec(),
                )
            };
            let (qt, kt, m, a) = fold_gates(&sl(&q, fk), &sl(&k, fk), &sl(&v, dv), sl(&g, fk));
            states.push((qt, kt, sl(&v, dv), ChunkState { m, a }));
        }
        let (prefixes, _) =
            prefix_states(&states.iter().map(|s| s.3.clone()).collect::<Vec<_>>());
        for (i, (qt, kt, vc, _)) in states.iter().enumerate() {
            let o = intra_heads(qt, kt, vc).add(&inter_heads(qt, &prefixes[i].m));
            outs.push(o.clone().reshape(&[c, dv]));
        }
        let got = Tensor::cat0(&outs);
        assert!(
            got.allclose(&want, 1e-4),
            "chunked vs recurrent: {}",
            got.max_rel_err(&want)
        );
    }

    #[test]
    fn part1_gla_retention_states_match_recurrence() {
        // full linear_part1 (projections + gates) for the decay variants,
        // then chunk-combined output vs the recurrence on the folded q/k.
        let cfg = tiny();
        for variant in [Variant::Retention, Variant::Gla] {
            let rq = cfg.qk_dim(variant);
            let x = Tensor::randn(&[cfg.chunk_len, cfg.d_model], 7).scale(0.5);
            let ln1 = Tensor::ones(&[cfg.d_model]);
            let wq = Tensor::randn(&[cfg.d_model, cfg.n_heads * rq], 8).scale(0.1);
            let wk = Tensor::randn(&[cfg.d_model, cfg.n_heads * rq], 9).scale(0.1);
            let wv = Tensor::randn(&[cfg.d_model, cfg.n_heads * cfg.head_dim], 10).scale(0.1);
            let wg = Tensor::randn(&[cfg.d_model, cfg.n_heads * rq], 11).scale(0.1);
            let extra: Vec<&Tensor> = if variant == Variant::Gla {
                vec![&wg]
            } else {
                vec![]
            };
            let p = linear_part1(&cfg, variant, &x, &ln1, &wq, &wk, &wv, &extra);
            // a must be the per-dim product of all gates: within (floor^C, 1]
            let floor_c = GATE_FLOOR.powi(cfg.chunk_len as i32);
            for &av in p.a.data() {
                assert!(av > floor_c * 0.99 && av <= 1.0 + 1e-6, "carry {av}");
            }
            // M from fold must equal (k~ * a)^T v by construction; check via
            // the intra+inter path against a one-chunk recurrence per head.
            for h in 0..cfg.n_heads {
                let o = intra_heads(&p.qt, &p.kt, &p.v);
                let oh = head_of(&o, h);
                // recurrence with folded q~,k~ and g=1 == masked product
                let want = recurrent_oracle(
                    &head_of(&p.qt, h),
                    &head_of(&p.kt, h),
                    &head_of(&p.v, h),
                    &Tensor::ones(&[cfg.chunk_len, cfg.feat_dim(variant)]),
                );
                assert!(oh.allclose(&want, 1e-3), "{variant} head {h}");
            }
        }
    }

    #[test]
    fn softmax_heads_known_value() {
        // uniform q/k -> causal softmax averages the visible v prefix rows
        let (c, hh, dh) = (4, 1, 2);
        let q = Tensor::zeros(&[c, hh, dh]);
        let k = Tensor::zeros(&[c, hh, dh]);
        let mut v = Tensor::zeros(&[c, hh, dh]);
        for i in 0..c {
            v.data_mut()[i * dh] = i as f32;
        }
        let out = softmax_attn_heads(&q, &k, &v, 0);
        for i in 0..c {
            let want = (0..=i).sum::<usize>() as f32 / (i + 1) as f32;
            assert!((out.data()[i * dh] - want).abs() < 1e-5, "row {i}");
        }
    }

    fn micro_cfg() -> ModelConfig {
        let mut f = HashMap::new();
        for (k, v) in [
            ("d_model", 8usize),
            ("n_heads", 2),
            ("n_layers", 2),
            ("vocab", 16),
            ("chunk_len", 4),
            ("max_seq", 16),
            ("head_dim", 4),
            ("ffn_dim", 8),
            ("qk_reduced", 2),
            ("train_batch", 1),
            ("train_seq", 8),
        ] {
            f.insert(k.to_string(), v);
        }
        ModelConfig::from_fields("micro", &f).unwrap()
    }

    fn micro_params(cfg: &ModelConfig, variant: Variant, pattern: &Pattern) -> Vec<Tensor> {
        param_specs(cfg, variant, pattern)
            .iter()
            .enumerate()
            .map(|(i, (_, sh, init))| match init {
                Init::Ones => Tensor::ones(sh),
                Init::Zeros => Tensor::zeros(sh),
                _ => Tensor::randn(sh, 40 + i as u64).scale(0.2),
            })
            .collect()
    }

    /// Loss of one micro sequence through `seq_loss_grads` (grads dropped).
    fn micro_loss(
        cfg: &ModelConfig,
        variant: Variant,
        pattern: &Pattern,
        params: &[Tensor],
        tokens: &[i32],
        targets: &[i32],
        masked: bool,
    ) -> f32 {
        let specs = param_specs(cfg, variant, pattern);
        let vals: Vec<Value> = params.iter().map(|t| Value::F32(t.clone())).collect();
        let pv = ParamView::new(&specs, &vals).unwrap();
        let mut g: Vec<Tensor> = specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
        let mask = vec![1.0f32; tokens.len()];
        seq_loss_grads(
            cfg,
            variant,
            pattern,
            &pv,
            &mut g,
            tokens,
            targets,
            &mask,
            tokens.len() as f32,
            masked,
        )
        .unwrap()
    }

    #[test]
    fn train_gradcheck_finite_differences() {
        // Hand-written backward vs central finite differences on a micro
        // config: hybrid (linear+softmax), unmasked, and EVERY linear
        // variant — including the backward through decay gates (retention,
        // gla incl. its learned gate projection wg) and through the
        // Based/ReBased feature maps (gamma/beta).
        let cfg = micro_cfg();
        let cases: Vec<(Variant, &str, bool)> = vec![
            (Variant::Basic, "LN", true),
            (Variant::Basic, "LL", false),
            (Variant::Lightning, "LL", true),
            (Variant::Retention, "LL", true),
            (Variant::Retention, "LN", true),
            (Variant::Gla, "LL", true),
            (Variant::Based, "LL", true),
            (Variant::Rebased, "LL", true),
        ];
        let tokens: Vec<i32> = (0..8).map(|i| (i * 5 + 3) % 16).collect();
        let targets: Vec<i32> = (0..8).map(|i| (i * 7 + 1) % 16).collect();
        let mask = vec![1.0f32; 8];
        for (variant, pat, masked) in cases {
            let pattern = Pattern(pat.to_string());
            let specs = param_specs(&cfg, variant, &pattern);
            let mut params = micro_params(&cfg, variant, &pattern);
            // analytic grads
            let vals: Vec<Value> = params.iter().map(|t| Value::F32(t.clone())).collect();
            let pv = ParamView::new(&specs, &vals).unwrap();
            let mut grads: Vec<Tensor> =
                specs.iter().map(|(_, s, _)| Tensor::zeros(s)).collect();
            seq_loss_grads(
                &cfg,
                variant,
                &pattern,
                &pv,
                &mut grads,
                &tokens,
                &targets,
                &mask,
                8.0,
                masked,
            )
            .unwrap();
            drop(pv);
            // probe coordinates: (param, coord, fd step, gate-scale check).
            // usize::MAX coord means "largest |analytic| coordinate".
            let mut probes: Vec<(&str, usize, f32, bool)> = vec![
                ("embed", 3, 2e-2, false),
                ("layer0.wq", 1, 2e-2, false),
                ("layer0.wk", 2, 2e-2, false),
                ("layer1.wv", 2, 2e-2, false),
                ("final_ln", 0, 2e-2, false),
            ];
            if variant == Variant::Gla {
                // the learned decay-gate projection: its gradient carries a
                // (1-floor)/tau ~ 3e-3 prefactor, so probe the largest
                // coordinate with a wide FD step and compare at ITS scale.
                probes.push(("layer0.wg", usize::MAX, 2.5e-1, true));
                probes.push(("layer1.wg", usize::MAX, 2.5e-1, true));
            }
            if variant == Variant::Rebased {
                // the quadratic feature map gives gamma/beta a large third
                // derivative: use a smaller FD step to keep truncation down
                probes.push(("layer0.gamma", 0, 5e-3, false));
                probes.push(("layer0.beta", 1, 5e-3, false));
            }
            for (name, off, h, gate_scale) in probes {
                let pi = specs.iter().position(|(nm, _, _)| nm == name).unwrap();
                let off = if off == usize::MAX {
                    let d = grads[pi].data();
                    (0..d.len()).fold(0, |b, j| if d[j].abs() > d[b].abs() { j } else { b })
                } else {
                    off
                };
                let orig = params[pi].data()[off];
                params[pi].data_mut()[off] = orig + h;
                let lp = micro_loss(&cfg, variant, &pattern, &params, &tokens, &targets, masked);
                params[pi].data_mut()[off] = orig - h;
                let lm = micro_loss(&cfg, variant, &pattern, &params, &tokens, &targets, masked);
                params[pi].data_mut()[off] = orig;
                let fd = (lp - lm) / (2.0 * h);
                let an = grads[pi].data()[off];
                let ok = if gate_scale {
                    // small-magnitude regime: compare at the gradient's own
                    // scale with an absolute floor for the f32 FD noise
                    // (measured agreement is ~0.3% rel / ~6e-7 abs)
                    (fd - an).abs() <= 0.05 * fd.abs().max(an.abs()) + 1e-5
                } else {
                    (fd - an).abs() <= 0.05 * (1.0 + fd.abs().max(an.abs()))
                };
                assert!(ok, "{variant} {} {name}[{off}]: fd {fd} vs analytic {an}", pattern.0);
            }
            if variant == Variant::Gla {
                // backward-through-gates must actually reach wg
                for l in [0usize, 1] {
                    let nm = format!("layer{l}.wg");
                    let pi = specs.iter().position(|(n2, _, _)| *n2 == nm).unwrap();
                    let norm: f32 = grads[pi].data().iter().map(|v| v * v).sum();
                    assert!(norm > 0.0, "{nm} gradient is identically zero");
                }
            }
        }
    }

    #[test]
    fn train_forward_loss_matches_chunked_oracle() {
        // The whole-sequence prefactor-folded forward inside seq_loss_grads
        // must equal the chunked forward_tokens oracle (itself validated
        // against the token-level gated recurrence above) for every linear
        // variant — this pins the gated/feature-mapped TRAINING forward.
        let cfg = micro_cfg();
        let pattern = Pattern("LL".to_string());
        let tokens: Vec<i32> = (0..8).map(|i| (i * 5 + 3) % 16).collect();
        let targets: Vec<i32> = (0..8).map(|i| (i * 7 + 1) % 16).collect();
        for &variant in Variant::linear_variants() {
            let specs = param_specs(&cfg, variant, &pattern);
            let params = micro_params(&cfg, variant, &pattern);
            let got = micro_loss(&cfg, variant, &pattern, &params, &tokens, &targets, true);
            let vals: Vec<Value> = params.iter().map(|t| Value::F32(t.clone())).collect();
            let pv = ParamView::new(&specs, &vals).unwrap();
            let logits = forward_tokens(&cfg, variant, &pattern, &pv, &tokens, true).unwrap();
            let vb = cfg.vocab;
            let mut want = 0.0f32;
            for (i, &t) in targets.iter().enumerate() {
                let row = &logits.data()[i * vb..(i + 1) * vb];
                let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let z: f32 = row.iter().map(|v| (v - mx).exp()).sum();
                want += (z.ln() + mx - row[t as usize]) / targets.len() as f32;
            }
            assert!(
                (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "{variant}: train-path loss {got} vs chunked oracle loss {want}"
            );
        }
    }

    #[test]
    fn registry_covers_scheduler_surface() {
        let cfg = tiny();
        let reg = Registry::build(&cfg);
        let man = reg.manifest(&cfg);
        for name in [
            "embed",
            "head",
            "l_part1_gla",
            "l_part2_based",
            "l_part2b_rebased",
            "l_intra_retention",
            "l_part2nm_basic",
            "l_bwd1_basic",
            "l_bwd2_basic",
            "s_part1",
            "s_part2_T2",
            "s_part2_T4",
            "mega_attn_basic_T4",
            // head-sharded surface for Ulysses (u = W) and USP rows (u | W)
            "s_attn_hs_Q32_N128_H2",
            "s_attn_hs_Q64_N128_H1",
            "s_attn_hs_Q128_N128_H1",
            "l_chunk_hs_basic_T4_H1",
            "l_chunk_hs_gla_T2_H1",
            "post_attn",
            "ring_step",
            "ring_finalize",
            "ring_linear_step",
            "forward_mono_basic_pure_N128",
            "forward_mono_softmax_std_N128",
            "forward_mono_basic_h2_N128",
            "init_basic_pure",
            "train_step_basic_pure",
            "train_step_softmax_std",
            "train_step_basic_pure_nm",
            // gated-variant training is native (backward-through-gates)
            "init_gla_pure",
            "train_step_gla_pure",
            "train_step_gla_h2",
            "init_retention_pure",
            "train_step_retention_pure",
            "train_step_retention_h2",
            // feature-map variants + lightning train natively too
            "train_step_lightning_pure",
            "train_step_based_pure",
            "init_rebased_h2",
            "train_step_rebased_pure",
            // every train tag exposes the optimizer-free gradient step
            // consumed by the ZeRO-sharded distributed driver
            "grad_step_basic_pure",
            "grad_step_softmax_std",
            "grad_step_basic_pure_nm",
            "grad_step_gla_pure",
            "grad_step_retention_h2",
            "grad_step_rebased_pure",
        ] {
            assert!(man.artifacts.contains_key(name), "{name}");
            assert!(reg.kernel(name).is_ok(), "{name}");
        }
        // serving decode surface: every linear variant at every registered
        // batch size, the std KV-cache decode/prefill, and the decode-shaped
        // embed/head
        for &b in DECODE_BATCH_SIZES {
            for v in Variant::linear_variants() {
                let name = format!("l_decode_{}_B{b}", v.name());
                assert!(man.artifacts.contains_key(&name), "{name}");
            }
            for name in [
                format!("s_decode_B{b}"),
                format!("embed_dec_B{b}"),
                format!("head_dec_B{b}"),
            ] {
                assert!(man.artifacts.contains_key(&name), "{name}");
                assert!(reg.kernel(&name).is_ok(), "{name}");
            }
        }
        assert!(man.artifacts.contains_key("s_prefill"));
        // tiny (2 layers) truncates the 1/8 and 1/4 patterns to all-L:
        // those tags must NOT exist, or a pure-linear model would pose as
        // a hybrid row in the Table-2/4 benches.
        for name in ["train_step_gla_h8", "train_step_basic_h4", "init_retention_h8"] {
            assert!(!man.artifacts.contains_key(name), "{name} should not be registered");
        }
        assert_eq!(man.fields["d_model"], 64);
    }
}
