//! In-memory "NCCL": the collectives the SP schedulers use, executed by
//! worker threads over shared memory, with per-op traffic accounting.
//!
//! Each simulated device is one OS thread holding a `Communicator`.  The
//! byte/step counters feed the §3.4 cost-model assertions (LASP-2: 2
//! collective steps per iteration; LASP-1: 2(W-1) P2P steps) and the
//! Table-5 split-gather ablation; wall-clock blocked time feeds the perf
//! pass.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::tensor::Tensor;

/// Message payload: a list of tensors (e.g. [M_t, a_t] for LASP-2 states).
pub type Msg = Vec<Tensor>;

#[derive(Debug, Default)]
pub struct CommCounters {
    /// collective operations launched (AllGather)
    pub collective_ops: AtomicU64,
    /// P2P send operations
    pub p2p_ops: AtomicU64,
    /// total bytes moved device-to-device (sum over devices)
    pub bytes: AtomicU64,
    /// wall nanos threads spent blocked in communication (sum over devices)
    pub blocked_nanos: AtomicU64,
}

impl CommCounters {
    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            collective_ops: self.collective_ops.load(Ordering::Relaxed),
            p2p_ops: self.p2p_ops.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            blocked_nanos: self.blocked_nanos.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.collective_ops.store(0, Ordering::Relaxed);
        self.p2p_ops.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.blocked_nanos.store(0, Ordering::Relaxed);
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommSnapshot {
    pub collective_ops: u64,
    pub p2p_ops: u64,
    pub bytes: u64,
    pub blocked_nanos: u64,
}

struct WorldInner {
    size: usize,
    slots: Mutex<Vec<Option<Msg>>>,
    barrier: Barrier,
    /// p2p channels: senders[dst][src], receivers[dst][src]
    senders: Vec<Vec<Sender<Msg>>>,
    receivers: Vec<Vec<Mutex<Receiver<Msg>>>>,
    counters: CommCounters,
}

/// A communication world of `size` simulated devices.
pub struct World {
    inner: Arc<WorldInner>,
}

impl World {
    pub fn new(size: usize) -> World {
        assert!(size >= 1);
        let mut senders: Vec<Vec<Sender<Msg>>> = (0..size).map(|_| Vec::new()).collect();
        let mut receivers: Vec<Vec<Mutex<Receiver<Msg>>>> =
            (0..size).map(|_| Vec::new()).collect();
        for dst in 0..size {
            for _src in 0..size {
                let (tx, rx) = channel();
                senders[dst].push(tx);
                receivers[dst].push(Mutex::new(rx));
            }
        }
        World {
            inner: Arc::new(WorldInner {
                size,
                slots: Mutex::new(vec![None; size]),
                barrier: Barrier::new(size),
                senders,
                receivers,
                counters: CommCounters::default(),
            }),
        }
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    pub fn communicator(&self, rank: usize) -> Communicator {
        assert!(rank < self.inner.size);
        Communicator { rank, inner: self.inner.clone() }
    }

    pub fn counters(&self) -> CommSnapshot {
        self.inner.counters.snapshot()
    }

    pub fn reset_counters(&self) {
        self.inner.counters.reset();
    }

    /// Run one SPMD closure per rank on its own thread; returns per-rank
    /// results in rank order.  Panics in workers propagate.
    pub fn run<T: Send>(
        &self,
        f: impl Fn(Communicator) -> T + Sync,
    ) -> Vec<T> {
        let n = self.size();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (rank, slot) in out.iter_mut().enumerate() {
                let comm = self.communicator(rank);
                let f = &f;
                handles.push(s.spawn(move || {
                    *slot = Some(f(comm));
                }));
            }
            for h in handles {
                h.join().expect("worker panicked");
            }
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

/// Per-device handle used inside worker threads.
#[derive(Clone)]
pub struct Communicator {
    rank: usize,
    inner: Arc<WorldInner>,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    pub fn barrier(&self) {
        self.inner.barrier.wait();
    }

    fn account(&self, bytes: usize, t0: Instant, collective: bool) {
        let c = &self.inner.counters;
        if collective {
            c.collective_ops.fetch_add(1, Ordering::Relaxed);
        } else {
            c.p2p_ops.fetch_add(1, Ordering::Relaxed);
        }
        c.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        c.blocked_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// AllGather: every rank contributes `msg`, every rank receives the full
    /// rank-ordered list.  THE LASP-2 communication primitive (Alg. 1 line
    /// 6 / Alg. 2 line 7 on [M_t], Alg. 3/4 on [dM_t], Alg. 7 on K/V).
    pub fn all_gather(&self, msg: Msg) -> Vec<Msg> {
        let t0 = Instant::now();
        let sent: usize = msg.iter().map(|t| t.byte_size()).sum();
        {
            let mut slots = self.inner.slots.lock().unwrap();
            slots[self.rank] = Some(msg);
        }
        self.inner.barrier.wait();
        let gathered: Vec<Msg> = {
            let slots = self.inner.slots.lock().unwrap();
            slots.iter().map(|s| s.as_ref().unwrap().clone()).collect()
        };
        self.inner.barrier.wait();
        // traffic: ring-allgather moves (W-1) * per-rank bytes per device
        self.account(sent * (self.size() - 1), t0, true);
        gathered
    }

    /// AllGather performed in `splits` sequential slices of the flattened
    /// payload (Table 5 ablation: "varying split sizes of gathering").
    /// Semantically identical to `all_gather`; launches `splits` collectives.
    pub fn all_gather_split(&self, msg: Msg, splits: usize) -> Vec<Msg> {
        assert!(splits >= 1);
        if splits == 1 {
            return self.all_gather(msg);
        }
        let shapes: Vec<Vec<usize>> = msg.iter().map(|t| t.shape().to_vec()).collect();
        let mut flat: Vec<f32> = Vec::new();
        for t in &msg {
            flat.extend_from_slice(t.data());
        }
        let n = flat.len();
        let per = n.div_ceil(splits);
        let mut gathered_flat: Vec<Vec<f32>> = vec![Vec::with_capacity(n); self.size()];
        for s in 0..splits {
            let lo = (s * per).min(n);
            let hi = ((s + 1) * per).min(n);
            let piece = vec![Tensor::new(vec![hi - lo], flat[lo..hi].to_vec())];
            let got = self.all_gather(piece);
            for (r, g) in got.into_iter().enumerate() {
                gathered_flat[r].extend_from_slice(g[0].data());
            }
        }
        gathered_flat
            .into_iter()
            .map(|f| {
                let mut out = Vec::with_capacity(shapes.len());
                let mut off = 0;
                for sh in &shapes {
                    let len: usize = sh.iter().product();
                    out.push(Tensor::new(sh.clone(), f[off..off + len].to_vec()));
                    off += len;
                }
                out
            })
            .collect()
    }

    /// P2P send (LASP-1's ring primitive).
    pub fn send(&self, dst: usize, msg: Msg) {
        let t0 = Instant::now();
        let bytes: usize = msg.iter().map(|t| t.byte_size()).sum();
        self.inner.senders[dst][self.rank].send(msg).expect("recv side gone");
        self.account(bytes, t0, false);
    }

    /// P2P blocking receive.
    pub fn recv(&self, src: usize) -> Msg {
        let t0 = Instant::now();
        let msg = self.inner.receivers[self.rank][src]
            .lock()
            .unwrap()
            .recv()
            .expect("send side gone");
        self.inner
            .counters
            .blocked_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        msg
    }

    /// Ring neighbors.
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.size()
    }

    pub fn left(&self) -> usize {
        (self.rank + self.size() - 1) % self.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rank: usize, v: f32) -> Tensor {
        Tensor::full(&[2, 2], rank as f32 * 100.0 + v)
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let w = World::new(4);
        let results = w.run(|c| c.all_gather(vec![t(c.rank(), 1.0)]));
        for msgs in results {
            assert_eq!(msgs.len(), 4);
            for (r, m) in msgs.iter().enumerate() {
                assert_eq!(m[0].data()[0], r as f32 * 100.0 + 1.0);
            }
        }
    }

    #[test]
    fn all_gather_repeated_generations() {
        let w = World::new(3);
        let results = w.run(|c| {
            let mut acc = 0.0;
            for it in 0..5 {
                let got = c.all_gather(vec![t(c.rank(), it as f32)]);
                acc += got[2][0].data()[0];
            }
            acc
        });
        for r in results {
            assert_eq!(r, (0..5).map(|i| 200.0 + i as f32).sum::<f32>());
        }
    }

    #[test]
    fn split_gather_equivalent() {
        let w = World::new(4);
        let a = w.run(|c| c.all_gather(vec![Tensor::randn(&[3, 5], c.rank() as u64)]));
        let w2 = World::new(4);
        let b = w2.run(|c| {
            c.all_gather_split(vec![Tensor::randn(&[3, 5], c.rank() as u64)], 4)
        });
        for (x, y) in a.iter().zip(&b) {
            for (mx, my) in x.iter().zip(y) {
                assert_eq!(mx[0], my[0]);
            }
        }
        // but 4x the collective launches
        assert_eq!(w.counters().collective_ops, 4); // 1 per rank
        assert_eq!(w2.counters().collective_ops, 16); // 4 per rank
    }

    #[test]
    fn ring_send_recv() {
        let w = World::new(4);
        let results = w.run(|c| {
            // pass rank around the full ring, accumulating
            let mut val = c.rank() as f32;
            for _ in 0..c.size() - 1 {
                c.send(c.right(), vec![Tensor::full(&[1], val)]);
                val = c.recv(c.left())[0].data()[0];
            }
            val
        });
        // after W-1 hops each rank holds its right neighbor's original value
        assert_eq!(results, vec![1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn counters_track_steps() {
        let w = World::new(4);
        w.run(|c| {
            c.all_gather(vec![Tensor::zeros(&[8])]);
        });
        let snap = w.counters();
        assert_eq!(snap.collective_ops, 4); // one launch per rank
        assert_eq!(snap.p2p_ops, 0);
        // ring-allgather traffic: each rank moves (W-1)*32 bytes
        assert_eq!(snap.bytes, 4 * 3 * 32);
    }

    #[test]
    fn barrier_sync() {
        let w = World::new(8);
        let r = w.run(|c| {
            c.barrier();
            c.rank()
        });
        assert_eq!(r, (0..8).collect::<Vec<_>>());
    }
}
