//! In-memory "NCCL": the collectives the SP schedulers use, executed by
//! worker threads over shared memory, with per-op traffic accounting.
//!
//! Each simulated device is one OS thread holding a `Communicator`.  The
//! byte/step counters feed the §3.4 cost-model assertions (LASP-2: 2
//! collective steps per iteration; LASP-1: 2(W-1) P2P steps) and the
//! Table-5 split-gather ablation; wall-clock blocked time feeds the perf
//! pass.
//!
//! Primitives (see `docs/SCHEDULERS.md` for which scheduler uses what):
//!
//! | primitive        | wire bytes per rank      | used by                  |
//! |------------------|--------------------------|--------------------------|
//! | `all_gather`     | (W-1) x payload          | LASP-2, Megatron-SP      |
//! | `all_to_all`     | (W-1)/W x payload        | Ulysses, USP rows        |
//! | `reduce_scatter` | (W-1)/W x payload        | (ZeRO-style partials)    |
//! | `send`/`recv`    | payload per hop          | LASP-1, Ring, ZeCO       |
//!
//! A `World` can also be built as a 2D mesh (`World::new_mesh`) whose
//! orthogonal row/column sub-communicators (`Communicator::row` /
//! `Communicator::col`) share one byte/step counter set with the root —
//! the USP-style hybrid runs LASP-2's AllGather over the full world for
//! linear layers and Ulysses All-to-All within rows for standard layers.
//!
//! **Fault model** (see `DESIGN.md` "Fault tolerance"): every primitive
//! returns `Result<_, CommError>` instead of panicking.  Waits are
//! bounded by a configurable timeout ([`World::set_timeout_ms`]); the
//! barrier carries an abort flag so one rank's failure (injected crash,
//! exhausted retries, worker panic) poisons the world and every peer
//! fails fast with the same typed error instead of hanging.  With a
//! [`FaultPlan`] installed, messages are sealed with an FNV-1a checksum
//! at send time and verified at delivery with bounded exponential-backoff
//! retries; without one, the hot path is untouched (no checksums, no
//! clones beyond the original implementation).  [`World::run_catch`]
//! supervises the per-rank threads and converts panics into per-rank
//! `Err` values.

pub mod fault;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::tensor::Tensor;

pub use fault::{CommError, FaultKind, FaultPlan};
use fault::{AbortCause, FaultState};

/// Message payload: a list of tensors (e.g. `[M_t, a_t]` for LASP-2 states).
pub type Msg = Vec<Tensor>;

/// Default bound on any single communicator wait (barrier or receive).
pub const DEFAULT_TIMEOUT_MS: u64 = 30_000;

/// Granularity at which blocked receivers poll the world abort flag.
const ABORT_POLL: Duration = Duration::from_millis(5);

/// Shared traffic counters, aggregated over every rank of a `World` (and,
/// for mesh worlds, over all row/column sub-communicators too).
#[derive(Debug, Default)]
pub struct CommCounters {
    /// collective operations launched (AllGather/All-to-All/ReduceScatter)
    pub collective_ops: AtomicU64,
    /// P2P send operations
    pub p2p_ops: AtomicU64,
    /// total bytes moved device-to-device (sum over devices)
    pub bytes: AtomicU64,
    /// wall nanos threads spent blocked in communication (sum over devices)
    pub blocked_nanos: AtomicU64,
}

impl CommCounters {
    /// Copy the live atomics into a plain snapshot struct.
    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            collective_ops: self.collective_ops.load(Ordering::Relaxed),
            p2p_ops: self.p2p_ops.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            blocked_nanos: self.blocked_nanos.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters (between benchmark iterations).
    pub fn reset(&self) {
        self.collective_ops.store(0, Ordering::Relaxed);
        self.p2p_ops.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.blocked_nanos.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time copy of [`CommCounters`] (what tests assert against).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommSnapshot {
    /// collective operations launched (AllGather/All-to-All/ReduceScatter)
    pub collective_ops: u64,
    /// P2P send operations
    pub p2p_ops: u64,
    /// total bytes moved device-to-device (sum over devices)
    pub bytes: u64,
    /// wall nanos threads spent blocked in communication (sum over devices)
    pub blocked_nanos: u64,
}

/// A message plus the FNV-1a checksum sealed in at send time (`None`
/// when no fault plan is installed — the clean path pays nothing).
#[derive(Clone)]
struct Sealed {
    msg: Msg,
    sum: Option<u64>,
}

/// Generation barrier with an abort flag: `wait` returns `Err` (instead
/// of blocking forever) once any rank records an [`AbortCause`], and a
/// waiter that times out poisons the barrier itself so its peers fail
/// fast too.  Replaces `std::sync::Barrier`, whose `wait` can neither
/// time out nor be interrupted.
struct SyncPoint {
    size: usize,
    state: Mutex<SyncState>,
    cv: Condvar,
}

struct SyncState {
    count: usize,
    generation: u64,
    abort: Option<AbortCause>,
}

impl SyncPoint {
    fn new(size: usize) -> SyncPoint {
        SyncPoint {
            size,
            state: Mutex::new(SyncState { count: 0, generation: 0, abort: None }),
            cv: Condvar::new(),
        }
    }

    fn wait(&self, rank: usize, timeout: Duration) -> Result<(), CommError> {
        let mut st =
            self.state.lock().map_err(|_| CommError::Poisoned { what: "barrier" })?;
        if let Some(cause) = st.abort {
            return Err(cause.to_error());
        }
        st.count += 1;
        if st.count == self.size {
            st.count = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = st.generation;
        let deadline = Instant::now() + timeout;
        loop {
            if st.generation != gen {
                return Ok(());
            }
            if let Some(cause) = st.abort {
                return Err(cause.to_error());
            }
            let now = Instant::now();
            if now >= deadline {
                let ms = timeout.as_millis() as u64;
                st.abort = Some(AbortCause::Timeout { rank, ms });
                self.cv.notify_all();
                return Err(CommError::Timeout { rank, ms });
            }
            let (guard, _) = self
                .cv
                .wait_timeout(st, deadline - now)
                .map_err(|_| CommError::Poisoned { what: "barrier" })?;
            st = guard;
        }
    }

    /// Record an abort cause (first writer wins) and wake every waiter.
    fn abort(&self, cause: AbortCause) {
        if let Ok(mut st) = self.state.lock() {
            if st.abort.is_none() {
                st.abort = Some(cause);
            }
            self.cv.notify_all();
        }
    }

    fn aborted(&self) -> Option<AbortCause> {
        self.state.lock().ok().and_then(|st| st.abort)
    }
}

/// 2D process-mesh topology attached to a root `WorldInner`: orthogonal
/// row/column sub-worlds that share the root's counters.
struct Mesh {
    rows: usize,
    cols: usize,
    /// one sub-world per row; row i holds consecutive ranks
    /// `[i*cols, (i+1)*cols)` (a contiguous sequence segment)
    row_groups: Vec<Arc<WorldInner>>,
    /// one sub-world per column; column j holds ranks `{j, j+cols, ...}`
    col_groups: Vec<Arc<WorldInner>>,
}

struct WorldInner {
    size: usize,
    slots: Mutex<Vec<Option<Sealed>>>,
    /// all_to_all mailbox: `mailbox[dst][src]`
    mailbox: Mutex<Vec<Vec<Option<Sealed>>>>,
    barrier: SyncPoint,
    /// p2p channels: `senders[dst][src]`, `receivers[dst][src]`
    senders: Vec<Vec<Sender<Sealed>>>,
    receivers: Vec<Vec<Mutex<Receiver<Sealed>>>>,
    /// shared with sub-worlds of a mesh so every hop is accounted once
    counters: Arc<CommCounters>,
    mesh: Option<Mesh>,
    /// bound on any single barrier/recv wait (millis)
    timeout_ms: AtomicU64,
    /// installed fault plan + per-rank op counters (root world only)
    fault: OnceLock<Arc<FaultState>>,
}

impl WorldInner {
    fn new(size: usize, counters: Arc<CommCounters>) -> WorldInner {
        assert!(size >= 1);
        let mut senders: Vec<Vec<Sender<Sealed>>> = (0..size).map(|_| Vec::new()).collect();
        let mut receivers: Vec<Vec<Mutex<Receiver<Sealed>>>> =
            (0..size).map(|_| Vec::new()).collect();
        for dst in 0..size {
            for _src in 0..size {
                let (tx, rx) = channel();
                senders[dst].push(tx);
                receivers[dst].push(Mutex::new(rx));
            }
        }
        WorldInner {
            size,
            slots: Mutex::new(vec![None; size]),
            mailbox: Mutex::new((0..size).map(|_| vec![None; size]).collect()),
            barrier: SyncPoint::new(size),
            senders,
            receivers,
            counters,
            mesh: None,
            timeout_ms: AtomicU64::new(DEFAULT_TIMEOUT_MS),
            fault: OnceLock::new(),
        }
    }
}

/// A worker thread panicked under [`World::run_catch`]; the payload (if
/// it was a string) is preserved for the supervisor's report.
#[derive(Debug)]
pub struct RankPanic {
    /// which rank's closure panicked
    pub rank: usize,
    /// the panic payload rendered as text
    pub message: String,
}

impl std::fmt::Display for RankPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank {} panicked: {}", self.rank, self.message)
    }
}

impl std::error::Error for RankPanic {}

/// A communication world of `size` simulated devices (one OS thread each
/// under [`World::run`]); optionally a 2D mesh with row/column
/// sub-communicators.
pub struct World {
    inner: Arc<WorldInner>,
}

impl World {
    /// Flat world of `size` ranks (no mesh sub-communicators).
    pub fn new(size: usize) -> World {
        World {
            inner: Arc::new(WorldInner::new(size, Arc::new(CommCounters::default()))),
        }
    }

    /// 2D mesh world of `rows * cols` ranks.  Rank `r` sits at row
    /// `r / cols`, column `r % cols`; rows hold CONSECUTIVE ranks, so a
    /// row spans a contiguous sequence segment under the usual
    /// chunk-per-rank layout.  Row/column sub-communicators
    /// ([`Communicator::row`] / [`Communicator::col`]) share this world's
    /// traffic counters.
    pub fn new_mesh(rows: usize, cols: usize) -> World {
        assert!(rows >= 1 && cols >= 1);
        let counters = Arc::new(CommCounters::default());
        let row_groups = (0..rows)
            .map(|_| Arc::new(WorldInner::new(cols, counters.clone())))
            .collect();
        let col_groups = (0..cols)
            .map(|_| Arc::new(WorldInner::new(rows, counters.clone())))
            .collect();
        let mut root = WorldInner::new(rows * cols, counters);
        root.mesh = Some(Mesh { rows, cols, row_groups, col_groups });
        World { inner: Arc::new(root) }
    }

    /// The world a `RunConfig` asks for: a `rows x usp_cols` mesh for the
    /// USP-2D scheduler, a flat world otherwise.
    pub fn for_run(run: &RunConfig) -> World {
        if run.scheduler == crate::config::Scheduler::Usp2d {
            let cols = run.usp_cols.clamp(1, run.world);
            assert!(
                run.world % cols == 0,
                "usp_cols {} must divide world {}",
                cols,
                run.world
            );
            World::new_mesh(run.world / cols, cols)
        } else {
            World::new(run.world)
        }
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// `(rows, cols)` when this world was built with [`World::new_mesh`].
    pub fn mesh_dims(&self) -> Option<(usize, usize)> {
        self.inner.mesh.as_ref().map(|m| (m.rows, m.cols))
    }

    /// Per-rank handle (normally obtained inside [`World::run`]).
    pub fn communicator(&self, rank: usize) -> Communicator {
        assert!(rank < self.inner.size);
        Communicator { rank, inner: self.inner.clone() }
    }

    /// Snapshot of the shared traffic counters.
    pub fn counters(&self) -> CommSnapshot {
        self.inner.counters.snapshot()
    }

    /// Zero the shared traffic counters.
    pub fn reset_counters(&self) {
        self.inner.counters.reset();
    }

    /// Bound every barrier/receive wait (root AND mesh sub-worlds) to
    /// `ms` milliseconds; a rank that exceeds it poisons the world with
    /// [`CommError::Timeout`].
    pub fn set_timeout_ms(&self, ms: u64) {
        self.inner.timeout_ms.store(ms, Ordering::Relaxed);
        if let Some(m) = &self.inner.mesh {
            for g in m.row_groups.iter().chain(&m.col_groups) {
                g.timeout_ms.store(ms, Ordering::Relaxed);
            }
        }
    }

    /// Install a fault plan on this world.  Messages gain checksums, and
    /// the plan's events fire against per-rank op counters that start at
    /// zero for THIS world (one-shot events already fired on a previous
    /// world stay fired).  At most one plan per world; later installs are
    /// ignored.
    pub fn install_faults(&self, plan: Arc<FaultPlan>) {
        let _ = self.inner.fault.set(Arc::new(FaultState::new(plan, self.inner.size)));
    }

    /// Run one SPMD closure per rank on its own thread; returns per-rank
    /// results in rank order.  Panics in workers propagate (thin wrapper
    /// over [`World::run_catch`] for call sites that treat a worker panic
    /// as fatal — fault-tolerant drivers use `run_catch` directly).
    pub fn run<T: Send>(&self, f: impl Fn(Communicator) -> T + Sync) -> Vec<T> {
        self.run_catch(f)
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(p) => panic!("worker panicked: {p}"),
            })
            .collect()
    }

    /// Run one SPMD closure per rank on its own thread, supervising the
    /// workers: a panicking rank yields `Err(RankPanic)` in its slot (and
    /// poisons the world so blocked peers fail fast with
    /// [`CommError::Aborted`]) instead of tearing down the process.
    pub fn run_catch<T: Send>(
        &self,
        f: impl Fn(Communicator) -> T + Sync,
    ) -> Vec<Result<T, RankPanic>> {
        let n = self.size();
        let mut out: Vec<Option<Result<T, RankPanic>>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (rank, slot) in out.iter_mut().enumerate() {
                let comm = self.communicator(rank);
                let f = &f;
                let inner = &self.inner;
                handles.push(s.spawn(move || {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(comm))) {
                        Ok(v) => *slot = Some(Ok(v)),
                        Err(payload) => {
                            inner.barrier.abort(AbortCause::Fail { rank });
                            let message = if let Some(s) = payload.downcast_ref::<&str>() {
                                (*s).to_string()
                            } else if let Some(s) = payload.downcast_ref::<String>() {
                                s.clone()
                            } else {
                                "non-string panic payload".to_string()
                            };
                            *slot = Some(Err(RankPanic { rank, message }));
                        }
                    }
                }));
            }
            for h in handles {
                let _ = h.join();
            }
        });
        out.into_iter()
            .map(|o| o.expect("worker thread wrote its slot"))
            .collect()
    }
}

/// Contiguous slice `idx` of `parts` equal parts along axis 0.
fn slice0(t: &Tensor, parts: usize, idx: usize) -> Tensor {
    let n = t.shape()[0];
    debug_assert_eq!(n % parts, 0);
    let rows = n / parts;
    let stride: usize = t.shape()[1..].iter().product();
    let mut shape = t.shape().to_vec();
    shape[0] = rows;
    Tensor::new(
        shape,
        t.data()[idx * rows * stride..(idx + 1) * rows * stride].to_vec(),
    )
}

fn lock<'a, T>(m: &'a Mutex<T>, what: &'static str) -> Result<MutexGuard<'a, T>, CommError> {
    m.lock().map_err(|_| CommError::Poisoned { what })
}

/// Per-device handle used inside worker threads.
#[derive(Clone)]
pub struct Communicator {
    rank: usize,
    inner: Arc<WorldInner>,
}

impl Communicator {
    /// This device's rank in `[0, size)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size (number of ranks in THIS communicator — a row/column
    /// sub-communicator reports its group size, not the root's).
    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// `(rows, cols)` when this communicator belongs to a mesh world.
    pub fn mesh_dims(&self) -> Option<(usize, usize)> {
        self.inner.mesh.as_ref().map(|m| (m.rows, m.cols))
    }

    /// Sub-communicator over this rank's mesh ROW (`cols` consecutive
    /// ranks — the Ulysses/All-to-All dimension of USP).  `None` on flat
    /// worlds and on sub-communicators themselves.
    pub fn row(&self) -> Option<Communicator> {
        self.inner.mesh.as_ref().map(|m| Communicator {
            rank: self.rank % m.cols,
            inner: m.row_groups[self.rank / m.cols].clone(),
        })
    }

    /// Sub-communicator over this rank's mesh COLUMN (stride-`cols` ranks
    /// — the cross-segment AllGather dimension of USP).  `None` on flat
    /// worlds and on sub-communicators themselves.
    pub fn col(&self) -> Option<Communicator> {
        self.inner.mesh.as_ref().map(|m| Communicator {
            rank: self.rank / m.cols,
            inner: m.col_groups[self.rank % m.cols].clone(),
        })
    }

    fn timeout(&self) -> Duration {
        Duration::from_millis(self.inner.timeout_ms.load(Ordering::Relaxed))
    }

    fn wait_barrier(&self) -> Result<(), CommError> {
        self.inner.barrier.wait(self.rank, self.timeout())
    }

    /// Start a communicator op: bump this rank's op counter and let the
    /// installed fault plan (if any) crash or delay us.  Returns the
    /// fault context later delivery validation needs.
    fn fault_enter(&self) -> Result<Option<(Arc<FaultState>, u64)>, CommError> {
        let Some(fs) = self.inner.fault.get() else {
            return Ok(None);
        };
        let op = fs.ops[self.rank].fetch_add(1, Ordering::Relaxed);
        if let Err(e) = fs.plan.on_op(self.rank, op) {
            // injected crash: poison the world so peers fail fast with a
            // typed error naming THIS rank instead of timing out
            self.inner.barrier.abort(AbortCause::Crash { rank: self.rank, op });
            return Err(e);
        }
        Ok(Some((fs.clone(), op)))
    }

    fn seal(&self, msg: Msg, checksum: bool) -> Sealed {
        let sum = checksum.then(|| fault::checksum_msg(&msg));
        Sealed { msg, sum }
    }

    /// Validate a delivered message against its sealed checksum, retrying
    /// with bounded exponential backoff while the fault plan drops or
    /// corrupts it.  Without a fault context this is a free unwrap.
    fn open(
        &self,
        sealed: Sealed,
        src: usize,
        fctx: &Option<(Arc<FaultState>, u64)>,
    ) -> Result<Msg, CommError> {
        let Some((fs, op)) = fctx else {
            return Ok(sealed.msg);
        };
        let plan = &fs.plan;
        let want = sealed.sum.unwrap_or_else(|| fault::checksum_msg(&sealed.msg));
        let mut attempt: u32 = 0;
        loop {
            if attempt > 0 {
                plan.note_retry();
                std::thread::sleep(plan.backoff(attempt));
            }
            let dropped = plan.injects_drop(self.rank, *op, src, attempt);
            if !dropped {
                let view = if plan.injects_corrupt(self.rank, *op, src, attempt) {
                    fault::corrupt_copy(&sealed.msg)
                } else {
                    sealed.msg.clone()
                };
                if fault::checksum_msg(&view) == want {
                    return Ok(view);
                }
            }
            attempt += 1;
            if attempt > plan.max_retries {
                let err = if dropped {
                    CommError::Lost { src, dst: self.rank, op: *op, attempts: attempt }
                } else {
                    CommError::Corrupt { src, dst: self.rank, op: *op, attempts: attempt }
                };
                self.inner.barrier.abort(AbortCause::Fail { rank: self.rank });
                return Err(err);
            }
        }
    }

    /// Block until every rank of this communicator arrives (or the world
    /// aborts / the wait times out).
    pub fn barrier(&self) -> Result<(), CommError> {
        let _fctx = self.fault_enter()?;
        self.wait_barrier()
    }

    /// Cooperatively poison this world: record an abort naming this rank
    /// and wake every blocked peer, which then fails with
    /// [`CommError::Aborted`].  For supervisors whose rank closure bails
    /// out for NON-communication reasons — without this, peers already
    /// blocked in a collective would wait out the full timeout.
    pub fn poison(&self) {
        self.inner.barrier.abort(AbortCause::Fail { rank: self.rank });
    }

    fn account(&self, bytes: usize, t0: Instant, collective: bool) {
        let c = &self.inner.counters;
        if collective {
            c.collective_ops.fetch_add(1, Ordering::Relaxed);
        } else {
            c.p2p_ops.fetch_add(1, Ordering::Relaxed);
        }
        c.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        c.blocked_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// AllGather: every rank contributes `msg`, every rank receives the full
    /// rank-ordered list.  THE LASP-2 communication primitive (Alg. 1 line
    /// 6 / Alg. 2 line 7 on the memory states `M_t`, Alg. 3/4 on `dM_t`,
    /// Alg. 7 on K/V).
    pub fn all_gather(&self, msg: Msg) -> Result<Vec<Msg>, CommError> {
        let t0 = Instant::now();
        let fctx = self.fault_enter()?;
        let sent: usize = msg.iter().map(|t| t.byte_size()).sum();
        {
            let mut slots = lock(&self.inner.slots, "all_gather slots")?;
            slots[self.rank] = Some(self.seal(msg, fctx.is_some()));
        }
        self.wait_barrier()?;
        let sealed: Vec<Sealed> = {
            let slots = lock(&self.inner.slots, "all_gather slots")?;
            let mut v = Vec::with_capacity(slots.len());
            for s in slots.iter() {
                v.push(
                    s.clone()
                        .ok_or(CommError::Protocol { what: "all_gather slot empty" })?,
                );
            }
            v
        };
        // fence the generation BEFORE validation: our copies are private,
        // so retry/backoff sleeps never stall peers starting the next op
        self.wait_barrier()?;
        let mut gathered = Vec::with_capacity(sealed.len());
        for (src, s) in sealed.into_iter().enumerate() {
            gathered.push(self.open(s, src, &fctx)?);
        }
        // traffic: ring-allgather moves (W-1) * per-rank bytes per device
        self.account(sent * (self.size() - 1), t0, true);
        Ok(gathered)
    }

    /// AllGather performed in `splits` sequential slices of the flattened
    /// payload (Table 5 ablation: "varying split sizes of gathering").
    /// Semantically identical to `all_gather`; launches `splits` collectives.
    pub fn all_gather_split(&self, msg: Msg, splits: usize) -> Result<Vec<Msg>, CommError> {
        assert!(splits >= 1);
        if splits == 1 {
            return self.all_gather(msg);
        }
        let shapes: Vec<Vec<usize>> = msg.iter().map(|t| t.shape().to_vec()).collect();
        let mut flat: Vec<f32> = Vec::new();
        for t in &msg {
            flat.extend_from_slice(t.data());
        }
        let n = flat.len();
        let per = n.div_ceil(splits);
        let mut gathered_flat: Vec<Vec<f32>> = vec![Vec::with_capacity(n); self.size()];
        for s in 0..splits {
            let lo = (s * per).min(n);
            let hi = ((s + 1) * per).min(n);
            let piece = vec![Tensor::new(vec![hi - lo], flat[lo..hi].to_vec())];
            let got = self.all_gather(piece)?;
            for (r, g) in got.into_iter().enumerate() {
                gathered_flat[r].extend_from_slice(g[0].data());
            }
        }
        Ok(gathered_flat
            .into_iter()
            .map(|f| {
                let mut out = Vec::with_capacity(shapes.len());
                let mut off = 0;
                for sh in &shapes {
                    let len: usize = sh.iter().product();
                    out.push(Tensor::new(sh.clone(), f[off..off + len].to_vec()));
                    off += len;
                }
                out
            })
            .collect())
    }

    /// All-to-All: rank r contributes `msgs[d]` for every destination d and
    /// receives, in rank order, what every source addressed to r —
    /// `out[s] == ` the `msgs[self.rank]` that rank s passed in.
    ///
    /// This is DeepSpeed-Ulysses' repartition primitive (arXiv:2309.14509):
    /// with per-head slices as messages it converts a sequence-parallel
    /// layout `[N/W, H, dh]` into a head-parallel layout `[N, H/W, dh]`
    /// and back.  Deterministic (rank-ordered output, two-barrier
    /// generation fencing like `all_gather`); wire accounting charges each
    /// rank the (W-1)/W of its payload that leaves the device.
    pub fn all_to_all(&self, msgs: Vec<Msg>) -> Result<Vec<Msg>, CommError> {
        let t0 = Instant::now();
        let fctx = self.fault_enter()?;
        let w = self.size();
        assert_eq!(msgs.len(), w, "all_to_all needs one message per destination");
        let sent: usize = msgs
            .iter()
            .enumerate()
            .filter(|(dst, _)| *dst != self.rank)
            .map(|(_, m)| m.iter().map(|t| t.byte_size()).sum::<usize>())
            .sum();
        let checksum = fctx.is_some();
        {
            let mut mb = lock(&self.inner.mailbox, "all_to_all mailbox")?;
            for (dst, m) in msgs.into_iter().enumerate() {
                debug_assert!(mb[dst][self.rank].is_none(), "mailbox generation overlap");
                mb[dst][self.rank] = Some(self.seal(m, checksum));
            }
        }
        self.wait_barrier()?;
        let sealed: Vec<Sealed> = {
            let mut mb = lock(&self.inner.mailbox, "all_to_all mailbox")?;
            let mut v = Vec::with_capacity(w);
            for s in mb[self.rank].iter_mut() {
                v.push(
                    s.take()
                        .ok_or(CommError::Protocol { what: "all_to_all slot empty" })?,
                );
            }
            v
        };
        // fence the generation: no rank may start writing the next
        // all_to_all's slots until every rank has drained its row
        self.wait_barrier()?;
        let mut out = Vec::with_capacity(w);
        for (src, s) in sealed.into_iter().enumerate() {
            out.push(self.open(s, src, &fctx)?);
        }
        self.account(sent, t0, true);
        Ok(out)
    }

    /// ReduceScatter: element-wise SUM of every rank's `msg`, then each
    /// rank keeps its own 1/W slice along axis 0 (axis 0 of every tensor
    /// must be divisible by the world size).
    ///
    /// The reduction is performed in fixed rank order 0..W-1 on every
    /// rank, so results are bit-identical regardless of thread timing —
    /// and regardless of whether contributions were validated/retried
    /// (the fault path clones before summing, preserving the exact
    /// rank-ordered slice arithmetic of the clean path).
    /// Wire accounting matches a ring reduce-scatter: (W-1)/W of the
    /// payload per rank.
    pub fn reduce_scatter(&self, msg: Msg) -> Result<Msg, CommError> {
        let t0 = Instant::now();
        let fctx = self.fault_enter()?;
        let w = self.size();
        let total: usize = msg.iter().map(|t| t.byte_size()).sum();
        for t in &msg {
            assert!(
                t.shape()[0] % w == 0,
                "reduce_scatter: axis 0 ({}) not divisible by world size {}",
                t.shape()[0],
                w
            );
        }
        {
            let mut slots = lock(&self.inner.slots, "reduce_scatter slots")?;
            slots[self.rank] = Some(self.seal(msg, fctx.is_some()));
        }
        self.wait_barrier()?;
        let out: Msg = if fctx.is_some() {
            // validated path: copy every contribution, fence, then verify
            // each checksum (retrying injected faults) before the sum
            let sealed: Vec<Sealed> = {
                let slots = lock(&self.inner.slots, "reduce_scatter slots")?;
                let mut v = Vec::with_capacity(w);
                for s in slots.iter() {
                    v.push(s.clone().ok_or(CommError::Protocol {
                        what: "reduce_scatter slot empty",
                    })?);
                }
                v
            };
            self.wait_barrier()?;
            let mut acc: Option<Vec<Tensor>> = None;
            for (src, s) in sealed.into_iter().enumerate() {
                let m = self.open(s, src, &fctx)?;
                let sl: Vec<Tensor> = m.iter().map(|t| slice0(t, w, self.rank)).collect();
                match &mut acc {
                    None => acc = Some(sl),
                    Some(a) => {
                        for (a, t) in a.iter_mut().zip(sl.iter()) {
                            a.add_assign(t);
                        }
                    }
                }
            }
            acc.ok_or(CommError::Protocol { what: "reduce_scatter empty world" })?
        } else {
            let out = {
                let slots = lock(&self.inner.slots, "reduce_scatter slots")?;
                let first = slots[0]
                    .as_ref()
                    .ok_or(CommError::Protocol { what: "reduce_scatter slot empty" })?;
                let mut acc: Vec<Tensor> =
                    first.msg.iter().map(|t| slice0(t, w, self.rank)).collect();
                for r in 1..w {
                    let m = slots[r]
                        .as_ref()
                        .ok_or(CommError::Protocol { what: "reduce_scatter slot empty" })?;
                    for (a, t) in acc.iter_mut().zip(m.msg.iter()) {
                        a.add_assign(&slice0(t, w, self.rank));
                    }
                }
                acc
            };
            self.wait_barrier()?;
            out
        };
        self.account(total / w * (w - 1), t0, true);
        Ok(out)
    }

    /// P2P send (LASP-1's ring primitive; also ZeCO's pipelined state hop).
    pub fn send(&self, dst: usize, msg: Msg) -> Result<(), CommError> {
        let t0 = Instant::now();
        let fctx = self.fault_enter()?;
        let bytes: usize = msg.iter().map(|t| t.byte_size()).sum();
        let sealed = self.seal(msg, fctx.is_some());
        self.inner.senders[dst][self.rank]
            .send(sealed)
            .map_err(|_| CommError::PeerGone { rank: self.rank, peer: dst })?;
        self.account(bytes, t0, false);
        Ok(())
    }

    /// P2P blocking receive, bounded by the world timeout and interrupted
    /// by a world abort (so a receiver whose sender crashed gets the
    /// crash's typed error, not a timeout).
    pub fn recv(&self, src: usize) -> Result<Msg, CommError> {
        let t0 = Instant::now();
        let fctx = self.fault_enter()?;
        let deadline = t0 + self.timeout();
        let sealed = {
            let rx = lock(&self.inner.receivers[self.rank][src], "recv channel")?;
            loop {
                if let Some(cause) = self.inner.barrier.aborted() {
                    return Err(cause.to_error());
                }
                match rx.recv_timeout(ABORT_POLL) {
                    Ok(s) => break s,
                    Err(RecvTimeoutError::Timeout) => {
                        if Instant::now() >= deadline {
                            let ms = self.inner.timeout_ms.load(Ordering::Relaxed);
                            self.inner
                                .barrier
                                .abort(AbortCause::Timeout { rank: self.rank, ms });
                            return Err(CommError::Timeout { rank: self.rank, ms });
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(CommError::PeerGone { rank: self.rank, peer: src })
                    }
                }
            }
        };
        let msg = self.open(sealed, src, &fctx)?;
        self.inner
            .counters
            .blocked_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(msg)
    }

    /// Right ring neighbor `(rank + 1) % W`.
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.size()
    }

    /// Left ring neighbor `(rank - 1) % W`.
    pub fn left(&self) -> usize {
        (self.rank + self.size() - 1) % self.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rank: usize, v: f32) -> Tensor {
        Tensor::full(&[2, 2], rank as f32 * 100.0 + v)
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let w = World::new(4);
        let results = w.run(|c| c.all_gather(vec![t(c.rank(), 1.0)]).unwrap());
        for msgs in results {
            assert_eq!(msgs.len(), 4);
            for (r, m) in msgs.iter().enumerate() {
                assert_eq!(m[0].data()[0], r as f32 * 100.0 + 1.0);
            }
        }
    }

    #[test]
    fn all_gather_repeated_generations() {
        let w = World::new(3);
        let results = w.run(|c| {
            let mut acc = 0.0;
            for it in 0..5 {
                let got = c.all_gather(vec![t(c.rank(), it as f32)]).unwrap();
                acc += got[2][0].data()[0];
            }
            acc
        });
        for r in results {
            assert_eq!(r, (0..5).map(|i| 200.0 + i as f32).sum::<f32>());
        }
    }

    #[test]
    fn split_gather_equivalent() {
        let w = World::new(4);
        let a = w.run(|c| c.all_gather(vec![Tensor::randn(&[3, 5], c.rank() as u64)]).unwrap());
        let w2 = World::new(4);
        let b = w2.run(|c| {
            c.all_gather_split(vec![Tensor::randn(&[3, 5], c.rank() as u64)], 4)
                .unwrap()
        });
        for (x, y) in a.iter().zip(&b) {
            for (mx, my) in x.iter().zip(y) {
                assert_eq!(mx[0], my[0]);
            }
        }
        // but 4x the collective launches
        assert_eq!(w.counters().collective_ops, 4); // 1 per rank
        assert_eq!(w2.counters().collective_ops, 16); // 4 per rank
    }

    #[test]
    fn ring_send_recv() {
        let w = World::new(4);
        let results = w.run(|c| {
            // pass rank around the full ring, accumulating
            let mut val = c.rank() as f32;
            for _ in 0..c.size() - 1 {
                c.send(c.right(), vec![Tensor::full(&[1], val)]).unwrap();
                val = c.recv(c.left()).unwrap()[0].data()[0];
            }
            val
        });
        // after W-1 hops each rank holds its right neighbor's original value
        assert_eq!(results, vec![1.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn counters_track_steps() {
        let w = World::new(4);
        w.run(|c| {
            c.all_gather(vec![Tensor::zeros(&[8])]).unwrap();
        });
        let snap = w.counters();
        assert_eq!(snap.collective_ops, 4); // one launch per rank
        assert_eq!(snap.p2p_ops, 0);
        // ring-allgather traffic: each rank moves (W-1)*32 bytes
        assert_eq!(snap.bytes, 4 * 3 * 32);
    }

    #[test]
    fn barrier_sync() {
        let w = World::new(8);
        let r = w.run(|c| {
            c.barrier().unwrap();
            c.rank()
        });
        assert_eq!(r, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn all_to_all_transposes_rank_pairs_and_counts_bytes() {
        // out[s] on rank r must be exactly what s addressed to r, at every
        // world size the schedulers use, with deterministic byte counters.
        for size in [2usize, 4, 8] {
            let w = World::new(size);
            let results = w.run(|c| {
                let msgs: Vec<Msg> = (0..c.size())
                    .map(|dst| vec![Tensor::full(&[4, 2], (c.rank() * 10 + dst) as f32)])
                    .collect();
                c.all_to_all(msgs).unwrap()
            });
            for (r, out) in results.iter().enumerate() {
                assert_eq!(out.len(), size);
                for (s, m) in out.iter().enumerate() {
                    assert_eq!(m[0].data()[0], (s * 10 + r) as f32, "W={size} r={r} s={s}");
                }
            }
            let snap = w.counters();
            assert_eq!(snap.collective_ops, size as u64, "one launch per rank");
            assert_eq!(snap.p2p_ops, 0);
            // each rank keeps its own slice: wire = (W-1) x 4*2*4 bytes/rank
            assert_eq!(snap.bytes, (size * (size - 1) * 32) as u64, "W={size}");
        }
    }

    #[test]
    fn all_to_all_deterministic_across_generations() {
        // repeated all_to_all under World::run must produce identical
        // values every generation (the two-barrier fence prevents a fast
        // rank from clobbering a slot the slow rank hasn't drained).
        let w = World::new(4);
        let results = w.run(|c| {
            let mut sums = Vec::new();
            for gen in 0..6 {
                let msgs: Vec<Msg> = (0..c.size())
                    .map(|dst| {
                        vec![Tensor::full(&[2], (gen * 100 + c.rank() * 10 + dst) as f32)]
                    })
                    .collect();
                let out = c.all_to_all(msgs).unwrap();
                sums.push(out.iter().map(|m| m[0].data()[0]).sum::<f32>());
            }
            sums
        });
        for (r, sums) in results.iter().enumerate() {
            for (gen, s) in sums.iter().enumerate() {
                let want: f32 =
                    (0..4).map(|src| (gen * 100 + src * 10 + r) as f32).sum();
                assert_eq!(*s, want, "rank {r} generation {gen}");
            }
        }
    }

    #[test]
    fn reduce_scatter_sums_and_slices() {
        for size in [2usize, 4, 8] {
            let w = World::new(size);
            let results = w.run(|c| {
                // every rank contributes [0, 1, ..., 2W-1] * (rank+1)
                let n = 2 * c.size();
                let data: Vec<f32> =
                    (0..n).map(|i| (i * (c.rank() + 1)) as f32).collect();
                c.reduce_scatter(vec![Tensor::new(vec![n], data)]).unwrap()
            });
            // sum over ranks of (rank+1) = W(W+1)/2
            let mult = (size * (size + 1) / 2) as f32;
            for (r, out) in results.iter().enumerate() {
                assert_eq!(out[0].shape(), &[2]);
                assert_eq!(out[0].data()[0], (2 * r) as f32 * mult);
                assert_eq!(out[0].data()[1], (2 * r + 1) as f32 * mult);
            }
            let snap = w.counters();
            assert_eq!(snap.collective_ops, size as u64);
            // ring reduce-scatter wire: (W-1)/W of 2W*4 bytes per rank
            assert_eq!(snap.bytes, (size * (size - 1) * 8) as u64);
        }
    }

    #[test]
    fn reduce_scatter_matches_allgather_then_slice() {
        // semantic contract the ZeRO optimizer leans on: reduce_scatter ==
        // all_gather everything, sum in RANK ORDER (0..W-1, starting from
        // rank 0's tensor), then keep your own axis-0 slice — bit-exact,
        // on random tensors, at both world sizes the driver tests use.
        for size in [2usize, 4] {
            let w = World::new(size);
            let got = w.run(|c| {
                let x = Tensor::randn(&[2 * c.size(), 3], 77 + c.rank() as u64);
                let rs = c.reduce_scatter(vec![x.clone()]).unwrap();
                let all = c.all_gather(vec![x]).unwrap();
                let mut sum = all[0][0].clone();
                for m in &all[1..] {
                    sum.add_assign(&m[0]);
                }
                (rs, slice0(&sum, c.size(), c.rank()))
            });
            for (r, (rs, want)) in got.iter().enumerate() {
                assert_eq!(rs[0].shape(), want.shape(), "W={size} rank {r}");
                for (a, b) in rs[0].data().iter().zip(want.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "W={size} rank {r}");
                }
            }
        }
    }

    #[test]
    fn mesh_row_col_groups_are_orthogonal() {
        // 2x2 mesh: rows {0,1},{2,3}; cols {0,2},{1,3}.
        let w = World::new_mesh(2, 2);
        assert_eq!(w.mesh_dims(), Some((2, 2)));
        let results = w.run(|c| {
            let row = c.row().expect("mesh row");
            let col = c.col().expect("mesh col");
            assert!(row.row().is_none(), "sub-communicators are flat");
            let rg = row.all_gather(vec![Tensor::full(&[1], c.rank() as f32)]).unwrap();
            let cg = col.all_gather(vec![Tensor::full(&[1], c.rank() as f32)]).unwrap();
            let rv: Vec<f32> = rg.iter().map(|m| m[0].data()[0]).collect();
            let cv: Vec<f32> = cg.iter().map(|m| m[0].data()[0]).collect();
            (rv, cv)
        });
        assert_eq!(results[0], (vec![0.0, 1.0], vec![0.0, 2.0]));
        assert_eq!(results[1], (vec![0.0, 1.0], vec![1.0, 3.0]));
        assert_eq!(results[2], (vec![2.0, 3.0], vec![0.0, 2.0]));
        assert_eq!(results[3], (vec![2.0, 3.0], vec![1.0, 3.0]));
        // sub-world traffic lands in the ROOT counters: 8 collective
        // launches (2 per rank), each moving (2-1)*4 bytes
        let snap = w.counters();
        assert_eq!(snap.collective_ops, 8);
        assert_eq!(snap.bytes, 8 * 4);
    }

    #[test]
    fn mesh_row_all_to_all_stays_inside_row() {
        let w = World::new_mesh(2, 2);
        let results = w.run(|c| {
            let row = c.row().unwrap();
            let msgs: Vec<Msg> = (0..row.size())
                .map(|d| vec![Tensor::full(&[1], (c.rank() * 10 + d) as f32)])
                .collect();
            let out = row.all_to_all(msgs).unwrap();
            out.iter().map(|m| m[0].data()[0]).collect::<Vec<f32>>()
        });
        // rank 0's row peers are {0,1}: receives [0*10+0, 1*10+0]
        assert_eq!(results[0], vec![0.0, 10.0]);
        assert_eq!(results[1], vec![1.0, 11.0]);
        // rank 2's row peers are {2,3}
        assert_eq!(results[2], vec![20.0, 30.0]);
        assert_eq!(results[3], vec![21.0, 31.0]);
    }

    #[test]
    fn run_catch_isolates_a_panicking_rank() {
        let w = World::new(3);
        let results = w.run_catch(|c| {
            if c.rank() == 1 {
                panic!("injected worker panic");
            }
            // peers blocked on the dead rank get a typed abort, not a hang
            match c.all_gather(vec![Tensor::zeros(&[2])]) {
                Err(CommError::Aborted { rank: 1 }) => c.rank(),
                other => panic!("expected Aborted{{1}}, got {other:?}"),
            }
        });
        assert_eq!(*results[0].as_ref().unwrap(), 0);
        let p = results[1].as_ref().unwrap_err();
        assert_eq!(p.rank, 1);
        assert!(p.message.contains("injected worker panic"), "{}", p.message);
        assert_eq!(*results[2].as_ref().unwrap(), 2);
    }

    #[test]
    fn injected_crash_poisons_world_with_typed_errors() {
        let w = World::new(4);
        // rank 2's second communicator call (op index 1) is its last
        w.install_faults(Arc::new(FaultPlan::new().crash(2, 1)));
        let results = w.run_catch(|c| {
            let mut errs = Vec::new();
            for it in 0..3 {
                match c.all_gather(vec![Tensor::full(&[2], it as f32)]) {
                    Ok(_) => {}
                    Err(e) => {
                        errs.push(e);
                        break;
                    }
                }
            }
            errs
        });
        for (r, res) in results.iter().enumerate() {
            let errs = res.as_ref().unwrap();
            assert_eq!(errs.len(), 1, "rank {r} must fail exactly once");
            // every rank — crasher and peers — names the crashed rank
            assert_eq!(errs[0], CommError::Crashed { rank: 2, op: 1 }, "rank {r}");
        }
    }

    #[test]
    fn transient_drop_and_corruption_recover_bit_exactly() {
        // faults below the retry budget are invisible to the caller: the
        // gathered values match a clean run bit-for-bit, and the plan
        // records the retries it took
        let clean = World::new(4)
            .run(|c| c.all_gather(vec![Tensor::randn(&[3, 2], c.rank() as u64)]).unwrap());
        let w = World::new(4);
        let plan = Arc::new(
            FaultPlan::new()
                .with_retry(3, 10)
                .drop_msg(0, 0, 2, 2)
                .corrupt(3, 0, 1, 1),
        );
        w.install_faults(plan.clone());
        let faulty =
            w.run(|c| c.all_gather(vec![Tensor::randn(&[3, 2], c.rank() as u64)]).unwrap());
        for (a, b) in clean.iter().zip(&faulty) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x[0], y[0]);
            }
        }
        assert!(plan.retries() >= 3, "2 dropped + 1 corrupt attempts retried");
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn persistent_corruption_surfaces_not_wrong_data() {
        // more corrupt attempts than retries: the receiver must surface
        // CommError::Corrupt — never deliver the flipped payload
        let w = World::new(2);
        let plan = Arc::new(FaultPlan::new().with_retry(2, 10).corrupt(1, 0, 0, 99));
        w.install_faults(plan);
        let results = w.run_catch(|c| c.all_gather(vec![Tensor::full(&[2], 7.0)]));
        let r1 = results[1].as_ref().unwrap();
        match r1 {
            Err(CommError::Corrupt { src: 0, dst: 1, op: 0, attempts: 3 }) => {}
            other => panic!("expected persistent Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn recv_timeout_is_typed_not_a_hang() {
        let w = World::new(2);
        w.set_timeout_ms(50);
        let results = w.run_catch(|c| {
            if c.rank() == 0 {
                // never sends: rank 1's recv must time out quickly
                Ok(vec![])
            } else {
                c.recv(0)
            }
        });
        match results[1].as_ref().unwrap() {
            Err(CommError::Timeout { rank: 1, ms: 50 }) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
    }
}
