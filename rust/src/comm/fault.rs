//! Deterministic fault injection for the in-memory communicator.
//!
//! A [`FaultPlan`] is a seeded list of one-shot events keyed on
//! `(rank, op_index)`, where a rank's op index counts every communicator
//! call it makes (collectives, P2P sends/receives, barriers) in program
//! order — so a plan built once replays identically at any thread count.
//! Installed on a [`World`](super::World) via
//! [`install_faults`](super::World::install_faults), the plan can:
//!
//! * **crash** a rank (it returns [`CommError::Crashed`] and poisons the
//!   world so peers fail fast instead of hanging),
//! * **delay** a rank (straggler injection — results must stay
//!   bit-identical thanks to the two-barrier generation fencing),
//! * **drop** a message on the receiver side for the first `times`
//!   delivery attempts (recovered by bounded-backoff retry),
//! * **corrupt** a message (a real bit flip in a copy, detected by the
//!   per-message FNV-1a checksum sealed in at send time; transient
//!   corruption is retried, persistent corruption surfaces as
//!   [`CommError::Corrupt`] — never as a wrong numerical result).
//!
//! Events fire at most once even if a plan is re-installed on a rebuilt
//! (elastic-recovery) world: the crash that killed W=4 must not kill the
//! resumed W=2 run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use super::Msg;

/// Typed communication failure.  Every collective and P2P primitive
/// returns `Result<_, CommError>`; the train driver keys its elastic
/// recovery policy on the variant (see `DESIGN.md` "Fault tolerance").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// An injected crash killed `rank` at its `op`-th communicator call
    /// (peers of a crashed rank observe the same variant via the abort
    /// flag, so the supervisor can tell *who* died from any rank's error).
    Crashed { rank: usize, op: u64 },
    /// A peer (`rank`) failed or panicked and poisoned the world.
    Aborted { rank: usize },
    /// A barrier or receive wait exceeded the world timeout.
    Timeout { rank: usize, ms: u64 },
    /// A message failed its FNV-1a checksum even after all retries.
    Corrupt { src: usize, dst: usize, op: u64, attempts: u32 },
    /// A message never arrived within the retry budget.
    Lost { src: usize, dst: usize, op: u64, attempts: u32 },
    /// A P2P channel endpoint disappeared (peer thread exited).
    PeerGone { rank: usize, peer: usize },
    /// A shared-memory lock was poisoned by a panicking peer.
    Poisoned { what: &'static str },
    /// Internal protocol invariant broken (empty slot between barriers).
    Protocol { what: &'static str },
    /// A mesh sub-communicator was requested on a flat world.
    NoMesh { dim: &'static str },
}

impl CommError {
    /// The rank a rebuilt world must exclude, when this error identifies
    /// one (only injected/observed crashes do — timeouts and corruption
    /// keep the world size and retry from the checkpoint instead).
    pub fn crashed_rank(&self) -> Option<usize> {
        match self {
            CommError::Crashed { rank, .. } => Some(*rank),
            _ => None,
        }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Crashed { rank, op } => {
                write!(f, "comm/crash: rank {rank} crashed at op {op}")
            }
            CommError::Aborted { rank } => {
                write!(f, "comm/abort: rank {rank} failed; world poisoned")
            }
            CommError::Timeout { rank, ms } => {
                write!(f, "comm/timeout: rank {rank} waited > {ms} ms")
            }
            CommError::Corrupt { src, dst, op, attempts } => write!(
                f,
                "comm/corrupt: checksum mismatch {src}->{dst} at op {op} after {attempts} attempts"
            ),
            CommError::Lost { src, dst, op, attempts } => write!(
                f,
                "comm/lost: message {src}->{dst} at op {op} dropped after {attempts} attempts"
            ),
            CommError::PeerGone { rank, peer } => {
                write!(f, "comm/peer-gone: rank {rank} lost channel to {peer}")
            }
            CommError::Poisoned { what } => write!(f, "comm/poisoned: {what} lock poisoned"),
            CommError::Protocol { what } => write!(f, "comm/protocol: {what}"),
            CommError::NoMesh { dim } => {
                write!(f, "comm/no-mesh: {dim} sub-communicator on a flat world")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Why a world was poisoned — recorded once in the barrier so every rank
/// blocked anywhere in the communicator fails fast with the SAME typed
/// error instead of each waiting out its own timeout.
#[derive(Clone, Copy, Debug)]
pub(crate) enum AbortCause {
    Crash { rank: usize, op: u64 },
    Fail { rank: usize },
    Timeout { rank: usize, ms: u64 },
}

impl AbortCause {
    pub(crate) fn to_error(self) -> CommError {
        match self {
            AbortCause::Crash { rank, op } => CommError::Crashed { rank, op },
            AbortCause::Fail { rank } => CommError::Aborted { rank },
            AbortCause::Timeout { rank, ms } => CommError::Timeout { rank, ms },
        }
    }
}

/// What an injected event does when its `(rank, at_op)` key matches.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// The rank dies: its next communicator call returns
    /// [`CommError::Crashed`] and the world is poisoned.
    Crash,
    /// Straggler: the rank sleeps `micros` before starting the op.
    Delay { micros: u64 },
    /// Receiver-side loss: the message from `src` is invisible for the
    /// first `times` delivery attempts (then retries see it).
    DropMsg { src: usize, times: u32 },
    /// Receiver-side corruption: a bit-flipped copy of the message from
    /// `src` is delivered for the first `times` attempts; the checksum
    /// catches it and the receiver retries.
    Corrupt { src: usize, times: u32 },
}

/// One scheduled fault: `kind` fires when rank `rank` executes its
/// `at_op`-th communicator call.  One-shot for `Crash`/`Delay` (the
/// `fired` latch survives plan re-installation on a rebuilt world).
#[derive(Debug)]
pub struct FaultEvent {
    rank: usize,
    at_op: u64,
    kind: FaultKind,
    fired: AtomicBool,
}

/// A deterministic, seeded schedule of faults plus the retry policy the
/// communicator uses when validation fails.  Build with the fluent
/// constructors, share via `Arc`, install with
/// [`World::install_faults`](super::World::install_faults).
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    /// delivery attempts beyond the first before giving up (default 3)
    pub max_retries: u32,
    /// first backoff sleep; doubles per attempt, capped at 2^10 x base
    pub backoff_base_us: u64,
    retries: AtomicU64,
    injected: AtomicU64,
}

impl FaultPlan {
    /// Empty plan with the default retry policy (3 retries, 100 us base).
    pub fn new() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            max_retries: 3,
            backoff_base_us: 100,
            retries: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    fn push(mut self, rank: usize, at_op: u64, kind: FaultKind) -> FaultPlan {
        self.events.push(FaultEvent { rank, at_op, kind, fired: AtomicBool::new(false) });
        self
    }

    /// Crash `rank` at its `at_op`-th communicator call.
    pub fn crash(self, rank: usize, at_op: u64) -> FaultPlan {
        self.push(rank, at_op, FaultKind::Crash)
    }

    /// Delay `rank` by `micros` before its `at_op`-th communicator call.
    pub fn delay(self, rank: usize, at_op: u64, micros: u64) -> FaultPlan {
        self.push(rank, at_op, FaultKind::Delay { micros })
    }

    /// Drop the message `src -> rank` during rank's `at_op`-th call for
    /// the first `times` delivery attempts.
    pub fn drop_msg(self, rank: usize, at_op: u64, src: usize, times: u32) -> FaultPlan {
        self.push(rank, at_op, FaultKind::DropMsg { src, times })
    }

    /// Corrupt the message `src -> rank` during rank's `at_op`-th call
    /// for the first `times` delivery attempts.
    pub fn corrupt(self, rank: usize, at_op: u64, src: usize, times: u32) -> FaultPlan {
        self.push(rank, at_op, FaultKind::Corrupt { src, times })
    }

    /// Override the retry policy.
    pub fn with_retry(mut self, max_retries: u32, backoff_base_us: u64) -> FaultPlan {
        self.max_retries = max_retries;
        self.backoff_base_us = backoff_base_us;
        self
    }

    /// Retries the communicator performed because of this plan.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Events that actually fired.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    pub(crate) fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Crash/delay hook, called as `rank` starts communicator op `op`.
    pub(crate) fn on_op(&self, rank: usize, op: u64) -> Result<(), CommError> {
        for ev in &self.events {
            if ev.rank != rank || ev.at_op != op {
                continue;
            }
            match ev.kind {
                FaultKind::Crash => {
                    if !ev.fired.swap(true, Ordering::Relaxed) {
                        self.injected.fetch_add(1, Ordering::Relaxed);
                        return Err(CommError::Crashed { rank, op });
                    }
                }
                FaultKind::Delay { micros } => {
                    if !ev.fired.swap(true, Ordering::Relaxed) {
                        self.injected.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_micros(micros));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn injects(&self, want_drop: bool, dst: usize, op: u64, src: usize, attempt: u32) -> bool {
        for ev in &self.events {
            if ev.rank != dst || ev.at_op != op {
                continue;
            }
            let hit = match ev.kind {
                FaultKind::DropMsg { src: s, times } if want_drop => s == src && attempt < times,
                FaultKind::Corrupt { src: s, times } if !want_drop => s == src && attempt < times,
                _ => false,
            };
            if hit {
                if !ev.fired.swap(true, Ordering::Relaxed) {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                }
                return true;
            }
        }
        false
    }

    /// Is the `src -> dst` message invisible on this delivery `attempt`?
    pub(crate) fn injects_drop(&self, dst: usize, op: u64, src: usize, attempt: u32) -> bool {
        self.injects(true, dst, op, src, attempt)
    }

    /// Is the `src -> dst` message bit-flipped on this delivery `attempt`?
    pub(crate) fn injects_corrupt(&self, dst: usize, op: u64, src: usize, attempt: u32) -> bool {
        self.injects(false, dst, op, src, attempt)
    }

    /// Backoff before delivery attempt `attempt` (>= 1): exponential from
    /// `backoff_base_us`, exponent capped so the sleep stays bounded.
    pub(crate) fn backoff(&self, attempt: u32) -> Duration {
        let exp = (attempt.saturating_sub(1)).min(10);
        Duration::from_micros(self.backoff_base_us.saturating_mul(1u64 << exp))
    }
}

/// Per-world fault bookkeeping: the shared plan plus one op counter per
/// rank (counters are world-local, so a rebuilt world replays op indices
/// from zero while the plan's one-shot latches carry over).
#[derive(Debug)]
pub(crate) struct FaultState {
    pub(crate) plan: std::sync::Arc<FaultPlan>,
    pub(crate) ops: Vec<AtomicU64>,
}

impl FaultState {
    pub(crate) fn new(plan: std::sync::Arc<FaultPlan>, size: usize) -> FaultState {
        FaultState { plan, ops: (0..size).map(|_| AtomicU64::new(0)).collect() }
    }
}

/// FNV-1a over every tensor's shape and raw f32 bits — the per-message
/// checksum sealed in at send time and verified at delivery.
pub fn checksum_msg(msg: &Msg) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(0x100000001b3);
    };
    for t in msg {
        for &d in t.shape() {
            for b in (d as u64).to_le_bytes() {
                eat(b);
            }
        }
        for &v in t.data() {
            for b in v.to_bits().to_le_bytes() {
                eat(b);
            }
        }
    }
    h
}

/// A genuinely corrupted copy: clone the message and flip the low bit of
/// the first element of the first non-empty tensor (so the checksum MUST
/// catch it — injection never silently alters the caller's data).
pub(crate) fn corrupt_copy(msg: &Msg) -> Msg {
    let mut out = msg.clone();
    for t in &mut out {
        if !t.data().is_empty() {
            let d = t.data_mut();
            d[0] = f32::from_bits(d[0].to_bits() ^ 1);
            break;
        }
    }
    out
}

/// splitmix64: the seeded generator chaos scenarios draw from (same
/// algorithm the data pipeline uses, kept dependency-free).
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn checksum_detects_single_bit_flip() {
        let msg: Msg = vec![Tensor::randn(&[4, 3], 7), Tensor::randn(&[2], 8)];
        let clean = checksum_msg(&msg);
        let bad = corrupt_copy(&msg);
        assert_ne!(clean, checksum_msg(&bad), "bit flip must change the checksum");
        // corruption happens in a COPY — the original is untouched
        assert_eq!(clean, checksum_msg(&msg));
    }

    #[test]
    fn checksum_covers_shape_not_just_data() {
        let a: Msg = vec![Tensor::new(vec![2, 3], vec![0.0; 6])];
        let b: Msg = vec![Tensor::new(vec![3, 2], vec![0.0; 6])];
        assert_ne!(checksum_msg(&a), checksum_msg(&b));
    }

    #[test]
    fn crash_event_fires_exactly_once() {
        let plan = FaultPlan::new().crash(1, 5);
        assert!(plan.on_op(1, 4).is_ok());
        assert!(plan.on_op(0, 5).is_ok(), "other ranks unaffected");
        assert_eq!(
            plan.on_op(1, 5),
            Err(CommError::Crashed { rank: 1, op: 5 })
        );
        // the latch holds across a re-installed plan (elastic rebuild)
        assert!(plan.on_op(1, 5).is_ok(), "one-shot: must not re-fire");
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn drop_and_corrupt_respect_attempt_budget() {
        let plan = FaultPlan::new().drop_msg(2, 7, 0, 2).corrupt(2, 9, 1, 1);
        assert!(plan.injects_drop(2, 7, 0, 0));
        assert!(plan.injects_drop(2, 7, 0, 1));
        assert!(!plan.injects_drop(2, 7, 0, 2), "attempt 2 sees the message");
        assert!(!plan.injects_drop(2, 7, 1, 0), "wrong src");
        assert!(plan.injects_corrupt(2, 9, 1, 0));
        assert!(!plan.injects_corrupt(2, 9, 1, 1));
        assert!(!plan.injects_corrupt(2, 7, 0, 0), "drop event is not corrupt");
    }

    #[test]
    fn backoff_is_bounded_exponential() {
        let plan = FaultPlan::new().with_retry(4, 100);
        assert_eq!(plan.backoff(1).as_micros(), 100);
        assert_eq!(plan.backoff(2).as_micros(), 200);
        assert_eq!(plan.backoff(3).as_micros(), 400);
        assert_eq!(plan.backoff(60).as_micros(), 100 << 10, "exponent capped");
    }

    #[test]
    fn splitmix64_is_deterministic() {
        let mut a = 42u64;
        let mut b = 42u64;
        let xs: Vec<u64> = (0..4).map(|_| splitmix64(&mut a)).collect();
        let ys: Vec<u64> = (0..4).map(|_| splitmix64(&mut b)).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs[0], xs[1]);
    }
}
