//! Deterministic data-parallel helpers for the compute core.
//!
//! Everything here preserves BIT-IDENTICAL results at any thread count:
//! work is split into contiguous index blocks, every item is computed by
//! exactly the same code with exactly the same accumulation order no
//! matter which thread runs it, and threads write disjoint output
//! regions.  Changing `LASP2_THREADS` (or `set_threads`) therefore never
//! changes a single output bit — it only changes wall-clock time.  This
//! is checked end-to-end by `tests/thread_determinism.rs`.
//!
//! Thread count resolution order:
//!   1. `set_threads(n)` with n >= 1 (tests, benches, embedders);
//!   2. the `LASP2_THREADS` env var (`1` = fully serial, the pre-threading
//!      behavior; `0`/unset/unparseable = auto);
//!   3. `std::thread::available_parallelism()`.
//!
//! Nested parallel regions run serially: a worker spawned by one `par_*`
//! call never spawns again (the distributed-world rank threads in
//! `comm::World` are NOT workers, so per-rank kernels may still use the
//! core — their results are identical either way).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Minimum floating-point work (in flops) before a loop is worth farming
/// out to threads: below this the `thread::scope` spawn cost dominates.
/// Thresholding is deterministic — it depends on the problem shape only,
/// never on the thread count — so it cannot affect results.
pub const PAR_MIN_FLOPS: usize = 1 << 21;

/// Runtime override set via `set_threads` (0 = none, use env/auto).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        match std::env::var("LASP2_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
        {
            Some(n) if n >= 1 => n,
            // 0, unset, or unparseable -> auto
            _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    })
}

/// The configured worker count (>= 1).
pub fn num_threads() -> usize {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Override the thread count at runtime (wins over `LASP2_THREADS`);
/// `0` restores env/auto resolution.  Results are bit-identical at any
/// setting, so flipping this concurrently is benign.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

thread_local! {
    static IN_PAR: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread IS a `par_*` worker (nested calls run
/// serially instead of oversubscribing).
pub fn in_par() -> bool {
    IN_PAR.with(|c| c.get())
}

/// How many workers a loop of `items` items totalling `flops` flops would
/// actually use right now (1 = it will run inline).
pub fn planned_threads(items: usize, flops: usize) -> usize {
    if items < 2 || flops < PAR_MIN_FLOPS || in_par() {
        return 1;
    }
    num_threads().min(items)
}

/// True when `par_map`/`for_each_row_band` over this shape would fan out.
pub fn would_parallelize(items: usize, flops: usize) -> bool {
    planned_threads(items, flops) > 1
}

/// Deterministic parallel map: returns exactly `(0..n).map(f).collect()`.
/// `flops` is the TOTAL floating-point work of all items; small loops run
/// inline (see `PAR_MIN_FLOPS`).
pub fn par_map<T, F>(n: usize, flops: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = planned_threads(n, flops);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let block = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(block).enumerate() {
            let f = &f;
            s.spawn(move || {
                IN_PAR.with(|c| c.set(true));
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(bi * block + off));
                }
            });
        }
    });
    out.into_iter()
        .map(|v| v.expect("par_map: worker left a slot empty"))
        .collect()
}

/// Deterministic row-band parallelism over a row-major output buffer.
///
/// `out` must span exactly `rows` rows at stride `ld` (the last row may be
/// shorter than `ld`).  `body(row0, nrows, band)` computes rows
/// `row0..row0 + nrows` into `band`, whose first element is row `row0`'s
/// first element.  Bands are contiguous and disjoint, so any `body` whose
/// per-row result is independent of the banding produces identical bits
/// at every thread count.
pub fn for_each_row_band<F>(out: &mut [f32], rows: usize, ld: usize, flops: usize, body: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let threads = planned_threads(rows, flops);
    if threads <= 1 {
        body(0, rows, out);
        return;
    }
    let band = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (bi, chunk) in out.chunks_mut(band * ld).enumerate() {
            let body = &body;
            s.spawn(move || {
                IN_PAR.with(|c| c.set(true));
                let row0 = bi * band;
                body(row0, band.min(rows - row0), chunk);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial_map() {
        // big enough flops to actually fan out (when threads are available)
        let a: Vec<usize> = par_map(1000, PAR_MIN_FLOPS * 2, |i| i * i);
        let b: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn par_map_small_runs_inline() {
        assert_eq!(par_map(3, 10, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn row_bands_cover_every_row_once() {
        let rows = 37;
        let ld = 8;
        let n = 5; // last-row short width
        let mut out = vec![0.0f32; (rows - 1) * ld + n];
        for_each_row_band(&mut out, rows, ld, PAR_MIN_FLOPS * 2, |row0, nrows, band| {
            for r in 0..nrows {
                for j in 0..n {
                    band[r * ld + j] += (row0 + r) as f32;
                }
            }
        });
        for r in 0..rows {
            for j in 0..n {
                assert_eq!(out[r * ld + j], r as f32, "row {r} col {j}");
            }
        }
    }

    #[test]
    fn nested_parallelism_is_suppressed() {
        let flat: Vec<usize> = par_map(4, PAR_MIN_FLOPS * 2, |i| {
            // inner call sees in_par() on worker threads and runs inline
            par_map(4, PAR_MIN_FLOPS * 2, move |j| i * 4 + j).len()
        });
        assert_eq!(flat, vec![4, 4, 4, 4]);
    }

    #[test]
    fn set_threads_override_round_trips() {
        // no assertions about speed — only that results stay identical
        let want: Vec<usize> = (0..64).map(|i| i * 3).collect();
        for t in [1usize, 2, 8] {
            set_threads(t);
            assert_eq!(par_map(64, PAR_MIN_FLOPS * 2, |i| i * 3), want);
        }
        set_threads(0);
    }
}
