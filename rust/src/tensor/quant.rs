//! Reduced-precision weight storage for the bandwidth-bound decode readout.
//!
//! The m=1 logit readout (`rmsnorm(x) · embᵀ`, `[1,d] x [vocab,d]`) streams
//! the entire `vocab x d` embedding matrix per generated token and does only
//! two flops per weight — it is memory-bound, so halving (bf16) or quartering
//! (int8) the bytes moved is worth more than any amount of flop tuning.
//! [`QuantMat`] stores such a matrix in one of two opt-in formats:
//!
//! * **bf16** — round-to-nearest-even truncation of the f32 high half.
//!   Relative weight error ≤ 2⁻⁸; decode is a 16-bit shift.
//! * **int8** — symmetric per-row scales: `scale[j] = max|w[j,·]| / 127`,
//!   `q = round(w / scale)` clamped to ±127.  Per-row (not per-tensor)
//!   scales keep outlier rows from flattening everyone else's resolution.
//!
//! ## Determinism contract
//!
//! Products are **accumulated in f32** with a fixed 8-lane chain, serially
//! over output rows, so quantized logits are a pure function of the inputs —
//! bit-identical across runs and thread counts, exactly like the default
//! path.  What changes is *which* function: weights are rounded, so logits
//! agree with the f32 readout only to tolerance (≲1e-2 on unit-scale
//! activations; pinned by `tests/quant_readout.rs` on the tiny preset).
//! That is why the path is opt-in via `--decode-dtype` and the default
//! stays bit-exact f32 (DESIGN.md §Compute core).

use anyhow::{bail, Result};

use super::Tensor;

/// Storage format for the decode readout weights (`--decode-dtype`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeDtype {
    /// Default: the `head_dec_B{b}` artifact's bit-exact f32 path.
    F32,
    /// bf16 weights (RNE), f32 accumulation.  2x less readout bandwidth.
    Bf16,
    /// int8 weights with per-row scales, f32 accumulation.  4x less.
    Int8,
}

impl DecodeDtype {
    pub fn parse(s: &str) -> Result<DecodeDtype> {
        match s {
            "f32" => Ok(DecodeDtype::F32),
            "bf16" => Ok(DecodeDtype::Bf16),
            "int8" => Ok(DecodeDtype::Int8),
            _ => bail!("unknown decode dtype {s:?} (expected f32 | bf16 | int8)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DecodeDtype::F32 => "f32",
            DecodeDtype::Bf16 => "bf16",
            DecodeDtype::Int8 => "int8",
        }
    }
}

/// f32 -> bf16 with round-to-nearest-even (the upper 16 bits of the f32,
/// rounded).  NaN payloads are forced non-zero so they stay NaN.
pub fn bf16_encode(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 1;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// bf16 -> f32: exact (bf16 is a prefix of the f32 encoding).
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

enum Repr {
    Bf16(Vec<u16>),
    Int8 { q: Vec<i8>, scale: Vec<f32> },
}

/// A `[rows, d]` weight matrix stored at reduced precision, with an
/// nt-layout (`x · Wᵀ`) matmul that accumulates in f32.
pub struct QuantMat {
    rows: usize,
    d: usize,
    repr: Repr,
}

impl QuantMat {
    /// Quantize a 2-D `[rows, d]` tensor.  `F32` is rejected: callers keep
    /// the original tensor (and the bit-exact artifact path) for that.
    pub fn quantize(w: &Tensor, dtype: DecodeDtype) -> Result<QuantMat> {
        anyhow::ensure!(
            w.shape().len() == 2,
            "QuantMat::quantize expects a [rows, d] matrix, got {:?}",
            w.shape()
        );
        let (rows, d) = (w.shape()[0], w.shape()[1]);
        let wd = w.data();
        let repr = match dtype {
            DecodeDtype::F32 => bail!("f32 readout needs no QuantMat"),
            DecodeDtype::Bf16 => {
                Repr::Bf16(wd.iter().map(|&v| bf16_encode(v)).collect())
            }
            DecodeDtype::Int8 => {
                let mut q = vec![0i8; rows * d];
                let mut scale = vec![0.0f32; rows];
                for j in 0..rows {
                    let row = &wd[j * d..(j + 1) * d];
                    let maxabs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    if maxabs > 0.0 {
                        let s = maxabs / 127.0;
                        let inv = 127.0 / maxabs;
                        scale[j] = s;
                        for (qq, &v) in q[j * d..(j + 1) * d].iter_mut().zip(row) {
                            *qq = (v * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    }
                }
                Repr::Int8 { q, scale }
            }
        };
        Ok(QuantMat { rows, d, repr })
    }

    pub fn dtype(&self) -> DecodeDtype {
        match self.repr {
            Repr::Bf16(_) => DecodeDtype::Bf16,
            Repr::Int8 { .. } => DecodeDtype::Int8,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Weight bytes actually streamed per full readout (for bench reports).
    pub fn bytes(&self) -> usize {
        match &self.repr {
            Repr::Bf16(w) => w.len() * 2,
            Repr::Int8 { q, scale } => q.len() + scale.len() * 4,
        }
    }

    /// `x: [m, d]` -> `[m, rows]`, computing `x · Wᵀ` with dequantized
    /// weights and f32 accumulation.  Serial and chain-fixed: bit-identical
    /// across runs and thread counts for given inputs.
    pub fn matmul_nt(&self, x: &Tensor) -> Tensor {
        let d = self.d;
        assert_eq!(
            *x.shape().last().unwrap(),
            d,
            "inner-dim mismatch in QuantMat::matmul_nt"
        );
        let m = x.len() / d;
        let mut out = vec![0.0f32; m * self.rows];
        for i in 0..m {
            let xr = &x.data()[i * d..(i + 1) * d];
            let or = &mut out[i * self.rows..(i + 1) * self.rows];
            match &self.repr {
                Repr::Bf16(w) => {
                    for (j, o) in or.iter_mut().enumerate() {
                        *o = dot_bf16(xr, &w[j * d..(j + 1) * d]);
                    }
                }
                Repr::Int8 { q, scale } => {
                    for (j, o) in or.iter_mut().enumerate() {
                        *o = scale[j] * dot_int8(xr, &q[j * d..(j + 1) * d]);
                    }
                }
            }
        }
        Tensor::new(vec![m, self.rows], out)
    }
}

/// Fixed reduction tree shared by both dots (mirrors `gemm::lanes8`).
fn lanes8(a: &[f32; 8]) -> f32 {
    ((a[0] + a[4]) + (a[2] + a[6])) + ((a[1] + a[5]) + (a[3] + a[7]))
}

fn dot_bf16(x: &[f32], w: &[u16]) -> f32 {
    let k = x.len();
    let c8 = k / 8;
    let mut acc = [0.0f32; 8];
    for cb in 0..c8 {
        let xo = &x[cb * 8..cb * 8 + 8];
        let wo = &w[cb * 8..cb * 8 + 8];
        for l in 0..8 {
            acc[l] += xo[l] * bf16_decode(wo[l]);
        }
    }
    let mut s = lanes8(&acc);
    for p in c8 * 8..k {
        s += x[p] * bf16_decode(w[p]);
    }
    s
}

fn dot_int8(x: &[f32], q: &[i8]) -> f32 {
    let k = x.len();
    let c8 = k / 8;
    let mut acc = [0.0f32; 8];
    for cb in 0..c8 {
        let xo = &x[cb * 8..cb * 8 + 8];
        let qo = &q[cb * 8..cb * 8 + 8];
        for l in 0..8 {
            acc[l] += xo[l] * qo[l] as f32;
        }
    }
    let mut s = lanes8(&acc);
    for p in c8 * 8..k {
        s += x[p] * q[p] as f32;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(state: &mut u64) -> f32 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        ((*state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn bf16_round_trips_representable_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, -2.5, 1024.0, -0.15625] {
            // values with ≤8 mantissa bits survive exactly
            let enc = bf16_encode(v);
            assert_eq!(bf16_decode(enc), v, "bf16 round trip of {v}");
        }
        assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
        assert_eq!(bf16_decode(bf16_encode(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn bf16_rounds_ties_to_even() {
        // low half exactly 0x8000 = a tie; round to the even 16-bit value
        let even = f32::from_bits(0x3F80_8000); // high = 0x3F80 (even)
        assert_eq!(bf16_encode(even), 0x3F80); // tie -> stays (down)
        let odd = f32::from_bits(0x3F81_8000); // high = 0x3F81 (odd)
        assert_eq!(bf16_encode(odd), 0x3F82); // tie -> rounds up to even
        // just above / below the tie round to nearest
        assert_eq!(bf16_encode(f32::from_bits(0x3F80_8001)), 0x3F81);
        assert_eq!(bf16_encode(f32::from_bits(0x3F80_7FFF)), 0x3F80);
    }

    #[test]
    fn bf16_relative_error_bounded() {
        let mut st = 7u64;
        for _ in 0..1000 {
            let v = xorshift(&mut st) * 100.0;
            let err = (bf16_decode(bf16_encode(v)) - v).abs();
            assert!(err <= v.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn int8_per_row_scales_hit_full_range() {
        let w = Tensor::new(
            vec![3, 4],
            vec![
                1.0, -2.0, 0.5, 4.0, // max 4.0
                0.0, 0.0, 0.0, 0.0, // zero row
                -0.01, 0.005, 0.0025, -0.0075, // tiny magnitudes
            ],
        );
        let q = QuantMat::quantize(&w, DecodeDtype::Int8).unwrap();
        let (qv, sc) = match &q.repr {
            Repr::Int8 { q, scale } => (q.clone(), scale.clone()),
            _ => unreachable!(),
        };
        assert_eq!(qv[3], 127); // row max maps to ±127
        assert_eq!(sc[1], 0.0);
        assert!(qv[4..8].iter().all(|&v| v == 0)); // zero row -> zeros
        assert_eq!(qv[8], -127); // tiny rows still use the full range
        // dequantized max is exact: 127 * (max/127) == max
        assert_eq!(sc[0] * qv[3] as f32, 4.0);
    }

    #[test]
    fn quantized_matmul_tracks_f32_within_tolerance() {
        let (rows, d, m) = (40, 96, 3);
        let mut st = 42u64;
        let w = Tensor::new(
            vec![rows, d],
            (0..rows * d).map(|_| xorshift(&mut st)).collect(),
        );
        let x = Tensor::new(
            vec![m, d],
            (0..m * d).map(|_| xorshift(&mut st)).collect(),
        );
        let exact = x.matmul_nt(&w);
        for dt in [DecodeDtype::Bf16, DecodeDtype::Int8] {
            let qm = QuantMat::quantize(&w, dt).unwrap();
            assert_eq!(qm.rows(), rows);
            assert_eq!(qm.dim(), d);
            let got = qm.matmul_nt(&x);
            assert_eq!(got.shape(), &[m, rows]);
            for (a, b) in got.data().iter().zip(exact.data()) {
                assert!(
                    (a - b).abs() <= 1e-2,
                    "{} logit off by {} ({a} vs {b})",
                    dt.name(),
                    (a - b).abs()
                );
            }
            // determinism: a second run is bit-identical
            let again = qm.matmul_nt(&x);
            assert_eq!(
                got.data()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect::<Vec<_>>(),
                again
                    .data()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn dtype_parsing_round_trips() {
        for dt in [DecodeDtype::F32, DecodeDtype::Bf16, DecodeDtype::Int8] {
            assert_eq!(DecodeDtype::parse(dt.name()).unwrap(), dt);
        }
        assert!(DecodeDtype::parse("fp16").is_err());
        assert!(QuantMat::quantize(&Tensor::zeros(&[2, 2]), DecodeDtype::F32).is_err());
    }

    #[test]
    fn bytes_reflect_storage_format() {
        let w = Tensor::zeros(&[10, 16]);
        let b16 = QuantMat::quantize(&w, DecodeDtype::Bf16).unwrap();
        assert_eq!(b16.bytes(), 10 * 16 * 2);
        assert_eq!(b16.dtype(), DecodeDtype::Bf16);
        let i8m = QuantMat::quantize(&w, DecodeDtype::Int8).unwrap();
        assert_eq!(i8m.bytes(), 10 * 16 + 10 * 4);
        assert_eq!(i8m.dtype(), DecodeDtype::Int8);
    }
}
