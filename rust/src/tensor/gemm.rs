//! Cache-blocked, SIMD-dispatched f32 GEMM kernels on strided row-major
//! buffers — the compute core every hot path routes through.
//!
//! Three layouts, each with an overwriting and an accumulating entry:
//!
//! * `nn` / `nn_acc` — `out[m,n] (+)= A[m,k] · B[k,n]`
//! * `nt` / `nt_acc` — `out[m,n] (+)= A[m,k] · B[n,k]ᵀ` (fused transpose:
//!   callers stop materializing `t()` copies)
//! * `tn` / `tn_acc` — `out[m,n] (+)= A[k,m]ᵀ · B[k,n]`
//!
//! Every operand takes an explicit row stride (`lda`/`ldb`/`ldo`), so a
//! per-head `[C, F]` view of a `[C, H, F]` tensor is addressed in place —
//! no `head_of`/`set_head` copies.
//!
//! # Kernel structure (see DESIGN.md §Compute core)
//!
//! * **k-panel blocking.** The k loop is split into `KC`-deep panels.
//!   Inside a panel, a register-tiled microkernel sweeps 4-row × 8/16-col
//!   output tiles with the partial sums held in registers (lane arrays in
//!   the scalar kernel, vector registers in the SIMD kernels); the panel's
//!   partial is then flushed with one `out += acc` per element.  Big `tn`
//!   backward GEMMs and `nt` panels therefore re-read a KC×n slab of B
//!   from L2 instead of streaming all of B from L3 per row tile.
//! * **ISA dispatch.** With the `simd` feature (on by default) the panel
//!   microkernel is an explicit-width `std::arch` kernel — AVX2 on
//!   x86_64 (runtime-detected), NEON on aarch64 — and the portable scalar
//!   kernel is the fallback everywhere else.  The scalar kernel is the
//!   bit-parity oracle: `nn_scalar`/`nt_scalar`/`tn_scalar` (and `_acc`
//!   forms) force it, and tests assert the SIMD paths match it BIT FOR
//!   BIT on every shape class.  Two rules make that possible:
//!   1. no FMA anywhere — every kernel uses separate multiply and add
//!      (`_mm256_mul_ps`+`_mm256_add_ps`, `vmulq_f32`+`vaddq_f32`), and
//!      Rust never enables floating-point contraction, so the scalar
//!      `a * b + c` stays unfused too;
//!   2. a fixed per-element accumulation chain — products accumulate in
//!      ascending-p order into a fresh accumulator per KC panel, panels
//!      flush in ascending order, and the m=1 `nt` row kernel reduces its
//!      8 lanes with the fixed tree
//!      `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` that AVX2's
//!      `extractf128/movehl/shuffle` reduction and NEON's paired
//!      `vadd_f32` reduction compute identically.
//! * **Strided-B packing.** When B is a strided head view (`ldb != n`)
//!   the dispatch layer packs it contiguous once through the scratch
//!   pool, so the microkernels always stream unit-stride B rows and the
//!   pack is shared by all banding threads.  Packing and blocking never
//!   change values: an f32 store/reload is exact.
//! * **`nt`, m == 1** (decode readout): four B rows per pass with 8-lane
//!   dot accumulators (a transpose would cost more than the whole
//!   product).  **`nt`, m > 1**: B is transposed once into a pooled
//!   scratch panel, then the blocked `nn` path runs.
//!
//! Large products are split into contiguous row bands across threads
//! (`par::for_each_row_band`); banding never changes accumulation order,
//! so outputs are bit-identical at any `LASP2_THREADS` setting.

use super::{par, scratch};

/// k-panel depth: a KC×n f32 slab of B (n ≤ 512 → ≤ 512 KiB) stays
/// L2-resident while the row tiles sweep over it.  Also the boundary of
/// the per-element accumulation chain (fresh accumulator per panel) — a
/// value every kernel, scalar and SIMD, must share for bit parity.
pub const KC: usize = 256;

/// Instruction set the panel microkernels dispatch to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable lane-array kernel — the bit-parity oracle.
    Scalar,
    /// x86_64 AVX2 (256-bit, runtime-detected; no FMA by design).
    Avx2,
    /// aarch64 NEON (128-bit, baseline on aarch64).
    Neon,
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn detect_isa() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
fn detect_isa() -> Isa {
    Isa::Neon
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn detect_isa() -> Isa {
    Isa::Scalar
}

/// The ISA the public entry points dispatch to (detected once).
pub fn active_isa() -> Isa {
    static ISA: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();
    *ISA.get_or_init(detect_isa)
}

/// Human-readable dispatch target, for bench provenance fields.
pub fn isa_name() -> &'static str {
    match active_isa() {
        Isa::Scalar => "scalar",
        Isa::Avx2 => "avx2",
        Isa::Neon => "neon",
    }
}

/// Elements spanned by `rows` rows at stride `ld` whose last row holds
/// `last` elements.
#[inline]
fn span(rows: usize, ld: usize, last: usize) -> usize {
    if rows == 0 {
        0
    } else {
        (rows - 1) * ld + last
    }
}

/// out = A·B.  A: m×k rows at `lda`; B: k×n rows at `ldb`; out: m×n rows
/// at `ldo` (overwritten).
pub fn nn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// out += A·B (same layout as `nn`).
pub fn nn_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// out = A·Bᵀ.  A: m×k rows at `lda`; B: n×k rows at `ldb`; out: m×n
/// rows at `ldo` (overwritten).
pub fn nt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// out += A·Bᵀ (same layout as `nt`).
pub fn nt_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// out = Aᵀ·B.  A: k×m rows at `lda` (the UNtransposed layout); B: k×n
/// rows at `ldb`; out: m×n rows at `ldo` (overwritten).
pub fn tn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// out += Aᵀ·B (same layout as `tn`).
pub fn tn_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, active_isa());
}

/// `nn` forced onto the portable scalar kernel — the bit-parity oracle
/// the SIMD paths are tested against.
pub fn nn_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

/// `nn_acc` forced onto the portable scalar kernel.
pub fn nn_acc_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

/// `nt` forced onto the portable scalar kernel.
pub fn nt_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

/// `nt_acc` forced onto the portable scalar kernel.
pub fn nt_acc_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

/// `tn` forced onto the portable scalar kernel.
pub fn tn_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

/// `tn_acc` forced onto the portable scalar kernel.
pub fn tn_acc_scalar(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo, Isa::Scalar);
}

fn nn_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= k && ldb >= n && ldo >= n, "gemm nn: bad strides");
    assert!(a.len() >= span(m, lda, k), "gemm nn: a too short");
    assert!(b.len() >= span(k, ldb, n), "gemm nn: b too short");
    let out = &mut out[..span(m, ldo, n)];
    if ldb != n && k > 0 {
        // pack strided B (head views) contiguous ONCE, on the caller
        // thread, so every banding worker streams unit-stride rows from
        // the same pack; value-preserving (f32 copy is exact)
        let mut bp = scratch::take(k * n);
        for p in 0..k {
            bp[p * n..p * n + n].copy_from_slice(&b[p * ldb..p * ldb + n]);
        }
        par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
            serial_panels::<ACC, false>(nrows, k, n, &a[row0 * lda..], lda, &bp, n, band, ldo, isa);
        });
        scratch::recycle(bp);
    } else {
        par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
            serial_panels::<ACC, false>(nrows, k, n, &a[row0 * lda..], lda, b, ldb, band, ldo, isa);
        });
    }
}

fn nt_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= k && ldb >= k && ldo >= n, "gemm nt: bad strides");
    assert!(a.len() >= span(m, lda, k), "gemm nt: a too short");
    assert!(b.len() >= span(n, ldb, k), "gemm nt: b too short");
    if m == 1 {
        nt_row_dispatch::<ACC>(k, n, &a[..k], b, ldb, &mut out[..n], isa);
        return;
    }
    // panel-transpose B once into pooled scratch, then run the blocked nn
    // path (amortizes over the m output rows; zero steady-state allocs)
    let mut bt = scratch::take(k * n);
    for j in 0..n {
        let br = &b[j * ldb..j * ldb + k];
        for (p, &v) in br.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
    let out = &mut out[..span(m, ldo, n)];
    par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
        serial_panels::<ACC, false>(nrows, k, n, &a[row0 * lda..], lda, &bt, n, band, ldo, isa);
    });
    scratch::recycle(bt);
}

fn tn_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= m && ldb >= n && ldo >= n, "gemm tn: bad strides");
    assert!(a.len() >= span(k, lda, m), "gemm tn: a too short");
    assert!(b.len() >= span(k, ldb, n), "gemm tn: b too short");
    let out = &mut out[..span(m, ldo, n)];
    if ldb != n && k > 0 {
        let mut bp = scratch::take(k * n);
        for p in 0..k {
            bp[p * n..p * n + n].copy_from_slice(&b[p * ldb..p * ldb + n]);
        }
        par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
            serial_panels::<ACC, true>(nrows, k, n, &a[row0..], lda, &bp, n, band, ldo, isa);
        });
        scratch::recycle(bp);
    } else {
        par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
            serial_panels::<ACC, true>(nrows, k, n, &a[row0..], lda, b, ldb, band, ldo, isa);
        });
    }
}

/// One thread band's worth of output rows: zero (if overwriting), then
/// sweep KC-deep k panels through the ISA-dispatched microkernel.  `TA`
/// selects the A addressing: `false` → `A[i*lda + p]` (nn/nt), `true` →
/// `A[p*lda + i]` (tn).  The per-element value is
/// `out + Σ_panels (fresh-acc ascending-p chain)` for every ISA.
fn serial_panels<const ACC: bool, const TA: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    if !ACC {
        for i in 0..m {
            out[i * ldo..i * ldo + n].fill(0.0);
        }
    }
    let mut pc = 0;
    while pc < k {
        let kl = KC.min(k - pc);
        let ap = if TA { &a[pc * lda..] } else { &a[pc..] };
        panel_dispatch::<TA>(m, kl, n, ap, lda, &b[pc * ldb..], ldb, out, ldo, isa);
        pc += kl;
    }
}

fn panel_dispatch<const TA: bool>(
    m: usize,
    kl: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    match isa {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: dispatch asserted every operand spans its indexed
        // extent, and Avx2 is only ever constructed after runtime
        // detection succeeded.
        Isa::Avx2 => unsafe { avx2::panel::<TA>(m, kl, n, a, lda, b, ldb, out, ldo) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: as above; NEON is baseline on aarch64.
        Isa::Neon => unsafe { neon::panel::<TA>(m, kl, n, a, lda, b, ldb, out, ldo) },
        _ => panel_scalar::<TA>(m, kl, n, a, lda, b, ldb, out, ldo),
    }
}

#[inline(always)]
fn a_at<const TA: bool>(a: &[f32], lda: usize, i: usize, p: usize) -> f32 {
    if TA {
        a[p * lda + i]
    } else {
        a[i * lda + p]
    }
}

/// Fixed 8-lane reduction tree shared by every ISA's m=1 dot kernel.
#[inline(always)]
fn lanes8(a: &[f32; 8]) -> f32 {
    ((a[0] + a[4]) + (a[2] + a[6])) + ((a[1] + a[5]) + (a[3] + a[7]))
}

/// Portable panel microkernel (always accumulates): 4-row × 8-col tiles
/// with the partials in lane arrays — the same per-element chains the
/// SIMD kernels compute, so it doubles as their bit-parity oracle.
fn panel_scalar<const TA: bool>(
    m: usize,
    kl: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    let mut i = 0;
    while i + 4 <= m {
        let (r0, rest) = out[i * ldo..].split_at_mut(ldo);
        let (r1, rest) = rest.split_at_mut(ldo);
        let (r2, rest) = rest.split_at_mut(ldo);
        let (r0, r1, r2, r3) = (&mut r0[..n], &mut r1[..n], &mut r2[..n], &mut rest[..n]);
        let mut j = 0;
        while j + 8 <= n {
            let mut c0 = [0.0f32; 8];
            let mut c1 = [0.0f32; 8];
            let mut c2 = [0.0f32; 8];
            let mut c3 = [0.0f32; 8];
            for p in 0..kl {
                let a0 = a_at::<TA>(a, lda, i, p);
                let a1 = a_at::<TA>(a, lda, i + 1, p);
                let a2 = a_at::<TA>(a, lda, i + 2, p);
                let a3 = a_at::<TA>(a, lda, i + 3, p);
                let br = &b[p * ldb + j..p * ldb + j + 8];
                for l in 0..8 {
                    let bv = br[l];
                    c0[l] += a0 * bv;
                    c1[l] += a1 * bv;
                    c2[l] += a2 * bv;
                    c3[l] += a3 * bv;
                }
            }
            for l in 0..8 {
                r0[j + l] += c0[l];
                r1[j + l] += c1[l];
                r2[j + l] += c2[l];
                r3[j + l] += c3[l];
            }
            j += 8;
        }
        while j < n {
            let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for p in 0..kl {
                let bv = b[p * ldb + j];
                c0 += a_at::<TA>(a, lda, i, p) * bv;
                c1 += a_at::<TA>(a, lda, i + 1, p) * bv;
                c2 += a_at::<TA>(a, lda, i + 2, p) * bv;
                c3 += a_at::<TA>(a, lda, i + 3, p) * bv;
            }
            r0[j] += c0;
            r1[j] += c1;
            r2[j] += c2;
            r3[j] += c3;
            j += 1;
        }
        i += 4;
    }
    while i < m {
        let r = &mut out[i * ldo..i * ldo + n];
        let mut j = 0;
        while j + 8 <= n {
            let mut c = [0.0f32; 8];
            for p in 0..kl {
                let av = a_at::<TA>(a, lda, i, p);
                let br = &b[p * ldb + j..p * ldb + j + 8];
                for l in 0..8 {
                    c[l] += av * br[l];
                }
            }
            for l in 0..8 {
                r[j + l] += c[l];
            }
            j += 8;
        }
        while j < n {
            let mut c = 0.0f32;
            for p in 0..kl {
                c += a_at::<TA>(a, lda, i, p) * b[p * ldb + j];
            }
            r[j] += c;
            j += 1;
        }
        i += 1;
    }
}

fn nt_row_dispatch<const ACC: bool>(
    k: usize,
    n: usize,
    ar: &[f32],
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    isa: Isa,
) {
    match isa {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: operand extents asserted by nt_dispatch; Avx2 implies
        // runtime detection succeeded.
        Isa::Avx2 => unsafe { avx2::nt_row::<ACC>(k, n, ar, b, ldb, out) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: as above; NEON is baseline on aarch64.
        Isa::Neon => unsafe { neon::nt_row::<ACC>(k, n, ar, b, ldb, out) },
        _ => nt_row_scalar::<ACC>(k, n, ar, b, ldb, out),
    }
}

/// Single-row A·Bᵀ (the m=1 decode-readout shape, e.g. logits = x·embᵀ):
/// four B rows per pass with 8-lane dot accumulators, reduced by the
/// fixed [`lanes8`] tree, scalar tail in ascending p.
fn nt_row_scalar<const ACC: bool>(
    k: usize,
    n: usize,
    ar: &[f32],
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
) {
    let c8 = k / 8;
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &b[j * ldb..j * ldb + k];
        let b1 = &b[(j + 1) * ldb..(j + 1) * ldb + k];
        let b2 = &b[(j + 2) * ldb..(j + 2) * ldb + k];
        let b3 = &b[(j + 3) * ldb..(j + 3) * ldb + k];
        let mut c0 = [0.0f32; 8];
        let mut c1 = [0.0f32; 8];
        let mut c2 = [0.0f32; 8];
        let mut c3 = [0.0f32; 8];
        for p in 0..c8 {
            for l in 0..8 {
                let av = ar[p * 8 + l];
                c0[l] += av * b0[p * 8 + l];
                c1[l] += av * b1[p * 8 + l];
                c2[l] += av * b2[p * 8 + l];
                c3[l] += av * b3[p * 8 + l];
            }
        }
        let mut s0 = lanes8(&c0);
        let mut s1 = lanes8(&c1);
        let mut s2 = lanes8(&c2);
        let mut s3 = lanes8(&c3);
        for p in c8 * 8..k {
            let av = ar[p];
            s0 += av * b0[p];
            s1 += av * b1[p];
            s2 += av * b2[p];
            s3 += av * b3[p];
        }
        if ACC {
            out[j] += s0;
            out[j + 1] += s1;
            out[j + 2] += s2;
            out[j + 3] += s3;
        } else {
            out[j] = s0;
            out[j + 1] = s1;
            out[j + 2] = s2;
            out[j + 3] = s3;
        }
        j += 4;
    }
    while j < n {
        let br = &b[j * ldb..j * ldb + k];
        let mut c = [0.0f32; 8];
        for p in 0..c8 {
            for l in 0..8 {
                c[l] += ar[p * 8 + l] * br[p * 8 + l];
            }
        }
        let mut s = lanes8(&c);
        for p in c8 * 8..k {
            s += ar[p] * br[p];
        }
        if ACC {
            out[j] += s;
        } else {
            out[j] = s;
        }
        j += 1;
    }
}

/// AVX2 microkernels.  DELIBERATELY no FMA: `mul`+`add` keeps every
/// per-element rounding identical to the scalar oracle (a fused
/// multiply-add rounds once, not twice, and would change bits).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use core::arch::x86_64::*;

    #[inline(always)]
    unsafe fn a_at<const TA: bool>(a: &[f32], lda: usize, i: usize, p: usize) -> f32 {
        if TA {
            *a.get_unchecked(p * lda + i)
        } else {
            *a.get_unchecked(i * lda + p)
        }
    }

    /// The [`super::lanes8`] reduction tree in vector form:
    /// lo/hi fold → movehl fold → lane-1 shuffle fold computes exactly
    /// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn hsum8(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s1 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s1, _mm_movehl_ps(s1, s1));
        let s3 = _mm_add_ss(s2, _mm_shuffle_ps::<0x55>(s2, s2));
        _mm_cvtss_f32(s3)
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn flush1(p: *mut f32, c: __m256) {
        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), c));
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn flush2(p: *mut f32, c0: __m256, c1: __m256) {
        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), c0));
        _mm256_storeu_ps(p.add(8), _mm256_add_ps(_mm256_loadu_ps(p.add(8)), c1));
    }

    /// Panel accumulate `out += A_panel · B_panel` (kl-deep), register
    /// tiles of 4 rows × 16 cols (8 ymm accumulators live across the
    /// whole k loop — the old kernel's per-p out-row load/store traffic
    /// is gone).  Column tail (< 8) runs the scalar oracle kernel, row
    /// tail runs 1-row vector strips; every per-element chain matches
    /// [`super::panel_scalar`] bit for bit.
    ///
    /// # Safety
    /// Caller guarantees `a`/`b`/`out` span the extents indexed by
    /// (m, kl, n) at the given strides, and that AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn panel<const TA: bool>(
        m: usize,
        kl: usize,
        n: usize,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        out: &mut [f32],
        ldo: usize,
    ) {
        let nv = n & !7;
        if nv < n {
            super::panel_scalar::<TA>(m, kl, n - nv, a, lda, &b[nv..], ldb, &mut out[nv..], ldo);
        }
        if nv == 0 {
            return;
        }
        let bp0 = b.as_ptr();
        let op0 = out.as_mut_ptr();
        let n16 = nv & !15;
        let mut i = 0;
        while i + 4 <= m {
            let mut j = 0;
            while j < n16 {
                let mut c00 = _mm256_setzero_ps();
                let mut c01 = _mm256_setzero_ps();
                let mut c10 = _mm256_setzero_ps();
                let mut c11 = _mm256_setzero_ps();
                let mut c20 = _mm256_setzero_ps();
                let mut c21 = _mm256_setzero_ps();
                let mut c30 = _mm256_setzero_ps();
                let mut c31 = _mm256_setzero_ps();
                for p in 0..kl {
                    let bp = bp0.add(p * ldb + j);
                    let b0 = _mm256_loadu_ps(bp);
                    let b1 = _mm256_loadu_ps(bp.add(8));
                    let a0 = _mm256_set1_ps(a_at::<TA>(a, lda, i, p));
                    let a1 = _mm256_set1_ps(a_at::<TA>(a, lda, i + 1, p));
                    let a2 = _mm256_set1_ps(a_at::<TA>(a, lda, i + 2, p));
                    let a3 = _mm256_set1_ps(a_at::<TA>(a, lda, i + 3, p));
                    c00 = _mm256_add_ps(c00, _mm256_mul_ps(a0, b0));
                    c01 = _mm256_add_ps(c01, _mm256_mul_ps(a0, b1));
                    c10 = _mm256_add_ps(c10, _mm256_mul_ps(a1, b0));
                    c11 = _mm256_add_ps(c11, _mm256_mul_ps(a1, b1));
                    c20 = _mm256_add_ps(c20, _mm256_mul_ps(a2, b0));
                    c21 = _mm256_add_ps(c21, _mm256_mul_ps(a2, b1));
                    c30 = _mm256_add_ps(c30, _mm256_mul_ps(a3, b0));
                    c31 = _mm256_add_ps(c31, _mm256_mul_ps(a3, b1));
                }
                flush2(op0.add(i * ldo + j), c00, c01);
                flush2(op0.add((i + 1) * ldo + j), c10, c11);
                flush2(op0.add((i + 2) * ldo + j), c20, c21);
                flush2(op0.add((i + 3) * ldo + j), c30, c31);
                j += 16;
            }
            if j < nv {
                // one 8-wide strip (nv - n16 is 0 or 8)
                let mut c0 = _mm256_setzero_ps();
                let mut c1 = _mm256_setzero_ps();
                let mut c2 = _mm256_setzero_ps();
                let mut c3 = _mm256_setzero_ps();
                for p in 0..kl {
                    let bv = _mm256_loadu_ps(bp0.add(p * ldb + j));
                    c0 = _mm256_add_ps(c0, _mm256_mul_ps(_mm256_set1_ps(a_at::<TA>(a, lda, i, p)), bv));
                    c1 = _mm256_add_ps(c1, _mm256_mul_ps(_mm256_set1_ps(a_at::<TA>(a, lda, i + 1, p)), bv));
                    c2 = _mm256_add_ps(c2, _mm256_mul_ps(_mm256_set1_ps(a_at::<TA>(a, lda, i + 2, p)), bv));
                    c3 = _mm256_add_ps(c3, _mm256_mul_ps(_mm256_set1_ps(a_at::<TA>(a, lda, i + 3, p)), bv));
                }
                flush1(op0.add(i * ldo + j), c0);
                flush1(op0.add((i + 1) * ldo + j), c1);
                flush1(op0.add((i + 2) * ldo + j), c2);
                flush1(op0.add((i + 3) * ldo + j), c3);
            }
            i += 4;
        }
        while i < m {
            let mut j = 0;
            while j < n16 {
                let mut c0 = _mm256_setzero_ps();
                let mut c1 = _mm256_setzero_ps();
                for p in 0..kl {
                    let bp = bp0.add(p * ldb + j);
                    let av = _mm256_set1_ps(a_at::<TA>(a, lda, i, p));
                    c0 = _mm256_add_ps(c0, _mm256_mul_ps(av, _mm256_loadu_ps(bp)));
                    c1 = _mm256_add_ps(c1, _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(8))));
                }
                flush2(op0.add(i * ldo + j), c0, c1);
                j += 16;
            }
            if j < nv {
                let mut c = _mm256_setzero_ps();
                for p in 0..kl {
                    let av = _mm256_set1_ps(a_at::<TA>(a, lda, i, p));
                    c = _mm256_add_ps(c, _mm256_mul_ps(av, _mm256_loadu_ps(bp0.add(p * ldb + j))));
                }
                flush1(op0.add(i * ldo + j), c);
            }
            i += 1;
        }
    }

    /// m=1 A·Bᵀ: four B rows per pass, one ymm accumulator each, reduced
    /// by [`hsum8`] (bit-identical to the scalar 8-lane tree), ascending
    /// scalar tail.
    ///
    /// # Safety
    /// Caller guarantees `ar` spans k, `b` spans n rows of k at `ldb`,
    /// `out` spans n, and that AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn nt_row<const ACC: bool>(
        k: usize,
        n: usize,
        ar: &[f32],
        b: &[f32],
        ldb: usize,
        out: &mut [f32],
    ) {
        let c8 = k / 8;
        let ap = ar.as_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let b0 = b.as_ptr().add(j * ldb);
            let b1 = b.as_ptr().add((j + 1) * ldb);
            let b2 = b.as_ptr().add((j + 2) * ldb);
            let b3 = b.as_ptr().add((j + 3) * ldb);
            let mut v0 = _mm256_setzero_ps();
            let mut v1 = _mm256_setzero_ps();
            let mut v2 = _mm256_setzero_ps();
            let mut v3 = _mm256_setzero_ps();
            for p in 0..c8 {
                let av = _mm256_loadu_ps(ap.add(p * 8));
                v0 = _mm256_add_ps(v0, _mm256_mul_ps(av, _mm256_loadu_ps(b0.add(p * 8))));
                v1 = _mm256_add_ps(v1, _mm256_mul_ps(av, _mm256_loadu_ps(b1.add(p * 8))));
                v2 = _mm256_add_ps(v2, _mm256_mul_ps(av, _mm256_loadu_ps(b2.add(p * 8))));
                v3 = _mm256_add_ps(v3, _mm256_mul_ps(av, _mm256_loadu_ps(b3.add(p * 8))));
            }
            let mut s0 = hsum8(v0);
            let mut s1 = hsum8(v1);
            let mut s2 = hsum8(v2);
            let mut s3 = hsum8(v3);
            for p in c8 * 8..k {
                let av = *ap.add(p);
                s0 += av * *b0.add(p);
                s1 += av * *b1.add(p);
                s2 += av * *b2.add(p);
                s3 += av * *b3.add(p);
            }
            if ACC {
                out[j] += s0;
                out[j + 1] += s1;
                out[j + 2] += s2;
                out[j + 3] += s3;
            } else {
                out[j] = s0;
                out[j + 1] = s1;
                out[j + 2] = s2;
                out[j + 3] = s3;
            }
            j += 4;
        }
        while j < n {
            let br = b.as_ptr().add(j * ldb);
            let mut v = _mm256_setzero_ps();
            for p in 0..c8 {
                v = _mm256_add_ps(
                    v,
                    _mm256_mul_ps(_mm256_loadu_ps(ap.add(p * 8)), _mm256_loadu_ps(br.add(p * 8))),
                );
            }
            let mut s = hsum8(v);
            for p in c8 * 8..k {
                s += *ap.add(p) * *br.add(p);
            }
            if ACC {
                out[j] += s;
            } else {
                out[j] = s;
            }
            j += 1;
        }
    }
}

/// NEON microkernels (aarch64).  Same two bit-parity rules as AVX2: no
/// fused multiply-add (`vmulq_f32`+`vaddq_f32`, never `vfmaq_f32`), and
/// the same per-element accumulation chains as the scalar oracle.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use core::arch::aarch64::*;

    #[inline(always)]
    unsafe fn a_at<const TA: bool>(a: &[f32], lda: usize, i: usize, p: usize) -> f32 {
        if TA {
            *a.get_unchecked(p * lda + i)
        } else {
            *a.get_unchecked(i * lda + p)
        }
    }

    /// [`super::lanes8`] over a lane-0..3 / lane-4..7 register pair.
    #[inline(always)]
    unsafe fn hsum8(lo: float32x4_t, hi: float32x4_t) -> f32 {
        let s1 = vaddq_f32(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
        let v = vadd_f32(vget_low_f32(s1), vget_high_f32(s1));
        vget_lane_f32::<0>(v) + vget_lane_f32::<1>(v)
    }

    #[inline(always)]
    unsafe fn flush1(p: *mut f32, c: float32x4_t) {
        vst1q_f32(p, vaddq_f32(vld1q_f32(p), c));
    }

    /// Panel accumulate `out += A_panel · B_panel`: 4-row × 8-col
    /// register tiles (8 q-register accumulators), 4-col strip, scalar
    /// oracle for the sub-4 column tail.
    ///
    /// # Safety
    /// Caller guarantees `a`/`b`/`out` span the extents indexed by
    /// (m, kl, n) at the given strides.
    pub unsafe fn panel<const TA: bool>(
        m: usize,
        kl: usize,
        n: usize,
        a: &[f32],
        lda: usize,
        b: &[f32],
        ldb: usize,
        out: &mut [f32],
        ldo: usize,
    ) {
        let nv = n & !3;
        if nv < n {
            super::panel_scalar::<TA>(m, kl, n - nv, a, lda, &b[nv..], ldb, &mut out[nv..], ldo);
        }
        if nv == 0 {
            return;
        }
        let bp0 = b.as_ptr();
        let op0 = out.as_mut_ptr();
        let n8 = nv & !7;
        let mut i = 0;
        while i + 4 <= m {
            let mut j = 0;
            while j < n8 {
                let mut c00 = vdupq_n_f32(0.0);
                let mut c01 = vdupq_n_f32(0.0);
                let mut c10 = vdupq_n_f32(0.0);
                let mut c11 = vdupq_n_f32(0.0);
                let mut c20 = vdupq_n_f32(0.0);
                let mut c21 = vdupq_n_f32(0.0);
                let mut c30 = vdupq_n_f32(0.0);
                let mut c31 = vdupq_n_f32(0.0);
                for p in 0..kl {
                    let bp = bp0.add(p * ldb + j);
                    let b0 = vld1q_f32(bp);
                    let b1 = vld1q_f32(bp.add(4));
                    let a0 = vdupq_n_f32(a_at::<TA>(a, lda, i, p));
                    let a1 = vdupq_n_f32(a_at::<TA>(a, lda, i + 1, p));
                    let a2 = vdupq_n_f32(a_at::<TA>(a, lda, i + 2, p));
                    let a3 = vdupq_n_f32(a_at::<TA>(a, lda, i + 3, p));
                    c00 = vaddq_f32(c00, vmulq_f32(a0, b0));
                    c01 = vaddq_f32(c01, vmulq_f32(a0, b1));
                    c10 = vaddq_f32(c10, vmulq_f32(a1, b0));
                    c11 = vaddq_f32(c11, vmulq_f32(a1, b1));
                    c20 = vaddq_f32(c20, vmulq_f32(a2, b0));
                    c21 = vaddq_f32(c21, vmulq_f32(a2, b1));
                    c30 = vaddq_f32(c30, vmulq_f32(a3, b0));
                    c31 = vaddq_f32(c31, vmulq_f32(a3, b1));
                }
                for (r, (ca, cb)) in [(c00, c01), (c10, c11), (c20, c21), (c30, c31)]
                    .into_iter()
                    .enumerate()
                {
                    let op = op0.add((i + r) * ldo + j);
                    flush1(op, ca);
                    flush1(op.add(4), cb);
                }
                j += 8;
            }
            if j < nv {
                let mut c0 = vdupq_n_f32(0.0);
                let mut c1 = vdupq_n_f32(0.0);
                let mut c2 = vdupq_n_f32(0.0);
                let mut c3 = vdupq_n_f32(0.0);
                for p in 0..kl {
                    let bv = vld1q_f32(bp0.add(p * ldb + j));
                    c0 = vaddq_f32(c0, vmulq_f32(vdupq_n_f32(a_at::<TA>(a, lda, i, p)), bv));
                    c1 = vaddq_f32(c1, vmulq_f32(vdupq_n_f32(a_at::<TA>(a, lda, i + 1, p)), bv));
                    c2 = vaddq_f32(c2, vmulq_f32(vdupq_n_f32(a_at::<TA>(a, lda, i + 2, p)), bv));
                    c3 = vaddq_f32(c3, vmulq_f32(vdupq_n_f32(a_at::<TA>(a, lda, i + 3, p)), bv));
                }
                flush1(op0.add(i * ldo + j), c0);
                flush1(op0.add((i + 1) * ldo + j), c1);
                flush1(op0.add((i + 2) * ldo + j), c2);
                flush1(op0.add((i + 3) * ldo + j), c3);
            }
            i += 4;
        }
        while i < m {
            let mut j = 0;
            while j < n8 {
                let mut c0 = vdupq_n_f32(0.0);
                let mut c1 = vdupq_n_f32(0.0);
                for p in 0..kl {
                    let bp = bp0.add(p * ldb + j);
                    let av = vdupq_n_f32(a_at::<TA>(a, lda, i, p));
                    c0 = vaddq_f32(c0, vmulq_f32(av, vld1q_f32(bp)));
                    c1 = vaddq_f32(c1, vmulq_f32(av, vld1q_f32(bp.add(4))));
                }
                let op = op0.add(i * ldo + j);
                flush1(op, c0);
                flush1(op.add(4), c1);
                j += 8;
            }
            if j < nv {
                let mut c = vdupq_n_f32(0.0);
                for p in 0..kl {
                    let av = vdupq_n_f32(a_at::<TA>(a, lda, i, p));
                    c = vaddq_f32(c, vmulq_f32(av, vld1q_f32(bp0.add(p * ldb + j))));
                }
                flush1(op0.add(i * ldo + j), c);
            }
            i += 1;
        }
    }

    /// m=1 A·Bᵀ with the shared 8-lane scheme: lanes 0..3 / 4..7 live in
    /// a q-register pair, reduced by [`hsum8`].
    ///
    /// # Safety
    /// Caller guarantees `ar` spans k, `b` spans n rows of k at `ldb`,
    /// and `out` spans n.
    pub unsafe fn nt_row<const ACC: bool>(
        k: usize,
        n: usize,
        ar: &[f32],
        b: &[f32],
        ldb: usize,
        out: &mut [f32],
    ) {
        let c8 = k / 8;
        let ap = ar.as_ptr();
        let mut j = 0;
        while j < n {
            let br = b.as_ptr().add(j * ldb);
            let mut lo = vdupq_n_f32(0.0);
            let mut hi = vdupq_n_f32(0.0);
            for p in 0..c8 {
                let a_lo = vld1q_f32(ap.add(p * 8));
                let a_hi = vld1q_f32(ap.add(p * 8 + 4));
                lo = vaddq_f32(lo, vmulq_f32(a_lo, vld1q_f32(br.add(p * 8))));
                hi = vaddq_f32(hi, vmulq_f32(a_hi, vld1q_f32(br.add(p * 8 + 4))));
            }
            let mut s = hsum8(lo, hi);
            for p in c8 * 8..k {
                s += *ap.add(p) * *br.add(p);
            }
            if ACC {
                out[j] += s;
            } else {
                out[j] = s;
            }
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::par;
    use super::*;

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn rng(seed: u64, len: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn nn_matches_naive_over_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 4), (9, 2, 13), (17, 33, 6)] {
            let a = rng(1 + m as u64, m * k);
            let b = rng(2 + n as u64, k * n);
            let mut out = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-5);
        }
    }

    #[test]
    fn nt_matches_naive_including_m1_and_wide_k() {
        for &(m, k, n) in &[(1, 64, 37), (1, 7, 3), (5, 6, 9), (12, 130, 4), (4, 2048, 3)] {
            let a = rng(3, m * k);
            let bt = rng(4, n * k); // B stored [n, k]
            // reference: transpose then naive nn
            let mut b = vec![0.0f32; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut out = vec![0.0f32; m * n];
            nt(m, k, n, &a, k, &bt, k, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-4);
        }
    }

    #[test]
    fn tn_matches_naive_including_deep_k() {
        for &(m, k, n) in &[(1, 3, 2), (6, 11, 5), (8, 400, 3), (5, 2, 31), (16, 700, 9)] {
            let at = rng(5, k * m); // A stored [k, m]
            let mut a = vec![0.0f32; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = at[p * m + i];
                }
            }
            let b = rng(6, k * n);
            let mut out = vec![0.0f32; m * n];
            tn(m, k, n, &at, m, &b, n, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-4);
        }
    }

    #[test]
    fn strided_views_match_packed() {
        // head-view addressing: A/B/out are [C, H, F] slices of head h
        let (c, hh, f) = (6, 3, 4);
        let a = rng(7, c * hh * f);
        let b = rng(8, c * hh * f);
        for h in 0..hh {
            // packed copies of head h
            let mut ah = vec![0.0f32; c * f];
            let mut bh = vec![0.0f32; c * f];
            for i in 0..c {
                for x in 0..f {
                    ah[i * f + x] = a[(i * hh + h) * f + x];
                    bh[i * f + x] = b[(i * hh + h) * f + x];
                }
            }
            // scores = Ah · Bhᵀ via strided nt directly on the [C,H,F] data
            let mut got = vec![0.0f32; c * c];
            nt(c, f, c, &a[h * f..], hh * f, &b[h * f..], hh * f, &mut got, c);
            let mut bt = vec![0.0f32; f * c];
            for j in 0..c {
                for p in 0..f {
                    bt[p * c + j] = bh[j * f + p];
                }
            }
            close(&got, &naive_nn(c, f, c, &ah, &bt), 1e-5);
            // strided OUTPUT: write head h of a [C, H, F] buffer via nn
            let m_h = rng(9 + h as u64, f * f);
            let mut out_full = vec![0.0f32; c * hh * f];
            nn(c, f, f, &a[h * f..], hh * f, &m_h, f, &mut out_full[h * f..], hh * f);
            let want = naive_nn(c, f, f, &ah, &m_h);
            for i in 0..c {
                for x in 0..f {
                    let got = out_full[(i * hh + h) * f + x];
                    let w = want[i * f + x];
                    assert!((got - w).abs() <= 1e-5 * (1.0 + w.abs()));
                }
            }
        }
    }

    #[test]
    fn acc_variants_add_on_top() {
        let (m, k, n) = (5, 6, 7);
        let a = rng(10, m * k);
        let b = rng(11, k * n);
        let base = rng(12, m * n);
        let mut out = base.clone();
        nn_acc(m, k, n, &a, k, &b, n, &mut out, n);
        let prod = naive_nn(m, k, n, &a, &b);
        for i in 0..m * n {
            assert!((out[i] - (base[i] + prod[i])).abs() < 1e-5);
        }
        // nt_acc with B in [n,k]
        let mut bt = vec![0.0f32; n * k];
        for j in 0..n {
            for p in 0..k {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut out2 = base.clone();
        nt_acc(m, k, n, &a, k, &bt, k, &mut out2, n);
        for i in 0..m * n {
            assert!((out2[i] - (base[i] + prod[i])).abs() < 1e-4);
        }
        // tn_acc with A in [k,m]
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut out3 = base.clone();
        tn_acc(m, k, n, &at, m, &b, n, &mut out3, n);
        for i in 0..m * n {
            assert!((out3[i] - (base[i] + prod[i])).abs() < 1e-4);
        }
    }

    #[test]
    fn dense_results_identical_with_and_without_zero_rows() {
        // zero rows/entries must give BIT-identical results to a
        // per-element fresh-accumulator reference that skips them
        // (skipping only ever elides exact ±0.0 contributions, and the
        // panel chain starts from a +0.0 accumulator)
        let (m, k, n) = (8, 16, 12);
        let mut a = rng(20, m * k);
        // zero out two full rows and a scattering of entries
        for p in 0..k {
            a[2 * k + p] = 0.0;
            a[5 * k + p] = 0.0;
        }
        a[0] = 0.0;
        a[7 * k + 3] = 0.0;
        let b = rng(21, k * n);
        let mut skip_ref = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * b[p * n + j];
                }
                skip_ref[i * n + j] += acc;
            }
        }
        let mut out = vec![0.0f32; m * n];
        nn(m, k, n, &a, k, &b, n, &mut out, n);
        assert_eq!(bits(&out), bits(&skip_ref), "zero-skip removal changed results");
    }

    #[test]
    fn large_gemm_bit_identical_across_thread_counts() {
        // big enough that row-banding actually kicks in, with k > KC so
        // the panel loop crosses a flush boundary
        let (m, k, n) = (128, 300, 128);
        let a = rng(30, m * k);
        let b = rng(31, k * n);
        let mut want = vec![0.0f32; m * n];
        par::set_threads(1);
        nn(m, k, n, &a, k, &b, n, &mut want, n);
        for t in [2usize, 8] {
            par::set_threads(t);
            let mut got = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut got, n);
            assert_eq!(bits(&got), bits(&want), "threads={t}");
        }
        par::set_threads(0);
    }

    #[test]
    fn simd_matches_scalar_bit_for_bit() {
        // rectangular, m=1, k >> n (crosses the KC panel boundary), and
        // ragged tails; on scalar-only builds this is trivially green
        for &(m, k, n) in &[
            (5, 7, 9),
            (4, 16, 32),
            (64, 300, 48),
            (1, 512, 33),
            (12, 2048, 4),
            (3, 1, 17),
            (33, 257, 31),
        ] {
            let a = rng(40 + m as u64, m * k);
            let b = rng(41 + n as u64, k * n);
            let base = rng(42, m * n);
            // nn / nn_acc
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut x, n);
            nn_scalar(m, k, n, &a, k, &b, n, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "nn {m}x{k}x{n}");
            let mut xa = base.clone();
            let mut ya = base.clone();
            nn_acc(m, k, n, &a, k, &b, n, &mut xa, n);
            nn_acc_scalar(m, k, n, &a, k, &b, n, &mut ya, n);
            assert_eq!(bits(&xa), bits(&ya), "nn_acc {m}x{k}x{n}");
            // nt / nt_acc (B stored [n, k]) — covers the m=1 row kernel
            let bt = rng(43 + k as u64, n * k);
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            nt(m, k, n, &a, k, &bt, k, &mut x, n);
            nt_scalar(m, k, n, &a, k, &bt, k, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "nt {m}x{k}x{n}");
            let mut xa = base.clone();
            let mut ya = base.clone();
            nt_acc(m, k, n, &a, k, &bt, k, &mut xa, n);
            nt_acc_scalar(m, k, n, &a, k, &bt, k, &mut ya, n);
            assert_eq!(bits(&xa), bits(&ya), "nt_acc {m}x{k}x{n}");
            // tn / tn_acc (A stored [k, m])
            let at = rng(44 + m as u64, k * m);
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            tn(m, k, n, &at, m, &b, n, &mut x, n);
            tn_scalar(m, k, n, &at, m, &b, n, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "tn {m}x{k}x{n}");
            let mut xa = base.clone();
            let mut ya = base.clone();
            tn_acc(m, k, n, &at, m, &b, n, &mut xa, n);
            tn_acc_scalar(m, k, n, &at, m, &b, n, &mut ya, n);
            assert_eq!(bits(&xa), bits(&ya), "tn_acc {m}x{k}x{n}");
        }
    }

    #[test]
    fn simd_matches_scalar_on_strided_head_views() {
        // non-contiguous strides on A, B, AND out (the [C, H, F] head
        // slices the attention kernels address in place)
        let (c, hh, f) = (12, 4, 24);
        let a = rng(50, c * hh * f);
        let b = rng(51, c * hh * f);
        for h in 0..hh {
            let (lda, ldb) = (hh * f, hh * f);
            // nt: scores = Ah · Bhᵀ  [c, c]
            let mut x = vec![0.0f32; c * c];
            let mut y = vec![0.0f32; c * c];
            nt(c, f, c, &a[h * f..], lda, &b[h * f..], ldb, &mut x, c);
            nt_scalar(c, f, c, &a[h * f..], lda, &b[h * f..], ldb, &mut y, c);
            assert_eq!(bits(&x), bits(&y), "nt head {h}");
            // nn with strided B and strided out
            let mut xo = vec![0.0f32; c * hh * f];
            let mut yo = vec![0.0f32; c * hh * f];
            nn(c, c, f, &x, c, &b[h * f..], ldb, &mut xo[h * f..], hh * f);
            nn_scalar(c, c, f, &x, c, &b[h * f..], ldb, &mut yo[h * f..], hh * f);
            assert_eq!(bits(&xo), bits(&yo), "nn head {h}");
            // tn with strided A (A stored [k, m] inside the head view)
            let mut xt = vec![0.0f32; f * f];
            let mut yt = vec![0.0f32; f * f];
            tn(f, c, f, &a[h * f..], lda, &b[h * f..], ldb, &mut xt, f);
            tn_scalar(f, c, f, &a[h * f..], lda, &b[h * f..], ldb, &mut yt, f);
            assert_eq!(bits(&xt), bits(&yt), "tn head {h}");
        }
    }

    #[test]
    fn randomized_shape_sweep_simd_vs_scalar() {
        // proptest-style sweep: deterministic xorshift drives shapes and
        // layouts; every draw must agree with naive within tolerance AND
        // with the scalar oracle bit for bit
        let mut s = 0xC0FFEE_u64;
        let mut draw = |lo: usize, hi: usize| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            lo + (s as usize) % (hi - lo + 1)
        };
        for case in 0..40 {
            let m = draw(1, 24);
            let n = draw(1, 40);
            // every 4th case crosses the KC boundary
            let k = if case % 4 == 0 { draw(KC, KC + 70) } else { draw(1, 80) };
            let a = rng(100 + case, m * k);
            let b = rng(200 + case, k * n);
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut x, n);
            nn_scalar(m, k, n, &a, k, &b, n, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "case {case}: nn {m}x{k}x{n}");
            close(&x, &naive_nn(m, k, n, &a, &b), 1e-4);
            let bt = rng(300 + case, n * k);
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            nt(m, k, n, &a, k, &bt, k, &mut x, n);
            nt_scalar(m, k, n, &a, k, &bt, k, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "case {case}: nt {m}x{k}x{n}");
            let at = rng(400 + case, k * m);
            let mut x = vec![0.0f32; m * n];
            let mut y = vec![0.0f32; m * n];
            tn(m, k, n, &at, m, &b, n, &mut x, n);
            tn_scalar(m, k, n, &at, m, &b, n, &mut y, n);
            assert_eq!(bits(&x), bits(&y), "case {case}: tn {m}x{k}x{n}");
        }
    }
}
