//! Cache-blocked, autovectorization-friendly f32 GEMM kernels on strided
//! row-major buffers — the compute core every hot path routes through.
//!
//! Three layouts, each with an overwriting and an accumulating entry:
//!
//! * `nn` / `nn_acc` — `out[m,n] (+)= A[m,k] · B[k,n]`
//! * `nt` / `nt_acc` — `out[m,n] (+)= A[m,k] · B[n,k]ᵀ` (fused transpose:
//!   callers stop materializing `t()` copies)
//! * `tn` / `tn_acc` — `out[m,n] (+)= A[k,m]ᵀ · B[k,n]`
//!
//! Every operand takes an explicit row stride (`lda`/`ldb`/`ldo`), so a
//! per-head `[C, F]` view of a `[C, H, F]` tensor is addressed in place —
//! no `head_of`/`set_head` copies.
//!
//! Kernel structure (measured on the shapes this repo actually runs —
//! see DESIGN.md §Compute core):
//! * `nn`/`tn`: MR=4 row panels — one pass over each B row updates four
//!   output rows, with a contiguous branch-free inner j-loop that the
//!   compiler vectorizes.  Per-element accumulation stays in ascending-p
//!   order, so results match the naive triple loop bit for bit on dense
//!   data (the old `a == 0.0` skip only ever elided exact `+0.0`
//!   contributions, which is why removing it is also value-preserving).
//! * `nt`, m == 1 (decode readout): four B rows per pass with 4-lane
//!   unrolled dot accumulators (a transpose would cost more than the
//!   whole product).
//! * `nt`, m > 1: B is transposed once into a pooled scratch panel
//!   (`tensor::scratch`, no allocation in steady state), then the tiled
//!   `nn` kernel runs — the transpose amortizes over m rows.
//!
//! Large products are split into contiguous row bands across threads
//! (`par::for_each_row_band`); banding never changes accumulation order,
//! so outputs are bit-identical at any `LASP2_THREADS` setting.

use super::{par, scratch};

/// Elements spanned by `rows` rows at stride `ld` whose last row holds
/// `last` elements.
#[inline]
fn span(rows: usize, ld: usize, last: usize) -> usize {
    if rows == 0 {
        0
    } else {
        (rows - 1) * ld + last
    }
}

/// out = A·B.  A: m×k rows at `lda`; B: k×n rows at `ldb`; out: m×n rows
/// at `ldo` (overwritten).
pub fn nn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo);
}

/// out += A·B (same layout as `nn`).
pub fn nn_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo);
}

/// out = A·Bᵀ.  A: m×k rows at `lda`; B: n×k rows at `ldb`; out: m×n
/// rows at `ldo` (overwritten).
pub fn nt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo);
}

/// out += A·Bᵀ (same layout as `nt`).
pub fn nt_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    nt_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo);
}

/// out = Aᵀ·B.  A: k×m rows at `lda` (the UNtransposed layout); B: k×n
/// rows at `ldb`; out: m×n rows at `ldo` (overwritten).
pub fn tn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<false>(m, k, n, a, lda, b, ldb, out, ldo);
}

/// out += Aᵀ·B (same layout as `tn`).
pub fn tn_acc(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    tn_dispatch::<true>(m, k, n, a, lda, b, ldb, out, ldo);
}

fn nn_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= k && ldb >= n && ldo >= n, "gemm nn: bad strides");
    assert!(a.len() >= span(m, lda, k), "gemm nn: a too short");
    assert!(b.len() >= span(k, ldb, n), "gemm nn: b too short");
    let out = &mut out[..span(m, ldo, n)];
    par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
        nn_serial::<ACC>(nrows, k, n, &a[row0 * lda..], lda, b, ldb, band, ldo);
    });
}

fn nn_serial<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    if !ACC {
        for i in 0..m {
            out[i * ldo..i * ldo + n].fill(0.0);
        }
    }
    let mut i = 0;
    while i + 4 <= m {
        let (r0, rest) = out[i * ldo..].split_at_mut(ldo);
        let (r1, rest) = rest.split_at_mut(ldo);
        let (r2, rest) = rest.split_at_mut(ldo);
        let (r0, r1, r2, r3) = (&mut r0[..n], &mut r1[..n], &mut r2[..n], &mut rest[..n]);
        for p in 0..k {
            let a0 = a[i * lda + p];
            let a1 = a[(i + 1) * lda + p];
            let a2 = a[(i + 2) * lda + p];
            let a3 = a[(i + 3) * lda + p];
            let br = &b[p * ldb..p * ldb + n];
            for j in 0..n {
                let bv = br[j];
                r0[j] += a0 * bv;
                r1[j] += a1 * bv;
                r2[j] += a2 * bv;
                r3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while i < m {
        let r = &mut out[i * ldo..i * ldo + n];
        for p in 0..k {
            let av = a[i * lda + p];
            let br = &b[p * ldb..p * ldb + n];
            for j in 0..n {
                r[j] += av * br[j];
            }
        }
        i += 1;
    }
}

fn nt_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= k && ldb >= k && ldo >= n, "gemm nt: bad strides");
    assert!(a.len() >= span(m, lda, k), "gemm nt: a too short");
    assert!(b.len() >= span(n, ldb, k), "gemm nt: b too short");
    if m == 1 {
        nt_row::<ACC>(k, n, &a[..k], b, ldb, &mut out[..n]);
        return;
    }
    // panel-transpose B once into pooled scratch, then run the tiled nn
    // kernel (amortizes over the m output rows; zero steady-state allocs)
    let mut bt = scratch::take(k * n);
    for j in 0..n {
        let br = &b[j * ldb..j * ldb + k];
        for (p, &v) in br.iter().enumerate() {
            bt[p * n + j] = v;
        }
    }
    let out = &mut out[..span(m, ldo, n)];
    par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
        nn_serial::<ACC>(nrows, k, n, &a[row0 * lda..], lda, &bt, n, band, ldo);
    });
    scratch::recycle(bt);
}

/// Single-row A·Bᵀ: four B rows per pass, 4-lane unrolled dot
/// accumulators (the m=1 decode-readout shape, e.g. logits = x · embᵀ).
fn nt_row<const ACC: bool>(k: usize, n: usize, ar: &[f32], b: &[f32], ldb: usize, out: &mut [f32]) {
    let c4 = k / 4;
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &b[j * ldb..j * ldb + k];
        let b1 = &b[(j + 1) * ldb..(j + 1) * ldb + k];
        let b2 = &b[(j + 2) * ldb..(j + 2) * ldb + k];
        let b3 = &b[(j + 3) * ldb..(j + 3) * ldb + k];
        let mut acc0 = [0.0f32; 4];
        let mut acc1 = [0.0f32; 4];
        let mut acc2 = [0.0f32; 4];
        let mut acc3 = [0.0f32; 4];
        for p in 0..c4 {
            for l in 0..4 {
                let av = ar[p * 4 + l];
                acc0[l] += av * b0[p * 4 + l];
                acc1[l] += av * b1[p * 4 + l];
                acc2[l] += av * b2[p * 4 + l];
                acc3[l] += av * b3[p * 4 + l];
            }
        }
        let mut s0 = (acc0[0] + acc0[2]) + (acc0[1] + acc0[3]);
        let mut s1 = (acc1[0] + acc1[2]) + (acc1[1] + acc1[3]);
        let mut s2 = (acc2[0] + acc2[2]) + (acc2[1] + acc2[3]);
        let mut s3 = (acc3[0] + acc3[2]) + (acc3[1] + acc3[3]);
        for p in c4 * 4..k {
            let av = ar[p];
            s0 += av * b0[p];
            s1 += av * b1[p];
            s2 += av * b2[p];
            s3 += av * b3[p];
        }
        if ACC {
            out[j] += s0;
            out[j + 1] += s1;
            out[j + 2] += s2;
            out[j + 3] += s3;
        } else {
            out[j] = s0;
            out[j + 1] = s1;
            out[j + 2] = s2;
            out[j + 3] = s3;
        }
        j += 4;
    }
    while j < n {
        let br = &b[j * ldb..j * ldb + k];
        let mut s = 0.0f32;
        for (av, bv) in ar.iter().zip(br) {
            s += av * bv;
        }
        if ACC {
            out[j] += s;
        } else {
            out[j] = s;
        }
        j += 1;
    }
}

fn tn_dispatch<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert!(lda >= m && ldb >= n && ldo >= n, "gemm tn: bad strides");
    assert!(a.len() >= span(k, lda, m), "gemm tn: a too short");
    assert!(b.len() >= span(k, ldb, n), "gemm tn: b too short");
    let out = &mut out[..span(m, ldo, n)];
    par::for_each_row_band(out, m, ldo, 2 * m * k * n, |row0, nrows, band| {
        tn_serial::<ACC>(nrows, k, n, &a[row0..], lda, b, ldb, band, ldo);
    });
}

fn tn_serial<const ACC: bool>(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
) {
    if !ACC {
        for i in 0..m {
            out[i * ldo..i * ldo + n].fill(0.0);
        }
    }
    let mut i = 0;
    while i + 4 <= m {
        let (r0, rest) = out[i * ldo..].split_at_mut(ldo);
        let (r1, rest) = rest.split_at_mut(ldo);
        let (r2, rest) = rest.split_at_mut(ldo);
        let (r0, r1, r2, r3) = (&mut r0[..n], &mut r1[..n], &mut r2[..n], &mut rest[..n]);
        for p in 0..k {
            let ap = &a[p * lda + i..p * lda + i + 4];
            let (a0, a1, a2, a3) = (ap[0], ap[1], ap[2], ap[3]);
            let br = &b[p * ldb..p * ldb + n];
            for j in 0..n {
                let bv = br[j];
                r0[j] += a0 * bv;
                r1[j] += a1 * bv;
                r2[j] += a2 * bv;
                r3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while i < m {
        let r = &mut out[i * ldo..i * ldo + n];
        for p in 0..k {
            let av = a[p * lda + i];
            let br = &b[p * ldb..p * ldb + n];
            for j in 0..n {
                r[j] += av * br[j];
            }
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::par;
    use super::*;

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn rng(seed: u64, len: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn nn_matches_naive_over_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 4), (9, 2, 13), (17, 33, 6)] {
            let a = rng(1 + m as u64, m * k);
            let b = rng(2 + n as u64, k * n);
            let mut out = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-5);
        }
    }

    #[test]
    fn nt_matches_naive_including_m1_and_wide_k() {
        for &(m, k, n) in &[(1, 64, 37), (1, 7, 3), (5, 6, 9), (12, 130, 4), (4, 2048, 3)] {
            let a = rng(3, m * k);
            let bt = rng(4, n * k); // B stored [n, k]
            // reference: transpose then naive nn
            let mut b = vec![0.0f32; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut out = vec![0.0f32; m * n];
            nt(m, k, n, &a, k, &bt, k, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-4);
        }
    }

    #[test]
    fn tn_matches_naive() {
        for &(m, k, n) in &[(1, 3, 2), (6, 11, 5), (8, 400, 3), (5, 2, 31)] {
            let at = rng(5, k * m); // A stored [k, m]
            let mut a = vec![0.0f32; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = at[p * m + i];
                }
            }
            let b = rng(6, k * n);
            let mut out = vec![0.0f32; m * n];
            tn(m, k, n, &at, m, &b, n, &mut out, n);
            close(&out, &naive_nn(m, k, n, &a, &b), 1e-4);
        }
    }

    #[test]
    fn strided_views_match_packed() {
        // head-view addressing: A/B/out are [C, H, F] slices of head h
        let (c, hh, f) = (6, 3, 4);
        let a = rng(7, c * hh * f);
        let b = rng(8, c * hh * f);
        for h in 0..hh {
            // packed copies of head h
            let mut ah = vec![0.0f32; c * f];
            let mut bh = vec![0.0f32; c * f];
            for i in 0..c {
                for x in 0..f {
                    ah[i * f + x] = a[(i * hh + h) * f + x];
                    bh[i * f + x] = b[(i * hh + h) * f + x];
                }
            }
            // scores = Ah · Bhᵀ via strided nt directly on the [C,H,F] data
            let mut got = vec![0.0f32; c * c];
            nt(c, f, c, &a[h * f..], hh * f, &b[h * f..], hh * f, &mut got, c);
            let mut bt = vec![0.0f32; f * c];
            for j in 0..c {
                for p in 0..f {
                    bt[p * c + j] = bh[j * f + p];
                }
            }
            close(&got, &naive_nn(c, f, c, &ah, &bt), 1e-5);
            // strided OUTPUT: write head h of a [C, H, F] buffer via nn
            let m_h = rng(9 + h as u64, f * f);
            let mut out_full = vec![0.0f32; c * hh * f];
            nn(c, f, f, &a[h * f..], hh * f, &m_h, f, &mut out_full[h * f..], hh * f);
            let want = naive_nn(c, f, f, &ah, &m_h);
            for i in 0..c {
                for x in 0..f {
                    let got = out_full[(i * hh + h) * f + x];
                    let w = want[i * f + x];
                    assert!((got - w).abs() <= 1e-5 * (1.0 + w.abs()));
                }
            }
        }
    }

    #[test]
    fn acc_variants_add_on_top() {
        let (m, k, n) = (5, 6, 7);
        let a = rng(10, m * k);
        let b = rng(11, k * n);
        let base = rng(12, m * n);
        let mut out = base.clone();
        nn_acc(m, k, n, &a, k, &b, n, &mut out, n);
        let prod = naive_nn(m, k, n, &a, &b);
        for i in 0..m * n {
            assert!((out[i] - (base[i] + prod[i])).abs() < 1e-5);
        }
        // nt_acc with B in [n,k]
        let mut bt = vec![0.0f32; n * k];
        for j in 0..n {
            for p in 0..k {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut out2 = base.clone();
        nt_acc(m, k, n, &a, k, &bt, k, &mut out2, n);
        for i in 0..m * n {
            assert!((out2[i] - (base[i] + prod[i])).abs() < 1e-4);
        }
        // tn_acc with A in [k,m]
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut out3 = base.clone();
        tn_acc(m, k, n, &at, m, &b, n, &mut out3, n);
        for i in 0..m * n {
            assert!((out3[i] - (base[i] + prod[i])).abs() < 1e-4);
        }
    }

    #[test]
    fn dense_results_identical_with_and_without_zero_rows() {
        // the old kernel's `if a == 0.0 { continue }` pessimization is
        // gone; zero rows/entries must still give BIT-identical results
        // to a reference that does skip them (skipping only ever elides
        // exact +0.0 contributions)
        let (m, k, n) = (8, 16, 12);
        let mut a = rng(20, m * k);
        // zero out two full rows and a scattering of entries
        for p in 0..k {
            a[2 * k + p] = 0.0;
            a[5 * k + p] = 0.0;
        }
        a[0] = 0.0;
        a[7 * k + 3] = 0.0;
        let b = rng(21, k * n);
        let mut skip_ref = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    skip_ref[i * n + j] += av * b[p * n + j];
                }
            }
        }
        let mut out = vec![0.0f32; m * n];
        nn(m, k, n, &a, k, &b, n, &mut out, n);
        assert_eq!(
            out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            skip_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "zero-skip removal changed results"
        );
    }

    #[test]
    fn large_gemm_bit_identical_across_thread_counts() {
        // big enough that row-banding actually kicks in
        let (m, k, n) = (128, 96, 128);
        let a = rng(30, m * k);
        let b = rng(31, k * n);
        let mut want = vec![0.0f32; m * n];
        par::set_threads(1);
        nn(m, k, n, &a, k, &b, n, &mut want, n);
        for t in [2usize, 8] {
            par::set_threads(t);
            let mut got = vec![0.0f32; m * n];
            nn(m, k, n, &a, k, &b, n, &mut got, n);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={t}"
            );
        }
        par::set_threads(0);
    }
}
