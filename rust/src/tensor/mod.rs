//! Minimal row-major f32 tensor used on the coordinator side, plus the
//! compute core the native backend runs on.
//!
//! The tensor type itself stays a thin shape + `Vec<f32>` wrapper; the
//! heavy math lives in three submodules (see DESIGN.md §Compute core):
//!
//! * [`gemm`] — k-panel-blocked strided GEMM kernels with explicit-width
//!   SIMD microkernels (AVX2/NEON, runtime-dispatched behind the `simd`
//!   feature; scalar oracle bit-identical on every path) and
//!   fused-transpose (`nt`/`tn`) + accumulate variants; `matmul`,
//!   `matmul_nt`, `matmul_tn` and the `*_into` methods below route
//!   through it.
//! * [`par`] — deterministic thread parallelism (`LASP2_THREADS`):
//!   contiguous index blocks, bit-identical results at any thread count.
//! * [`scratch`] — per-thread buffer pool so steady-state train/decode
//!   iterations stop allocating.
//! * [`quant`] — opt-in bf16 / per-row-scale int8 weight storage for the
//!   bandwidth-bound decode readout (f32 accumulation, tolerance-parity;
//!   see `--decode-dtype`).
//!
//! Kept dependency-free and fully unit-tested.

pub mod gemm;
pub mod par;
pub mod quant;
pub mod scratch;

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, "{:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs {} elems",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar1(v: f32) -> Self {
        Self { shape: vec![1], data: vec![v] }
    }

    /// Deterministic pseudo-random tensor (xorshift), for tests/benches.
    pub fn randn(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        let mut data = Vec::with_capacity(n);
        let mut next = || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 11) as f64 / (1u64 << 53) as f64
        };
        for _ in 0..n {
            // Box-Muller
            let u1 = next().max(1e-12);
            let u2 = next();
            data.push(
                ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
                    as f32,
            );
        }
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D matmul: [m, k] x [k, n] -> [m, n].  Runs on the tiled `gemm`
    /// core (branch-free inner loops — the old per-element zero-skip is
    /// gone; row-band threaded for large shapes, bit-identical at any
    /// `LASP2_THREADS`).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul dims {:?} x {:?}", self.shape, rhs.shape);
        let mut out = vec![0.0f32; m * n];
        gemm::nn(m, k, n, &self.data, k, &rhs.data, n, &mut out, n);
        Tensor::new(vec![m, n], out)
    }

    /// Fused-transpose matmul: self [m, k] x rhs [n, k]ᵀ -> [m, n], i.e.
    /// `self.matmul(&rhs.t())` without materializing the transpose.
    pub fn matmul_nt(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul_nt dims {:?} x {:?}ᵀ", self.shape, rhs.shape);
        let mut out = vec![0.0f32; m * n];
        gemm::nt(m, k, n, &self.data, k, &rhs.data, k, &mut out, n);
        Tensor::new(vec![m, n], out)
    }

    /// Fused-transpose matmul: self [k, m]ᵀ x rhs [k, n] -> [m, n], i.e.
    /// `self.t().matmul(&rhs)` without materializing the transpose.
    pub fn matmul_tn(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        assert_eq!(rhs.shape.len(), 2);
        let (k, m) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul_tn dims {:?}ᵀ x {:?}", self.shape, rhs.shape);
        let mut out = vec![0.0f32; m * n];
        gemm::tn(m, k, n, &self.data, m, &rhs.data, n, &mut out, n);
        Tensor::new(vec![m, n], out)
    }

    /// `matmul` into a caller-owned output tensor (no allocation): the
    /// scratch-buffer entry point for steady-state loops.  `out` must be
    /// preshaped to [m, n]; its prior contents are overwritten.
    pub fn matmul_into(&self, rhs: &Tensor, out: &mut Tensor) {
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = rhs.shape[1];
        assert_eq!(k, rhs.shape[0], "matmul_into dims");
        assert_eq!(out.shape, [m, n], "matmul_into out shape");
        gemm::nn(m, k, n, &self.data, k, &rhs.data, n, &mut out.data, n);
    }

    /// `matmul_nt` into a caller-owned output tensor (no allocation).
    pub fn matmul_nt_into(&self, rhs: &Tensor, out: &mut Tensor) {
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = rhs.shape[0];
        assert_eq!(k, rhs.shape[1], "matmul_nt_into dims");
        assert_eq!(out.shape, [m, n], "matmul_nt_into out shape");
        gemm::nt(m, k, n, &self.data, k, &rhs.data, k, &mut out.data, n);
    }

    /// `matmul_tn` into a caller-owned output tensor (no allocation).
    pub fn matmul_tn_into(&self, rhs: &Tensor, out: &mut Tensor) {
        let (k, m) = (self.shape[0], self.shape[1]);
        let n = rhs.shape[1];
        assert_eq!(k, rhs.shape[0], "matmul_tn_into dims");
        assert_eq!(out.shape, [m, n], "matmul_tn_into out shape");
        gemm::tn(m, k, n, &self.data, m, &rhs.data, n, &mut out.data, n);
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    pub fn add(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        let data = self
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| a + b)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn add_assign(&mut self, rhs: &Tensor) {
        assert_eq!(self.shape, rhs.shape);
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
    }

    pub fn sub(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        let data = self
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| a - b)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn mul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        let data = self
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| a * b)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }

    /// Elementwise division (used by the native backend's decay-prefactor
    /// trick: k~ = k / B).
    pub fn div(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        let data = self
            .data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| a / b)
            .collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::new(self.shape.clone(), self.data.iter().map(|a| a * s).collect())
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Max |a - b| / (1 + |b|) over all elements.
    pub fn max_rel_err(&self, rhs: &Tensor) -> f32 {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f32, f32::max)
    }

    pub fn allclose(&self, rhs: &Tensor, tol: f32) -> bool {
        self.shape == rhs.shape && self.max_rel_err(rhs) <= tol
    }

    /// Split along axis 0 into `parts` equal tensors.
    pub fn chunk0(&self, parts: usize) -> Vec<Tensor> {
        assert!(!self.shape.is_empty() && self.shape[0] % parts == 0);
        let rows = self.shape[0] / parts;
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = rows;
        (0..parts)
            .map(|p| {
                Tensor::new(
                    shape.clone(),
                    self.data[p * rows * stride..(p + 1) * rows * stride].to_vec(),
                )
            })
            .collect()
    }

    /// Concatenate along axis 0.
    pub fn cat0(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut shape = parts[0].shape.clone();
        let tail = &parts[0].shape[1..];
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            assert_eq!(&p.shape[1..], tail);
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        shape[0] = rows;
        Tensor::new(shape, data)
    }
}

/// The LASP-2 memory state of one chunk for one layer:
/// `m`: [H, fk, dh] state contribution P_t, `a`: [H, fk] total decay carry.
/// For non-decay variants `a` is all-ones and the combine degenerates to the
/// paper's plain Sum / PrefixSum (Alg. 1 line 7 / Alg. 2 line 9).
#[derive(Clone, Debug)]
pub struct ChunkState {
    pub m: Tensor,
    pub a: Tensor,
}

impl ChunkState {
    pub fn zero_like(other: &ChunkState) -> ChunkState {
        ChunkState {
            m: Tensor::zeros(other.m.shape()),
            a: Tensor::ones(other.a.shape()),
        }
    }

    pub fn byte_size(&self) -> usize {
        self.m.byte_size() + self.a.byte_size()
    }
}

/// The gated prefix-combine monoid:
///   (a1, m1) . (a2, m2) = (a1*a2, a2 (x) m1 + m2)
/// `m`: [H, fk, dh], `a`: [H, fk] broadcast over the trailing dh axis.
/// This is what each device evaluates after the AllGather; associativity is
/// proptest-checked (it underpins both the recursion in Eq. 9 and the split
/// -gather ablation of Table 5).
pub fn state_combine(left: &ChunkState, right: &ChunkState) -> ChunkState {
    let (ms, as_) = (left.m.shape(), left.a.shape());
    assert_eq!(ms, right.m.shape());
    assert_eq!(as_, right.a.shape());
    let dh = ms[ms.len() - 1];
    let mut m = right.m.clone();
    let a2 = right.a.data();
    let m1 = left.m.data();
    for (i, mv) in m.data_mut().iter_mut().enumerate() {
        *mv += a2[i / dh] * m1[i];
    }
    ChunkState { m, a: left.a.mul(&right.a) }
}

/// Exclusive gated prefix states M_{1:t-1} for every chunk t, plus total.
/// (What LASP-2 computes on every device after its single AllGather.)
pub fn prefix_states(states: &[ChunkState]) -> (Vec<ChunkState>, ChunkState) {
    let mut acc = ChunkState::zero_like(&states[0]);
    let mut out = Vec::with_capacity(states.len());
    for s in states {
        out.push(acc.clone());
        acc = state_combine(&acc, s);
    }
    (out, acc)
}

/// Suffix sums of gradient states dM_{t+1:T} (Alg. 4 line 9; basic variant,
/// plain sums).
pub fn suffix_dstates(dstates: &[Tensor]) -> Vec<Tensor> {
    let t = dstates.len();
    let mut out = vec![Tensor::zeros(dstates[0].shape()); t];
    let mut acc = Tensor::zeros(dstates[0].shape());
    for i in (0..t.saturating_sub(1)).rev() {
        acc.add_assign(&dstates[i + 1]);
        out[i] = acc.clone();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_vs_naive_random() {
        let a = Tensor::randn(&[7, 5], 1);
        let b = Tensor::randn(&[5, 9], 2);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..9 {
                let mut s = 0.0;
                for p in 0..5 {
                    s += a.data()[i * 5 + p] * b.data()[p * 9 + j];
                }
                assert!((c.data()[i * 9 + j] - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_nt_tn_match_explicit_transpose() {
        let a = Tensor::randn(&[7, 5], 11);
        let b = Tensor::randn(&[9, 5], 12);
        assert!(a.matmul_nt(&b).allclose(&a.matmul(&b.t()), 1e-5));
        let c = Tensor::randn(&[5, 7], 13);
        let d = Tensor::randn(&[5, 9], 14);
        assert!(c.matmul_tn(&d).allclose(&c.t().matmul(&d), 1e-5));
        // decode-shaped m=1 (nt takes the dot-microkernel path)
        let q = Tensor::randn(&[1, 8], 15);
        let e = Tensor::randn(&[13, 8], 16);
        assert!(q.matmul_nt(&e).allclose(&q.matmul(&e.t()), 1e-5));
    }

    #[test]
    fn matmul_into_variants_match_allocating_forms() {
        let a = Tensor::randn(&[4, 6], 17);
        let b = Tensor::randn(&[6, 3], 18);
        let mut out = Tensor::full(&[4, 3], 9.0); // stale contents overwritten
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
        let bt = Tensor::randn(&[3, 6], 19);
        let mut out2 = Tensor::full(&[4, 3], 9.0);
        a.matmul_nt_into(&bt, &mut out2);
        assert_eq!(out2, a.matmul_nt(&bt));
        let at = Tensor::randn(&[6, 4], 20);
        let mut out3 = Tensor::full(&[4, 3], 9.0);
        at.matmul_tn_into(&b, &mut out3);
        assert_eq!(out3, at.matmul_tn(&b));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::randn(&[4, 6], 3);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn chunk_cat_roundtrip() {
        let a = Tensor::randn(&[8, 3], 4);
        let parts = a.chunk0(4);
        assert_eq!(parts.len(), 4);
        assert_eq!(Tensor::cat0(&parts), a);
    }

    #[test]
    fn combine_identity() {
        let s = ChunkState { m: Tensor::randn(&[2, 4, 4], 5), a: Tensor::ones(&[2, 4]) };
        let id = ChunkState::zero_like(&s);
        let r = state_combine(&id, &s);
        assert!(r.m.allclose(&s.m, 1e-6));
        let r2 = state_combine(&s, &id);
        assert!(r2.m.allclose(&s.m, 1e-6));
    }

    #[test]
    fn combine_matches_sum_when_no_decay() {
        // a = 1 everywhere -> prefix states are plain prefix sums (Alg. 2).
        let states: Vec<ChunkState> = (0..4)
            .map(|i| ChunkState {
                m: Tensor::randn(&[2, 3, 3], i as u64 + 10),
                a: Tensor::ones(&[2, 3]),
            })
            .collect();
        let (prefixes, total) = prefix_states(&states);
        let mut acc = Tensor::zeros(&[2, 3, 3]);
        for (i, s) in states.iter().enumerate() {
            assert!(prefixes[i].m.allclose(&acc, 1e-5), "chunk {i}");
            acc.add_assign(&s.m);
        }
        assert!(total.m.allclose(&acc, 1e-5));
    }

    #[test]
    fn combine_associative_with_decay() {
        let mk = |seed: u64| ChunkState {
            m: Tensor::randn(&[2, 3, 4], seed),
            a: Tensor::new(
                vec![2, 3],
                Tensor::randn(&[2, 3], seed + 100)
                    .data()
                    .iter()
                    .map(|v| 0.9 + 0.1 * (v.tanh() * 0.5 + 0.5))
                    .collect(),
            ),
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        let l = state_combine(&state_combine(&a, &b), &c);
        let r = state_combine(&a, &state_combine(&b, &c));
        assert!(l.m.allclose(&r.m, 1e-5));
        assert!(l.a.allclose(&r.a, 1e-5));
    }

    #[test]
    fn suffix_sums() {
        let ds: Vec<Tensor> = (0..4).map(|i| Tensor::full(&[2, 2], i as f32)).collect();
        let suf = suffix_dstates(&ds);
        // dM_{t+1:T}: t=0 -> 1+2+3=6, t=1 -> 5, t=2 -> 3, t=3 -> 0
        assert_eq!(suf[0].data()[0], 6.0);
        assert_eq!(suf[1].data()[0], 5.0);
        assert_eq!(suf[2].data()[0], 3.0);
        assert_eq!(suf[3].data()[0], 0.0);
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Tensor::randn(&[10], 7), Tensor::randn(&[10], 7));
        assert_ne!(Tensor::randn(&[10], 7), Tensor::randn(&[10], 8));
    }
}
