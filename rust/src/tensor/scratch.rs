//! Thread-local scratch-buffer pool: allocation reuse for the hot-path
//! temporaries of the compute core (attention score matrices, GEMM
//! transpose panels, decode staging buffers).
//!
//! `take(len)` hands out a zero-filled `Vec<f32>` of exactly `len`
//! elements, reusing a pooled allocation when one with enough capacity
//! exists; `recycle(buf)` returns it.  Steady-state loops (train steps,
//! autoregressive decode) that bracket their temporaries with
//! `take`/`recycle` stop hitting the allocator after the first iteration.
//!
//! The pool is per-thread (no locks, no cross-thread traffic) and fully
//! deterministic: a pooled buffer is indistinguishable from a fresh
//! `vec![0.0; len]`.  Unreturned buffers are simply freed by `Vec`'s own
//! drop, so forgetting to `recycle` is a performance leak, never a bug.

use std::cell::RefCell;

/// Buffers kept per thread; beyond this, `recycle` just drops.
const MAX_POOLED: usize = 24;

thread_local! {
    static POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// A zero-filled buffer of exactly `len` elements (pooled when possible).
pub fn take(len: usize) -> Vec<f32> {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if let Some(pos) = pool.iter().position(|b| b.capacity() >= len) {
            let mut b = pool.swap_remove(pos);
            b.clear();
            b.resize(len, 0.0);
            return b;
        }
        drop(pool);
        vec![0.0; len]
    })
}

/// Return a buffer to the current thread's pool.
pub fn recycle(buf: Vec<f32>) {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.len() < MAX_POOLED {
            pool.push(buf);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_even_after_recycle() {
        let mut b = take(16);
        b.iter_mut().for_each(|v| *v = 7.0);
        recycle(b);
        let b2 = take(8);
        assert_eq!(b2.len(), 8);
        assert!(b2.iter().all(|&v| v == 0.0));
        recycle(b2);
    }

    #[test]
    fn reuses_capacity() {
        let b = take(1024);
        let ptr = b.as_ptr();
        recycle(b);
        let b2 = take(512);
        // same thread, enough capacity -> same allocation comes back
        assert_eq!(b2.as_ptr(), ptr);
        recycle(b2);
    }
}
