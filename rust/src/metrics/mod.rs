//! Reporting helpers: throughput meters and markdown/CSV table writers
//! shared by the benchmark harness binaries.

use std::fmt::Write as _;
use std::time::Instant;

/// Simple throughput meter.
pub struct Throughput {
    t0: Instant,
    units: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput { t0: Instant::now(), units: 0 }
    }

    pub fn add(&mut self, units: u64) {
        self.units += units;
    }

    pub fn per_sec(&self) -> f64 {
        self.units as f64 / self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Markdown table builder (the bench harness prints paper-shaped tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            out.push('|');
            for (c, width) in cells.iter().zip(w) {
                let _ = write!(out, " {c:width$} |");
            }
            out.push('\n');
        };
        line(&self.header, &w, &mut out);
        out.push('|');
        for width in &w {
            let _ = write!(out, "{}|", "-".repeat(width + 2));
        }
        out.push('\n');
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",") + "\n";
        for r in &self.rows {
            out += &(r.join(",") + "\n");
        }
        out
    }
}

/// Human formatting helpers.
pub fn fmt_si(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

pub fn fmt_seq(tokens: usize) -> String {
    if tokens % 1024 == 0 {
        format!("{}K", tokens / 1024)
    } else {
        tokens.to_string()
    }
}

/// Nearest-rank percentile over an ASCENDING-sorted slice (`q` in 0..=1).
/// Returns 0.0 for an empty slice — callers report "no samples" as zero.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
        assert_eq!(t.to_csv(), "a,bb\n1,2\n");
    }

    #[test]
    fn si_format() {
        assert_eq!(fmt_si(1500.0), "1.5K");
        assert_eq!(fmt_si(2_000_000.0), "2.00M");
        assert_eq!(fmt_seq(2048 * 1024), "2048K");
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
