//! Continuous-batching serve loop (module `step_loop`; the file keeps the
//! scheduler's colloquial name, `loop.rs`).
//!
//! One [`ServeLoop::step`] is one scheduler tick:
//!
//! 1. **resume** — parked (evicted) sessions re-enter, oldest id first,
//!    while the active-state memory budget allows;
//! 2. **admit** — queued requests whose arrival tick has passed enter,
//!    restoring a [`PrefixCache`] snapshot when their system prefix was
//!    already prefilled by an earlier request;
//! 3. **prefill** — up to `prefill_chunks_per_tick` chunk-sized units of
//!    prompt are fed, round-robin across admitted requests, so a long
//!    prompt never monopolizes a tick;
//! 4. **decode** — every request whose prompt is complete advances ONE
//!    token through [`decode_step`](super::decode_step), the same batched
//!    entry point `Session::decode`/`Batch::decode` use;
//! 5. **evict** — while active state exceeds the budget, the request with
//!    the latest deadline is snapshotted and parked (its state moves off
//!    the active pool, e.g. to host memory), to be resumed in phase 1.
//!
//! **Determinism.** Every scheduling decision is a pure function of the
//! logical tick counter and request ids — never wall-clock time, which is
//! only sampled for REPORTED metrics.  Since the kernels are bit-identical
//! at any `LASP2_THREADS` and batched decode is bit-identical to B=1
//! decode, each request's token stream equals a sequential
//! `Session::generate` bit-for-bit, through prefix-cache hits and
//! evict/resume cycles (pinned by `tests/serve_loop.rs`).
//!
//! **Graceful degradation.** One unserviceable request must never abort
//! the in-flight sessions.  A request whose prompt cannot fit the model's
//! context window is rejected at [`ServeLoop::enqueue`] and recorded; a
//! session that fails at runtime (prefill error, or a decode that would
//! overrun `max_seq`) is culled from the active pool alone and recorded
//! as a [`FailedRequest`].  Survivors keep their id-ordered schedule, so
//! their token streams — and hence the output digest — are bit-identical
//! to a run without the poison request (pinned by the tests below).

use std::time::Instant;

use anyhow::{bail, Result};

use super::admission::{AdmissionQueue, Request};
use super::prefix_cache::{token_hash, PrefixCache};
use super::{argmax, decode_step, Model, Session, Snapshot};

/// Serve-loop knobs.  `mem_budget` bounds the summed `state_bytes` of
/// ACTIVE sessions (0 = unbounded); parked snapshots and the prefix cache
/// model host-side storage and are not counted against it.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max concurrently active (admitted, unparked) sessions.
    pub max_active: usize,
    /// Active-state byte budget; exceeding it triggers eviction.  The
    /// loop never parks its last active session, so one request always
    /// makes progress even when a single state outgrows the budget.
    pub mem_budget: usize,
    /// Prefill units (one chunk, or one ragged tail) fed per tick.
    pub prefill_chunks_per_tick: usize,
    /// Prefix-cache capacity in entries (0 disables caching).
    pub prefix_cache_entries: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_active: 8,
            mem_budget: 0,
            prefill_chunks_per_tick: 2,
            prefix_cache_entries: 8,
        }
    }
}

/// An admitted request and its live session.
struct InFlight<'m> {
    req: Request,
    session: Session<'m>,
    /// Prompt tokens consumed so far.
    fed: usize,
    /// Next token to feed (last generated), once prefill is complete.
    last: i32,
    out: Vec<i32>,
    /// Prefix is chunk-aligned, shorter than the prompt, and nonzero —
    /// i.e. eligible for cache lookup/insert.
    cacheable_prefix: bool,
    /// Restored from the prefix cache (skip the cold-path insert).
    from_cache: bool,
    t_admit: Instant,
    ttft_tick: Option<u64>,
    ttft_wall_ms: Option<f64>,
}

/// An evicted request: state snapshotted off the active pool.
struct Parked {
    req: Request,
    snap: Snapshot,
    fed: usize,
    last: i32,
    out: Vec<i32>,
    cacheable_prefix: bool,
    from_cache: bool,
    t_admit: Instant,
    ttft_tick: Option<u64>,
    ttft_wall_ms: Option<f64>,
}

/// A request the loop could not serve: either rejected at enqueue time
/// (infeasible against the model's context window) or failed at runtime,
/// in which case only its own session was evicted.
#[derive(Clone, Debug)]
pub struct FailedRequest {
    pub id: u64,
    /// Human-readable cause (context exhaustion, decode error, ...).
    pub reason: String,
    /// Tick at which the request was rejected or culled.
    pub tick: u64,
}

/// A completed request, as the summary reports it.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Ticks from arrival to the first generated token.
    pub ttft_ticks: u64,
    /// Wall ms from admission to the first generated token.
    pub ttft_wall_ms: f64,
    pub finished_tick: u64,
    /// Final resident state bytes of the session.
    pub state_bytes: usize,
}

/// Aggregate metrics over one trace replay.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub sessions: usize,
    pub total_ticks: u64,
    pub generated_tokens: usize,
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    /// Tokens/s over time spent INSIDE batched decode calls.
    pub decode_tps: f64,
    /// Generated tokens/s over the whole replay wall time.
    pub sustained_tps: f64,
    pub mean_state_bytes: f64,
    /// 1e9 / mean_state_bytes — the headline serving-density number.
    pub sessions_per_gb: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_insertions: u64,
    pub evictions: u64,
    pub resumes: u64,
    /// Requests rejected at enqueue (prompt cannot fit the window).
    pub rejected_requests: usize,
    /// Requests whose session failed at runtime and was culled alone.
    pub failed_requests: usize,
    /// FNV-1a over `(id, tokens)` in id order — equal across thread
    /// counts and scheduling knobs iff the token streams are bit-equal.
    pub output_digest: u64,
    pub elapsed_s: f64,
}

/// FNV-1a digest of the finished token streams, in id order.
pub fn output_digest(finished: &[FinishedRequest]) -> u64 {
    let mut sorted: Vec<&FinishedRequest> = finished.iter().collect();
    sorted.sort_by_key(|f| f.id);
    let mut h: u64 = 0xcbf29ce484222325;
    let mut fold = |h: &mut u64, bytes: &[u8]| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100000001b3);
        }
    };
    for f in sorted {
        fold(&mut h, &f.id.to_le_bytes());
        h = h.wrapping_add(token_hash(&f.tokens));
    }
    h
}

/// The continuous-batching scheduler over one [`Model`].
pub struct ServeLoop<'m> {
    model: &'m Model,
    cfg: ServeConfig,
    queue: AdmissionQueue,
    cache: PrefixCache,
    active: Vec<InFlight<'m>>,
    parked: Vec<Parked>,
    finished: Vec<FinishedRequest>,
    rejected: Vec<FailedRequest>,
    failed: Vec<FailedRequest>,
    tick: u64,
    evictions: u64,
    resumes: u64,
    decode_nanos: u64,
    decoded_tokens: usize,
    /// Livelock bound bookkeeping for [`run`](Self::run).
    work_units: u64,
    max_arrival: u64,
    t0: Instant,
}

impl<'m> ServeLoop<'m> {
    pub fn new(model: &'m Model, cfg: ServeConfig) -> ServeLoop<'m> {
        let cache = PrefixCache::new(cfg.prefix_cache_entries);
        ServeLoop {
            model,
            cfg,
            queue: AdmissionQueue::new(),
            cache,
            active: Vec::new(),
            parked: Vec::new(),
            finished: Vec::new(),
            rejected: Vec::new(),
            failed: Vec::new(),
            tick: 0,
            evictions: 0,
            resumes: 0,
            decode_nanos: 0,
            decoded_tokens: 0,
            work_units: 0,
            max_arrival: 0,
            t0: Instant::now(),
        }
    }

    /// Queue a request for admission at its arrival tick.  A request
    /// whose PROMPT cannot fit the model's context window is rejected
    /// here (it could never finish prefill); a generation budget that
    /// overruns the window is admitted and degrades at runtime instead —
    /// the session is culled alone once `max_seq` is reached.
    pub fn enqueue(&mut self, req: Request) {
        let cfg = self.model.config();
        if req.prompt.len() > cfg.max_seq {
            self.rejected.push(FailedRequest {
                id: req.id,
                reason: format!(
                    "prompt ({} tokens) exceeds model max_seq ({})",
                    req.prompt.len(),
                    cfg.max_seq
                ),
                tick: self.tick,
            });
            return;
        }
        self.work_units +=
            (req.prompt.len() / cfg.chunk_len + 2 + req.max_new) as u64;
        self.max_arrival = self.max_arrival.max(req.arrival_tick);
        self.queue.push(req);
    }

    pub fn tick(&self) -> u64 {
        self.tick
    }

    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty() && self.parked.is_empty()
    }

    pub fn finished(&self) -> &[FinishedRequest] {
        &self.finished
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Requests rejected at enqueue time (never admitted).
    pub fn rejected(&self) -> &[FailedRequest] {
        &self.rejected
    }

    /// Requests whose session failed at runtime and was culled alone.
    pub fn failures(&self) -> &[FailedRequest] {
        &self.failed
    }

    /// Remove failed sessions from the active pool and record them.  The
    /// survivors' schedule (id order, tick counter) is untouched, so
    /// their token streams stay bit-identical to a failure-free run.
    fn cull_failed(&mut self, failed: &mut Vec<(u64, String)>, tick: u64) {
        for (id, reason) in failed.drain(..) {
            self.active.retain(|f| f.req.id != id);
            eprintln!("[serve] request {id} failed at tick {tick}: {reason}");
            self.failed.push(FailedRequest { id, reason, tick });
        }
    }

    pub fn cache(&self) -> &PrefixCache {
        &self.cache
    }

    fn active_bytes(&self) -> usize {
        self.active.iter().map(|f| f.session.state_bytes()).sum()
    }

    fn over_budget(&self) -> bool {
        self.cfg.mem_budget > 0 && self.active_bytes() > self.cfg.mem_budget
    }

    /// One scheduler tick: resume -> admit -> prefill -> decode -> evict.
    pub fn step(&mut self) -> Result<()> {
        // idle fast-forward: with nothing in flight, jump straight to the
        // next arrival (keeps tick-based TTFT meaningful for sparse traces)
        if self.active.is_empty() && self.parked.is_empty() {
            if let Some(a) = self.queue.next_arrival() {
                if a > self.tick {
                    self.tick = a;
                }
            }
        }
        let tick = self.tick;

        // 1. resume parked sessions, oldest id first, while budget allows
        // (always resume into an empty pool, so parking can't deadlock)
        while !self.parked.is_empty() && self.active.len() < self.cfg.max_active {
            let pi = self
                .parked
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| p.req.id)
                .map(|(i, _)| i)
                .unwrap();
            let fits = self.cfg.mem_budget == 0
                || self.active_bytes() + self.parked[pi].snap.state_bytes()
                    <= self.cfg.mem_budget;
            if !fits && !self.active.is_empty() {
                break;
            }
            let p = self.parked.remove(pi);
            let mut session = self.model.session();
            session.restore(&p.snap);
            self.active.push(InFlight {
                req: p.req,
                session,
                fed: p.fed,
                last: p.last,
                out: p.out,
                cacheable_prefix: p.cacheable_prefix,
                from_cache: p.from_cache,
                t_admit: p.t_admit,
                ttft_tick: p.ttft_tick,
                ttft_wall_ms: p.ttft_wall_ms,
            });
            self.resumes += 1;
        }

        // 2. admit arrived requests while the pool and the budget allow
        while self.active.len() < self.cfg.max_active {
            if self.over_budget() && !self.active.is_empty() {
                break;
            }
            let Some(req) = self.queue.pop_ready(tick) else { break };
            let c = self.model.config().chunk_len;
            let cacheable = req.prefix_len > 0
                && req.prefix_len % c == 0
                && req.prefix_len < req.prompt.len();
            let mut session = self.model.session();
            let mut fed = 0;
            let mut from_cache = false;
            if cacheable {
                if let Some(snap) = self.cache.lookup(&req.prompt[..req.prefix_len], tick) {
                    session.restore(snap);
                    fed = req.prefix_len;
                    from_cache = true;
                }
            }
            self.active.push(InFlight {
                req,
                session,
                fed,
                last: 0,
                out: Vec::new(),
                cacheable_prefix: cacheable,
                from_cache,
                t_admit: Instant::now(),
                ttft_tick: None,
                ttft_wall_ms: None,
            });
        }
        // decode/prefill order is id order, independent of admission path
        self.active.sort_by_key(|f| f.req.id);

        // 3. chunked prefill, round-robin in id order; a prefill failure
        // culls THAT session only (recorded below), never the tick
        let mut failed: Vec<(u64, String)> = Vec::new();
        let mut units = self.cfg.prefill_chunks_per_tick;
        let c = self.model.config().chunk_len;
        let vb = self.model.config().vocab;
        while units > 0 {
            let mut fed_any = false;
            for f in self.active.iter_mut() {
                if units == 0 {
                    break;
                }
                let plen = f.req.prompt.len();
                if f.fed >= plen || failed.iter().any(|(id, _)| *id == f.req.id) {
                    continue;
                }
                let take = if f.session.pos() % c == 0 && plen - f.fed >= c {
                    c
                } else {
                    plen - f.fed
                };
                let logits = match f.session.prefill(&f.req.prompt[f.fed..f.fed + take]) {
                    Ok(l) => l,
                    Err(e) => {
                        failed.push((f.req.id, format!("prefill: {e}")));
                        continue;
                    }
                };
                f.fed += take;
                units -= 1;
                fed_any = true;
                if f.cacheable_prefix && !f.from_cache && f.fed == f.req.prefix_len {
                    // cold path: snapshot right after the shared prefix so
                    // later requests with the same system prompt skip it
                    self.cache
                        .insert(&f.req.prompt[..f.fed], f.session.snapshot(), tick);
                }
                if f.fed == plen {
                    f.ttft_tick = Some(tick);
                    f.ttft_wall_ms = Some(f.t_admit.elapsed().as_secs_f64() * 1e3);
                    if f.req.max_new > 0 {
                        let rows = logits.shape()[0];
                        let first = argmax(&logits.data()[(rows - 1) * vb..]);
                        f.last = first;
                        f.out.push(first);
                    }
                }
            }
            if !fed_any {
                break;
            }
        }
        self.cull_failed(&mut failed, tick);

        // 4. batched decode: one token for every prompt-complete request.
        // Pre-check each candidate's position so a session that would
        // overrun the context window fails ALONE instead of poisoning the
        // whole batched decode_step call.
        let ms = self.model.config().max_seq;
        for f in self.active.iter() {
            if f.fed == f.req.prompt.len()
                && f.out.len() < f.req.max_new
                && f.session.pos() >= ms
            {
                failed.push((
                    f.req.id,
                    format!(
                        "decode: context window exhausted (pos {} >= max_seq {ms})",
                        f.session.pos()
                    ),
                ));
            }
        }
        self.cull_failed(&mut failed, tick);
        let mut sess: Vec<&mut Session<'m>> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        let mut sinks: Vec<(&mut i32, &mut Vec<i32>)> = Vec::new();
        for f in self.active.iter_mut() {
            if f.fed == f.req.prompt.len() && f.out.len() < f.req.max_new {
                toks.push(f.last);
                sess.push(&mut f.session);
                sinks.push((&mut f.last, &mut f.out));
            }
        }
        if !sess.is_empty() {
            let td = Instant::now();
            let rows = decode_step(&mut sess, &toks)?;
            self.decode_nanos += td.elapsed().as_nanos() as u64;
            self.decoded_tokens += rows.len();
            for (row, (last, out)) in rows.iter().zip(sinks) {
                let next = argmax(row.data());
                *last = next;
                out.push(next);
            }
        }

        // retire completed requests
        let mut i = 0;
        while i < self.active.len() {
            let done = {
                let f = &self.active[i];
                f.fed == f.req.prompt.len() && f.out.len() >= f.req.max_new
            };
            if done {
                let f = self.active.remove(i);
                self.finished.push(FinishedRequest {
                    id: f.req.id,
                    state_bytes: f.session.state_bytes(),
                    ttft_ticks: f
                        .ttft_tick
                        .map(|t| t.saturating_sub(f.req.arrival_tick))
                        .unwrap_or(0),
                    ttft_wall_ms: f.ttft_wall_ms.unwrap_or(0.0),
                    finished_tick: tick,
                    tokens: f.out,
                });
            } else {
                i += 1;
            }
        }

        // 5. evict while over budget (latest deadline first, largest id
        // on ties); the last active session is never parked
        while self.over_budget() && self.active.len() > 1 {
            let vi = self
                .active
                .iter()
                .enumerate()
                .max_by_key(|(_, f)| (f.req.deadline_tick, f.req.id))
                .map(|(i, _)| i)
                .unwrap();
            let f = self.active.remove(vi);
            self.parked.push(Parked {
                snap: f.session.snapshot(),
                req: f.req,
                fed: f.fed,
                last: f.last,
                out: f.out,
                cacheable_prefix: f.cacheable_prefix,
                from_cache: f.from_cache,
                t_admit: f.t_admit,
                ttft_tick: f.ttft_tick,
                ttft_wall_ms: f.ttft_wall_ms,
            });
            self.evictions += 1;
        }

        self.tick += 1;
        Ok(())
    }

    /// Drive [`step`](Self::step) to completion and summarize.  Bails on a
    /// livelocked schedule (tick count far beyond the enqueued work).
    pub fn run(&mut self) -> Result<ServeSummary> {
        let bound = self.max_arrival + 10 * self.work_units + 1000;
        while !self.is_done() {
            if self.tick > bound {
                bail!(
                    "serve loop livelock: tick {} exceeds bound {bound} \
                     ({} active, {} parked, {} queued)",
                    self.tick,
                    self.active.len(),
                    self.parked.len(),
                    self.queue.len()
                );
            }
            self.step()?;
        }
        Ok(self.summary())
    }

    /// Aggregate metrics over the finished requests so far.
    pub fn summary(&self) -> ServeSummary {
        let elapsed = self.t0.elapsed().as_secs_f64();
        let mut ttfts: Vec<f64> =
            self.finished.iter().map(|f| f.ttft_wall_ms).collect();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let generated: usize = self.finished.iter().map(|f| f.tokens.len()).sum();
        let mean_state = if self.finished.is_empty() {
            0.0
        } else {
            self.finished.iter().map(|f| f.state_bytes as f64).sum::<f64>()
                / self.finished.len() as f64
        };
        ServeSummary {
            sessions: self.finished.len(),
            total_ticks: self.tick,
            generated_tokens: generated,
            p50_ttft_ms: crate::metrics::percentile(&ttfts, 0.50),
            p99_ttft_ms: crate::metrics::percentile(&ttfts, 0.99),
            decode_tps: if self.decode_nanos > 0 {
                self.decoded_tokens as f64 / (self.decode_nanos as f64 / 1e9)
            } else {
                0.0
            },
            sustained_tps: if elapsed > 0.0 { generated as f64 / elapsed } else { 0.0 },
            mean_state_bytes: mean_state,
            sessions_per_gb: if mean_state > 0.0 { 1e9 / mean_state } else { 0.0 },
            cache_hits: self.cache.hits,
            cache_misses: self.cache.misses,
            cache_insertions: self.cache.insertions,
            evictions: self.evictions,
            resumes: self.resumes,
            rejected_requests: self.rejected.len(),
            failed_requests: self.failed.len(),
            output_digest: output_digest(&self.finished),
            elapsed_s: elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;

    fn request(id: u64, arrival: u64, prompt: Vec<i32>, max_new: usize) -> Request {
        Request {
            id,
            arrival_tick: arrival,
            prompt,
            prefix_len: 0,
            max_new,
            deadline_tick: arrival + 64,
        }
    }

    #[test]
    fn loop_drains_and_matches_sequential_generate() {
        let model = Model::load("tiny", Variant::Basic, "0", 11).unwrap();
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..40).map(|i| ((i * 7 + k * 13 + 5) % 256) as i32).collect())
            .collect();
        for (k, p) in prompts.iter().enumerate() {
            sl.enqueue(request(k as u64, k as u64, p.clone(), 6));
        }
        let sum = sl.run().unwrap();
        assert_eq!(sum.sessions, 3);
        assert_eq!(sum.generated_tokens, 18);
        let mut fin = sl.finished().to_vec();
        fin.sort_by_key(|f| f.id);
        for (k, p) in prompts.iter().enumerate() {
            let mut s = model.session();
            let want = s.generate(p, 6).unwrap();
            assert_eq!(fin[k].tokens, want, "request {k}");
        }
    }

    #[test]
    fn oversized_prompt_is_rejected_at_enqueue_never_admitted() {
        let model = Model::load("tiny", Variant::Basic, "0", 11).unwrap();
        let ms = model.config().max_seq;
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        sl.enqueue(request(0, 0, vec![1; ms + 1], 4));
        assert_eq!(sl.rejected().len(), 1);
        assert!(sl.rejected()[0].reason.contains("max_seq"));
        let sum = sl.run().unwrap();
        assert_eq!(sum.sessions, 0);
        assert_eq!(sum.rejected_requests, 1);
        assert_eq!(sum.failed_requests, 0);
        assert_eq!(sum.generated_tokens, 0);
    }

    #[test]
    fn poison_request_fails_alone_and_survivors_are_bit_identical() {
        let model = Model::load("tiny", Variant::Basic, "0", 11).unwrap();
        let ms = model.config().max_seq;
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|k| (0..40).map(|i| ((i * 7 + k * 13 + 5) % 256) as i32).collect())
            .collect();
        let clean = {
            let mut sl = ServeLoop::new(&model, ServeConfig::default());
            for (k, p) in prompts.iter().enumerate() {
                sl.enqueue(request(k as u64, k as u64, p.clone(), 6));
            }
            sl.run().unwrap()
        };
        // poison: the prompt fills the window exactly, so the generation
        // budget can never be decoded — it must fail alone, at runtime
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        for (k, p) in prompts.iter().enumerate() {
            sl.enqueue(request(k as u64, k as u64, p.clone(), 6));
        }
        sl.enqueue(request(9, 0, vec![3; ms], 4));
        let sum = sl.run().unwrap();
        assert_eq!(sum.rejected_requests, 0, "poison passes admission");
        assert_eq!(sum.failed_requests, 1);
        assert_eq!(sl.failures()[0].id, 9);
        assert!(sl.failures()[0].reason.contains("context window exhausted"));
        assert_eq!(sum.sessions, 3, "all survivors finish");
        assert_eq!(
            sum.output_digest, clean.output_digest,
            "survivor token streams must be bit-identical to the clean run"
        );
    }

    #[test]
    fn idle_fast_forward_skips_to_next_arrival() {
        let model = Model::load("tiny", Variant::Basic, "0", 11).unwrap();
        let mut sl = ServeLoop::new(&model, ServeConfig::default());
        sl.enqueue(request(0, 500, vec![1, 2, 3], 2));
        let sum = sl.run().unwrap();
        assert_eq!(sum.sessions, 1);
        // one tick of ragged prefill + one decode tick, right after arrival
        assert!(sum.total_ticks >= 500 && sum.total_ticks < 510);
    }

    #[test]
    fn digest_is_order_insensitive_but_content_sensitive() {
        let a = FinishedRequest {
            id: 1,
            tokens: vec![5, 6],
            ttft_ticks: 0,
            ttft_wall_ms: 0.0,
            finished_tick: 0,
            state_bytes: 0,
        };
        let mut b = a.clone();
        b.id = 2;
        b.tokens = vec![7];
        let d1 = output_digest(&[a.clone(), b.clone()]);
        let d2 = output_digest(&[b.clone(), a.clone()]);
        assert_eq!(d1, d2);
        let mut c = b.clone();
        c.tokens = vec![8];
        assert_ne!(d1, output_digest(&[a, c]));
    }
}
