//! Prefix cache: shared system prompts prefill ONCE.
//!
//! Keyed by an FNV-1a hash of the prefix tokens (full token equality is
//! re-checked on lookup, so a hash collision degrades to a miss, never a
//! wrong restore).  Values are [`Snapshot`]s taken right after the prefix
//! was prefilled; a hit restores the snapshot into a fresh session and the
//! loop skips straight to the user-specific suffix.  Because `prefill` is
//! deterministic and chunk-aligned restores replay the identical op
//! sequence, a hit is bit-identical to a cold prefill (pinned by
//! `tests/serve_loop.rs`).

use super::Snapshot;

/// FNV-1a over the token stream — the cache key and the serve loop's
/// output digest both use it (stable, dependency-free, order-sensitive).
pub fn token_hash(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

struct Entry {
    hash: u64,
    tokens: Vec<i32>,
    snap: Snapshot,
    last_used: u64,
    bytes: usize,
}

/// Fixed-capacity LRU cache from token prefixes to state snapshots.
pub struct PrefixCache {
    entries: Vec<Entry>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl PrefixCache {
    /// `capacity` = max entries (0 disables the cache entirely).
    pub fn new(capacity: usize) -> PrefixCache {
        PrefixCache {
            entries: Vec::new(),
            capacity,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total resident bytes of all cached snapshots.
    pub fn bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Look up a prefix; on a hit, refresh its LRU stamp with the caller's
    /// tick and return the snapshot to restore.
    pub fn lookup(&mut self, tokens: &[i32], tick: u64) -> Option<&Snapshot> {
        if self.capacity == 0 {
            return None;
        }
        let h = token_hash(tokens);
        let at = self
            .entries
            .iter()
            .position(|e| e.hash == h && e.tokens == tokens);
        match at {
            Some(i) => {
                self.hits += 1;
                self.entries[i].last_used = tick;
                Some(&self.entries[i].snap)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly prefilled prefix, evicting the least-recently-used
    /// entry (ties: smallest hash) when at capacity.  Re-inserting an
    /// existing prefix refreshes its snapshot in place.
    pub fn insert(&mut self, tokens: &[i32], snap: Snapshot, tick: u64) {
        if self.capacity == 0 {
            return;
        }
        let h = token_hash(tokens);
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.hash == h && e.tokens == tokens)
        {
            e.snap = snap;
            e.last_used = tick;
            return;
        }
        while self.entries.len() >= self.capacity {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| (e.last_used, e.hash))
                .map(|(i, _)| i)
                .unwrap();
            self.entries.remove(victim);
            self.evictions += 1;
        }
        let bytes = snap.state_bytes();
        self.entries.push(Entry {
            hash: h,
            tokens: tokens.to_vec(),
            snap,
            last_used: tick,
            bytes,
        });
        self.insertions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::Model;
    use super::*;
    use crate::config::Variant;

    #[test]
    fn token_hash_is_order_sensitive_and_stable() {
        assert_eq!(token_hash(&[1, 2, 3]), token_hash(&[1, 2, 3]));
        assert_ne!(token_hash(&[1, 2, 3]), token_hash(&[3, 2, 1]));
        assert_ne!(token_hash(&[]), token_hash(&[0]));
    }

    #[test]
    fn lru_insert_lookup_evict() {
        let model = Model::load("tiny", Variant::Basic, "0", 3).unwrap();
        let s = model.session();
        let mut cache = PrefixCache::new(2);
        cache.insert(&[1, 2], s.snapshot(), 10);
        cache.insert(&[3, 4], s.snapshot(), 11);
        assert!(cache.lookup(&[1, 2], 12).is_some()); // refreshes [1,2]
        cache.insert(&[5, 6], s.snapshot(), 13); // evicts [3,4] (LRU)
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions, 1);
        assert!(cache.lookup(&[3, 4], 14).is_none());
        assert!(cache.lookup(&[1, 2], 15).is_some());
        assert!(cache.lookup(&[5, 6], 16).is_some());
        assert_eq!(cache.hits, 4);
        assert_eq!(cache.misses, 1);
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let model = Model::load("tiny", Variant::Basic, "0", 3).unwrap();
        let s = model.session();
        let mut cache = PrefixCache::new(0);
        cache.insert(&[1], s.snapshot(), 0);
        assert!(cache.lookup(&[1], 1).is_none());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses, 0);
    }
}
