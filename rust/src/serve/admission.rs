//! Request admission queue for the continuous-batching serve loop.
//!
//! Requests carry LOGICAL arrival/deadline metadata measured in scheduler
//! ticks (one tick = one [`super::step_loop::ServeLoop::step`] call), not
//! wall-clock time: the loop's admission decisions are pure functions of
//! the tick counter, which is what makes the whole schedule — and hence
//! every session's token stream — bit-reproducible at any thread count.

use std::collections::VecDeque;

/// One serving request: a prompt to prefill, a generation budget, and the
/// scheduling metadata the loop orders work by.
#[derive(Clone, Debug)]
pub struct Request {
    /// Unique, monotonically increasing id (ties in every scheduling
    /// ordering break on id, which keeps the loop deterministic).
    pub id: u64,
    /// Tick at which the request becomes visible to admission.
    pub arrival_tick: u64,
    /// Prompt tokens (system prefix + user turn).
    pub prompt: Vec<i32>,
    /// Length of the shared system prefix (prefix-cache key); 0 disables
    /// prefix caching for this request.  Cache hits additionally require
    /// the prefix to be chunk-aligned and shorter than the prompt.
    pub prefix_len: usize,
    /// Tokens to generate after the prompt.
    pub max_new: usize,
    /// Soft deadline tick; the eviction policy parks the request with the
    /// LATEST deadline first (it has the most slack to absorb a stall).
    pub deadline_tick: u64,
}

/// Arrival-ordered admission queue.  `push` keeps the queue sorted by
/// `(arrival_tick, id)`; `pop_ready` releases the head once the loop's
/// tick has reached its arrival.
#[derive(Default)]
pub struct AdmissionQueue {
    queue: VecDeque<Request>,
}

impl AdmissionQueue {
    pub fn new() -> AdmissionQueue {
        AdmissionQueue::default()
    }

    /// Insert in `(arrival_tick, id)` order (stable for any push order).
    pub fn push(&mut self, req: Request) {
        let key = (req.arrival_tick, req.id);
        let at = self
            .queue
            .iter()
            .position(|r| (r.arrival_tick, r.id) > key)
            .unwrap_or(self.queue.len());
        self.queue.insert(at, req);
    }

    /// Take the earliest request whose arrival tick has passed.
    pub fn pop_ready(&mut self, tick: u64) -> Option<Request> {
        match self.queue.front() {
            Some(r) if r.arrival_tick <= tick => self.queue.pop_front(),
            _ => None,
        }
    }

    /// Arrival tick of the next queued request (for idle fast-forward).
    pub fn next_arrival(&self) -> Option<u64> {
        self.queue.front().map(|r| r.arrival_tick)
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: u64) -> Request {
        Request {
            id,
            arrival_tick: arrival,
            prompt: vec![1, 2, 3],
            prefix_len: 0,
            max_new: 4,
            deadline_tick: arrival + 100,
        }
    }

    #[test]
    fn pops_in_arrival_then_id_order_regardless_of_push_order() {
        let mut q = AdmissionQueue::new();
        q.push(req(3, 5));
        q.push(req(1, 5));
        q.push(req(2, 0));
        assert_eq!(q.len(), 3);
        assert_eq!(q.next_arrival(), Some(0));
        assert_eq!(q.pop_ready(10).unwrap().id, 2);
        assert_eq!(q.pop_ready(10).unwrap().id, 1);
        assert_eq!(q.pop_ready(10).unwrap().id, 3);
        assert!(q.is_empty());
    }

    #[test]
    fn holds_requests_until_their_arrival_tick() {
        let mut q = AdmissionQueue::new();
        q.push(req(1, 7));
        assert!(q.pop_ready(6).is_none());
        assert_eq!(q.pop_ready(7).unwrap().id, 1);
        assert!(q.pop_ready(7).is_none());
    }
}
