//! Synthetic multi-tenant trace generator for the serve loop.
//!
//! Traces model a serving mix: a handful of shared SYSTEM prompts (the
//! prefix-cache workload), per-request user turns of mixed length, short
//! generations, and arrivals spread over a window of scheduler ticks.
//! Generation is a pure function of the seed (an LCG, no external RNG),
//! so the same `TraceConfig` always produces the identical request list —
//! which the CI digest check relies on to compare thread counts.

use crate::config::ModelConfig;

use super::admission::Request;

/// Trace shape knobs; build with [`TraceConfig::for_model`] so lengths
/// stay inside the preset's chunk/context geometry.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub sessions: usize,
    pub seed: u64,
    /// Number of distinct shared system prompts.
    pub sys_prompts: usize,
    /// System-prefix length in tokens (chunk-aligned for cacheability).
    pub sys_len: usize,
    /// User-turn length range (inclusive).
    pub user_min: usize,
    pub user_max: usize,
    /// Generation-budget range (inclusive).
    pub gen_min: usize,
    pub gen_max: usize,
    /// Arrivals are spread uniformly over `[0, arrival_window)` ticks.
    pub arrival_window: u64,
    /// Deadline slack added beyond the request's own work estimate.
    pub deadline_slack: u64,
    pub vocab: usize,
}

impl TraceConfig {
    /// Defaults derived from the model geometry: chunk-aligned system
    /// prefix (one chunk), user turns of half-to-two chunks, 4-16 token
    /// generations.  The longest possible request stays well inside
    /// `max_seq` for every built-in preset.
    pub fn for_model(cfg: &ModelConfig, sessions: usize, seed: u64) -> TraceConfig {
        let c = cfg.chunk_len;
        let t = TraceConfig {
            sessions,
            seed,
            sys_prompts: 4,
            sys_len: c,
            user_min: c / 2,
            user_max: 2 * c,
            gen_min: 4,
            gen_max: 16,
            arrival_window: (sessions as u64) / 2 + 1,
            deadline_slack: 256,
            vocab: cfg.vocab,
        };
        assert!(
            t.sys_len + t.user_max + t.gen_max < cfg.max_seq,
            "trace lengths exceed max_seq {}",
            cfg.max_seq
        );
        t
    }
}

/// Deterministic 64-bit LCG (Knuth's MMIX constants).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    /// Uniform in `[lo, hi]` (inclusive).
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

/// The shared system prompt with index `s`: deterministic tokens, distinct
/// across prompts, independent of the trace seed (so two traces over the
/// same model share cache entries).
fn sys_prompt(s: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len)
        .map(|i| ((s * 31 + i * 7 + 3) % vocab) as i32)
        .collect()
}

/// Generate the request list for a trace, in id order.
pub fn gen_trace(t: &TraceConfig) -> Vec<Request> {
    let mut rng = Lcg(t.seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    let mut out = Vec::with_capacity(t.sessions);
    for id in 0..t.sessions as u64 {
        let s = rng.range(0, t.sys_prompts as u64 - 1) as usize;
        let mut prompt = sys_prompt(s, t.sys_len, t.vocab);
        let user_len = rng.range(t.user_min as u64, t.user_max as u64) as usize;
        for _ in 0..user_len {
            prompt.push((rng.next() % t.vocab as u64) as i32);
        }
        let max_new = rng.range(t.gen_min as u64, t.gen_max as u64) as usize;
        let arrival_tick = rng.range(0, t.arrival_window - 1);
        let work = (prompt.len() as u64) / 8 + max_new as u64;
        out.push(Request {
            id,
            arrival_tick,
            prefix_len: t.sys_len,
            prompt,
            max_new,
            deadline_tick: arrival_tick + work + t.deadline_slack,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn trace_is_deterministic_and_in_bounds() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let t = TraceConfig::for_model(&cfg, 32, 7);
        let a = gen_trace(&t);
        let b = gen_trace(&t);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival_tick, y.arrival_tick);
            assert_eq!(x.max_new, y.max_new);
        }
        for r in &a {
            assert!(r.prompt.len() + r.max_new < cfg.max_seq);
            assert_eq!(r.prefix_len, cfg.chunk_len);
            assert!(r.prompt.len() > r.prefix_len);
            assert!(r.prompt.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
            assert!(r.arrival_tick < t.arrival_window);
        }
        // different seeds produce different traces
        let c = gen_trace(&TraceConfig { seed: 8, ..t });
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn shared_system_prompts_repeat_across_requests() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let t = TraceConfig::for_model(&cfg, 64, 3);
        let trace = gen_trace(&t);
        let mut prefixes: Vec<&[i32]> =
            trace.iter().map(|r| &r.prompt[..r.prefix_len]).collect();
        prefixes.sort();
        prefixes.dedup();
        // 64 requests draw from only sys_prompts distinct prefixes
        assert!(prefixes.len() <= t.sys_prompts);
        assert!(prefixes.len() > 1);
    }
}
