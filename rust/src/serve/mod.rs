//! Serving layer: constant-memory autoregressive decode on the recurrent
//! state (the paper's opening claim for linear attention, §1).
//!
//! * `Model` — load a preset + parameters ONCE; weights are staged through
//!   `Engine::cache_buffer` on first use and shared by every session.
//! * `Session` — per-request mutable state: one `ChunkState {M, a}` per
//!   linear layer (H x fk x dh floats, **independent of position**), a KV
//!   cache per std layer for hybrid patterns (grows with position — the
//!   contrast the decode bench quantifies), and the position offset.
//!   `prefill` runs the existing chunked LASP-2 path (l_part1 -> gated
//!   prefix combine -> l_part2) to populate state a chunk at a time;
//!   `decode` is an O(1)-memory single-token step through the
//!   `l_decode_*`/`s_decode` artifacts.  `snapshot`/`restore` clone the
//!   state for prefix reuse (system-prompt caching).
//! * `Batch` — steps many sessions per kernel call by grouping them into
//!   the batched decode artifacts (`*_B{2,4,8}`).
//! * `step_loop` (in `loop.rs`) — the continuous-batching serve loop:
//!   admission ([`admission`]), prefix caching ([`prefix_cache`]),
//!   eviction/resume under a memory budget, and chunked-prefill/decode
//!   interleaving.  [`loadgen`] builds the synthetic multi-tenant traces
//!   that drive it (`lasp2 serve-sim` / `lasp2 bench-serve`).
//!
//! Correctness is pinned by `tests/serve_decode.rs`: decoding token by
//! token reproduces the `forward_mono_*` oracle logits at every position
//! for all six linear variants, a hybrid pattern, and the std baseline.
//! `tests/serve_loop.rs` pins the loop itself: its per-session token
//! streams are bit-identical to sequential `Session::generate`, through
//! prefix-cache hits and evict/resume cycles, at any thread count.

pub mod admission;
pub mod loadgen;
pub mod prefix_cache;
#[path = "loop.rs"]
pub mod step_loop;

pub use admission::{AdmissionQueue, Request};
pub use loadgen::{gen_trace, TraceConfig};
pub use prefix_cache::PrefixCache;
pub use step_loop::{
    FailedRequest, FinishedRequest, ServeConfig, ServeLoop, ServeSummary,
};

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, Pattern, Variant};
use crate::coordinator::Params;
use crate::runtime::{Engine, Value};
use crate::tensor::quant::{DecodeDtype, QuantMat};
use crate::tensor::{scratch, state_combine, ChunkState, Tensor};

/// Greedy sampling: index of the max logit (ties -> lowest index).
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (j, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = j;
        }
    }
    best as i32
}

/// Pre-quantized decode readout (`--decode-dtype bf16|int8`): the
/// `final_ln` weights plus the embedding matrix stored reduced-precision.
/// Built once per model by [`Model::set_decode_dtype`]; `decode_group`
/// then computes the per-token logits as
/// `rmsnorm(x, final_ln) · dequant(emb)ᵀ` with f32 accumulation instead
/// of running the `head_dec_B{b}` artifact.  Tolerance-parity (≤1e-2
/// logits), still deterministic across runs and thread counts.
struct QuantReadout {
    final_ln: Tensor,
    emb: QuantMat,
}

/// A loaded model: engine + parameters, shared (read-only) by sessions.
pub struct Model {
    engine: Arc<Engine>,
    params: Params,
    /// `Some` only when an opt-in reduced-precision readout is active.
    readout: Option<QuantReadout>,
}

impl Model {
    /// Load a preset and initialize parameters: via the `init_*` artifact
    /// when one is registered for (variant, ratio) — the same init law the
    /// training path uses — else deterministic `Params::randn`.
    pub fn load(preset: &str, variant: Variant, ratio: &str, seed: i32) -> Result<Model> {
        let engine = Engine::load_preset(preset)?;
        Self::with_engine(engine, variant, ratio, seed)
    }

    /// Same as `load` for an engine the caller already holds.
    pub fn with_engine(
        engine: Arc<Engine>,
        variant: Variant,
        ratio: &str,
        seed: i32,
    ) -> Result<Model> {
        let pattern = Pattern::from_ratio(engine.model.n_layers, ratio)?;
        anyhow::ensure!(
            variant != Variant::Softmax || pattern.n_linear() == 0,
            "variant softmax requires ratio \"all\" (got pattern {})",
            pattern.0
        );
        let init_name = format!("init_{}_{}", variant.name(), Pattern::tag(ratio));
        let params = if engine.has_artifact(&init_name) {
            Params::from_init_artifact(&engine, variant, &pattern, &init_name, seed)?
        } else {
            Params::randn(&engine.model, variant, &pattern, seed as u64)
        };
        Ok(Model { engine, params, readout: None })
    }

    /// Wrap an engine + parameter set the caller built directly (tests,
    /// checkpoints restored from a training run).
    pub fn from_parts(engine: Arc<Engine>, params: Params) -> Model {
        Model { engine, params, readout: None }
    }

    /// Select the decode-readout weight dtype (`--decode-dtype`).  `F32`
    /// (the default) keeps the bit-exact `head_dec_B{b}` artifact path;
    /// `Bf16`/`Int8` quantize the embedding once here and route decode
    /// logits through [`QuantReadout`].  Prefill logits stay f32 either
    /// way — only the per-token decode readout is bandwidth-bound.
    pub fn set_decode_dtype(&mut self, dtype: DecodeDtype) -> Result<()> {
        self.readout = match dtype {
            DecodeDtype::F32 => None,
            _ => Some(QuantReadout {
                final_ln: self.params.get("final_ln")?.clone(),
                emb: QuantMat::quantize(self.params.get("embed")?, dtype)?,
            }),
        };
        Ok(())
    }

    /// The active decode-readout dtype.
    pub fn decode_dtype(&self) -> DecodeDtype {
        self.readout.as_ref().map_or(DecodeDtype::F32, |r| r.emb.dtype())
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn config(&self) -> &ModelConfig {
        &self.engine.model
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    pub fn variant(&self) -> Variant {
        self.params.variant
    }

    pub fn pattern(&self) -> &Pattern {
        &self.params.pattern
    }

    /// A fresh session: zero recurrent state, empty KV caches, position 0.
    /// Std KV caches start at capacity 0 and grow on demand (power-of-two
    /// doubling), so an idle hybrid session costs only its linear states.
    pub fn session(&self) -> Session<'_> {
        let cfg = &self.engine.model;
        let (hh, dh) = (cfg.n_heads, cfg.head_dim);
        let fk = cfg.feat_dim(self.params.variant);
        let states = self
            .params
            .pattern
            .layers()
            .map(|(_, is_linear)| {
                if is_linear {
                    LayerState::Linear(ChunkState {
                        m: Tensor::zeros(&[hh, fk, dh]),
                        a: Tensor::ones(&[hh, fk]),
                    })
                } else {
                    LayerState::Std {
                        k: Tensor::zeros(&[0, hh, dh]),
                        v: Tensor::zeros(&[0, hh, dh]),
                        len: 0,
                    }
                }
            })
            .collect();
        Session { model: self, states, pos: 0 }
    }

    /// Pre-instantiate the serving artifacts (prefill + B=1 decode) so the
    /// first request doesn't pay first-call jitter.
    pub fn warmup_serving(&self) -> Result<()> {
        let v = self.params.variant.name();
        let names = [
            "embed".to_string(),
            "head".to_string(),
            format!("l_part1_{v}"),
            format!("l_part2_{v}"),
            "s_prefill".to_string(),
            "embed_dec_B1".to_string(),
            "head_dec_B1".to_string(),
            format!("l_decode_{v}_B1"),
            "s_decode_B1".to_string(),
        ];
        let present: Vec<&str> = names
            .iter()
            .filter(|n| self.engine.has_artifact(n.as_str()))
            .map(|n| n.as_str())
            .collect();
        self.engine.warmup(&present)
    }
}

/// Per-layer request state: the LASP-2 recurrent memory for linear layers
/// (size independent of position) or the softmax KV cache for std layers
/// (grows one row per decoded token).
///
/// The linear state is kept as the WHOLE prefix-combine monoid element
/// `(M, a)`: decode/prefill readouts consume only `M` (the incoming
/// chunk's own decay is what the combine applies), but `a` — the total
/// decay carry over everything consumed so far — is maintained so the
/// state composes with any future `state_combine`-based consumer (e.g.
/// migrating a session into a distributed prefill) exactly like the
/// chunk states the SP AllGather moves.
#[derive(Clone)]
enum LayerState {
    Linear(ChunkState),
    /// `k`/`v` are capacity-sized `[cap, H, dh]` (cap ≥ `len`, power-of-
    /// two doubling via [`grow_kv`]); only the first `len` rows are live.
    Std { k: Tensor, v: Tensor, len: usize },
}

/// Total resident bytes of a state vector: the whole `ChunkState` for
/// linear layers, the ALLOCATED capacity (not the logical `len`) for std
/// KV caches — what a serving system actually pins per session.
fn states_bytes(states: &[LayerState]) -> usize {
    states
        .iter()
        .map(|s| match s {
            LayerState::Linear(cs) => cs.byte_size(),
            LayerState::Std { k, v, .. } => k.byte_size() + v.byte_size(),
        })
        .sum()
}

/// Grow a std layer's KV cache to hold at least `needed` rows, copying
/// the `live` rows over.  Capacity doubles (min 16 rows) and is capped at
/// `max_seq` — the position checks upstream guarantee `needed <= max_seq`.
fn grow_kv(k: &mut Tensor, v: &mut Tensor, live: usize, needed: usize, max_seq: usize) {
    let cap = k.shape()[0];
    if cap >= needed {
        return;
    }
    let (hh, dh) = (k.shape()[1], k.shape()[2]);
    let new_cap = needed.next_power_of_two().max(16).min(max_seq);
    let stride = hh * dh;
    for t in [k, v] {
        let mut buf = vec![0.0f32; new_cap * stride];
        buf[..live * stride].copy_from_slice(&t.data()[..live * stride]);
        *t = Tensor::new(vec![new_cap, hh, dh], buf);
    }
}

/// A point-in-time copy of a session's state (prefix reuse: snapshot after
/// the system prompt, restore per request).  Only valid for sessions of
/// the same `Model` it was taken from — `restore` checks the model's
/// identity, not just the state shapes.
#[derive(Clone)]
pub struct Snapshot {
    model_id: usize,
    states: Vec<LayerState>,
    pos: usize,
}

impl Snapshot {
    /// Position the snapshot was taken at.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Resident bytes of the captured state (same accounting as
    /// [`Session::state_bytes`]) — what a parked/cached copy costs.
    pub fn state_bytes(&self) -> usize {
        states_bytes(&self.states)
    }
}

/// One in-flight request: mutable decode state over a shared `Model`.
#[derive(Clone)]
pub struct Session<'m> {
    model: &'m Model,
    states: Vec<LayerState>,
    pos: usize,
}

impl<'m> Session<'m> {
    /// Tokens consumed so far (the next token lands at this position).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes of per-request state a serving system must hold: the
    /// recurrent `ChunkState` for linear layers (CONSTANT in position) and
    /// the ALLOCATED capacity of the std KV caches (grows with position,
    /// power-of-two doubling).  This is actual resident memory — what the
    /// sessions-per-GB accounting in `bench-serve` divides by — not the
    /// logical row count.
    pub fn state_bytes(&self) -> usize {
        states_bytes(&self.states)
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            model_id: self.model as *const Model as usize,
            states: self.states.clone(),
            pos: self.pos,
        }
    }

    pub fn restore(&mut self, snap: &Snapshot) {
        assert_eq!(
            snap.model_id,
            self.model as *const Model as usize,
            "snapshot from a different model"
        );
        self.states = snap.states.clone();
        self.pos = snap.pos;
    }

    /// Feed `tokens` and return logits for every fed position `[n, vocab]`.
    ///
    /// Chunk-aligned full chunks run the chunked LASP-2 path (one
    /// `l_part1` + gated prefix combine + `l_part2` per linear layer);
    /// a ragged tail (or a start at an unaligned position) falls back to
    /// single-token decode steps, which compute the same math (pinned by
    /// the parity tests).
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Tensor> {
        anyhow::ensure!(!tokens.is_empty(), "prefill: empty token list");
        let c = self.model.engine.model.chunk_len;
        let vocab = self.model.engine.model.vocab;
        let mut parts = Vec::new();
        let mut i = 0;
        while i < tokens.len() {
            if self.pos % c == 0 && tokens.len() - i >= c {
                parts.push(self.prefill_chunk(&tokens[i..i + c])?);
                i += c;
            } else {
                let row = self.decode(tokens[i])?;
                parts.push(row.reshape(&[1, vocab]));
                i += 1;
            }
        }
        Ok(Tensor::cat0(&parts))
    }

    /// One full chunk through the chunked LASP-2 path.  `self.pos` must be
    /// chunk-aligned (enforced by `prefill`).
    fn prefill_chunk(&mut self, tokens: &[i32]) -> Result<Tensor> {
        let model = self.model;
        let engine = model.engine.as_ref();
        let cfg = &engine.model;
        let c = cfg.chunk_len;
        anyhow::ensure!(tokens.len() == c, "prefill_chunk: not a full chunk");
        anyhow::ensure!(
            self.pos + c <= cfg.max_seq,
            "context window exhausted (pos {} + chunk {} > max_seq {})",
            self.pos,
            c,
            cfg.max_seq
        );
        let vname = model.params.variant.name();

        let embed = engine.artifact("embed")?;
        let mut x = embed.run1(&[
            Value::I32(tokens.to_vec(), vec![c]),
            Value::i32_scalar(self.pos as i32),
            model.params.value(engine, "embed")?,
            model.params.value(engine, "pos")?,
        ])?;

        for (li, is_linear) in model.params.pattern.layers() {
            if is_linear {
                let p1 = engine.artifact(&format!("l_part1_{vname}"))?;
                let mut ins = vec![
                    x.clone().into(),
                    model.params.layer_value(engine, li, "ln1")?,
                    model.params.layer_value(engine, li, "wq")?,
                    model.params.layer_value(engine, li, "wk")?,
                    model.params.layer_value(engine, li, "wv")?,
                ];
                ins.extend(model.params.part1_extra(engine, li)?);
                let mut p1_out = p1.run(&ins)?; // qt, kt, v, m, a
                let a_c = p1_out.pop().unwrap();
                let m_c = p1_out.pop().unwrap();
                let v_c = p1_out.pop().unwrap();
                let kt = p1_out.pop().unwrap();
                let qt = p1_out.pop().unwrap();
                let state = match &mut self.states[li] {
                    LayerState::Linear(cs) => cs,
                    LayerState::Std { .. } => bail!("layer {li}: state kind mismatch"),
                };
                let p2 = engine.artifact(&format!("l_part2_{vname}"))?;
                let mut ins2 = vec![
                    x.into(),
                    qt.into(),
                    kt.into(),
                    v_c.into(),
                    state.m.clone().into(),
                ];
                ins2.extend(model.params.epilogue(engine, li)?);
                x = p2.run1(&ins2)?;
                *state = state_combine(state, &ChunkState { m: m_c, a: a_c });
            } else {
                let (k_cache, v_cache, len) = match &self.states[li] {
                    LayerState::Std { k, v, len } => (k.clone(), v.clone(), *len),
                    LayerState::Linear(_) => bail!("layer {li}: state kind mismatch"),
                };
                let exe = engine.artifact("s_prefill")?;
                let mut ins = vec![
                    x.into(),
                    model.params.layer_value(engine, li, "ln1")?,
                    model.params.layer_value(engine, li, "wq")?,
                    model.params.layer_value(engine, li, "wk")?,
                    model.params.layer_value(engine, li, "wv")?,
                    k_cache.into(),
                    v_cache.into(),
                    Value::i32_scalar(len as i32),
                ];
                ins.extend(model.params.epilogue(engine, li)?);
                let mut outs = exe.run(&ins)?; // y, k_new, v_new
                let v_new = outs.pop().unwrap();
                let k_new = outs.pop().unwrap();
                x = outs.pop().unwrap();
                if let LayerState::Std { k, v, len } = &mut self.states[li] {
                    let stride = cfg.n_heads * cfg.head_dim;
                    grow_kv(k, v, *len, *len + c, cfg.max_seq);
                    k.data_mut()[*len * stride..(*len + c) * stride]
                        .copy_from_slice(k_new.data());
                    v.data_mut()[*len * stride..(*len + c) * stride]
                        .copy_from_slice(v_new.data());
                    *len += c;
                }
            }
        }

        let head = engine.artifact("head")?;
        let logits = head.run1(&[
            x.into(),
            model.params.value(engine, "final_ln")?,
            model.params.value(engine, "embed")?,
        ])?;
        self.pos += c;
        Ok(logits)
    }

    /// One autoregressive step: O(1) memory on linear layers (recurrent
    /// state update), one KV-cache row on std layers.  Returns `[vocab]`
    /// logits for the NEXT position.  Routed through [`decode_step`] — the
    /// same batching entry point the serve loop and `Batch` use — so the
    /// B=1 path is the batched path, not a separate code path.
    pub fn decode(&mut self, token: i32) -> Result<Tensor> {
        let mut out = decode_step(&mut [self], &[token])?;
        Ok(out.pop().unwrap())
    }

    /// Greedy generation: prefill the prompt, then decode `n` tokens.
    pub fn generate(&mut self, prompt: &[i32], n: usize) -> Result<Vec<i32>> {
        let logits = self.prefill(prompt)?;
        if n == 0 {
            return Ok(Vec::new());
        }
        let vb = *logits.shape().last().unwrap();
        let rows = logits.shape()[0];
        let mut next = argmax(&logits.data()[(rows - 1) * vb..]);
        let mut out = Vec::with_capacity(n);
        out.push(next);
        while out.len() < n {
            let row = self.decode(next)?;
            next = argmax(row.data());
            out.push(next);
        }
        Ok(out)
    }
}

/// Many concurrent sessions of one model, stepped together: each decode
/// call runs ONE batched kernel per layer for as many sessions as the
/// registered `*_B{b}` artifacts cover (greedy grouping, B=1 remainder).
pub struct Batch<'m> {
    model: &'m Model,
    sessions: Vec<Session<'m>>,
}

impl<'m> Batch<'m> {
    pub fn new(model: &'m Model) -> Batch<'m> {
        Batch { model, sessions: Vec::new() }
    }

    pub fn push(&mut self, session: Session<'m>) {
        assert!(
            std::ptr::eq(session.model, self.model),
            "session belongs to a different model"
        );
        self.sessions.push(session);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[Session<'m>] {
        &self.sessions
    }

    pub fn sessions_mut(&mut self) -> &mut [Session<'m>] {
        &mut self.sessions
    }

    pub fn into_sessions(self) -> Vec<Session<'m>> {
        self.sessions
    }

    /// Step every session by one token (`tokens[i]` feeds session i).
    /// Returns per-session `[vocab]` logits.
    pub fn decode(&mut self, tokens: &[i32]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            tokens.len() == self.sessions.len(),
            "batch decode: {} tokens for {} sessions",
            tokens.len(),
            self.sessions.len()
        );
        let mut refs: Vec<&mut Session<'m>> = self.sessions.iter_mut().collect();
        decode_step(&mut refs, tokens)
    }
}

/// Largest registered decode batch size that fits `n` sessions.
pub(crate) fn group_size(engine: &Engine, n: usize) -> usize {
    crate::runtime::native::DECODE_BATCH_SIZES
        .iter()
        .rev()
        .copied()
        .find(|b| *b <= n && engine.has_artifact(&format!("head_dec_B{b}")))
        .unwrap_or(1)
}

/// Step an arbitrary set of sessions by one token each (`tokens[i]` feeds
/// `sessions[i]`): the SINGLE batching entry point every decode path goes
/// through — `Session::decode`, `Session::generate`, `Batch::decode`, and
/// the continuous-batching serve loop.  Sessions are greedily split into
/// the largest registered `*_B{b}` kernel groups (B=1 remainder), so a
/// lone session and a member of a full batch run the identical code path.
/// Returns per-session `[vocab]` logits.
pub fn decode_step(sessions: &mut [&mut Session<'_>], tokens: &[i32]) -> Result<Vec<Tensor>> {
    anyhow::ensure!(
        !sessions.is_empty() && tokens.len() == sessions.len(),
        "decode_step: {} tokens for {} sessions",
        tokens.len(),
        sessions.len()
    );
    let engine = sessions[0].model.engine.clone();
    let mut out = Vec::with_capacity(tokens.len());
    let mut start = 0;
    while start < sessions.len() {
        let b = group_size(&engine, sessions.len() - start);
        out.extend(decode_group(
            &mut sessions[start..start + b],
            &tokens[start..start + b],
        )?);
        start += b;
    }
    Ok(out)
}

/// The shared decode step over one kernel group (batch size == group
/// length; a matching `*_B{len}` artifact set must be registered).
fn decode_group(sessions: &mut [&mut Session<'_>], tokens: &[i32]) -> Result<Vec<Tensor>> {
    let b = sessions.len();
    anyhow::ensure!(b > 0 && tokens.len() == b, "decode group arity");
    let model = sessions[0].model;
    anyhow::ensure!(
        sessions.iter().all(|s| std::ptr::eq(s.model, model)),
        "decode group spans different models"
    );
    let engine = model.engine.as_ref();
    let cfg = &engine.model;
    let (hh, dh, ms) = (cfg.n_heads, cfg.head_dim, cfg.max_seq);
    let fk = cfg.feat_dim(model.params.variant);
    for s in sessions.iter() {
        anyhow::ensure!(
            s.pos < ms,
            "context window exhausted (pos {} >= max_seq {ms})",
            s.pos
        );
    }

    let embed = engine
        .artifact(&format!("embed_dec_B{b}"))
        .with_context(|| format!("decode batch size {b} not registered"))?;
    let offsets: Vec<i32> = sessions.iter().map(|s| s.pos as i32).collect();
    let mut x = embed.run1(&[
        Value::I32(tokens.to_vec(), vec![b]),
        Value::I32(offsets, vec![b]),
        model.params.value(engine, "embed")?,
        model.params.value(engine, "pos")?,
    ])?;

    for (li, is_linear) in model.params.pattern.layers() {
        if is_linear {
            let exe = engine.artifact(&format!(
                "l_decode_{}_B{b}",
                model.params.variant.name()
            ))?;
            let mut ins = vec![
                x.into(),
                model.params.layer_value(engine, li, "ln1")?,
                model.params.layer_value(engine, li, "wq")?,
                model.params.layer_value(engine, li, "wk")?,
                model.params.layer_value(engine, li, "wv")?,
            ];
            ins.extend(model.params.part1_extra(engine, li)?);
            // fetch the epilogue weights BEFORE moving any session state:
            // every fallible step must happen while the session is intact
            let epi_vals = model.params.epilogue(engine, li)?;
            let m_idx = ins.len();
            let mstride = hh * fk * dh;
            // stage the recurrent states: B=1 MOVES the session's state
            // tensor into the Value (zero copy); B>1 packs all rows into
            // one pooled scratch buffer (single copy, no allocation in
            // steady state)
            let m_val = if b == 1 {
                match &mut sessions[0].states[li] {
                    LayerState::Linear(cs) => std::mem::replace(&mut cs.m, Tensor::zeros(&[0]))
                        .reshape(&[1, hh, fk, dh]),
                    LayerState::Std { .. } => bail!("layer {li}: state kind mismatch"),
                }
            } else {
                let mut buf = scratch::take(b * mstride);
                for (bi, s) in sessions.iter().enumerate() {
                    match &s.states[li] {
                        LayerState::Linear(cs) => buf[bi * mstride..(bi + 1) * mstride]
                            .copy_from_slice(cs.m.data()),
                        LayerState::Std { .. } => bail!("layer {li}: state kind mismatch"),
                    }
                }
                Tensor::new(vec![b, hh, fk, dh], buf)
            };
            ins.push(m_val.into());
            ins.extend(epi_vals);
            let run_res = exe.run(&ins); // y, m_new, a
            let m_back = std::mem::replace(&mut ins[m_idx], Value::i32_scalar(0));
            if b == 1 {
                if run_res.is_err() {
                    // put the moved state back so the session stays usable
                    if let Value::F32(mt) = m_back {
                        if let LayerState::Linear(cs) = &mut sessions[0].states[li] {
                            cs.m = mt.reshape(&[hh, fk, dh]);
                        }
                    }
                }
            } else if let Value::F32(mt) = m_back {
                scratch::recycle(mt.into_data());
            }
            let mut outs = run_res?;
            let a_new = outs.pop().unwrap();
            let m_new = outs.pop().unwrap();
            x = outs.pop().unwrap();
            for ((s, mc), ac) in sessions
                .iter_mut()
                .zip(m_new.chunk0(b))
                .zip(a_new.chunk0(b))
            {
                if let LayerState::Linear(cs) = &mut s.states[li] {
                    cs.m = mc.reshape(&[hh, fk, dh]);
                    cs.a = cs.a.mul(&ac.reshape(&[hh, fk]));
                }
            }
        } else {
            let exe = engine.artifact(&format!("s_decode_B{b}"))?;
            let stride = hh * dh;
            // fetch every fallible weight Value BEFORE moving the caches
            let ln1_v = model.params.layer_value(engine, li, "ln1")?;
            let wq_v = model.params.layer_value(engine, li, "wq")?;
            let wk_v = model.params.layer_value(engine, li, "wk")?;
            let wv_v = model.params.layer_value(engine, li, "wv")?;
            let epi_vals = model.params.epilogue(engine, li)?;
            // stage the KV caches: B=1 MOVES both cache tensors into the
            // Values (zero copy — the kernel attends over the live rows
            // in place); B>1 packs the LIVE rows into pooled scratch
            // buffers sized to the group's max extent (the kernels take
            // the capacity dim as a wildcard and never read past `len`)
            let (k_val, v_val, lens, cap1) = if b == 1 {
                match &mut sessions[0].states[li] {
                    LayerState::Std { k, v, len } => {
                        let cap = k.shape()[0];
                        (
                            std::mem::replace(k, Tensor::zeros(&[0]))
                                .reshape(&[1, cap, hh, dh]),
                            std::mem::replace(v, Tensor::zeros(&[0]))
                                .reshape(&[1, cap, hh, dh]),
                            vec![*len as i32],
                            cap,
                        )
                    }
                    LayerState::Linear(_) => bail!("layer {li}: state kind mismatch"),
                }
            } else {
                let mut lens = Vec::with_capacity(b);
                for s in sessions.iter() {
                    match &s.states[li] {
                        LayerState::Std { len, .. } => lens.push(*len as i32),
                        LayerState::Linear(_) => bail!("layer {li}: state kind mismatch"),
                    }
                }
                let gcap = lens.iter().map(|&l| l as usize + 1).max().unwrap();
                let mut kd = scratch::take(b * gcap * stride);
                let mut vd = scratch::take(b * gcap * stride);
                for (bi, s) in sessions.iter().enumerate() {
                    if let LayerState::Std { k, v, len } = &s.states[li] {
                        let n = *len * stride;
                        let base = bi * gcap * stride;
                        kd[base..base + n].copy_from_slice(&k.data()[..n]);
                        vd[base..base + n].copy_from_slice(&v.data()[..n]);
                    }
                }
                (
                    Tensor::new(vec![b, gcap, hh, dh], kd),
                    Tensor::new(vec![b, gcap, hh, dh], vd),
                    lens,
                    0,
                )
            };
            let mut ins = vec![
                x.into(),
                ln1_v,
                wq_v,
                wk_v,
                wv_v,
                k_val.into(),
                v_val.into(),
                Value::I32(lens, vec![b]),
            ];
            ins.extend(epi_vals);
            let run_res = exe.run(&ins); // y, k_new, v_new
            // recover the staged caches whether or not the run succeeded:
            // B=1 returns them to the session (zero-copy round trip),
            // B>1 recycles the scratch packing
            let kc_back = std::mem::replace(&mut ins[5], Value::i32_scalar(0));
            let vc_back = std::mem::replace(&mut ins[6], Value::i32_scalar(0));
            if b == 1 {
                if let (Value::F32(kt), Value::F32(vt)) = (kc_back, vc_back) {
                    if let LayerState::Std { k, v, .. } = &mut sessions[0].states[li] {
                        *k = kt.reshape(&[cap1, hh, dh]);
                        *v = vt.reshape(&[cap1, hh, dh]);
                    }
                }
            } else {
                if let Value::F32(kt) = kc_back {
                    scratch::recycle(kt.into_data());
                }
                if let Value::F32(vt) = vc_back {
                    scratch::recycle(vt.into_data());
                }
            }
            let mut outs = run_res?;
            let v_new = outs.pop().unwrap();
            let k_new = outs.pop().unwrap();
            x = outs.pop().unwrap();
            for ((s, kr), vr) in sessions
                .iter_mut()
                .zip(k_new.chunk0(b))
                .zip(v_new.chunk0(b))
            {
                if let LayerState::Std { k, v, len } = &mut s.states[li] {
                    grow_kv(k, v, *len, *len + 1, ms);
                    k.data_mut()[*len * stride..(*len + 1) * stride]
                        .copy_from_slice(kr.data());
                    v.data_mut()[*len * stride..(*len + 1) * stride]
                        .copy_from_slice(vr.data());
                    *len += 1;
                }
            }
        }
    }

    let logits = if let Some(qr) = &model.readout {
        // opt-in reduced-precision readout: same rmsnorm as the artifact
        // (shared fn), then the quantized `x · embᵀ` with f32 accumulation
        qr.emb.matmul_nt(&crate::runtime::native::rmsnorm(&x, &qr.final_ln))
    } else {
        let head = engine.artifact(&format!("head_dec_B{b}"))?;
        head.run1(&[
            x.into(),
            model.params.value(engine, "final_ln")?,
            model.params.value(engine, "embed")?,
        ])?
    }; // [b, vocab]
    for s in sessions.iter_mut() {
        s.pos += 1;
    }
    let vb = cfg.vocab;
    Ok(logits
        .chunk0(b)
        .into_iter()
        .map(|r| r.reshape(&[vb]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max_and_breaks_ties_low() {
        assert_eq!(argmax(&[0.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn session_starts_empty_and_snapshots_round_trip() {
        let model = Model::load("tiny", Variant::Basic, "1/2", 0).unwrap();
        let s = model.session();
        assert_eq!(s.pos(), 0);
        // hybrid LN on tiny: one linear recurrent state, one (empty) KV cache
        let cfg = model.config();
        let m_bytes =
            (cfg.n_heads * cfg.head_dim * cfg.head_dim + cfg.n_heads * cfg.head_dim) * 4;
        assert_eq!(s.state_bytes(), m_bytes);
        let snap = s.snapshot();
        let mut s2 = model.session();
        s2.restore(&snap);
        assert_eq!(s2.pos(), 0);
        assert_eq!(s2.state_bytes(), m_bytes);
    }
}
