//! Discrete-event cluster simulator: evaluates scheduler `Plan`s at the
//! paper's testbed scale (up to 128 GPUs, 4096K-token sequences), which the
//! CPU-PJRT real-execution path cannot reach.
//!
//! The cost model is an α–β (latency–bandwidth) link model plus an
//! effective-FLOPs compute model, calibrated so that LASP-2 on the paper's
//! Table-6 anchor point (16 GPUs, 16K tokens) lands near the reported
//! throughput.  We claim SHAPE fidelity (who wins, by roughly what factor,
//! where the crossovers and OOM frontier fall), not absolute numbers —
//! the substrate is a simulator, not 16 DGX-A100s (see DESIGN.md).

use crate::config::Scheduler;
use crate::coordinator::plan::{build_plan, Plan, PlanOp, SimShape};

/// Hardware model of the simulated cluster (defaults: DGX-A100 pod).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// achievable FLOP/s per device (peak x MFU)
    pub flops_per_sec: f64,
    /// collective launch latency (one NCCL kernel)
    pub alpha_collective: f64,
    /// P2P op latency (send/recv pair launch + sync) — the paper's "too
    /// many small P2P operators" penalty
    pub alpha_p2p: f64,
    /// intra-node bandwidth (NVSwitch), bytes/s per device
    pub beta_intra: f64,
    /// inter-node bandwidth (IB), bytes/s per device
    pub beta_inter: f64,
    /// devices per node (bandwidth tier boundary)
    pub devices_per_node: usize,
    /// per-device memory capacity (bytes) -> OOM frontier
    pub mem_capacity: f64,
    /// fixed per-iteration overhead: optimizer step over ~1B params, data
    /// loading, launch storm, logging — calibrated from Table 6's
    /// near-constant iteration time at short sequences (~1.6 s)
    pub fixed_overhead: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            // 312 TFLOP/s bf16 peak x ~0.42 MFU (calibrated on Table 6's
            // 64-GPU/1024K row)
            flops_per_sec: 312e12 * 0.42,
            alpha_collective: 12e-6,
            alpha_p2p: 30e-6,
            beta_intra: 250e9,
            beta_inter: 22e9,
            devices_per_node: 8,
            mem_capacity: 80e9,
            fixed_overhead: 1.55,
        }
    }
}

impl CostModel {
    /// Topology-aware effective bandwidth: in a ring/collective over W
    /// devices laid out 8-per-node, only 1/node-size of the hops cross the
    /// slow inter-node links.
    fn beta(&self, world: usize) -> f64 {
        if world <= self.devices_per_node {
            self.beta_intra
        } else {
            let f_inter = 1.0 / self.devices_per_node as f64;
            1.0 / ((1.0 - f_inter) / self.beta_intra + f_inter / self.beta_inter)
        }
    }

    fn compute_time(&self, flops: f64) -> f64 {
        flops / self.flops_per_sec
    }

    /// Ring-AllGather: single launch, (W-1) pipelined slices.
    pub fn allgather_time(&self, bytes_per_rank: f64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        self.alpha_collective
            + (world as f64 - 1.0) * bytes_per_rank / self.beta(world)
    }

    /// One explicit P2P hop (launch + transfer).
    fn p2p_time(&self, bytes: f64, world: usize) -> f64 {
        self.alpha_p2p + bytes / self.beta(world)
    }

    /// All-to-All / ReduceScatter: single launch; each rank keeps its own
    /// 1/W slice, so only (W-1)/W of the payload crosses the wire.
    pub fn a2a_time(&self, bytes_per_rank: f64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        self.alpha_collective
            + bytes_per_rank * (world as f64 - 1.0) / world as f64 / self.beta(world)
    }

    /// Elastic-recovery time estimate: failure DETECTION (a collective
    /// timeout firing), checkpoint RELOAD of params plus both Adam
    /// moments over the storage link (modeled at the inter-node
    /// bandwidth tier), and RECOMPUTE of the steps rolled back to the
    /// last snapshot.  The real counterpart is `TrainReport::recovery_ms`
    /// plus `steps_lost` x step time; this closed form is what `lasp2
    /// chaos` and the scheduler atlas quote at paper scale.
    pub fn recovery_time(
        &self,
        param_bytes: f64,
        steps_lost: usize,
        iter_time: f64,
        detect_timeout: f64,
    ) -> f64 {
        let reload = 3.0 * param_bytes / self.beta_inter;
        detect_timeout + reload + steps_lost as f64 * iter_time
    }
}

/// Result of simulating one configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    pub iter_time: f64,
    pub tokens_per_sec: f64,
    pub mem_gb: f64,
    pub oom: bool,
    pub comm_time: f64,
    pub compute_time: f64,
}

fn eval_ops(ops: &[PlanOp], cm: &CostModel, world: usize, comm: &mut f64, comp: &mut f64) -> f64 {
    let mut t = 0.0;
    for op in ops {
        match op {
            PlanOp::Compute { flops, .. } => {
                let dt = cm.compute_time(*flops);
                *comp += dt;
                t += dt;
            }
            PlanOp::AllGather { bytes_per_rank } => {
                let dt = cm.allgather_time(*bytes_per_rank, world);
                *comm += dt;
                t += dt;
            }
            PlanOp::AllToAll { bytes_per_rank }
            | PlanOp::ReduceScatter { bytes_per_rank } => {
                let dt = cm.a2a_time(*bytes_per_rank, world);
                *comm += dt;
                t += dt;
            }
            PlanOp::Grouped { group, ops } => {
                // collectives inside a mesh sub-group see the GROUP's size
                // and bandwidth tier (a row of <= 8 stays on NVSwitch)
                t += eval_ops(ops, cm, *group, comm, comp);
            }
            PlanOp::P2pHop { bytes } => {
                let dt = cm.p2p_time(*bytes, world);
                *comm += dt;
                t += dt;
            }
            PlanOp::Sequential { hops, per_hop_flops, bytes } => {
                // serialized chain across ranks: the last rank waits for
                // every hop (LASP-1's low computation parallelism)
                let dt = *hops as f64
                    * (cm.p2p_time(*bytes, world) + cm.compute_time(*per_hop_flops));
                *comm += *hops as f64 * cm.p2p_time(*bytes, world);
                *comp += *hops as f64 * cm.compute_time(*per_hop_flops);
                t += dt;
            }
            PlanOp::Overlap { a, b } => {
                let mut ca = 0.0;
                let mut pa = 0.0;
                let ta = eval_ops(a, cm, world, &mut ca, &mut pa);
                let mut cb = 0.0;
                let mut pb = 0.0;
                let tb = eval_ops(b, cm, world, &mut cb, &mut pb);
                // attribute the hidden branch's time as overlapped
                *comm += ca + cb;
                *comp += pa + pb;
                t += ta.max(tb);
            }
        }
    }
    t
}

/// Simulate one plan on the cost model.
pub fn simulate_plan(plan: &Plan, shape: &SimShape, cm: &CostModel) -> SimResult {
    let mut comm = 0.0;
    let mut comp = 0.0;
    let iter_time = cm.fixed_overhead
        + eval_ops(&plan.ops, cm, shape.world, &mut comm, &mut comp);
    let tokens = shape.batch * shape.seq_len();
    SimResult {
        iter_time,
        tokens_per_sec: tokens / iter_time,
        mem_gb: plan.mem_bytes / 1e9,
        oom: plan.mem_bytes > cm.mem_capacity,
        comm_time: comm,
        compute_time: comp,
    }
}

/// Convenience: build + simulate.
pub fn simulate(
    shape: &SimShape,
    sched: Scheduler,
    gather_splits: usize,
    cm: &CostModel,
) -> SimResult {
    let plan = build_plan(shape, sched, gather_splits);
    simulate_plan(&plan, shape, cm)
}

/// ZeRO-1 data-parallel sharding model: what the in-memory training driver
/// measures at toy scale (`TrainReport::{opt_bytes_per_rank, wire_bytes}`),
/// extrapolated to paper scale on the α–β cost model.  `bench-all` prints
/// this next to the scheduler tables so the replicated-vs-sharded memory
/// and wire cost are visible at W = 64 / 2048K without running anything.
#[derive(Clone, Copy, Debug)]
pub struct ZeroShardModel {
    pub world: usize,
    pub param_elems: f64,
    /// Adam-moment bytes per rank when every rank replicates (2·P·4)
    pub opt_bytes_replicated: f64,
    /// Adam-moment bytes per rank under ZeRO-1 (2·P·4/W)
    pub opt_bytes_sharded: f64,
    /// gradient reduce-scatter + parameter all-gather wire bytes per rank
    /// per step: 2·(W-1)/W·P·4
    pub wire_bytes_per_rank: f64,
    /// α–β time for the two collectives (seconds per step)
    pub comm_time: f64,
}

/// Cost the per-step ZeRO-1 collectives for `param_elems` f32 parameters.
pub fn zero_shard(param_elems: f64, world: usize, cm: &CostModel) -> ZeroShardModel {
    let pbytes = param_elems * 4.0;
    let w = world.max(1);
    let opt_rep = 2.0 * pbytes;
    let (wire, comm_time) = if w > 1 {
        (
            2.0 * pbytes * (w as f64 - 1.0) / w as f64,
            // grads reduce-scatter over the full flat vector, then the
            // updated 1/W shards all-gather back
            cm.a2a_time(pbytes, w) + cm.allgather_time(pbytes / w as f64, w),
        )
    } else {
        (0.0, 0.0)
    };
    ZeroShardModel {
        world: w,
        param_elems,
        opt_bytes_replicated: opt_rep,
        opt_bytes_sharded: opt_rep / w as f64,
        wire_bytes_per_rank: wire,
        comm_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheduler as S;

    fn fig3_shape(seq_k: usize) -> SimShape {
        SimShape::linear_llama3_1b(64, seq_k * 1024, 1)
    }

    #[test]
    fn zero_shard_memory_and_wire_laws() {
        // ZeRO-1 at paper scale: optimizer memory per rank falls as 1/W,
        // wire bytes per rank approach (but never reach) 2·P·4.
        let cm = CostModel::default();
        let p = SimShape::linear_llama3_1b(64, 2048 * 1024, 1).param_count();
        let z1 = zero_shard(p, 1, &cm);
        let z4 = zero_shard(p, 4, &cm);
        let z64 = zero_shard(p, 64, &cm);
        // W=1 is the replicated degenerate case: no sharding, no wire
        assert_eq!(z1.opt_bytes_sharded, z1.opt_bytes_replicated);
        assert_eq!(z1.wire_bytes_per_rank, 0.0);
        assert_eq!(z1.comm_time, 0.0);
        // memory: exactly 1/W of replicated
        assert!((z4.opt_bytes_sharded - z4.opt_bytes_replicated / 4.0).abs() < 1.0);
        assert!((z64.opt_bytes_sharded - z64.opt_bytes_replicated / 64.0).abs() < 1.0);
        // wire: 2·(W-1)/W·P·4, monotone in W, bounded by 2·P·4
        let cap = 2.0 * p * 4.0;
        assert!(z4.wire_bytes_per_rank < z64.wire_bytes_per_rank);
        assert!(z64.wire_bytes_per_rank < cap);
        assert!(z64.wire_bytes_per_rank > 0.98 * cap);
        // the collectives cost real time at W=64 but far less than the
        // fixed per-iteration overhead the Table-6 calibration absorbs
        assert!(z64.comm_time > 0.0);
        assert!(z64.comm_time < cm.fixed_overhead);
    }

    #[test]
    fn recovery_time_laws() {
        // detection dominates when nothing was lost; lost work dominates
        // once the snapshot interval stretches; reload scales with params.
        let cm = CostModel::default();
        let p = SimShape::linear_llama3_1b(64, 2048 * 1024, 1).param_count();
        let pb = p * 4.0;
        let iter = 1.6; // Table-6 anchor iteration time
        let t0 = cm.recovery_time(pb, 0, iter, 30.0);
        assert!(t0 >= 30.0, "detection timeout is a floor: {t0}");
        let t8 = cm.recovery_time(pb, 8, iter, 30.0);
        assert!((t8 - t0 - 8.0 * iter).abs() < 1e-9);
        // reload term alone: params + 2 Adam moments over the IB tier
        let reload = cm.recovery_time(pb, 0, iter, 0.0);
        assert!((reload - 3.0 * pb / cm.beta_inter).abs() < 1e-9);
        assert!(cm.recovery_time(2.0 * pb, 0, iter, 0.0) > reload);
    }

    #[test]
    fn lasp2_beats_lasp1_beats_ring_at_long_seq() {
        // Fig. 3's ordering at 2048K over 64 GPUs.
        let cm = CostModel::default();
        let s = fig3_shape(2048);
        let l2 = simulate(&s, S::Lasp2Overlap, 1, &cm).tokens_per_sec;
        let l1 = simulate(&s, S::Lasp1, 1, &cm).tokens_per_sec;
        let ra = simulate(&s, S::RingAttention, 1, &cm).tokens_per_sec;
        let ms = simulate(&s, S::MegatronSp, 1, &cm).tokens_per_sec;
        assert!(l2 > l1, "LASP-2 {l2} vs LASP-1 {l1}");
        assert!(l1 > ra, "LASP-1 {l1} vs Ring {ra}");
        assert!(l2 > ms, "LASP-2 {l2} vs Megatron-SP {ms}");
    }

    #[test]
    fn advantage_grows_with_seq_len() {
        // the paper: 17.8% over Ring at 512K -> 36.6% at 2048K; we assert
        // the monotone-shape claim (gap ratio grows with N).
        let cm = CostModel::default();
        let gap = |k: usize| {
            let s = fig3_shape(k);
            simulate(&s, S::Lasp2Overlap, 1, &cm).tokens_per_sec
                / simulate(&s, S::RingAttention, 1, &cm).tokens_per_sec
        };
        assert!(gap(2048) > gap(512), "{} vs {}", gap(2048), gap(512));
    }

    #[test]
    fn memory_scales_down_with_world() {
        // Fig. 4 / Table 6: same N, more GPUs -> less memory per GPU.
        let cm = CostModel::default();
        let m32 = simulate(
            &SimShape::linear_llama3_1b(32, 512 * 1024, 1), S::Lasp2, 1, &cm);
        let m128 = simulate(
            &SimShape::linear_llama3_1b(128, 512 * 1024, 1), S::Lasp2, 1, &cm);
        assert!(m128.mem_gb < m32.mem_gb);
    }

    #[test]
    fn oom_frontier_matches_table6_shape() {
        // Table 6: 512K OOMs on 16 GPUs but fits on 32; 2048K needs 128.
        let cm = CostModel::default();
        let fits = |w: usize, k: usize| {
            !simulate(&SimShape::linear_llama3_1b(w, k * 1024, 1), S::Lasp2, 1, &cm).oom
        };
        assert!(fits(16, 128));
        assert!(!fits(16, 512));
        assert!(fits(32, 512));
        assert!(!fits(64, 2048));
        assert!(fits(128, 2048));
        assert!(!fits(128, 4096)); // the paper's all-OOM row
    }

    #[test]
    fn linear_scalability_of_throughput() {
        // Fig. 4: throughput roughly doubles when both N and W double.
        let cm = CostModel::default();
        let t1 = simulate(
            &SimShape::linear_llama3_1b(32, 256 * 1024, 1), S::Lasp2, 1, &cm)
            .tokens_per_sec;
        let t2 = simulate(
            &SimShape::linear_llama3_1b(64, 512 * 1024, 1), S::Lasp2, 1, &cm)
            .tokens_per_sec;
        let ratio = t2 / t1;
        assert!(ratio > 1.6 && ratio < 2.4, "{ratio}");
    }

    #[test]
    fn split_gather_slightly_slower() {
        // Table 5: more splits -> slightly lower throughput (launch alphas).
        let cm = CostModel::default();
        let s = SimShape::linear_llama3_1b(64, 1024 * 1024, 1);
        let t1 = simulate(&s, S::Lasp2, 1, &cm).tokens_per_sec;
        let t64 = simulate(&s, S::Lasp2, 64, &cm).tokens_per_sec;
        assert!(t64 < t1);
        assert!((t1 - t64) / t1 < 0.05, "effect should be small: {t1} {t64}");
    }

    #[test]
    fn overlap_helps() {
        let cm = CostModel::default();
        let s = fig3_shape(256);
        let a = simulate(&s, S::Lasp2, 1, &cm).iter_time;
        let b = simulate(&s, S::Lasp2Overlap, 1, &cm).iter_time;
        assert!(b <= a);
    }
}
