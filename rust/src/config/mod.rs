//! Model/run configuration: presets mirroring `python/compile/model.py`,
//! hybrid-layer patterns, SP scheduler selection, and a tiny flat-text
//! config parser (`key = value` lines) for run files.

use std::collections::HashMap;
use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Linear-attention module variants (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Basic,
    Lightning,
    Retention,
    Gla,
    Based,
    Rebased,
    /// standard softmax attention (the Llama3 baseline / hybrid "N" layers)
    Softmax,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Basic => "basic",
            Variant::Lightning => "lightning",
            Variant::Retention => "retention",
            Variant::Gla => "gla",
            Variant::Based => "based",
            Variant::Rebased => "rebased",
            Variant::Softmax => "softmax",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "basic" => Variant::Basic,
            "lightning" => Variant::Lightning,
            "retention" => Variant::Retention,
            "gla" => Variant::Gla,
            "based" => Variant::Based,
            "rebased" => Variant::Rebased,
            "softmax" | "standard" => Variant::Softmax,
            _ => bail!("unknown variant {s}"),
        })
    }

    pub fn linear_variants() -> &'static [Variant] {
        &[
            Variant::Basic,
            Variant::Lightning,
            Variant::Retention,
            Variant::Gla,
            Variant::Based,
            Variant::Rebased,
        ]
    }

    /// Variants whose decay carry `a` is not identically 1.
    pub fn has_decay(&self) -> bool {
        matches!(self, Variant::Retention | Variant::Gla)
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Sequence-parallelism scheduler (paper Fig. 3 comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// this paper: single AllGather on memory states (Alg. 1/2)
    Lasp2,
    /// LASP-2 with the AllGather overlapped with intra-chunk compute
    Lasp2Overlap,
    /// LASP-1 (Sun et al., 2024a): ring-style P2P on memory states
    Lasp1,
    /// Ring Attention (Liu et al., 2023): ring over K/V chunks
    RingAttention,
    /// Megatron-SP style: gather full K/V, compute locally (no trick)
    MegatronSp,
    /// DeepSpeed-Ulysses (arXiv:2309.14509): All-to-All seq->head
    /// repartition, full attention per owned head, All-to-All back
    Ulysses,
    /// ZeCO-style (arXiv:2507.01004): the sequential state exchange fully
    /// hidden behind intra-chunk compute (pipelined P2P overlap)
    Zeco,
    /// USP-style 2D mesh (arXiv:2405.07719): LASP-2 AllGather across the
    /// full world for linear layers, Ulysses All-to-All within mesh rows
    /// plus a column AllGather for std layers
    Usp2d,
}

impl Scheduler {
    pub fn name(&self) -> &'static str {
        match self {
            Scheduler::Lasp2 => "lasp2",
            Scheduler::Lasp2Overlap => "lasp2-overlap",
            Scheduler::Lasp1 => "lasp1",
            Scheduler::RingAttention => "ring",
            Scheduler::MegatronSp => "megatron-sp",
            Scheduler::Ulysses => "ulysses",
            Scheduler::Zeco => "zeco",
            Scheduler::Usp2d => "usp2d",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lasp2" => Scheduler::Lasp2,
            "lasp2-overlap" | "lasp2_overlap" => Scheduler::Lasp2Overlap,
            "lasp1" => Scheduler::Lasp1,
            "ring" | "ring-attention" => Scheduler::RingAttention,
            "megatron-sp" | "megatron" => Scheduler::MegatronSp,
            "ulysses" | "deepspeed-ulysses" => Scheduler::Ulysses,
            "zeco" => Scheduler::Zeco,
            "usp2d" | "usp" => Scheduler::Usp2d,
            _ => bail!("unknown scheduler {s}"),
        })
    }

    pub fn all() -> &'static [Scheduler] {
        &[
            Scheduler::Lasp2,
            Scheduler::Lasp2Overlap,
            Scheduler::Lasp1,
            Scheduler::RingAttention,
            Scheduler::MegatronSp,
            Scheduler::Ulysses,
            Scheduler::Zeco,
            Scheduler::Usp2d,
        ]
    }
}

impl fmt::Display for Scheduler {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Hybrid layer pattern: which layers are linear (L) vs standard (N).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pattern(pub String);

impl Pattern {
    /// Mirrors `model.hybrid_pattern`: ratio in {0, 1/8, 1/4, 1/2, all}.
    pub fn from_ratio(n_layers: usize, ratio: &str) -> Result<Pattern> {
        let unit = match ratio {
            "0" => "L",
            "1/8" => "LLLLLLLN",
            "1/4" => "LLLN",
            "1/2" => "LN",
            "all" => "N",
            _ => bail!("unknown hybrid ratio {ratio}"),
        };
        let s: String = unit.chars().cycle().take(n_layers).collect();
        Ok(Pattern(s))
    }

    pub fn tag(ratio: &str) -> &'static str {
        match ratio {
            "0" => "pure",
            "1/8" => "h8",
            "1/4" => "h4",
            "1/2" => "h2",
            "all" => "std",
            _ => "custom",
        }
    }

    pub fn layers(&self) -> impl Iterator<Item = (usize, bool)> + '_ {
        // (layer index, is_linear)
        self.0.chars().enumerate().map(|(i, c)| (i, c == 'L'))
    }

    pub fn n_linear(&self) -> usize {
        self.0.chars().filter(|c| *c == 'L').count()
    }

    pub fn n_std(&self) -> usize {
        self.0.len() - self.n_linear()
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// Model-shape configuration, parsed from the artifact manifest so that the
/// rust side can never drift from what was compiled.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub preset: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub chunk_len: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub qk_reduced: usize,
    pub train_batch: usize,
    pub train_seq: usize,
}

impl ModelConfig {
    /// Built-in presets mirroring `python/compile/model.py::PRESETS` — the
    /// shape source of truth for the native backend, which needs no
    /// artifact manifest on disk.
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let (d_model, n_heads, n_layers, vocab, chunk_len, max_seq, qk_reduced, ffn_dim, tb, ts) =
            match name {
                "tiny" => (64, 2, 2, 256, 32, 512, 8, 128, 2, 64),
                "small" => (256, 4, 4, 512, 128, 2048, 16, 512, 4, 512),
                // ffn_mult 2.6875 -> 2064
                "medium" => (768, 12, 12, 16384, 128, 1024, 16, 2064, 1, 512),
                other => bail!("unknown preset {other} (expected tiny|small|medium)"),
            };
        Ok(ModelConfig {
            preset: name.to_string(),
            d_model,
            n_heads,
            n_layers,
            vocab,
            chunk_len,
            max_seq,
            head_dim: d_model / n_heads,
            ffn_dim,
            qk_reduced,
            train_batch: tb,
            train_seq: ts,
        })
    }

    /// SP world sizes for which gathered-KV artifacts exist (mirrors
    /// `python/compile/aot.py::cfg_sp_sizes`).
    pub fn sp_world_sizes(&self) -> &'static [usize] {
        if self.preset == "tiny" {
            &[2, 4]
        } else {
            &[4]
        }
    }

    pub fn from_fields(preset: &str, f: &HashMap<String, usize>) -> Result<Self> {
        let get = |k: &str| -> Result<usize> {
            f.get(k).copied().with_context(|| format!("manifest missing field {k}"))
        };
        Ok(ModelConfig {
            preset: preset.to_string(),
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            n_layers: get("n_layers")?,
            vocab: get("vocab")?,
            chunk_len: get("chunk_len")?,
            max_seq: get("max_seq")?,
            head_dim: get("head_dim")?,
            ffn_dim: get("ffn_dim")?,
            qk_reduced: get("qk_reduced")?,
            train_batch: get("train_batch")?,
            train_seq: get("train_seq")?,
        })
    }

    /// Raw per-head q/k projection width for a variant (mirrors python's
    /// `qk_dim`): Based/ReBased project to the reduced dim before the
    /// feature map; everything else uses the full head dim.
    pub fn qk_dim(&self, v: Variant) -> usize {
        match v {
            Variant::Based | Variant::Rebased => self.qk_reduced,
            _ => self.head_dim,
        }
    }

    /// Feature (memory-state key) dim per variant — mirrors python.
    pub fn feat_dim(&self, v: Variant) -> usize {
        match v {
            Variant::Based => 1 + self.qk_reduced + self.qk_reduced * self.qk_reduced,
            Variant::Rebased => self.qk_reduced,
            _ => self.head_dim,
        }
    }

    /// Per-layer memory-state element count H * fk * dh (the AllGather
    /// payload size of LASP-2, independent of sequence length — §3.4).
    pub fn state_elems(&self, v: Variant) -> usize {
        self.n_heads * self.feat_dim(v) * self.head_dim
    }
}

/// Runtime options for a distributed run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub world: usize,
    pub scheduler: Scheduler,
    pub variant: Variant,
    pub pattern: Pattern,
    /// AllGather split count (Table 5 ablation); 1 = one collective.
    pub gather_splits: usize,
    /// Mesh column count for the `usp2d` scheduler (the Ulysses/All-to-All
    /// dimension); must divide `world`.  Ignored by flat schedulers.
    pub usp_cols: usize,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            world: 4,
            scheduler: Scheduler::Lasp2,
            variant: Variant::Basic,
            pattern: Pattern("LL".into()),
            gather_splits: 1,
            usp_cols: 2,
            seed: 0,
        }
    }
}

/// Tiny `key = value` / `key value` flat config file parser (std-only).
pub fn parse_kv_file(path: &Path) -> Result<HashMap<String, String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(parse_kv(&text))
}

pub fn parse_kv(text: &str) -> HashMap<String, String> {
    let mut map = HashMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = match line.split_once('=') {
            Some((k, v)) => (k, v),
            None => match line.split_once(' ') {
                Some((k, v)) => (k, v),
                None => continue,
            },
        };
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_ratios() {
        assert_eq!(Pattern::from_ratio(16, "1/4").unwrap().0, "LLLN".repeat(4));
        assert_eq!(Pattern::from_ratio(16, "0").unwrap().0, "L".repeat(16));
        assert_eq!(Pattern::from_ratio(2, "1/2").unwrap().0, "LN");
        assert_eq!(Pattern::from_ratio(16, "1/8").unwrap().n_std(), 2);
        assert!(Pattern::from_ratio(4, "2/3").is_err());
    }

    #[test]
    fn variant_roundtrip() {
        for v in Variant::linear_variants() {
            assert_eq!(Variant::parse(v.name()).unwrap(), *v);
        }
        assert_eq!(Variant::parse("standard").unwrap(), Variant::Softmax);
    }

    #[test]
    fn scheduler_roundtrip() {
        for s in Scheduler::all() {
            assert_eq!(Scheduler::parse(s.name()).unwrap(), *s);
        }
    }

    #[test]
    fn kv_parser() {
        let m = parse_kv("a = 1\n# comment\nb 2\nbad-line\nc = x y # t\n");
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "2");
        assert_eq!(m["c"], "x y");
        assert!(!m.contains_key("bad-line"));
    }

    #[test]
    fn builtin_presets_match_python() {
        let t = ModelConfig::preset("tiny").unwrap();
        assert_eq!(
            (t.d_model, t.n_heads, t.n_layers, t.vocab, t.chunk_len),
            (64, 2, 2, 256, 32)
        );
        assert_eq!((t.head_dim, t.ffn_dim, t.max_seq), (32, 128, 512));
        assert_eq!(t.sp_world_sizes(), &[2, 4]);
        let s = ModelConfig::preset("small").unwrap();
        assert_eq!((s.head_dim, s.ffn_dim), (64, 512));
        assert_eq!(s.sp_world_sizes(), &[4]);
        let m = ModelConfig::preset("medium").unwrap();
        assert_eq!(m.ffn_dim, 2064); // 768 * 2.6875
        assert!(ModelConfig::preset("huge").is_err());
    }

    #[test]
    fn feat_dims() {
        let mut f = HashMap::new();
        for (k, v) in [
            ("d_model", 64usize), ("n_heads", 2), ("n_layers", 2),
            ("vocab", 256), ("chunk_len", 32), ("max_seq", 512),
            ("head_dim", 32), ("ffn_dim", 128), ("qk_reduced", 8),
            ("train_batch", 2), ("train_seq", 64),
        ] {
            f.insert(k.to_string(), v);
        }
        let cfg = ModelConfig::from_fields("tiny", &f).unwrap();
        assert_eq!(cfg.feat_dim(Variant::Basic), 32);
        assert_eq!(cfg.feat_dim(Variant::Based), 1 + 8 + 64);
        assert_eq!(cfg.feat_dim(Variant::Rebased), 8);
        assert_eq!(cfg.state_elems(Variant::Basic), 2 * 32 * 32);
    }
}
