//! Schedule plans: a declarative description of the per-iteration
//! communication/computation timeline of each SP scheduler.
//!
//! The SAME plan structures drive (a) the §3.4 closed-form communication
//! accounting (steps + traffic, asserted in tests against the paper's
//! formulas) and (b) the discrete-event cost simulator (`crate::sim`) that
//! extrapolates to the paper's testbed scale (64-128 GPUs, up to 4096K
//! tokens) for Figs. 3/4 and Table 6.

use crate::config::Scheduler;

/// One step of a rank's SPMD timeline.
#[derive(Clone, Debug)]
pub enum PlanOp {
    /// Local compute: `flops` floating-point ops on this rank.
    Compute { name: &'static str, flops: f64 },
    /// Synchronizing collective; every rank contributes `bytes_per_rank`.
    AllGather { bytes_per_rank: f64 },
    /// All-to-All repartition (Ulysses/USP): every rank keeps its own 1/W
    /// slice, so only (W-1)/W of `bytes_per_rank` crosses the wire.
    AllToAll { bytes_per_rank: f64 },
    /// ReduceScatter: sum + keep-own-slice, same (W-1)/W wire factor as
    /// All-to-All (ring schedule).
    ReduceScatter { bytes_per_rank: f64 },
    /// One pipelined ring hop (all ranks exchange concurrently).
    P2pHop { bytes: f64 },
    /// Ops executed on a sub-communicator of `group` ranks (a 2D-mesh row
    /// or column): collective sizes/latencies use `group`, not the world.
    Grouped { group: usize, ops: Vec<PlanOp> },
    /// LASP-1-style serialized chain: `hops` sequential (P2P + compute)
    /// steps that ranks must wait through one after another.
    Sequential { hops: usize, per_hop_flops: f64, bytes: f64 },
    /// Two branches executed concurrently (comm/compute overlap);
    /// wall time = max(branch times).
    Overlap { a: Vec<PlanOp>, b: Vec<PlanOp> },
}

/// A full per-iteration plan for one rank (SPMD-symmetric), plus the peak
/// per-device memory it implies.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub ops: Vec<PlanOp>,
    pub mem_bytes: f64,
}

/// Closed-form communication accounting extracted from a plan.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommAccount {
    /// number of collective launches per iteration (per rank)
    pub collective_steps: usize,
    /// number of P2P communication steps (sequential hops count once each)
    pub p2p_steps: usize,
    /// total bytes communicated per rank per iteration
    pub bytes: f64,
}

fn account_ops(ops: &[PlanOp], acc: &mut CommAccount, world: usize) {
    for op in ops {
        match op {
            PlanOp::Compute { .. } => {}
            PlanOp::AllGather { bytes_per_rank } => {
                acc.collective_steps += 1;
                acc.bytes += bytes_per_rank * (world as f64 - 1.0);
            }
            PlanOp::AllToAll { bytes_per_rank }
            | PlanOp::ReduceScatter { bytes_per_rank } => {
                acc.collective_steps += 1;
                acc.bytes += bytes_per_rank * (world as f64 - 1.0) / world as f64;
            }
            PlanOp::Grouped { group, ops } => {
                account_ops(ops, acc, *group);
            }
            PlanOp::P2pHop { bytes } => {
                acc.p2p_steps += 1;
                acc.bytes += bytes;
            }
            PlanOp::Sequential { hops, bytes, .. } => {
                acc.p2p_steps += hops;
                acc.bytes += bytes * *hops as f64;
            }
            PlanOp::Overlap { a, b } => {
                account_ops(a, acc, world);
                account_ops(b, acc, world);
            }
        }
    }
}

impl Plan {
    /// Extract the paper's §3.4 closed-form accounting (collective
    /// launches, P2P steps, bytes on wire per rank) from this plan.
    pub fn account(&self, world: usize) -> CommAccount {
        let mut acc = CommAccount::default();
        account_ops(&self.ops, &mut acc, world);
        acc
    }
}

/// Model/workload dimensions for plan construction (paper-scale values go
/// straight in here — no artifacts involved).
#[derive(Clone, Copy, Debug)]
pub struct SimShape {
    pub d_model: f64,
    pub n_heads: f64,
    pub head_dim: f64,
    /// memory-state feature dim (== head_dim except Based/ReBased)
    pub feat_dim: f64,
    pub ffn_dim: f64,
    pub n_linear_layers: f64,
    pub n_std_layers: f64,
    pub batch: f64,
    pub world: usize,
    /// chunk length per device; N = world * chunk
    pub chunk: f64,
    /// USP-2D mesh column count (the row/All-to-All dimension); only the
    /// `usp2d` scheduler reads it
    pub usp_cols: usize,
}

impl SimShape {
    /// Linear-Llama3-1B (paper Sec. 4): 16 layers, d=2048, 16 heads.
    pub fn linear_llama3_1b(world: usize, seq_len: usize, batch: usize) -> SimShape {
        SimShape {
            d_model: 2048.0,
            n_heads: 16.0,
            head_dim: 128.0,
            feat_dim: 128.0,
            ffn_dim: 5504.0,
            n_linear_layers: 16.0,
            n_std_layers: 0.0,
            batch: batch as f64,
            world,
            chunk: seq_len as f64 / world as f64,
            // keep mesh rows intra-node-sized by default (8 GPUs/node)
            usp_cols: 8.min(world),
        }
    }

    /// Convert `ratio_num` of the layers to standard attention (the
    /// LASP-2H hybrid pattern, e.g. 0.25 for the paper's 1/4 ratio).
    pub fn with_hybrid(mut self, ratio_num: f64) -> SimShape {
        let total = self.n_linear_layers + self.n_std_layers;
        let std = (total * ratio_num).round();
        self.n_std_layers = std;
        self.n_linear_layers = total - std;
        self
    }

    /// Total sequence length N = W * C.
    pub fn seq_len(&self) -> f64 {
        self.chunk * self.world as f64
    }

    /// Paper §3.4: the memory-state AllGather payload per rank, BHd² * 4
    /// bytes (f32) — independent of sequence length.
    pub fn state_bytes(&self) -> f64 {
        self.batch * self.n_heads * self.feat_dim * self.head_dim * 4.0
    }

    /// K/V bytes per rank (what Ring Attention / Megatron-SP move).
    pub fn kv_bytes(&self) -> f64 {
        self.batch * self.chunk * self.n_heads * (self.feat_dim + self.head_dim) * 4.0
    }

    /// Folded q~/k~/v bytes per rank (the Ulysses forward All-to-All
    /// payload for a linear layer).
    pub fn qkv_bytes(&self) -> f64 {
        self.batch * self.chunk * self.n_heads * (2.0 * self.feat_dim + self.head_dim) * 4.0
    }

    /// Parameter count of the model (for the memory model).
    pub fn param_count(&self) -> f64 {
        let l = self.n_linear_layers + self.n_std_layers;
        let attn = 4.0 * self.d_model * self.n_heads * self.head_dim;
        let mlp = 3.0 * self.d_model * self.ffn_dim;
        l * (attn + mlp) + 2.0 * 32000.0 * self.d_model
    }

    // ---- per-layer FLOP terms (per rank, forward) ----
    /// On-device kernels tile the chunk into KERNEL_BLOCK-sized tiles
    /// (Lightning-Attention-style), so intra-chunk cost is LINEAR in C
    /// with a small quadratic block factor — matching the paper's Triton
    /// kernels (and our Pallas kernels' BlockSpec).
    pub const KERNEL_BLOCK: f64 = 256.0;

    fn f_qkv(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.d_model
            * self.n_heads * (2.0 * self.feat_dim + self.head_dim)
    }

    fn f_state(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.n_heads * self.feat_dim * self.head_dim
    }

    fn f_intra(&self) -> f64 {
        2.0 * self.batch * self.chunk * Self::KERNEL_BLOCK * self.n_heads
            * (self.feat_dim + self.head_dim)
    }

    fn f_inter(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.n_heads * self.feat_dim * self.head_dim
    }

    /// LM head + embedding (once per iteration, vocab-sized matmul).
    fn f_head(&self) -> f64 {
        2.0 * self.batch * self.chunk * 32000.0 * self.d_model
    }

    fn f_epilogue(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.n_heads * self.head_dim * self.d_model
            + 6.0 * self.batch * self.chunk * self.d_model * self.ffn_dim
    }

    /// full-sequence left-product attention (no right-product trick)
    fn f_full_attn(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.seq_len() * self.n_heads
            * (self.feat_dim + self.head_dim)
    }

    fn f_std_attn_full(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.seq_len() * self.n_heads
            * self.head_dim * 2.0
    }

    fn f_std_attn_block(&self) -> f64 {
        2.0 * self.batch * self.chunk * self.chunk * self.n_heads
            * self.head_dim * 2.0
    }

    // ---- memory terms (bytes per device) ----
    // Calibrated against Table 6's anchor cells: the 1B model's static
    // footprint is ~25.6 GB (fp32 master params + grads + Adam moments +
    // fp16 copies ≈ 25 B/param) and activation memory grows ~2.2 MB per
    // token per device (full saved activations, no selective recompute).
    fn mem_weights(&self) -> f64 {
        self.param_count() * 25.3
    }

    fn mem_activations_per_layer(&self) -> f64 {
        // x, q~, k~, v, attn-out, MLP intermediates + workspace (~3x f16)
        self.batch * self.chunk
            * (2.0 * self.d_model
                + self.n_heads * (2.0 * self.feat_dim + 2.0 * self.head_dim)
                + 2.0 * self.ffn_dim)
            * 2.0
            * 3.0
    }
}

/// Build the per-iteration (forward + backward) plan for one scheduler.
/// `masked` = causal LM training (the paper's experimental setting).
pub fn build_plan(shape: &SimShape, sched: Scheduler, gather_splits: usize) -> Plan {
    let s = shape;
    let w = s.world;
    let mut ops: Vec<PlanOp> = Vec::new();
    let state = s.state_bytes();
    let bwd = 2.0; // backward ~ 2x forward flops

    // ---------- linear layers ----------
    let lin = s.n_linear_layers;
    if lin > 0.0 {
        let part1 = PlanOp::Compute { name: "part1", flops: s.f_qkv() + s.f_state() };
        let epi = PlanOp::Compute { name: "epilogue", flops: s.f_epilogue() };
        match sched {
            // USP-2D runs plain full-world LASP-2 on linear layers (its 2D
            // split only changes the std path)
            Scheduler::Lasp2 | Scheduler::Lasp2Overlap | Scheduler::Usp2d => {
                let intra = PlanOp::Compute {
                    name: "intra",
                    flops: s.f_intra() + s.f_inter(),
                };
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    let gathers: Vec<PlanOp> = (0..gather_splits)
                        .map(|_| PlanOp::AllGather {
                            bytes_per_rank: state / gather_splits as f64,
                        })
                        .collect();
                    if sched == Scheduler::Lasp2Overlap {
                        // Alg. 2: AllGather overlaps with O_intra
                        ops.push(PlanOp::Overlap { a: gathers, b: vec![intra.clone()] });
                    } else {
                        ops.extend(gathers);
                        ops.push(intra.clone());
                    }
                    ops.push(epi.clone());
                    // backward: one AllGather on dM + ~2x compute
                    ops.push(PlanOp::AllGather { bytes_per_rank: state });
                    ops.push(PlanOp::Compute {
                        name: "bwd",
                        flops: bwd * (s.f_qkv() + s.f_state() + s.f_intra()
                            + s.f_inter() + s.f_epilogue()),
                    });
                }
            }
            Scheduler::Lasp1 => {
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    ops.push(PlanOp::Compute { name: "intra", flops: s.f_intra() });
                    // the serialized ring: W-1 hops of (send M, inter-update)
                    ops.push(PlanOp::Sequential {
                        hops: w - 1,
                        per_hop_flops: s.f_inter() + s.f_state() / s.chunk,
                        bytes: state,
                    });
                    ops.push(PlanOp::Compute { name: "inter", flops: s.f_inter() });
                    ops.push(epi.clone());
                    // backward: reverse serialized ring on dM
                    ops.push(PlanOp::Sequential {
                        hops: w - 1,
                        per_hop_flops: s.f_inter(),
                        bytes: state,
                    });
                    ops.push(PlanOp::Compute {
                        name: "bwd",
                        flops: bwd * (s.f_qkv() + s.f_state() + s.f_intra()
                            + s.f_inter() + s.f_epilogue()),
                    });
                }
            }
            Scheduler::RingAttention => {
                // Ring Attention keeps its KV-block ring (comm volume grows
                // with C, unlike LASP's states) with per-hop launch costs;
                // each hop's block compute uses the block kernels and
                // overlaps with the next hop's transfer (its design).
                let hop_flops = (s.f_intra() + s.f_state() + s.f_inter()) / w as f64;
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    for _ in 0..w - 1 {
                        ops.push(PlanOp::Overlap {
                            a: vec![PlanOp::P2pHop { bytes: s.kv_bytes() }],
                            b: vec![PlanOp::Compute { name: "ring-blk", flops: hop_flops }],
                        });
                    }
                    ops.push(PlanOp::Compute { name: "ring-blk", flops: hop_flops });
                    ops.push(epi.clone());
                    // backward mirrors the ring
                    for _ in 0..w - 1 {
                        ops.push(PlanOp::Overlap {
                            a: vec![PlanOp::P2pHop { bytes: s.kv_bytes() }],
                            b: vec![PlanOp::Compute {
                                name: "ring-blk-bwd",
                                flops: bwd * hop_flops,
                            }],
                        });
                    }
                    ops.push(PlanOp::Compute {
                        name: "bwd-rest",
                        flops: bwd * (s.f_qkv() + s.f_epilogue() + hop_flops),
                    });
                }
            }
            Scheduler::MegatronSp => {
                // gathers full K/V along the sequence (O(N) bytes) and
                // computes gathered attention locally WITHOUT the
                // right-product trick (paper Sec. 4.1) — genuinely
                // quadratic compute, which is why it collapses at long N.
                let attn = s.f_full_attn();
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    ops.push(PlanOp::AllGather { bytes_per_rank: s.kv_bytes() });
                    ops.push(PlanOp::Compute { name: "full-attn", flops: attn });
                    ops.push(epi.clone());
                    ops.push(PlanOp::AllGather { bytes_per_rank: s.kv_bytes() });
                    ops.push(PlanOp::Compute {
                        name: "bwd",
                        flops: bwd * (s.f_qkv() + attn + s.f_epilogue()),
                    });
                }
            }
            Scheduler::Ulysses => {
                // seq->head All-to-All, full-depth chunkwise scan over the
                // owned heads, All-to-All back.  Parallelism is capped by
                // the head count: past W = H some ranks idle while loaded
                // ranks run W/H times the per-head work (the Ulysses
                // degree-of-parallelism ceiling).
                let imb = (w as f64 / s.n_heads).max(1.0);
                let a2a_fwd = s.qkv_bytes() + state;
                let a2a_back = s.batch * s.chunk * s.n_heads * s.head_dim * 4.0;
                let scan = PlanOp::Compute {
                    name: "ulysses-scan",
                    flops: (s.f_intra() + s.f_inter()) * imb,
                };
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    ops.push(PlanOp::AllToAll { bytes_per_rank: a2a_fwd });
                    ops.push(scan.clone());
                    ops.push(PlanOp::AllToAll { bytes_per_rank: a2a_back });
                    ops.push(epi.clone());
                    // backward repartitions gradients the same two ways
                    ops.push(PlanOp::AllToAll { bytes_per_rank: a2a_back });
                    ops.push(PlanOp::AllToAll { bytes_per_rank: a2a_fwd });
                    ops.push(PlanOp::Compute {
                        name: "bwd",
                        flops: bwd
                            * (s.f_qkv()
                                + s.f_state()
                                + (s.f_intra() + s.f_inter()) * imb
                                + s.f_epilogue()),
                    });
                }
            }
            Scheduler::Zeco => {
                // LASP-1's relay chain, but fully hidden behind O_intra:
                // the (W-1)-hop state pipeline rides a helper stream while
                // every rank computes its intra block (ZeCO's zero
                // communication overhead — when intra is long enough).
                let relay = PlanOp::Sequential {
                    hops: w - 1,
                    per_hop_flops: s.f_state() / s.chunk,
                    bytes: state,
                };
                for _ in 0..lin as usize {
                    ops.push(part1.clone());
                    ops.push(PlanOp::Overlap {
                        a: vec![relay.clone()],
                        b: vec![PlanOp::Compute { name: "intra", flops: s.f_intra() }],
                    });
                    ops.push(PlanOp::Compute { name: "inter", flops: s.f_inter() });
                    ops.push(epi.clone());
                    // backward: reverse relay overlapped with the chunk grad
                    ops.push(PlanOp::Overlap {
                        a: vec![relay.clone()],
                        b: vec![PlanOp::Compute {
                            name: "bwd",
                            flops: bwd
                                * (s.f_qkv() + s.f_state() + s.f_intra()
                                    + s.f_inter() + s.f_epilogue()),
                        }],
                    });
                }
            }
        }
    }

    // ---------- standard layers (hybrid "N", LASP-2H: Alg. 7) ----------
    let std_l = s.n_std_layers;
    if std_l > 0.0 {
        let kv = s.batch * s.chunk * s.n_heads * s.head_dim * 2.0 * 4.0;
        // USP mesh factorization W = R rows x U cols (row = All-to-All dim)
        let u = s.usp_cols.clamp(1, w);
        let r = (w / u).max(1);
        for _ in 0..std_l as usize {
            ops.push(PlanOp::Compute { name: "s_part1", flops: s.f_qkv() });
            // attention flops per scheduler (head imbalance caps Ulysses)
            let attn_flops = match sched {
                Scheduler::Ulysses => {
                    s.f_std_attn_full() * (w as f64 / s.n_heads).max(1.0)
                }
                Scheduler::Usp2d => {
                    s.f_std_attn_full() * (u as f64 / s.n_heads).max(1.0)
                }
                _ => s.f_std_attn_full(),
            };
            match sched {
                Scheduler::RingAttention => {
                    for _ in 0..w - 1 {
                        ops.push(PlanOp::Overlap {
                            a: vec![PlanOp::P2pHop { bytes: kv }],
                            b: vec![PlanOp::Compute {
                                name: "flash-blk",
                                flops: s.f_std_attn_block(),
                            }],
                        });
                    }
                    ops.push(PlanOp::Compute {
                        name: "flash-blk",
                        flops: s.f_std_attn_block(),
                    });
                }
                Scheduler::Ulysses => {
                    // seq->head on q/k/v, full attention, head->seq on out
                    ops.push(PlanOp::AllToAll { bytes_per_rank: 1.5 * kv });
                    ops.push(PlanOp::Compute { name: "ulysses-attn", flops: attn_flops });
                    ops.push(PlanOp::AllToAll { bytes_per_rank: 0.5 * kv });
                }
                Scheduler::Usp2d => {
                    // row All-to-All (U ranks, intra-node at U <= 8), then a
                    // column AllGather over only R = W/U ranks — the USP
                    // saving vs a full-world (W-1)-factor gather
                    ops.push(PlanOp::Grouped {
                        group: u,
                        ops: vec![PlanOp::AllToAll { bytes_per_rank: 1.5 * kv }],
                    });
                    ops.push(PlanOp::Grouped {
                        group: r,
                        ops: vec![PlanOp::AllGather { bytes_per_rank: kv }],
                    });
                    ops.push(PlanOp::Compute { name: "usp-attn", flops: attn_flops });
                    ops.push(PlanOp::Grouped {
                        group: u,
                        ops: vec![PlanOp::AllToAll { bytes_per_rank: 0.5 * kv }],
                    });
                }
                _ => {
                    ops.push(PlanOp::AllGather { bytes_per_rank: kv });
                    ops.push(PlanOp::Compute { name: "flash", flops: attn_flops });
                }
            }
            ops.push(PlanOp::Compute { name: "epilogue", flops: s.f_epilogue() });
            // backward: comm mirrors the forward repartition
            match sched {
                Scheduler::Ulysses => {
                    ops.push(PlanOp::AllToAll { bytes_per_rank: 0.5 * kv });
                    ops.push(PlanOp::AllToAll { bytes_per_rank: 1.5 * kv });
                }
                Scheduler::Usp2d => {
                    ops.push(PlanOp::Grouped {
                        group: u,
                        ops: vec![PlanOp::AllToAll { bytes_per_rank: 0.5 * kv }],
                    });
                    ops.push(PlanOp::Grouped {
                        group: r,
                        ops: vec![PlanOp::AllGather { bytes_per_rank: kv }],
                    });
                    ops.push(PlanOp::Grouped {
                        group: u,
                        ops: vec![PlanOp::AllToAll { bytes_per_rank: 1.5 * kv }],
                    });
                }
                _ => ops.push(PlanOp::AllGather { bytes_per_rank: kv }),
            }
            ops.push(PlanOp::Compute {
                name: "bwd",
                flops: bwd * (s.f_qkv() + attn_flops + s.f_epilogue()),
            });
        }
    }

    // ---------- embedding + LM head (once per iteration) ----------
    ops.push(PlanOp::Compute { name: "embed+head", flops: 3.0 * s.f_head() });

    // ---------- memory model ----------
    let layers = lin + std_l;
    let mut mem = s.mem_weights() + layers * s.mem_activations_per_layer();
    match sched {
        Scheduler::Lasp2 | Scheduler::Lasp2Overlap | Scheduler::Lasp1 | Scheduler::Zeco => {
            // cached M_{1:t} per linear layer ("HBM cache" note, Sec. 3.1)
            mem += lin * s.state_bytes() * (w as f64).min(2.0);
        }
        Scheduler::MegatronSp => {
            // gathered K/V for the layer being computed (peak, transient)
            mem += s.kv_bytes() * w as f64 * 2.0;
        }
        Scheduler::RingAttention => {
            mem += 3.0 * s.kv_bytes();
        }
        Scheduler::Ulysses => {
            // state cache plus the repartitioned full-sequence activations
            // for the owned heads (transient; grows as W/H past W = H)
            let imb = (w as f64 / s.n_heads).max(1.0);
            let kvb = s.batch * s.chunk * s.n_heads * s.head_dim * 2.0 * 4.0;
            mem += lin * s.state_bytes() * (w as f64).min(2.0);
            mem += lin.min(1.0) * s.qkv_bytes() * imb;
            mem += std_l.min(1.0) * 1.5 * kvb * imb;
        }
        Scheduler::Usp2d => {
            // linear path is LASP-2; std path holds the column-gathered
            // full-sequence K/V for the owned heads (R x the row segment)
            let u = s.usp_cols.clamp(1, w);
            let r = (w / u).max(1);
            let kvb = s.batch * s.chunk * s.n_heads * s.head_dim * 2.0 * 4.0;
            mem += lin * s.state_bytes() * (w as f64).min(2.0);
            mem += std_l.min(1.0) * kvb * r as f64;
        }
    }
    Plan { ops, mem_bytes: mem }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(w: usize) -> SimShape {
        SimShape::linear_llama3_1b(w, w * 8192, 1)
    }

    #[test]
    fn lasp2_comm_steps_match_paper() {
        // §3.4: LASP-2 has 2 communication steps per iteration per layer
        // (1 AllGather fwd on M, 1 AllGather bwd on dM).
        let p = build_plan(&shape(8), Scheduler::Lasp2, 1);
        let acc = p.account(8);
        assert_eq!(acc.collective_steps as f64, 2.0 * 16.0);
        assert_eq!(acc.p2p_steps, 0);
    }

    #[test]
    fn lasp1_comm_steps_match_paper() {
        // §3.4: LASP-1 has 2(W-1) sequential P2P steps per iteration.
        let w = 8;
        let p = build_plan(&shape(w), Scheduler::Lasp1, 1);
        let acc = p.account(w);
        assert_eq!(acc.p2p_steps, 2 * (w - 1) * 16);
        assert_eq!(acc.collective_steps, 0);
    }

    #[test]
    fn traffic_ratio_matches_w_minus_1() {
        // §3.4: per-layer traffic LASP-1 : LASP-2 — both move the BHd²
        // state; LASP-1 moves it 2(W-1) times, LASP-2's ring-allgather
        // moves 2(W-1) slices too, so BYTES match; the step count differs.
        let w = 16;
        let s = shape(w);
        let l1 = build_plan(&s, Scheduler::Lasp1, 1).account(w);
        let l2 = build_plan(&s, Scheduler::Lasp2, 1).account(w);
        assert!((l1.bytes - l2.bytes).abs() / l2.bytes < 1e-9);
        assert_eq!(l1.p2p_steps, 2 * (w - 1) * 16);
        assert_eq!(l2.collective_steps, 2 * 16);
    }

    #[test]
    fn state_bytes_independent_of_seq_len() {
        let a = SimShape::linear_llama3_1b(8, 64 * 1024, 1);
        let b = SimShape::linear_llama3_1b(8, 2048 * 1024, 1);
        assert_eq!(a.state_bytes(), b.state_bytes());
        assert!(b.kv_bytes() > a.kv_bytes());
    }

    #[test]
    fn paper_state_size_example() {
        // §3.4: Linear-Llama3-1B with B=16, H=16, d=2048 -> BHd² ≈ 1.07e9
        // elements (the paper's 2.14 GB in FP16).
        let s = SimShape {
            d_model: 2048.0,
            n_heads: 16.0,
            head_dim: 2048.0, // the paper's d here is the full model dim
            feat_dim: 2048.0,
            ffn_dim: 5504.0,
            n_linear_layers: 16.0,
            n_std_layers: 0.0,
            batch: 16.0,
            world: 64,
            chunk: 1024.0,
            usp_cols: 8,
        };
        let elems = s.state_bytes() / 4.0;
        assert!((elems - 1.07e9).abs() / 1.07e9 < 0.01, "{elems}");
    }

    #[test]
    fn hybrid_split() {
        let s = shape(8).with_hybrid(0.25);
        assert_eq!(s.n_std_layers, 4.0);
        assert_eq!(s.n_linear_layers, 12.0);
        let p = build_plan(&s, Scheduler::Lasp2, 1);
        // hybrid keeps collectives: 2 per linear layer + 2 per std layer
        assert_eq!(p.account(8).collective_steps, 2 * 12 + 2 * 4);
    }

    #[test]
    fn split_gather_multiplies_launches() {
        let p1 = build_plan(&shape(8), Scheduler::Lasp2, 1).account(8);
        let p4 = build_plan(&shape(8), Scheduler::Lasp2, 4).account(8);
        // fwd gather split into 4, bwd kept at 1 -> 5 per layer
        assert_eq!(p4.collective_steps, 16 * 5);
        assert!((p4.bytes - p1.bytes).abs() / p1.bytes < 1e-9);
    }
}
