//! Layer-3 coordinator: the paper's system contribution.
//!
//! * `params`     — model parameter store (order mirrors the artifacts)
//! * `schedulers` — LASP-2 / LASP-2(overlap) / LASP-1 / Ring Attention /
//!                  Megatron-SP per-layer distributed attention (Fig. 3 set)
//! * `pipeline`   — multi-layer LASP-2H forward across the SP world
//! * `plan`       — schedule descriptions consumed by the discrete-event
//!                  simulator (paper-scale extrapolation)

pub mod params;
pub mod pipeline;
pub mod plan;
pub mod schedulers;

pub use params::{param_specs, FlatLayout, Params};
pub use pipeline::{forward_distributed, forward_mono, forward_rank};
pub use schedulers::{
    lasp1_attention_backward, lasp2_attention_backward, LinearFwdCache,
};
