//! Multi-layer distributed forward: the full Linear-Llama3 pipeline driven
//! chunk-wise across the SP world (embed -> L layers -> LM head), with the
//! per-layer scheduler dispatch (LASP-2H semantics: linear layers use the
//! memory-state AllGather, standard layers the K/V AllGather — Fig. 2).

use anyhow::Result;

use crate::comm::{Communicator, World};
use crate::config::RunConfig;
use crate::runtime::{Engine, Value};
use crate::tensor::Tensor;

use super::schedulers::{self, LinearFwdCache};
use super::Params;

/// Everything one rank produces in a forward pass.
pub struct RankForward {
    pub logits: Tensor,
    /// per-LINEAR-layer forward caches (for the backward pass), layer-major
    pub caches: Vec<(usize, LinearFwdCache)>,
}

/// Run the forward pass for this rank's chunk.
///
/// `tokens` is this rank's chunk of token ids (len == chunk_len).
pub fn forward_rank(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &Params,
    tokens: &[i32],
    masked: bool,
    keep_cache: bool,
) -> Result<RankForward> {
    let m = &engine.model;
    let c = m.chunk_len;
    anyhow::ensure!(tokens.len() == c, "chunk length mismatch");
    let offset = (comm.rank() * c) as i32;

    let embed = engine.artifact("embed")?;
    let mut x = embed.run1(&[
        Value::I32(tokens.to_vec(), vec![c]),
        Value::i32_scalar(offset),
        params.value(engine, "embed")?,
        params.value(engine, "pos")?,
    ])?;

    let mut caches = Vec::new();
    for (i, is_linear) in run.pattern.layers() {
        if is_linear {
            let out = schedulers::linear_layer(
                engine, comm, run, params, i, x, masked, keep_cache,
            )?;
            x = out.y;
            if let Some(cache) = out.cache {
                caches.push((i, cache));
            }
        } else {
            x = schedulers::std_layer(engine, comm, run, params, i, x)?;
        }
    }

    let head = engine.artifact("head")?;
    let logits = head.run1(&[
        x.into(),
        params.value(engine, "final_ln")?,
        params.value(engine, "embed")?,
    ])?;
    Ok(RankForward { logits, caches })
}

/// Full distributed forward over a W-rank world; returns concatenated
/// logits [N, vocab] (gathered for verification) and per-rank walltimes.
pub fn forward_distributed(
    engine: &std::sync::Arc<Engine>,
    world: &World,
    run: &RunConfig,
    params: &Params,
    tokens: &[i32],
    masked: bool,
) -> Result<Tensor> {
    let c = engine.model.chunk_len;
    anyhow::ensure!(tokens.len() == world.size() * c, "token count != W*C");
    let results = world.run(|comm| {
        let r = comm.rank();
        forward_rank(
            engine,
            &comm,
            run,
            params,
            &tokens[r * c..(r + 1) * c],
            masked,
            false,
        )
        .map(|f| f.logits)
    });
    let mut chunks = Vec::with_capacity(results.len());
    for r in results {
        chunks.push(r?);
    }
    Ok(Tensor::cat0(&chunks))
}

/// Single-device oracle: execute the `forward_mono_*` artifact on the same
/// tokens/params.  The distributed pipeline must reproduce this (allclose).
pub fn forward_mono(
    engine: &Engine,
    artifact: &str,
    params: &Params,
    tokens: &[i32],
) -> Result<Tensor> {
    let exe = engine.artifact(artifact)?;
    let mut ins = params.flat_values(engine);
    ins.push(Value::I32(tokens.to_vec(), vec![tokens.len()]));
    exe.run1(&ins)
}
