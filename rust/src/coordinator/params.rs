//! Model parameter store.
//!
//! The flat parameter list (names, shapes, ORDER) mirrors
//! `python/compile/model.py::param_specs` exactly — the train_step /
//! init / forward_mono artifacts consume parameters positionally in this
//! order, so any drift is caught by the shape checks in `Executable::run`.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::config::{ModelConfig, Pattern, Variant};
use crate::runtime::{CachedBuffer, Engine, Value};
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Weight-initialization family for one parameter spec.
pub enum Init {
    Normal,
    Xavier,
    Ones,
    Zeros,
}

/// (name, shape, init) — one entry per parameter tensor.
pub fn param_specs(
    cfg: &ModelConfig,
    variant: Variant,
    pattern: &Pattern,
) -> Vec<(String, Vec<usize>, Init)> {
    let (d, h, dh, f) = (cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.ffn_dim);
    let rq = match variant {
        Variant::Based | Variant::Rebased => cfg.qk_reduced,
        _ => dh,
    };
    let mut specs: Vec<(String, Vec<usize>, Init)> = vec![
        ("embed".into(), vec![cfg.vocab, d], Init::Normal),
        ("pos".into(), vec![cfg.max_seq, d], Init::Normal),
        ("final_ln".into(), vec![d], Init::Ones),
    ];
    for (i, is_linear) in pattern.layers() {
        let p = format!("layer{i}");
        specs.push((format!("{p}.ln1"), vec![d], Init::Ones));
        let qk = if is_linear { h * rq } else { h * dh };
        specs.push((format!("{p}.wq"), vec![d, qk], Init::Xavier));
        specs.push((format!("{p}.wk"), vec![d, qk], Init::Xavier));
        specs.push((format!("{p}.wv"), vec![d, h * dh], Init::Xavier));
        specs.push((format!("{p}.wo"), vec![h * dh, d], Init::Xavier));
        if is_linear && variant == Variant::Gla {
            specs.push((format!("{p}.wg"), vec![d, h * rq], Init::Xavier));
        }
        if is_linear && variant == Variant::Rebased {
            specs.push((format!("{p}.gamma"), vec![rq], Init::Ones));
            specs.push((format!("{p}.beta"), vec![rq], Init::Zeros));
        }
        specs.push((format!("{p}.ln2"), vec![d], Init::Ones));
        specs.push((format!("{p}.w1"), vec![d, f], Init::Xavier));
        specs.push((format!("{p}.w3"), vec![d, f], Init::Xavier));
        specs.push((format!("{p}.w2"), vec![f, d], Init::Xavier));
    }
    specs
}

/// Flat f32 address space over a `param_specs` list: every parameter
/// tensor occupies a contiguous [offset, offset+len) range, in spec order.
/// This is the space the ZeRO-sharded optimizer shards — rank r owns rows
/// [r*S, (r+1)*S) of the zero-padded length `padded(world)`, so shard
/// boundaries may fall inside a tensor (exactly like real ZeRO-1 on a
/// flattened grad bucket).
pub struct FlatLayout {
    shapes: Vec<Vec<usize>>,
    offsets: Vec<usize>,
    total: usize,
    /// per-spec weight-decay flag (AdamW skips norm gains/biases, i.e.
    /// every Ones/Zeros-initialized spec)
    decay: Vec<bool>,
}

impl FlatLayout {
    pub fn new(specs: &[(String, Vec<usize>, Init)]) -> FlatLayout {
        let mut offsets = Vec::with_capacity(specs.len());
        let mut shapes = Vec::with_capacity(specs.len());
        let mut decay = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for (_, shape, init) in specs {
            offsets.push(off);
            off += shape.iter().product::<usize>();
            shapes.push(shape.clone());
            decay.push(!matches!(init, Init::Ones | Init::Zeros));
        }
        FlatLayout { shapes, offsets, total: off, decay }
    }

    /// Total number of parameter elements.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Length padded up to a multiple of `world` (shards must be equal).
    pub fn padded(&self, world: usize) -> usize {
        self.total.div_ceil(world.max(1)) * world.max(1)
    }

    /// Pack spec-ordered tensors into one flat vector of length `pad`
    /// (>= `total()`; the tail is zero — padding never carries signal).
    pub fn flatten(&self, tensors: &[Tensor], pad: usize) -> Vec<f32> {
        assert_eq!(tensors.len(), self.shapes.len());
        assert!(pad >= self.total);
        let mut out = vec![0.0f32; pad];
        for (i, t) in tensors.iter().enumerate() {
            debug_assert_eq!(t.shape(), self.shapes[i].as_slice());
            let off = self.offsets[i];
            out[off..off + t.len()].copy_from_slice(t.data());
        }
        out
    }

    /// Split a flat vector (length >= `total()`) back into spec-ordered
    /// tensors; padding beyond `total()` is ignored.
    pub fn unflatten(&self, flat: &[f32]) -> Vec<Tensor> {
        assert!(flat.len() >= self.total);
        self.shapes
            .iter()
            .zip(&self.offsets)
            .map(|(shape, &off)| {
                let len: usize = shape.iter().product();
                Tensor::new(shape.clone(), flat[off..off + len].to_vec())
            })
            .collect()
    }

    /// Per-element AdamW decay coefficient over `[lo, hi)` of the padded
    /// flat space: `wd` on decayed specs, 0.0 on norm gains/biases and on
    /// padding — matching `train_step_*`'s per-spec decay selection.
    pub fn decay_coeff(&self, wd: f32, lo: usize, hi: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; hi - lo];
        for (i, &off) in self.offsets.iter().enumerate() {
            if !self.decay[i] {
                continue;
            }
            let len: usize = self.shapes[i].iter().product();
            let a = off.max(lo);
            let b = (off + len).min(hi);
            for c in out.iter_mut().take(b.saturating_sub(lo)).skip(a.saturating_sub(lo)) {
                *c = wd;
            }
        }
        out
    }
}

/// A named parameter set for one (variant, pattern) model.
///
/// Parameters are constant on the forward hot path, so their XLA literals
/// are converted ONCE and cached (perf pass: cuts a host memcpy per weight
/// per artifact call); the cache is invalidated on mutation.
pub struct Params {
    pub variant: Variant,
    pub pattern: Pattern,
    names: Vec<String>,
    map: HashMap<String, Tensor>,
    lit_cache: Mutex<HashMap<String, std::sync::Arc<CachedBuffer>>>,
}

impl Clone for Params {
    fn clone(&self) -> Self {
        Params {
            variant: self.variant,
            pattern: self.pattern.clone(),
            names: self.names.clone(),
            map: self.map.clone(),
            lit_cache: Mutex::new(HashMap::new()),
        }
    }
}

impl Params {
    /// Deterministic rust-side init (for SP-vs-mono equality tests where
    /// only consistency matters, not the init law).
    pub fn randn(
        cfg: &ModelConfig,
        variant: Variant,
        pattern: &Pattern,
        seed: u64,
    ) -> Params {
        let specs = param_specs(cfg, variant, pattern);
        let mut map = HashMap::new();
        let mut names = Vec::new();
        for (i, (name, shape, init)) in specs.iter().enumerate() {
            let t = match init {
                Init::Ones => Tensor::ones(shape),
                Init::Zeros => Tensor::zeros(shape),
                Init::Normal => Tensor::randn(shape, seed + i as u64).scale(0.02),
                Init::Xavier => {
                    let fan: usize = shape.iter().sum();
                    let std = (2.0 / fan as f32).sqrt();
                    Tensor::randn(shape, seed + i as u64).scale(std)
                }
            };
            map.insert(name.clone(), t);
            names.push(name.clone());
        }
        Params {
            variant,
            pattern: pattern.clone(),
            names,
            map,
            lit_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Initialize by executing the AOT `init_<variant>_<tag>` artifact
    /// (jax.random init, identical to what the paper's training used).
    pub fn from_init_artifact(
        engine: &Engine,
        variant: Variant,
        pattern: &Pattern,
        artifact: &str,
        seed: i32,
    ) -> Result<Params> {
        let exe = engine.artifact(artifact)?;
        let outs = exe.run(&[Value::I32(vec![seed], vec![1])])?;
        let specs = param_specs(&engine.model, variant, pattern);
        anyhow::ensure!(outs.len() == specs.len(), "init arity mismatch");
        let mut map = HashMap::new();
        let mut names = Vec::new();
        for ((name, shape, _), t) in specs.iter().zip(outs) {
            anyhow::ensure!(t.shape() == shape.as_slice(), "init shape {name}");
            map.insert(name.clone(), t);
            names.push(name.clone());
        }
        Ok(Params {
            variant,
            pattern: pattern.clone(),
            names,
            map,
            lit_cache: Mutex::new(HashMap::new()),
        })
    }

    /// Parameter as a runtime Value backed by a device-resident buffer
    /// (weights are constant on the forward path; staged once).
    pub fn value(&self, engine: &Engine, name: &str) -> Result<Value> {
        if let Some(c) = self.lit_cache.lock().unwrap().get(name) {
            return Ok(Value::Buf(c.clone()));
        }
        let t = self.get(name)?;
        let c = engine.cache_buffer(t)?;
        self.lit_cache
            .lock()
            .unwrap()
            .insert(name.to_string(), c.clone());
        Ok(Value::Buf(c))
    }

    /// `value()` for the per-layer parameter `layer{i}.{name}`.
    pub fn layer_value(&self, engine: &Engine, i: usize, name: &str) -> Result<Value> {
        self.value(engine, &format!("layer{i}.{name}"))
    }

    /// Borrow a parameter tensor by name.
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("param {name}"))
    }

    /// Replace an existing parameter (invalidates its device cache).
    pub fn set(&mut self, name: &str, t: Tensor) {
        assert!(self.map.contains_key(name), "unknown param {name}");
        self.lit_cache.lock().unwrap().remove(name);
        self.map.insert(name.to_string(), t);
    }

    /// Parameter names in spec order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Number of parameters.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when the model has no parameters (never for real presets).
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Flat Value list in spec order (for mono/train artifacts), using the
    /// device-buffer cache.
    pub fn flat_values(&self, engine: &Engine) -> Vec<Value> {
        self.names
            .iter()
            .map(|n| self.value(engine, n).expect("param"))
            .collect()
    }

    /// Replace all params from a flat tensor list in spec order.
    pub fn set_flat(&mut self, flat: &[Tensor]) {
        assert_eq!(flat.len(), self.names.len());
        self.lit_cache.lock().unwrap().clear();
        for (n, t) in self.names.iter().zip(flat) {
            self.map.insert(n.clone(), t.clone());
        }
    }

    /// Total parameter count (for the ~100M check in train_e2e).
    pub fn n_elems(&self) -> usize {
        self.names.iter().map(|n| self.map[n].len()).sum()
    }

    /// Layer param accessors in the order the phase artifacts expect.
    pub fn layer(&self, i: usize, name: &str) -> Result<&Tensor> {
        self.get(&format!("layer{i}.{name}"))
    }

    /// Extra part1 inputs for the variant (`[]` | `[wg]` | `[gamma, beta]`).
    pub fn part1_extra(&self, engine: &Engine, i: usize) -> Result<Vec<Value>> {
        Ok(match self.variant {
            Variant::Gla => vec![self.layer_value(engine, i, "wg")?],
            Variant::Rebased => vec![
                self.layer_value(engine, i, "gamma")?,
                self.layer_value(engine, i, "beta")?,
            ],
            _ => vec![],
        })
    }

    /// The shared epilogue params (wo, ln2, w1, w3, w2) for layer i.
    pub fn epilogue(&self, engine: &Engine, i: usize) -> Result<Vec<Value>> {
        Ok(vec![
            self.layer_value(engine, i, "wo")?,
            self.layer_value(engine, i, "ln2")?,
            self.layer_value(engine, i, "w1")?,
            self.layer_value(engine, i, "w3")?,
            self.layer_value(engine, i, "w2")?,
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap as Map;

    fn cfg() -> ModelConfig {
        let mut f = Map::new();
        for (k, v) in [
            ("d_model", 64usize), ("n_heads", 2), ("n_layers", 2),
            ("vocab", 256), ("chunk_len", 32), ("max_seq", 512),
            ("head_dim", 32), ("ffn_dim", 128), ("qk_reduced", 8),
            ("train_batch", 2), ("train_seq", 64),
        ] {
            f.insert(k.to_string(), v);
        }
        ModelConfig::from_fields("tiny", &f).unwrap()
    }

    #[test]
    fn spec_counts() {
        let c = cfg();
        let pat = Pattern("LL".into());
        // 3 globals + 2 layers x 9
        assert_eq!(param_specs(&c, Variant::Basic, &pat).len(), 21);
        // gla adds wg per linear layer
        assert_eq!(param_specs(&c, Variant::Gla, &pat).len(), 23);
        // rebased adds gamma+beta per linear layer
        assert_eq!(param_specs(&c, Variant::Rebased, &pat).len(), 25);
        // std layers never get variant extras
        let pat2 = Pattern("LN".into());
        assert_eq!(param_specs(&c, Variant::Gla, &pat2).len(), 22);
    }

    #[test]
    fn qk_width_depends_on_variant_and_kind() {
        let c = cfg();
        let pat = Pattern("LN".into());
        let specs = param_specs(&c, Variant::Based, &pat);
        let find = |n: &str| specs.iter().find(|s| s.0 == n).unwrap().1.clone();
        assert_eq!(find("layer0.wq"), vec![64, 2 * 8]); // linear: reduced
        assert_eq!(find("layer1.wq"), vec![64, 2 * 32]); // std: full
    }

    #[test]
    fn flat_layout_roundtrip_and_padding() {
        let c = cfg();
        let pat = Pattern("LL".into());
        let specs = param_specs(&c, Variant::Basic, &pat);
        let layout = FlatLayout::new(&specs);
        let n_elems: usize = specs.iter().map(|s| s.1.iter().product::<usize>()).sum();
        assert_eq!(layout.total(), n_elems);
        // padding rounds UP to a multiple of world and never shrinks
        assert_eq!(layout.padded(1), n_elems);
        let p4 = layout.padded(4);
        assert!(p4 >= n_elems && p4 % 4 == 0 && p4 - n_elems < 4);

        let p = Params::randn(&c, Variant::Basic, &pat, 11);
        let tensors: Vec<Tensor> =
            specs.iter().map(|(n, _, _)| p.get(n).unwrap().clone()).collect();
        let flat = layout.flatten(&tensors, p4);
        assert!(flat[n_elems..].iter().all(|&x| x == 0.0), "padding must be zero");
        let back = layout.unflatten(&flat);
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn flat_decay_coeff_skips_norm_params() {
        let c = cfg();
        let pat = Pattern("LL".into());
        let specs = param_specs(&c, Variant::Rebased, &pat);
        let layout = FlatLayout::new(&specs);
        let pad = layout.padded(4);
        let full = layout.decay_coeff(0.1, 0, pad);
        // spec-by-spec: Ones/Zeros specs (ln*, gamma, beta) must be 0.0,
        // everything else 0.1 — exactly train_step_impl's selection
        let mut off = 0usize;
        for (name, shape, init) in &specs {
            let len: usize = shape.iter().product();
            let want = match init {
                Init::Ones | Init::Zeros => 0.0,
                _ => 0.1,
            };
            assert!(
                full[off..off + len].iter().all(|&x| x == want),
                "{name}: expected {want}"
            );
            off += len;
        }
        // padding gets no decay
        assert!(full[layout.total()..].iter().all(|&x| x == 0.0));
        // a shard slice agrees with the corresponding full-range slice
        let (lo, hi) = (pad / 4, pad / 2);
        assert_eq!(layout.decay_coeff(0.1, lo, hi), full[lo..hi].to_vec());
    }

    #[test]
    fn randn_params_roundtrip() {
        let c = cfg();
        let pat = Pattern("LL".into());
        let p = Params::randn(&c, Variant::Basic, &pat, 0);
        assert_eq!(p.len(), 21);
        assert!(p.get("layer1.w2").is_ok());
        assert!(p.get("nope").is_err());
        let ln = p.get("final_ln").unwrap();
        assert!(ln.allclose(&Tensor::ones(&[64]), 1e-6));
        // epilogue/part1_extra need an Engine (device staging); covered by
        // the integration tests that run against real artifacts.
    }
}
