//! The SP schedulers: per-layer distributed attention, one function per
//! method in the paper's Fig. 3 comparison, all SPMD (called on every
//! rank's thread with that rank's chunk).
//!
//! | scheduler      | comm primitive            | per-layer fwd comm steps |
//! |----------------|---------------------------|--------------------------|
//! | LASP-2         | 1 AllGather on (M_t, a_t) | 1 collective             |
//! | LASP-2 overlap | same, overlapped w/ intra | 1 collective (hidden)    |
//! | LASP-1         | ring P2P on M             | W-1 sequential hops      |
//! | Ring Attention | ring P2P on (K_t, V_t)    | W-1 hops (pipelined)     |
//! | Megatron-SP    | AllGather on (K, V)       | 1 collective, O(N) bytes |
//! | Ulysses        | All-to-All seq<->head     | 2 collectives, O(C) each |
//! | ZeCO-style     | ring P2P on M, hidden     | W-1 hops (overlapped)    |
//! | USP-2D         | row A2A + column AllGather| 3 collectives (std path) |
//!
//! See `docs/SCHEDULERS.md` — the scheduler atlas — for per-scheduler
//! bytes-on-wire formulas, the overlap story, hybrid-layer roles, and the
//! SIM crossover table (who wins at which world size / sequence length).
//!
//! All functions return the layer output chunk y_t and (for the linear
//! ones) leave behind the forward state cache needed by the backward pass
//! (m_prefix per layer — the paper's "cache M_{1:t} in HBM" note).

use anyhow::{bail, Context, Result};

use crate::comm::{CommError, Communicator};
use crate::config::{RunConfig, Scheduler, Variant};
use crate::runtime::{Engine, Value};
use crate::tensor::{prefix_states, suffix_dstates, ChunkState, Tensor};

/// Forward cache for one linear layer on one rank (backward needs it).
#[derive(Clone)]
pub struct LinearFwdCache {
    pub qt: Tensor,
    pub kt: Tensor,
    pub v: Tensor,
    pub m_prefix: Tensor,
}

/// Output of one distributed linear-attention layer.
pub struct LinearLayerOut {
    pub y: Tensor,
    pub cache: Option<LinearFwdCache>,
}

fn part1(
    engine: &Engine,
    variant: Variant,
    layer: usize,
    params: &super::Params,
    x: &Tensor,
) -> Result<(Tensor, Tensor, Tensor, Tensor, Tensor)> {
    let exe = engine.artifact(&format!("l_part1_{}", variant.name()))?;
    let mut ins: Vec<Value> = vec![
        x.clone().into(),
        params.layer_value(engine, layer, "ln1")?,
        params.layer_value(engine, layer, "wq")?,
        params.layer_value(engine, layer, "wk")?,
        params.layer_value(engine, layer, "wv")?,
    ];
    ins.extend(params.part1_extra(engine, layer)?);
    let mut o = exe.run(&ins)?;
    let a = o.pop().unwrap();
    let m = o.pop().unwrap();
    let v = o.pop().unwrap();
    let kt = o.pop().unwrap();
    let qt = o.pop().unwrap();
    Ok((qt, kt, v, m, a))
}

/// LASP-2 (Alg. 2 masked / Alg. 1 unmasked): one AllGather on the chunk
/// memory states, prefix-combine locally, fused part2.
pub fn lasp2_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    masked: bool,
    keep_cache: bool,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    let (qt, kt, v, m, a) = part1(engine, variant, layer, params, &x)?;

    // THE communication of LASP-2: a single AllGather over [M_t, a_t]
    // (size independent of sequence length — §3.4).
    let gathered = comm.all_gather_split(vec![m, a], run.gather_splits)?;
    let states: Vec<ChunkState> = gathered
        .into_iter()
        .map(|mut g| {
            let a = g.pop().unwrap();
            let m = g.pop().unwrap();
            ChunkState { m, a }
        })
        .collect();

    let (y, cache) = if masked {
        // Alg. 2 line 9: gated PrefixSum, evaluated concurrently per rank
        let (mut prefixes, _) = prefix_states(&states);
        let mp = std::mem::replace(
            &mut prefixes[comm.rank()].m,
            Tensor::zeros(&[0]),
        );
        let exe = engine.artifact(&format!("l_part2_{}", variant.name()))?;
        // clone activations only when the backward pass needs them cached
        let cache = keep_cache.then(|| LinearFwdCache {
            qt: qt.clone(),
            kt: kt.clone(),
            v: v.clone(),
            m_prefix: mp.clone(),
        });
        let mut ins: Vec<Value> = vec![
            x.into(),
            qt.into(),
            kt.into(),
            v.into(),
            mp.into(),
        ];
        ins.extend(params.epilogue(engine, layer)?);
        (exe.run1(&ins)?, cache)
    } else {
        // Alg. 1 line 7: Sum over all chunk states
        let (_, total) = prefix_states(&states);
        if variant != Variant::Basic {
            bail!("unmasked path is defined for the basic variant");
        }
        let exe = engine.artifact("l_part2nm_basic")?;
        let cache = keep_cache.then(|| LinearFwdCache {
            qt: qt.clone(),
            kt: kt.clone(),
            v: v.clone(),
            m_prefix: total.m.clone(),
        });
        let mut ins: Vec<Value> = vec![
            x.into(),
            qt.into(),
            v.into(),
            total.m.into(),
        ];
        ins.extend(params.epilogue(engine, layer)?);
        (exe.run1(&ins)?, cache)
    };
    Ok(LinearLayerOut { y, cache })
}

/// LASP-2 with communication/computation overlap: the AllGather runs on a
/// helper thread while this rank computes O_intra (Alg. 2's magenta/cyan
/// lines executed concurrently).
pub fn lasp2_overlap_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    keep_cache: bool,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    let (qt, kt, v, m, a) = part1(engine, variant, layer, params, &x)?;

    let comm2 = comm.clone();
    let splits = run.gather_splits;
    let (states, o_intra) = std::thread::scope(
        |s| -> Result<(Vec<ChunkState>, Tensor)> {
            // communication branch
            let gather = s.spawn(move || comm2.all_gather_split(vec![m, a], splits));
            // computation branch: O_intra (overlaps with the collective)
            let exe = engine.artifact(&format!("l_intra_{}", variant.name()))?;
            let o_intra = exe.run1(&[
                qt.clone().into(),
                kt.clone().into(),
                v.clone().into(),
            ])?;
            let gathered = gather.join().expect("gather thread")?;
            let states = gathered
                .into_iter()
                .map(|mut g| {
                    let a = g.pop().unwrap();
                    let m = g.pop().unwrap();
                    ChunkState { m, a }
                })
                .collect();
            Ok((states, o_intra))
        },
    )?;

    let (mut prefixes, _) = prefix_states(&states);
    let mp = std::mem::replace(&mut prefixes[comm.rank()].m, Tensor::zeros(&[0]));
    let exe = engine.artifact(&format!("l_part2b_{}", variant.name()))?;
    let cache = keep_cache.then(|| LinearFwdCache {
        qt: qt.clone(),
        kt,
        v,
        m_prefix: mp.clone(),
    });
    let mut ins: Vec<Value> = vec![
        x.into(),
        qt.into(),
        o_intra.into(),
        mp.into(),
    ];
    ins.extend(params.epilogue(engine, layer)?);
    let y = exe.run1(&ins)?;
    Ok(LinearLayerOut { y, cache })
}

/// LASP-1 (Alg. 6): intra computed in parallel, then a SEQUENTIAL ring of
/// P2P hops carrying the running memory state — the serialization LASP-2
/// removes.
pub fn lasp1_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    keep_cache: bool,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    let (qt, kt, v, m, a) = part1(engine, variant, layer, params, &x)?;
    let intra = engine.artifact(&format!("l_intra_{}", variant.name()))?;
    let o_intra = intra.run1(&[
        qt.clone().into(),
        kt.clone().into(),
        v.clone().into(),
    ])?;

    // Sequential ring (Alg. 6 lines 9-15): rank i waits for M_{1:i-1}.
    let rank = comm.rank();
    let w = comm.size();
    let m_prefix = if rank == 0 {
        Tensor::zeros(m.shape())
    } else {
        let mut msg = comm.recv(rank - 1)?;
        msg.pop().unwrap()
    };
    // O_t = O_intra + Q~ M_{1:t-1}; then forward the updated state.
    if rank + 1 < w {
        // M_{1:t} = a_t (x) M_{1:t-1} + M_t  (Eq. 9, gated)
        let own = ChunkState { m, a };
        let prev = ChunkState { m: m_prefix.clone(), a: Tensor::ones(own.a.shape()) };
        let updated = crate::tensor::state_combine(&prev, &own);
        comm.send(rank + 1, vec![updated.m])?;
    }
    let exe = engine.artifact(&format!("l_part2b_{}", variant.name()))?;
    let cache = keep_cache.then(|| LinearFwdCache {
        qt: qt.clone(),
        kt,
        v,
        m_prefix: m_prefix.clone(),
    });
    let mut ins: Vec<Value> = vec![
        x.into(),
        qt.into(),
        o_intra.into(),
        m_prefix.into(),
    ];
    ins.extend(params.epilogue(engine, layer)?);
    let y = exe.run1(&ins)?;
    Ok(LinearLayerOut { y, cache })
}

// ------------------------------------------------------------ head sharding
/// Balanced contiguous split of `hh` heads over `parts` ranks: rank j gets
/// `(start, count)` with counts differing by at most one (the first
/// `hh % parts` ranks get the extra head; trailing ranks may get zero).
/// Zero-head ranks still join every collective with zero-width tensors so
/// the SPMD communication schedule stays uniform.
pub fn head_partition(hh: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = hh / parts;
    let rem = hh % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for j in 0..parts {
        let n = base + usize::from(j < rem);
        out.push((start, n));
        start += n;
    }
    out
}

/// Slice heads `[start, start+count)` out of a `[C, H, K]` tensor (axis 1).
fn slice_heads_mid(t: &Tensor, start: usize, count: usize) -> Tensor {
    let (c, hh, k) = (t.shape()[0], t.shape()[1], t.shape()[2]);
    debug_assert!(start + count <= hh);
    let mut out = Tensor::zeros(&[c, count, k]);
    for i in 0..c {
        out.data_mut()[i * count * k..(i + 1) * count * k]
            .copy_from_slice(&t.data()[(i * hh + start) * k..(i * hh + start + count) * k]);
    }
    out
}

/// Slice heads `[start, start+count)` out of a `[H, ...]` tensor (axis 0).
fn slice_heads0(t: &Tensor, start: usize, count: usize) -> Tensor {
    let stride: usize = t.shape()[1..].iter().product();
    let mut shape = t.shape().to_vec();
    shape[0] = count;
    Tensor::new(shape, t.data()[start * stride..(start + count) * stride].to_vec())
}

/// Concatenate `[C, h_j, K]` head slices back into `[C, sum h_j, K]`
/// (inverse of `slice_heads_mid`, rank order).
fn concat_heads_mid(parts: &[Tensor]) -> Tensor {
    let c = parts[0].shape()[0];
    let k = parts[0].shape()[2];
    let hh: usize = parts.iter().map(|p| p.shape()[1]).sum();
    let mut out = Tensor::zeros(&[c, hh, k]);
    let mut off = 0;
    for p in parts {
        let ph = p.shape()[1];
        for i in 0..c {
            out.data_mut()[(i * hh + off) * k..(i * hh + off + ph) * k]
                .copy_from_slice(&p.data()[i * ph * k..(i + 1) * ph * k]);
        }
        off += ph;
    }
    out
}

/// DeepSpeed-Ulysses (arXiv:2309.14509) applied to a LINEAR layer: an
/// All-to-All repartitions the folded q~/k~/v and chunk states from
/// sequence-parallel `[C, H, fk]` to head-parallel `[W*C, hl, fk]`, each
/// rank runs the full-depth chunkwise scan (Alg. 2's intra + gated-prefix
/// inter, `l_chunk_hs_*`) over its owned heads, and a second All-to-All
/// returns the outputs to sequence layout.  Per-head math is bit-identical
/// to `lasp2_linear_layer`; wire bytes scale with C (not N) like LASP-2,
/// but two collectives instead of one and head-count-limited parallelism.
pub fn ulysses_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    masked: bool,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    if !masked {
        bail!("ulysses linear path is defined for the masked (causal) case");
    }
    let (qt, kt, v, m, a) = part1(engine, variant, layer, params, &x)?;
    let w = comm.size();
    let rank = comm.rank();
    let (c, dh) = (engine.model.chunk_len, engine.model.head_dim);
    let parts = head_partition(engine.model.n_heads, w);

    // seq -> head repartition: destination j gets our chunk's slice of its
    // owned heads (q~, k~, v per token; M_t, a_t per chunk)
    let msgs: Vec<Vec<Tensor>> = parts
        .iter()
        .map(|&(s, n)| {
            vec![
                slice_heads_mid(&qt, s, n),
                slice_heads_mid(&kt, s, n),
                slice_heads_mid(&v, s, n),
                slice_heads0(&m, s, n),
                slice_heads0(&a, s, n),
            ]
        })
        .collect();
    let recv = comm.all_to_all(msgs)?;

    let my_heads = parts[rank].1;
    let o_full = if my_heads == 0 {
        // no heads landed here; contribute zero-width chunks to the return
        Tensor::zeros(&[w * c, 0, dh])
    } else {
        let col = |i: usize| Tensor::cat0(&recv.iter().map(|g| g[i].clone()).collect::<Vec<_>>());
        let exe = engine.artifact(&format!(
            "l_chunk_hs_{}_T{w}_H{my_heads}",
            variant.name()
        ))?;
        exe.run1(&[
            col(0).into(),
            col(1).into(),
            col(2).into(),
            col(3).into(),
            col(4).into(),
        ])?
    };

    // head -> seq repartition: chunk t of the output goes back to rank t
    let back = comm.all_to_all(o_full.chunk0(w).into_iter().map(|t| vec![t]).collect())?;
    let attn = concat_heads_mid(&back.iter().map(|g| g[0].clone()).collect::<Vec<_>>());
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), attn.into()];
    ins.extend(params.epilogue(engine, layer)?);
    Ok(LinearLayerOut { y: post.run1(&ins)?, cache: None })
}

/// DeepSpeed-Ulysses on a STANDARD softmax layer: All-to-All to
/// head-parallel layout, full causal attention over the whole sequence for
/// the owned heads (`s_attn_hs_*`), All-to-All back before the head-mixing
/// output projection in `post_attn`.
pub fn ulysses_std_layer(
    engine: &Engine,
    comm: &Communicator,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<Tensor> {
    let m = &engine.model;
    let (c, dh) = (m.chunk_len, m.head_dim);
    let w = comm.size();
    let rank = comm.rank();
    let p1 = engine.artifact("s_part1")?;
    let mut o = p1.run(&[
        Value::F32(x.clone()),
        params.layer_value(engine, layer, "ln1")?,
        params.layer_value(engine, layer, "wq")?,
        params.layer_value(engine, layer, "wk")?,
        params.layer_value(engine, layer, "wv")?,
    ])?;
    let v = o.pop().unwrap();
    let k = o.pop().unwrap();
    let q = o.pop().unwrap();

    let parts = head_partition(m.n_heads, w);
    let msgs: Vec<Vec<Tensor>> = parts
        .iter()
        .map(|&(s, n)| {
            vec![
                slice_heads_mid(&q, s, n),
                slice_heads_mid(&k, s, n),
                slice_heads_mid(&v, s, n),
            ]
        })
        .collect();
    let recv = comm.all_to_all(msgs)?;

    let my_heads = parts[rank].1;
    let o_full = if my_heads == 0 {
        Tensor::zeros(&[w * c, 0, dh])
    } else {
        let col = |i: usize| Tensor::cat0(&recv.iter().map(|g| g[i].clone()).collect::<Vec<_>>());
        let n = w * c;
        let exe = engine.artifact(&format!("s_attn_hs_Q{n}_N{n}_H{my_heads}"))?;
        exe.run1(&[
            col(0).into(),
            col(1).into(),
            col(2).into(),
            Value::i32_scalar(0),
        ])?
    };

    let back = comm.all_to_all(o_full.chunk0(w).into_iter().map(|t| vec![t]).collect())?;
    let attn = concat_heads_mid(&back.iter().map(|g| g[0].clone()).collect::<Vec<_>>());
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), attn.into()];
    ins.extend(params.epilogue(engine, layer)?);
    post.run1(&ins)
}

/// ZeCO-style schedule (arXiv:2507.01004): LASP-1's sequential state relay,
/// but the P2P chain runs on a helper thread CONCURRENTLY with this rank's
/// O_intra — zero communication overhead whenever the intra-chunk compute
/// is longer than one (recv, combine, send) hop.  The relayed math is
/// identical to `lasp1_linear_layer`, so outputs match bit-for-bit.
pub fn zeco_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    keep_cache: bool,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    let (qt, kt, v, m, a) = part1(engine, variant, layer, params, &x)?;
    let rank = comm.rank();
    let w = comm.size();
    let comm2 = comm.clone();
    let (m_prefix, o_intra) = std::thread::scope(|s| -> Result<(Tensor, Tensor)> {
        // communication branch: the pipelined state relay (Alg. 6 lines
        // 9-15), off the critical path
        let scan = s.spawn(move || -> Result<Tensor, CommError> {
            let m_prefix = if rank == 0 {
                Tensor::zeros(m.shape())
            } else {
                comm2.recv(rank - 1)?.pop().unwrap()
            };
            if rank + 1 < w {
                // M_{1:t} = a_t (x) M_{1:t-1} + M_t  (Eq. 9, gated)
                let prev = ChunkState { m: m_prefix.clone(), a: Tensor::ones(a.shape()) };
                let own = ChunkState { m, a };
                let updated = crate::tensor::state_combine(&prev, &own);
                comm2.send(rank + 1, vec![updated.m])?;
            }
            Ok(m_prefix)
        });
        // computation branch: O_intra overlaps the whole relay
        let exe = engine.artifact(&format!("l_intra_{}", variant.name()))?;
        let o_intra = exe.run1(&[
            qt.clone().into(),
            kt.clone().into(),
            v.clone().into(),
        ])?;
        Ok((scan.join().expect("zeco relay thread")?, o_intra))
    })?;

    let exe = engine.artifact(&format!("l_part2b_{}", variant.name()))?;
    let cache = keep_cache.then(|| LinearFwdCache {
        qt: qt.clone(),
        kt,
        v,
        m_prefix: m_prefix.clone(),
    });
    let mut ins: Vec<Value> = vec![
        x.into(),
        qt.into(),
        o_intra.into(),
        m_prefix.into(),
    ];
    ins.extend(params.epilogue(engine, layer)?);
    let y = exe.run1(&ins)?;
    Ok(LinearLayerOut { y, cache })
}

/// Scale a [C, H, fk] tensor by a per-(head, feature) factor vector
/// (len H*fk), broadcast over the chunk axis — folds an inter-chunk decay
/// product into a locally-folded K~ chunk.
fn scale_features(t: &Tensor, f: &[f32]) -> Tensor {
    let mut out = t.clone();
    let stride = f.len();
    for (i, v) in out.data_mut().iter_mut().enumerate() {
        *v *= f[i % stride];
    }
    out
}

/// True when the baseline K/V-circulating schedulers can run this variant:
/// they reuse the basic-shaped [C, H, dh] artifacts, so the feature dim
/// must equal head_dim (everything except Based/ReBased).
fn baseline_supports(variant: Variant) -> bool {
    matches!(
        variant,
        Variant::Basic | Variant::Lightning | Variant::Retention | Variant::Gla
    )
}

/// Ring Attention applied to the linear-attention instance WITHOUT the
/// right-product trick (paper Sec. 4.1 comparison setup): K/V chunks
/// circulate the ring; each hop accumulates a masked left-product block.
/// For decay-gated variants the chunk's carry a_t circulates too and the
/// receiver folds the inter-chunk decay prod_{s<=u<rank} a_u into the
/// incoming K~ (the prefactor trick across chunk boundaries).
pub fn ring_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    if !baseline_supports(variant) {
        bail!("ring baseline needs fk == head_dim (got variant {variant})");
    }
    let (qt, kt, v, _m, a) = part1(engine, variant, layer, params, &x)?;
    let c = engine.model.chunk_len;
    let step = engine.artifact("ring_linear_step")?;
    let rank = comm.rank();
    let w = comm.size();

    let mut acc = Tensor::zeros(v.shape());
    let mut cur_k = kt;
    let mut cur_v = v;
    let mut cur_a = a;
    // F(s) = prod_{s<=u<rank} a_u for the chunk s currently held (ones for
    // non-decay variants; wrapped-around chunks s > rank are masked out by
    // the offset-causal mask, so their stale F never contributes).
    let mut fvec = vec![1.0f32; cur_a.len()];
    let mut cur_idx = rank;
    for hop in 0..w {
        let k_use = if variant.has_decay() && hop > 0 {
            scale_features(&cur_k, &fvec)
        } else {
            cur_k.clone()
        };
        acc = step.run1(&[
            qt.clone().into(),
            k_use.into(),
            cur_v.clone().into(),
            acc.into(),
            Value::i32_scalar((rank * c) as i32),
            Value::i32_scalar((cur_idx * c) as i32),
        ])?;
        if hop + 1 < w {
            // the carry a_t rides along only when decay makes it meaningful
            // (don't inflate the basic baseline's measured comm bytes)
            if variant.has_decay() {
                comm.send(comm.right(), vec![cur_k, cur_v, cur_a])?;
                let mut msg = comm.recv(comm.left())?;
                cur_a = msg.pop().unwrap();
                cur_v = msg.pop().unwrap();
                cur_k = msg.pop().unwrap();
                // F(s) = a_s * F(s+1): fold in the newly arrived carry
                for (f, av) in fvec.iter_mut().zip(cur_a.data()) {
                    *f *= av;
                }
            } else {
                comm.send(comm.right(), vec![cur_k, cur_v])?;
                let mut msg = comm.recv(comm.left())?;
                cur_v = msg.pop().unwrap();
                cur_k = msg.pop().unwrap();
            }
            cur_idx = (cur_idx + w - 1) % w;
        }
    }
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), acc.into()];
    ins.extend(params.epilogue(engine, layer)?);
    Ok(LinearLayerOut { y: post.run1(&ins)?, cache: None })
}

/// Megatron-SP style baseline: AllGather the FULL K/V along the sequence
/// (bytes grow with N) and compute the left product locally.  Decay-gated
/// variants also gather the per-chunk carries a_t and fold the inter-chunk
/// decay into the earlier K~ chunks before the local product.
pub fn megatron_linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<LinearLayerOut> {
    let variant = run.variant;
    if !baseline_supports(variant) {
        bail!("megatron-sp baseline needs fk == head_dim (got variant {variant})");
    }
    let (qt, kt, v, _m, a) = part1(engine, variant, layer, params, &x)?;
    let c = engine.model.chunk_len;
    let w = comm.size();
    let rank = comm.rank();
    // the carries ride the AllGather only for decay variants (keeps the
    // basic baseline's measured comm bytes identical to the paper setup)
    let gathered = if variant.has_decay() {
        comm.all_gather(vec![kt, v, a])?
    } else {
        comm.all_gather(vec![kt, v])?
    };
    let mut k_chunks: Vec<Tensor> = gathered.iter().map(|g| g[0].clone()).collect();
    if variant.has_decay() {
        // chunk s < rank is scaled by prod_{s<=u<rank} a_u; chunks past our
        // own are zeroed by the offset-causal mask and stay unscaled.
        let mut f = vec![1.0f32; gathered[rank][2].len()];
        for s in (0..rank).rev() {
            for (fv, av) in f.iter_mut().zip(gathered[s][2].data()) {
                *fv *= av;
            }
            k_chunks[s] = scale_features(&k_chunks[s], &f);
        }
    }
    let k_all = Tensor::cat0(&k_chunks);
    let v_all = Tensor::cat0(&gathered.iter().map(|g| g[1].clone()).collect::<Vec<_>>());
    let exe = engine.artifact(&format!("mega_attn_basic_T{w}"))?;
    let attn = exe.run1(&[
        qt.into(),
        k_all.into(),
        v_all.into(),
        Value::i32_scalar((comm.rank() * c) as i32),
    ])?;
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), attn.into()];
    ins.extend(params.epilogue(engine, layer)?);
    Ok(LinearLayerOut { y: post.run1(&ins)?, cache: None })
}

/// Dispatch one linear layer by scheduler.
pub fn linear_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
    masked: bool,
    keep_cache: bool,
) -> Result<LinearLayerOut> {
    match run.scheduler {
        Scheduler::Lasp2 => {
            lasp2_linear_layer(engine, comm, run, params, layer, x, masked, keep_cache)
        }
        Scheduler::Lasp2Overlap => {
            lasp2_overlap_linear_layer(engine, comm, run, params, layer, x, keep_cache)
        }
        Scheduler::Lasp1 => {
            lasp1_linear_layer(engine, comm, run, params, layer, x, keep_cache)
        }
        Scheduler::RingAttention => ring_linear_layer(engine, comm, run, params, layer, x),
        Scheduler::MegatronSp => megatron_linear_layer(engine, comm, run, params, layer, x),
        Scheduler::Ulysses => {
            ulysses_linear_layer(engine, comm, run, params, layer, x, masked)
        }
        Scheduler::Zeco => zeco_linear_layer(engine, comm, run, params, layer, x, keep_cache),
        // USP's 2D split only pays off on std layers; linear layers run the
        // plain full-world LASP-2 AllGather (the LASP-2H hybrid recipe)
        Scheduler::Usp2d => {
            lasp2_linear_layer(engine, comm, run, params, layer, x, masked, keep_cache)
        }
    }
}

// ---------------------------------------------------------------- standard
/// Standard-attention layer, AllGather-based context parallelism (Alg. 7):
/// the LASP-2H treatment of hybrid "N" layers (K_t, V_t gathered — C x d
/// per rank, much smaller than Q given the quadratic attention compute).
pub fn std_layer_allgather(
    engine: &Engine,
    comm: &Communicator,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<Tensor> {
    let c = engine.model.chunk_len;
    let w = comm.size();
    let p1 = engine.artifact("s_part1")?;
    let mut o = p1.run(&[
        Value::F32(x.clone()),
        params.layer_value(engine, layer, "ln1")?,
        params.layer_value(engine, layer, "wq")?,
        params.layer_value(engine, layer, "wk")?,
        params.layer_value(engine, layer, "wv")?,
    ])?;
    let v = o.pop().unwrap();
    let k = o.pop().unwrap();
    let q = o.pop().unwrap();
    let gathered = comm.all_gather(vec![k, v])?;
    let k_all = Tensor::cat0(&gathered.iter().map(|g| g[0].clone()).collect::<Vec<_>>());
    let v_all = Tensor::cat0(&gathered.iter().map(|g| g[1].clone()).collect::<Vec<_>>());
    let p2 = engine.artifact(&format!("s_part2_T{w}"))?;
    let mut ins: Vec<Value> = vec![
        x.into(),
        q.into(),
        k_all.into(),
        v_all.into(),
        Value::i32_scalar((comm.rank() * c) as i32),
    ];
    ins.extend(params.epilogue(engine, layer)?);
    p2.run1(&ins)
}

/// Standard-attention layer via Ring Attention (online-softmax ring) — the
/// baseline treatment of "N" layers under the Ring scheduler.
pub fn std_layer_ring(
    engine: &Engine,
    comm: &Communicator,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<Tensor> {
    let m = &engine.model;
    let (c, hh, dh) = (m.chunk_len, m.n_heads, m.head_dim);
    let p1 = engine.artifact("s_part1")?;
    let mut o = p1.run(&[
        Value::F32(x.clone()),
        params.layer_value(engine, layer, "ln1")?,
        params.layer_value(engine, layer, "wq")?,
        params.layer_value(engine, layer, "wk")?,
        params.layer_value(engine, layer, "wv")?,
    ])?;
    let v = o.pop().unwrap();
    let k = o.pop().unwrap();
    let q = o.pop().unwrap();

    let step = engine.artifact("ring_step")?;
    let fin = engine.artifact("ring_finalize")?;
    let rank = comm.rank();
    let w = comm.size();
    let mut mstat = Tensor::full(&[c, hh], -1e30);
    let mut lstat = Tensor::zeros(&[c, hh]);
    let mut acc = Tensor::zeros(&[c, hh, dh]);
    let mut cur_k = k;
    let mut cur_v = v;
    let mut cur_idx = rank;
    for hop in 0..w {
        let mut outs = step.run(&[
            q.clone().into(),
            cur_k.clone().into(),
            cur_v.clone().into(),
            mstat.into(),
            lstat.into(),
            acc.into(),
            Value::i32_scalar((rank * c) as i32),
            Value::i32_scalar((cur_idx * c) as i32),
        ])?;
        acc = outs.pop().unwrap();
        lstat = outs.pop().unwrap();
        mstat = outs.pop().unwrap();
        if hop + 1 < w {
            comm.send(comm.right(), vec![cur_k, cur_v])?;
            let mut msg = comm.recv(comm.left())?;
            cur_v = msg.pop().unwrap();
            cur_k = msg.pop().unwrap();
            cur_idx = (cur_idx + w - 1) % w;
        }
    }
    let attn = fin.run1(&[lstat.into(), acc.into()])?;
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), attn.into()];
    ins.extend(params.epilogue(engine, layer)?);
    post.run1(&ins)
}

/// USP-style 2D-mesh standard layer (arXiv:2405.07719): the world is an
/// R x U mesh (`World::new_mesh`); a row All-to-All repartitions the row's
/// contiguous U-chunk segment to head-parallel layout, a column AllGather
/// assembles the full-sequence K/V for the owned heads (R-1 instead of W-1
/// gather factors — the USP saving), full causal attention at the row's
/// sequence offset, then the row All-to-All back.  Linear layers of the
/// same run use plain full-world LASP-2.
pub fn usp2d_std_layer(
    engine: &Engine,
    comm: &Communicator,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<Tensor> {
    let row = comm
        .row()
        .ok_or(CommError::NoMesh { dim: "row" })
        .context("usp2d scheduler needs a mesh world (World::new_mesh / World::for_run)")?;
    let col = comm.col().ok_or(CommError::NoMesh { dim: "col" })?;
    let m = &engine.model;
    let (c, dh) = (m.chunk_len, m.head_dim);
    let u = row.size();
    let w = u * col.size();
    let row_idx = comm.rank() / u;
    let p1 = engine.artifact("s_part1")?;
    let mut o = p1.run(&[
        Value::F32(x.clone()),
        params.layer_value(engine, layer, "ln1")?,
        params.layer_value(engine, layer, "wq")?,
        params.layer_value(engine, layer, "wk")?,
        params.layer_value(engine, layer, "wv")?,
    ])?;
    let v = o.pop().unwrap();
    let k = o.pop().unwrap();
    let q = o.pop().unwrap();

    // Ulysses dimension: repartition heads within the row's segment
    let parts = head_partition(m.n_heads, u);
    let msgs: Vec<Vec<Tensor>> = parts
        .iter()
        .map(|&(s, n)| {
            vec![
                slice_heads_mid(&q, s, n),
                slice_heads_mid(&k, s, n),
                slice_heads_mid(&v, s, n),
            ]
        })
        .collect();
    let recv = row.all_to_all(msgs)?;

    // every member of a column shares row.rank(), hence the same head
    // count — zero-head columns skip the gather together (no deadlock)
    let my_heads = parts[row.rank()].1;
    let o_seg = if my_heads == 0 {
        Tensor::zeros(&[u * c, 0, dh])
    } else {
        let col_of = |i: usize| {
            Tensor::cat0(&recv.iter().map(|g| g[i].clone()).collect::<Vec<_>>())
        };
        let q_seg = col_of(0);
        // ring dimension: gather K/V across rows (full sequence, hl heads)
        let gathered = col.all_gather(vec![col_of(1), col_of(2)])?;
        let k_all =
            Tensor::cat0(&gathered.iter().map(|g| g[0].clone()).collect::<Vec<_>>());
        let v_all =
            Tensor::cat0(&gathered.iter().map(|g| g[1].clone()).collect::<Vec<_>>());
        let exe = engine.artifact(&format!(
            "s_attn_hs_Q{}_N{}_H{my_heads}",
            u * c,
            w * c
        ))?;
        exe.run1(&[
            q_seg.into(),
            k_all.into(),
            v_all.into(),
            Value::i32_scalar((row_idx * u * c) as i32),
        ])?
    };

    let back = row.all_to_all(o_seg.chunk0(u).into_iter().map(|t| vec![t]).collect())?;
    let attn = concat_heads_mid(&back.iter().map(|g| g[0].clone()).collect::<Vec<_>>());
    let post = engine.artifact("post_attn")?;
    let mut ins: Vec<Value> = vec![x.into(), attn.into()];
    ins.extend(params.epilogue(engine, layer)?);
    post.run1(&ins)
}

/// Dispatch one standard layer by scheduler (LASP-2H unifies on AllGather;
/// Ulysses/USP repartition to head parallelism instead — see the atlas).
pub fn std_layer(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    params: &super::Params,
    layer: usize,
    x: Tensor,
) -> Result<Tensor> {
    match run.scheduler {
        Scheduler::RingAttention => std_layer_ring(engine, comm, params, layer, x),
        Scheduler::Ulysses => ulysses_std_layer(engine, comm, params, layer, x),
        Scheduler::Usp2d => usp2d_std_layer(engine, comm, params, layer, x),
        _ => std_layer_allgather(engine, comm, params, layer, x),
    }
}

// ---------------------------------------------------------------- backward
/// LASP-2 distributed backward over one attention module (Alg. 3/4): one
/// AllGather on dM_t, suffix-summed locally, then the chunk gradient.
pub fn lasp2_attention_backward(
    engine: &Engine,
    comm: &Communicator,
    run: &RunConfig,
    cache: &LinearFwdCache,
    do_t: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    let bwd1 = engine.artifact("l_bwd1_basic")?;
    let dm = bwd1.run1(&[cache.qt.clone().into(), do_t.clone().into()])?;
    // the backward's single collective (Alg. 4 line 4)
    let gathered = comm.all_gather_split(vec![dm], run.gather_splits)?;
    let dms: Vec<Tensor> = gathered.into_iter().map(|mut g| g.pop().unwrap()).collect();
    let suffix = suffix_dstates(&dms);
    let bwd2 = engine.artifact("l_bwd2_basic")?;
    let outs = bwd2.run(&[
        cache.qt.clone().into(),
        cache.kt.clone().into(),
        cache.v.clone().into(),
        do_t.clone().into(),
        cache.m_prefix.clone().into(),
        suffix[comm.rank()].clone().into(),
    ])?;
    let mut it = outs.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
}

/// LASP-1 backward: the dM suffix accumulates over a reverse sequential
/// ring (2(W-1) total hops per iteration when paired with the forward).
pub fn lasp1_attention_backward(
    engine: &Engine,
    comm: &Communicator,
    cache: &LinearFwdCache,
    do_t: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    let bwd1 = engine.artifact("l_bwd1_basic")?;
    let dm = bwd1.run1(&[cache.qt.clone().into(), do_t.clone().into()])?;
    let rank = comm.rank();
    let w = comm.size();
    // reverse ring: rank i receives dM_{i+1:T} from rank i+1
    let dm_suffix = if rank == w - 1 {
        Tensor::zeros(dm.shape())
    } else {
        let mut msg = comm.recv(rank + 1)?;
        msg.pop().unwrap()
    };
    if rank > 0 {
        let mut fwd = dm_suffix.clone();
        fwd.add_assign(&dm);
        comm.send(rank - 1, vec![fwd])?;
    }
    let bwd2 = engine.artifact("l_bwd2_basic")?;
    let outs = bwd2.run(&[
        cache.qt.clone().into(),
        cache.kt.clone().into(),
        cache.v.clone().into(),
        do_t.clone().into(),
        cache.m_prefix.clone().into(),
        dm_suffix.into(),
    ])?;
    let mut it = outs.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
}
