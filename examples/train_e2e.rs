//! End-to-end training driver (the repo's required full-system proof):
//! trains a Linear-Llama3 model through the distributed driver (grad_step
//! artifact + sharded AdamW; W=1 replicated here) on the synthetic corpus,
//! and logs the loss curve to CSV.
//!
//!     cargo run --release --example train_e2e -- [preset] [steps] [variant]
//!
//! Defaults: preset=medium (~110M params, the paper-style "~100M
//! transformer trained for a few hundred steps"), steps=200,
//! variant=basic.  Any linear variant trains natively, including the
//! decay-gated ones (gla, retention).  The run is recorded in
//! EXPERIMENTS.md §End-to-end.

use lasp2::config::{Pattern, Variant};
use lasp2::runtime::Engine;
use lasp2::train::{train, TrainOpts};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("medium").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);

    let engine = match Engine::load_preset(&preset) {
        Ok(e) => e,
        Err(e) => {
            eprintln!(
                "preset '{preset}' not built ({e}); build with\n  \
                 cd python && python -m compile.aot --preset {preset}"
            );
            std::process::exit(2);
        }
    };
    let variant = match args.get(2) {
        Some(s) => Variant::parse(s)?,
        None => Variant::Basic,
    };
    let cfg = engine.model.clone();
    // linear variants train the pure-linear model; "softmax" maps to the
    // all-standard-attention baseline (its only registered tag)
    let (pattern, tag) = if variant == Variant::Softmax {
        (Pattern::from_ratio(cfg.n_layers, "all")?, "softmax_std".to_string())
    } else {
        (Pattern::from_ratio(cfg.n_layers, "0")?, format!("{}_pure", variant.name()))
    };
    let csv = format!("results/train_e2e_{preset}_{}_loss.csv", variant.name());
    println!(
        "training Linear-Llama3 ({preset}, {variant}): d={} L={} vocab={} batch={} seq={} steps={steps}",
        cfg.d_model, cfg.n_layers, cfg.vocab, cfg.train_batch, cfg.train_seq
    );
    let opts = TrainOpts {
        steps,
        peak_lr: 3e-4,
        min_lr: 1e-6,
        seed: 0,
        mlm: false,
        log_every: 10,
        csv: Some(csv.clone()),
        ..Default::default()
    };
    let rep = train(&engine, variant, &pattern, &tag, &opts)?;
    println!("\n=== end-to-end training report ===");
    println!("parameters       : {:.1}M", rep.params as f64 / 1e6);
    println!("steps            : {}", rep.steps);
    println!("initial loss     : {:.4}", rep.losses[0]);
    println!("final loss       : {:.4}", rep.final_loss);
    println!("tail loss (10%)  : {:.4}", rep.tail_loss);
    println!("throughput       : {:.0} tokens/s", rep.tokens_per_sec);
    println!("loss curve CSV   : {csv}");
    anyhow::ensure!(
        rep.tail_loss < rep.losses[0],
        "training did not reduce the loss"
    );
    Ok(())
}
