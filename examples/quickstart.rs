//! Quickstart: the two public surfaces of the crate, end to end.
//!
//!     cargo run --release --example quickstart [-- <preset> [world]]
//!
//! 1. **Serving** (`serve::Model`/`serve::Session`): load the model once,
//!    prefill a prompt through the chunked LASP-2 path, then decode
//!    autoregressively on the recurrent state — and verify the decoded
//!    logits reproduce the single-device oracle at every position.
//! 2. **Sequence parallelism** (`forward_distributed`): the same model
//!    run over W simulated devices, each linear layer doing Alg. 2:
//!    part1 -> ONE AllGather over the (M_t, a_t) memory states -> local
//!    prefix combine -> fused part2 — also verified against the oracle.
//!
//! LASP-2 is one of eight schedulers; swap `Scheduler::Lasp2` below for
//! `Ulysses`, `Zeco`, `Usp2d`, ... — docs/SCHEDULERS.md (the scheduler
//! atlas) explains what each one communicates and where it wins.

use std::time::Instant;

use lasp2::comm::World;
use lasp2::config::{RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono};
use lasp2::serve::Model;
use lasp2::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("tiny").to_string();
    let world_size: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    // ---- load once: preset shapes + params, weights staged on first use
    let model = Model::load(&preset, Variant::Basic, "0", 42)?;
    let cfg = model.config().clone();
    println!(
        "model: preset={} d_model={} heads={} layers={} chunk_len={}",
        cfg.preset, cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.chunk_len
    );

    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();

    // ---- 1. serving: prefill + decode, verified position by position
    let mut session = model.session();
    let split = n / 2;
    let t0 = Instant::now();
    let prefill_logits = session.prefill(&tokens[..split])?;
    let mut rows = vec![prefill_logits];
    for &t in &tokens[split..] {
        rows.push(session.decode(t)?.reshape(&[1, cfg.vocab]));
    }
    let dt = t0.elapsed().as_secs_f64();
    let served = Tensor::cat0(&rows);
    println!(
        "serve: prefilled {split} + decoded {} tokens in {:.1} ms (state {} bytes, constant)",
        n - split,
        dt * 1e3,
        session.state_bytes()
    );

    // ---- 2. distributed: LASP-2 over W devices
    let run = RunConfig {
        world: world_size,
        scheduler: Scheduler::Lasp2,
        variant: model.variant(),
        pattern: model.pattern().clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let world = World::new(world_size);
    let engine = model.engine();
    // warm-up instantiates the artifacts
    forward_distributed(engine, &world, &run, model.params(), &tokens, true)?;
    world.reset_counters();
    let t1 = Instant::now();
    let iters = 5;
    let mut logits = None;
    for _ in 0..iters {
        logits = Some(forward_distributed(
            engine,
            &world,
            &run,
            model.params(),
            &tokens,
            true,
        )?);
    }
    let dt = t1.elapsed().as_secs_f64() / iters as f64;
    let logits = logits.unwrap();
    let snap = world.counters();
    println!(
        "LASP-2 forward over {world_size} devices: N={n} tokens in {:.1} ms  ({:.0} tokens/s)",
        dt * 1e3,
        n as f64 / dt
    );
    println!(
        "comm per iteration: {} AllGathers, {} P2P ops, {:.1} KB moved (state-sized, N-independent)",
        snap.collective_ops / iters as u64,
        snap.p2p_ops / iters as u64,
        snap.bytes as f64 / 1e3 / iters as f64,
    );

    // ---- verify BOTH surfaces against the single-device oracle
    let mono_name = format!("forward_mono_basic_pure_N{n}");
    if engine.has_artifact(&mono_name) {
        let want = forward_mono(engine, &mono_name, model.params(), &tokens)?;
        let serve_err = served.max_rel_err(&want);
        let sp_err = logits.max_rel_err(&want);
        println!("verification vs single-device oracle:");
        println!("  serve (prefill+decode) max rel err {serve_err:.2e}");
        println!("  distributed (LASP-2)   max rel err {sp_err:.2e}");
        anyhow::ensure!(serve_err < 1e-4, "serving decode diverged from oracle");
        anyhow::ensure!(sp_err < 2e-3, "distributed forward diverged from oracle");
        println!("OK — decode == LASP-2 distributed == monolithic.");
    } else {
        println!("(oracle forward_mono artifact not built for W={world_size}; skipped)");
    }
    Ok(())
}
