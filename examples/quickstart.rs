//! Quickstart: run LASP-2 sequence-parallel inference over 4 simulated
//! devices and verify it reproduces the single-device oracle exactly.
//!
//!     make artifacts            # once (builds tiny+small HLO artifacts)
//!     cargo run --release --example quickstart [-- <preset> [world]]
//!
//! What happens:
//!  1. the PJRT runtime loads the AOT artifacts (no python involved);
//!  2. 4 worker threads each own one sequence chunk;
//!  3. every linear layer does Alg. 2: part1 -> ONE AllGather over the
//!     (M_t, a_t) memory states -> local prefix combine -> fused part2;
//!  4. the gathered logits are checked against forward_mono (allclose).

use std::time::Instant;

use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono, Params};
use lasp2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("tiny").to_string();
    let world_size: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let engine = Engine::load_preset(&preset)?;
    let cfg = engine.model.clone();
    println!(
        "model: preset={} d_model={} heads={} layers={} chunk_len={}",
        cfg.preset, cfg.d_model, cfg.n_heads, cfg.n_layers, cfg.chunk_len
    );

    let pattern = Pattern("L".repeat(cfg.n_layers));
    let run = RunConfig {
        world: world_size,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        seed: 0,
    };
    let params = Params::randn(&cfg, run.variant, &pattern, 42);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 7 + 3) % cfg.vocab as i32).collect();

    let world = World::new(world_size);
    // warm-up compiles the artifacts
    forward_distributed(&engine, &world, &run, &params, &tokens, true)?;
    world.reset_counters();

    let t0 = Instant::now();
    let iters = 5;
    let mut logits = None;
    for _ in 0..iters {
        logits = Some(forward_distributed(&engine, &world, &run, &params, &tokens, true)?);
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    let logits = logits.unwrap();
    let snap = world.counters();

    println!(
        "LASP-2 forward over {world_size} devices: N={n} tokens in {:.1} ms  ({:.0} tokens/s)",
        dt * 1e3,
        n as f64 / dt
    );
    println!(
        "comm per iteration: {} AllGathers, {} P2P ops, {:.1} KB moved (state-sized, N-independent)",
        snap.collective_ops / iters as u64,
        snap.p2p_ops / iters as u64,
        snap.bytes as f64 / 1e3 / iters as f64,
    );

    let mono_name = format!("forward_mono_basic_pure_N{n}");
    if engine.has_artifact(&mono_name) {
        let want = forward_mono(&engine, &mono_name, &params, &tokens)?;
        let err = logits.max_rel_err(&want);
        println!("verification vs single-device oracle: max rel err {err:.2e}");
        anyhow::ensure!(err < 2e-3, "distributed forward diverged from oracle");
        println!("OK — LASP-2 distributed == monolithic.");
    } else {
        println!("(oracle forward_mono artifact not built for W={world_size}; skipped)");
    }
    Ok(())
}
