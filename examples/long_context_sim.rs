//! Paper-scale long-context simulation: reproduces the SHAPE of Fig. 3
//! (speed comparison at 64 GPUs up to 2048K tokens), Fig. 4 (scalability)
//! and Table 6 (throughput + memory/GPU + OOM frontier) on the calibrated
//! discrete-event cluster model.
//!
//!     cargo run --release --example long_context_sim

use lasp2::bench;
use lasp2::sim::CostModel;

fn main() {
    let cm = CostModel::default();
    println!("cost model: {:.0} GFLOP/s/device, alpha_coll {:.0}us, alpha_p2p {:.0}us,",
        cm.flops_per_sec / 1e9, cm.alpha_collective * 1e6, cm.alpha_p2p * 1e6);
    println!("            beta intra {:.0} GB/s / inter {:.0} GB/s, {:.0} GB HBM, fixed {:.2}s/iter\n",
        cm.beta_intra / 1e9, cm.beta_inter / 1e9, cm.mem_capacity / 1e9, cm.fixed_overhead);

    println!("# Fig. 3 — tokens/s vs sequence length (64 GPUs, Linear-Llama3-1B, batch 1)\n");
    println!("{}", bench::fig3_speed(&cm).to_markdown());

    println!("# Fig. 4 — scalability frontier (LASP-2)\n");
    println!("{}", bench::fig4_scalability(&cm).to_markdown());

    println!("# Table 5 — AllGather split-size ablation (64 GPUs, 1024K)\n");
    println!("{}", bench::table5_splits(&cm).to_markdown());

    println!("# Table 6 — quantitative scalability (throughput / memory per GPU)\n");
    println!("{}", bench::table6_scalability(&cm).to_markdown());
}
