//! LASP-2H on a hybrid model (Fig. 2): linear layers AllGather their d×d
//! memory states, standard-attention layers AllGather their K/V chunks —
//! one unified collective design across the whole network.
//!
//!     cargo run --release --example hybrid -- [preset]
//!
//! Prints the per-layer-kind communication payloads (the Fig.-2 asymmetry)
//! and verifies the hybrid distributed forward against the monolithic
//! hybrid oracle.

use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, forward_mono, Params};
use lasp2::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let engine = Engine::load_preset(&preset)?;
    let cfg = engine.model.clone();
    let world_size = 4;

    // "1/2 hybrid": alternating L (linear) and N (standard) layers.
    let pattern = Pattern::from_ratio(cfg.n_layers, "1/2")?;
    println!(
        "LASP-2H hybrid: pattern {} ({} linear + {} standard layers), W={world_size}",
        pattern.0,
        pattern.n_linear(),
        pattern.n_std()
    );

    let run = RunConfig {
        world: world_size,
        scheduler: Scheduler::Lasp2,
        variant: Variant::Basic,
        pattern: pattern.clone(),
        gather_splits: 1,
        usp_cols: 2,
        seed: 0,
    };
    let params = Params::randn(&cfg, run.variant, &pattern, 33);
    let n = world_size * cfg.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| (i * 11 + 1) % cfg.vocab as i32).collect();

    let world = World::new(world_size);
    let logits = forward_distributed(&engine, &world, &run, &params, &tokens, true)?;
    let snap = world.counters();

    // Fig. 2's payload asymmetry, from first principles:
    let state_bytes = (cfg.state_elems(Variant::Basic) + cfg.n_heads * cfg.head_dim) * 4;
    let kv_bytes = 2 * cfg.chunk_len * cfg.n_heads * cfg.head_dim * 4;
    println!("\nper-rank AllGather payloads:");
    println!(
        "  linear layer  (M_t, a_t)  : {:>8} B  — independent of sequence length",
        state_bytes
    );
    println!(
        "  standard layer (K_t, V_t) : {:>8} B  — grows with chunk length C={}",
        kv_bytes, cfg.chunk_len
    );
    println!(
        "\nmeasured: {} collectives, {} P2P ops, {} B total moved",
        snap.collective_ops, snap.p2p_ops, snap.bytes
    );
    let expect = world_size * (world_size - 1)
        * (pattern.n_linear() * state_bytes + pattern.n_std() * kv_bytes);
    println!("expected from the cost model: {expect} B");
    anyhow::ensure!(snap.bytes == expect as u64, "byte accounting mismatch");

    let mono = format!("forward_mono_basic_h2_N{n}");
    if engine.has_artifact(&mono) {
        let want = forward_mono(&engine, &mono, &params, &tokens)?;
        let err = logits.max_rel_err(&want);
        println!("\nverification vs monolithic hybrid oracle: max rel err {err:.2e}");
        anyhow::ensure!(err < 2e-3);
        println!("OK — LASP-2H hybrid distributed == monolithic.");
    }
    Ok(())
}
