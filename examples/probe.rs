//! Perf-pass profiling probe (used for the EXPERIMENTS.md §Perf table).
use lasp2::comm::World;
use lasp2::config::{Pattern, RunConfig, Scheduler, Variant};
use lasp2::coordinator::{forward_distributed, Params};
use lasp2::runtime::Engine;
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let engine = Engine::load_preset("small")?;
    let m = engine.model.clone();
    let pattern = Pattern("L".repeat(m.n_layers));
    let params = Params::randn(&m, Variant::Basic, &pattern, 7);
    let n = 4 * m.chunk_len;
    let tokens: Vec<i32> = (0..n as i32).map(|i| i % m.vocab as i32).collect();
    for sched in [Scheduler::Lasp2, Scheduler::Lasp2Overlap, Scheduler::Lasp1] {
        let run = RunConfig { world: 4, scheduler: sched, variant: Variant::Basic,
            pattern: pattern.clone(), gather_splits: 1, usp_cols: 2, seed: 0 };
        let world = World::new(4);
        forward_distributed(&engine, &world, &run, &params, &tokens, true)?;
        let t0 = Instant::now();
        for _ in 0..10 { forward_distributed(&engine, &world, &run, &params, &tokens, true)?; }
        println!("{}: {:.1} ms/fwd", sched.name(), t0.elapsed().as_secs_f64() / 10.0 * 1e3);
    }
    Ok(())
}
