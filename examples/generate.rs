//! Serving demo: constant-memory autoregressive generation on the
//! LASP-2 recurrent state.
//!
//!     cargo run --release --example generate [-- <preset> [variant] [n]]
//!
//! What happens:
//!  1. `Model::load` stages the weights once (preset + init artifact);
//!  2. a `Session` prefills the prompt through the chunked LASP-2 path
//!     (one l_part1 + gated prefix combine + l_part2 per linear layer);
//!  3. `decode` then emits one token per step by updating the per-head
//!     recurrent state M <- diag(g) M + k^T v — the per-request state
//!     stays EXACTLY the same size no matter how long the sequence gets;
//!  4. `snapshot`/`restore` reuse the prefilled prompt for a second
//!     continuation without re-running prefill;
//!  5. `Batch` steps several sessions per kernel call.

use std::time::Instant;

use lasp2::config::Variant;
use lasp2::serve::{argmax, Batch, Model, Session};

/// Greedy-decode `n` tokens starting from the token chosen by `last_row`.
fn continuation(
    session: &mut Session<'_>,
    last_row: &[f32],
    n: usize,
) -> anyhow::Result<Vec<i32>> {
    if n == 0 {
        return Ok(Vec::new());
    }
    let mut next = argmax(last_row);
    let mut out = Vec::with_capacity(n);
    out.push(next);
    while out.len() < n {
        let row = session.decode(next)?;
        next = argmax(row.data());
        out.push(next);
    }
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("tiny").to_string();
    let variant = Variant::parse(args.get(1).map(|s| s.as_str()).unwrap_or("gla"))?;
    let n_tokens: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32).max(1);

    let model = Model::load(&preset, variant, "0", 0)?;
    model.warmup_serving()?;
    let cfg = model.config().clone();
    println!(
        "model: preset={} variant={} pattern={} d_model={} chunk_len={}",
        cfg.preset,
        variant,
        model.pattern().0,
        cfg.d_model,
        cfg.chunk_len
    );

    let prompt: Vec<i32> = (0..cfg.chunk_len as i32)
        .map(|i| (i * 7 + 3) % cfg.vocab as i32)
        .collect();
    let mut session = model.session();
    let t0 = Instant::now();
    let logits = session.prefill(&prompt)?;
    println!(
        "prefill: {} tokens in {:.1} ms (state {} bytes)",
        prompt.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        session.state_bytes()
    );
    let last_row = logits.data()[(prompt.len() - 1) * cfg.vocab..].to_vec();

    // prefix reuse: snapshot after the prompt, decode two continuations
    let snap = session.snapshot();
    let bytes_before = session.state_bytes();
    let t1 = Instant::now();
    let cont_a = continuation(&mut session, &last_row, n_tokens)?;
    let dt = t1.elapsed().as_secs_f64().max(1e-9);
    // the first token comes free from the prefill logits; only
    // n_tokens - 1 decode steps ran in the timed window
    println!(
        "decode: {} tokens in {:.1} ms ({:.0} tokens/s)",
        n_tokens - 1,
        dt * 1e3,
        (n_tokens - 1) as f64 / dt
    );
    println!(
        "state bytes: {} after prefill -> {} after {} more tokens{}",
        bytes_before,
        session.state_bytes(),
        n_tokens - 1,
        if session.state_bytes() == bytes_before {
            "  (CONSTANT — the recurrent state does not grow)"
        } else {
            "  (grows: std KV-cache layers present)"
        }
    );
    session.restore(&snap);
    let cont_b = continuation(&mut session, &last_row, n_tokens)?;
    anyhow::ensure!(
        cont_a == cont_b,
        "snapshot/restore must make generation deterministic"
    );
    println!("continuation (greedy): {cont_a:?}");
    println!("snapshot/restore replay: identical — prefix reuse OK");

    // batched decode: 4 sessions stepped per kernel call
    let mut batch = Batch::new(&model);
    for _ in 0..4 {
        let mut s = model.session();
        s.prefill(&prompt)?;
        batch.push(s);
    }
    let t2 = Instant::now();
    let mut toks = vec![argmax(&last_row); 4];
    for _ in 0..n_tokens {
        let rows = batch.decode(&toks)?;
        for (t, row) in toks.iter_mut().zip(&rows) {
            *t = argmax(row.data());
        }
    }
    let dt2 = t2.elapsed().as_secs_f64().max(1e-9);
    println!(
        "batched decode: 4 sessions x {} tokens in {:.1} ms ({:.0} tokens/s aggregate)",
        n_tokens,
        dt2 * 1e3,
        (4 * n_tokens) as f64 / dt2
    );
    Ok(())
}
