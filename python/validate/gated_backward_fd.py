"""Float64 finite-difference validation of the variant-aware
linear-attention backward implemented in rust/src/runtime/native.rs
seq_loss_grads.  Mirrors the Rust code operation-for-operation
(whole-sequence prefactor folding, cumprod gates, GLA gate projection,
Based/ReBased feature maps); float64 so the FD error floor is ~1e-9.

This is the provenance for DESIGN.md's "derived against a float64
prototype" claim — run it with only numpy installed:

    python3 python/validate/gated_backward_fd.py
"""
import numpy as np

rng = np.random.default_rng(0)
GATE_FLOOR = 0.95
GLA_TAU = 16.0

n, d, hh, dh, rq_red = 6, 8, 2, 4, 2   # micro shapes

def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))

def phi_based(x):
    # x: [n, hh, r] -> [n, hh, 1+r+r*r]
    r = x.shape[-1]
    out = np.empty(x.shape[:-1] + (1 + r + r * r,))
    out[..., 0] = 1.0
    out[..., 1:1 + r] = x
    out[..., 1 + r:] = (x[..., :, None] * x[..., None, :]).reshape(x.shape[:-1] + (r * r,)) / np.sqrt(2)
    return out

def phi_based_bwd(x, dphi):
    r = x.shape[-1]
    dx = dphi[..., 1:1 + r].copy()
    douter = dphi[..., 1 + r:].reshape(x.shape[:-1] + (r, r)) / np.sqrt(2)
    # phi_ab = x_a x_b / sqrt2 -> dx_a += sum_b (douter[a,b] + douter[b,a]) x_b
    dx += np.einsum('...ab,...b->...a', douter, x)
    dx += np.einsum('...ba,...b->...a', douter, x)
    return dx

def phi_rebased(x, gamma, beta):
    t = x * gamma + beta
    return t * t

def phi_rebased_bwd(x, gamma, beta, dphi):
    t = x * gamma + beta
    dt = 2.0 * t * dphi
    dx = dt * gamma
    dgamma = np.einsum('nhr,nhr->r', dt, x)
    dbeta = np.einsum('nhr->r', dt)
    return dx, dgamma, dbeta

def retention_gates(nn, fk):
    lam = np.maximum(1.0 - 2.0 ** (-(5.0 + np.arange(hh))), GATE_FLOOR)  # [hh]
    return np.broadcast_to(lam[None, :, None], (nn, hh, fk)).copy()

def gla_gates(raw):
    # raw: [n, hh*fk] -> g [n, hh, fk]
    s = sigmoid(raw)
    g = GATE_FLOOR + (1.0 - GATE_FLOOR) * s ** (1.0 / GLA_TAU)
    return g

def gla_gates_bwd(raw, dg_flat):
    # d raw from d g; dg_flat: [n, hh*fk]
    s = sigmoid(raw)
    # dg/draw = (1-floor)*(1/tau)*s^(1/tau-1) * s*(1-s) = (1-floor)/tau * s^(1/tau) * (1-s)
    return dg_flat * (1.0 - GATE_FLOOR) / GLA_TAU * s ** (1.0 / GLA_TAU) * (1.0 - s)

def forward(variant, hn, wq, wk, wv, wg, gamma, beta, masked=True, want_cache=False):
    rq = rq_red if variant in ('based', 'rebased') else dh
    qr = (hn @ wq).reshape(n, hh, rq)
    kr = (hn @ wk).reshape(n, hh, rq)
    v = (hn @ wv).reshape(n, hh, dh)
    if variant == 'based':
        q, k = phi_based(qr), phi_based(kr)
    elif variant == 'rebased':
        q, k = phi_rebased(qr, gamma, beta), phi_rebased(kr, gamma, beta)
    else:
        q, k = qr, kr
    fk = q.shape[-1]
    if variant == 'retention':
        g = retention_gates(n, fk)
    elif variant == 'gla':
        raw = hn @ wg  # [n, hh*fk]
        g = gla_gates(raw).reshape(n, hh, fk)
    else:
        g = None
    if g is not None:
        b = np.cumprod(g, axis=0)
        qt, kt = q * b, k / b
    else:
        b = None
        qt, kt = q, k
    attn = np.empty((n, hh, dh))
    tril = np.tril(np.ones((n, n)))
    for h in range(hh):
        s = qt[:, h, :] @ kt[:, h, :].T
        if masked:
            s = s * tril
        attn[:, h, :] = s @ v[:, h, :]
    if want_cache:
        return attn, dict(qr=qr, kr=kr, q=q, k=k, v=v, g=g, b=b)
    return attn

def backward(variant, hn, wq, wk, wv, wg, gamma, beta, dattn, masked=True):
    """Returns grads dict incl. dhn."""
    attn, c = forward(variant, hn, wq, wk, wv, wg, gamma, beta, masked, want_cache=True)
    q, k, v, g, b = c['q'], c['k'], c['v'], c['g'], c['b']
    fk = q.shape[-1]
    rq = rq_red if variant in ('based', 'rebased') else dh
    if b is not None:
        qt, kt = q * b, k / b
    else:
        qt, kt = q, k
    tril = np.tril(np.ones((n, n)))
    dqt = np.zeros_like(qt); dkt = np.zeros_like(kt); dv = np.zeros_like(v)
    for h in range(hh):
        doh = dattn[:, h, :]
        s = qt[:, h, :] @ kt[:, h, :].T
        if masked:
            s = s * tril
        dv[:, h, :] = s.T @ doh
        ds = doh @ v[:, h, :].T
        if masked:
            ds = ds * tril
        dqt[:, h, :] = ds @ kt[:, h, :]
        dkt[:, h, :] = ds.T @ qt[:, h, :]
    grads = {}
    if b is not None:
        dq = dqt * b
        dk = dkt / b
        if variant == 'gla':
            db = dqt * q - dk * k / b
            # cumprod backward: dg_s = (sum_{i>=s} db_i * b_i) / g_s
            dbb = db * b
            suff = np.cumsum(dbb[::-1], axis=0)[::-1]
            dg = suff / g
            draw = gla_gates_bwd(hn @ wg, dg.reshape(n, hh * fk))
            grads['wg'] = hn.T @ draw
            dhn_gate = draw @ wg.T
        else:
            dhn_gate = 0.0
    else:
        dq, dk = dqt, dkt
        dhn_gate = 0.0
    # feature map backward
    if variant == 'based':
        dqr = phi_based_bwd(c['qr'], dq)
        dkr = phi_based_bwd(c['kr'], dk)
    elif variant == 'rebased':
        dqr, dgq, dbq = phi_rebased_bwd(c['qr'], gamma, beta, dq)
        dkr, dgk, dbk = phi_rebased_bwd(c['kr'], gamma, beta, dk)
        grads['gamma'] = dgq + dgk
        grads['beta'] = dbq + dbk
    else:
        dqr, dkr = dq, dk
    dqf = dqr.reshape(n, hh * rq)
    dkf = dkr.reshape(n, hh * rq)
    dvf = dv.reshape(n, hh * dh)
    grads['wq'] = hn.T @ dqf
    grads['wk'] = hn.T @ dkf
    grads['wv'] = hn.T @ dvf
    grads['hn'] = dqf @ wq.T + dkf @ wk.T + dvf @ wv.T + dhn_gate
    return grads

def recurrent_oracle(q, k, v, g):
    # token recurrence per head: M_s = diag(g_s) M_{s-1} + k_s^T v_s; o = q_s M_s
    out = np.zeros((n, hh, dh))
    for h in range(hh):
        fk = q.shape[-1]
        M = np.zeros((fk, dh))
        for s in range(n):
            gs = g[s, h, :] if g is not None else np.ones(fk)
            M = gs[:, None] * M + np.outer(k[s, h, :], v[s, h, :])
            out[s, h, :] = q[s, h, :] @ M
    return out

def check(variant):
    rq = rq_red if variant in ('based', 'rebased') else dh
    fk = {'based': 1 + rq + rq * rq, 'rebased': rq}.get(variant, dh)
    hn = rng.standard_normal((n, d)) * 0.5
    wq = rng.standard_normal((d, hh * rq)) * 0.3
    wk = rng.standard_normal((d, hh * rq)) * 0.3
    wv = rng.standard_normal((d, hh * dh)) * 0.3
    wg = rng.standard_normal((d, hh * fk)) * 0.3
    gamma = rng.standard_normal(rq) * 0.5 + 1.0
    beta = rng.standard_normal(rq) * 0.1
    W = rng.standard_normal((n, hh, dh))
    loss = lambda **kw: np.sum(forward(variant, **{**dict(hn=hn, wq=wq, wk=wk, wv=wv, wg=wg, gamma=gamma, beta=beta), **kw}) * W)
    grads = backward(variant, hn, wq, wk, wv, wg, gamma, beta, W)
    # forward matches the token recurrence oracle
    attn, c = forward(variant, hn, wq, wk, wv, wg, gamma, beta, want_cache=True)
    want = recurrent_oracle(c['q'], c['k'], c['v'], c['g'])
    ferr = np.max(np.abs(attn - want) / (1.0 + np.abs(want)))
    assert ferr < 1e-10, (variant, ferr)
    # finite differences
    params = {'hn': hn, 'wq': wq, 'wk': wk, 'wv': wv}
    if variant == 'gla':
        params['wg'] = wg
    if variant == 'rebased':
        params['gamma'] = gamma; params['beta'] = beta
    eps = 1e-6
    for name, p in params.items():
        fd = np.zeros_like(p)
        it = np.nditer(p, flags=['multi_index'])
        for _ in it:
            idx = it.multi_index
            p0 = p[idx]
            p[idx] = p0 + eps; lp = loss(**{name: p})
            p[idx] = p0 - eps; lm = loss(**{name: p})
            p[idx] = p0
            fd[idx] = (lp - lm) / (2 * eps)
        an = grads[name]
        err = np.max(np.abs(fd - an) / (1.0 + np.abs(fd)))
        assert err < 1e-6, (variant, name, err)
        print(f"  {variant:10s} {name:6s} max rel err {err:.2e}")

def check_jax(variant):
    """Optional gold check: the hand backward vs jax.grad (machine eps)."""
    import jax
    import jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    rq = rq_red if variant in ('based', 'rebased') else dh

    def jax_forward(hn, wq, wk, wv, wg, gamma, beta):
        qr = (hn @ wq).reshape(n, hh, rq)
        kr = (hn @ wk).reshape(n, hh, rq)
        v = (hn @ wv).reshape(n, hh, dh)
        if variant == 'based':
            def phi(x):
                r = x.shape[-1]
                return jnp.concatenate([
                    jnp.ones(x.shape[:-1] + (1,)), x,
                    (x[..., :, None] * x[..., None, :]).reshape(x.shape[:-1] + (r * r,))
                    / jnp.sqrt(2.0)], -1)
            q, k = phi(qr), phi(kr)
        elif variant == 'rebased':
            q, k = (qr * gamma + beta) ** 2, (kr * gamma + beta) ** 2
        else:
            q, k = qr, kr
        fk = q.shape[-1]
        if variant == 'retention':
            lam = jnp.maximum(1.0 - 2.0 ** (-(5.0 + jnp.arange(hh))), GATE_FLOOR)
            g = jnp.broadcast_to(lam[None, :, None], (n, hh, fk))
        elif variant == 'gla':
            g = (GATE_FLOOR + (1 - GATE_FLOOR)
                 * jax.nn.sigmoid(hn @ wg) ** (1 / GLA_TAU)).reshape(n, hh, fk)
        else:
            g = None
        if g is not None:
            b = jnp.cumprod(g, axis=0)
            qt, kt = q * b, k / b
        else:
            qt, kt = q, k
        tril = jnp.tril(jnp.ones((n, n)))
        return jnp.stack([((qt[:, h] @ kt[:, h].T) * tril) @ v[:, h] for h in range(hh)], 1)

    fk = {'based': 1 + rq + rq * rq, 'rebased': rq}.get(variant, dh)
    hn = rng.standard_normal((n, d)) * 0.5
    wq = rng.standard_normal((d, hh * rq)) * 0.3
    wk = rng.standard_normal((d, hh * rq)) * 0.3
    wv = rng.standard_normal((d, hh * dh)) * 0.3
    wg = rng.standard_normal((d, hh * fk)) * 0.3
    gamma = rng.standard_normal(rq) * 0.5 + 1.0
    beta = rng.standard_normal(rq) * 0.1
    W = rng.standard_normal((n, hh, dh))
    loss = lambda *a: jnp.sum(jax_forward(*a) * W)
    jg = jax.grad(loss, argnums=tuple(range(7)))(hn, wq, wk, wv, wg, gamma, beta)
    mine = backward(variant, hn, wq, wk, wv, wg, gamma, beta, W)
    for nm, jgrad in zip(['hn', 'wq', 'wk', 'wv', 'wg', 'gamma', 'beta'], jg):
        if nm not in mine:
            continue
        err = np.max(np.abs(np.asarray(jgrad) - mine[nm]) / (1 + np.abs(np.asarray(jgrad))))
        assert err < 1e-12, (variant, nm, err)
        print(f"  {variant:10s} {nm:6s} vs jax.grad  max rel err {err:.2e}")


if __name__ == '__main__':
    for v in ['basic', 'lightning', 'retention', 'gla', 'based', 'rebased']:
        check(v)
    try:
        import jax  # noqa: F401
        for v in ['basic', 'lightning', 'retention', 'gla', 'based', 'rebased']:
            check_jax(v)
        print("jax.grad cross-check OK")
    except ImportError:
        print("(jax not installed; skipped the jax.grad cross-check)")
    print("ALL OK")
