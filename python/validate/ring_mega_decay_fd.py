"""Float64 validation of the gated ring / megatron-SP chunk math in
rust/src/coordinator/schedulers.rs: local prefactor folding per chunk +
the inter-chunk decay product F(s) = prod_{s<=u<rank} a_u folded into
incoming K~ chunks must equal the token-level gated recurrence.

    python3 python/validate/ring_mega_decay_fd.py
"""
import numpy as np

rng = np.random.default_rng(3)
W, C, fk, dv = 4, 5, 3, 4
n = W * C

q = rng.standard_normal((n, fk))
k = rng.standard_normal((n, fk))
v = rng.standard_normal((n, dv))
g = 0.95 + 0.05 * rng.random((n, fk))  # gates in (0.95, 1)

# oracle: token recurrence
M = np.zeros((fk, dv)); want = np.zeros((n, dv))
for s in range(n):
    M = g[s][:, None] * M + np.outer(k[s], v[s])
    want[s] = q[s] @ M

# per-chunk local folding (fold_gates)
qt = np.zeros_like(q); kt = np.zeros_like(k); a = np.zeros((W, fk))
for t in range(W):
    sl = slice(t * C, (t + 1) * C)
    B = np.cumprod(g[sl], axis=0)
    qt[sl] = q[sl] * B
    kt[sl] = k[sl] / B
    a[t] = B[-1]

# ring/megatron accumulation for each rank r: sum over chunks s<=r of
# (qt_r (F(s)*kt_s)^T . mask) v_s with F(s) = prod_{u=s}^{r-1} a_u
got = np.zeros((n, dv))
for r in range(W):
    acc = np.zeros((C, dv))
    F = np.ones(fk)
    # process own chunk then walk backwards (ring order), folding carries
    for s in range(r, -1, -1):
        ks = kt[s * C:(s + 1) * C] * (F if s < r else 1.0)
        S = qt[r * C:(r + 1) * C] @ ks.T
        mask = np.ones((C, C)) if s < r else np.tril(np.ones((C, C)))
        acc += (S * mask) @ v[s * C:(s + 1) * C]
        if s > 0:
            F = F * a[s - 1] if s - 1 < r else F  # next incoming chunk s-1: F(s-1)=a_{s-1}*F(s)
    got[r * C:(r + 1) * C] = acc
err = np.max(np.abs(got - want) / (1 + np.abs(want)))
print("ring/mega gated vs recurrence:", err)
assert err < 1e-10
print("OK")
