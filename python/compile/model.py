"""Layer-2: Linear-Llama3 in JAX (build-time only; lowered to HLO artifacts).

The paper's evaluation model is "Linear-Llama3": Llama3 with standard
softmax attention replaced by a linear-attention module (basic / Lightning /
Retention / GLA / Based / ReBased), optionally keeping every 4th layer as
standard attention (the "1/4 hybrid").  This file defines:

  * per-chunk PHASE functions — the units the rust coordinator executes per
    device between collectives:
      linear_part1  : X_t -> Q~_t, K~_t, V_t, M_t, a_t     (Alg. 2 lines 5-6)
      linear_part2  : ... M_{1:t-1} -> Y_t                 (Alg. 2 lines 8-11
                                                            + O-proj + MLP)
      linear_bwd1/2 : Alg. 4 chunk backward phases
      std_part1/2   : Alg. 7 (AllGather-based context parallelism)
      mega_attn     : Megatron-SP-style gathered left-product baseline
      ring_step     : Ring Attention per-hop online-softmax update
  * MONOLITHIC functions — single-device oracle forward and the Adam
    `train_step` used for the convergence experiments (Tables 2, 3, 4).

Design notes:
  * All linear variants are expressed through per-token decay gates g and
    the prefactor trick (see kernels/ref.py): q~ = q*B, k~ = k/B.  The
    cross-chunk state combine is the monoid
        (a1, m1) . (a2, m2) = (a1*a2, diag(a2) m1 + m2)
    which the rust coordinator evaluates after its AllGather, and which
    `associative_scan` evaluates here in the monolithic oracle.
  * No RoPE (substitution, documented in DESIGN.md): positional information
    comes from learned absolute position embeddings, which keeps the linear
    and standard branches consistent.
  * Gates are floored (g = floor + (1-floor)*sigmoid) so that the in-chunk
    cumprod stays well inside f32 range for C <= 512.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .kernels import linear_attn as ka
from .kernels import lightning as kl
from .kernels import softmax_attn as ks
from .kernels import features as kf
from .kernels import ref as kref

LINEAR_VARIANTS = ("basic", "lightning", "retention", "gla", "based",
                   "rebased")
GATE_FLOOR = 0.95
GLA_TAU = 16.0  # gate temperature, as in GLA (Yang et al., 2023)


# =========================================================== configuration
@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    vocab: int = 256
    ffn_mult: float = 2.0
    chunk_len: int = 32           # C: SP chunk length per device
    max_seq: int = 1024           # position-embedding table size
    qk_reduced: int = 8           # reduced qk head dim for based/rebased
    train_batch: int = 2
    train_seq: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        return int(self.d_model * self.ffn_mult)

    def qk_dim(self, variant: str) -> int:
        """Raw per-head qk projection width for a variant."""
        if variant in ("based", "rebased"):
            return self.qk_reduced
        return self.head_dim

    def feat_dim(self, variant: str) -> int:
        """Memory-state key dim (feature dim) fk: M_t is [H, fk, head_dim]."""
        r = self.qk_dim(variant)
        if variant == "based":
            return kf.based_feature_dim(r)
        return r


PRESETS = {
    # tests / fast CI
    "tiny": ModelConfig(name="tiny", d_model=64, n_heads=2, n_layers=2,
                        vocab=256, chunk_len=32, max_seq=512, qk_reduced=8,
                        train_batch=2, train_seq=64),
    # examples / convergence benches
    "small": ModelConfig(name="small", d_model=256, n_heads=4, n_layers=4,
                         vocab=512, chunk_len=128, max_seq=2048,
                         qk_reduced=16, train_batch=4, train_seq=512),
    # ~100M-parameter end-to-end training driver
    "medium": ModelConfig(name="medium", d_model=768, n_heads=12,
                          n_layers=12, vocab=16384, ffn_mult=2.6875,
                          chunk_len=128, max_seq=1024, qk_reduced=16,
                          train_batch=1, train_seq=512),
}


def hybrid_pattern(n_layers: int, ratio: str) -> str:
    """Build the paper's layer pattern strings (Sec. A.5.2).

    ratio in {"0", "1/8", "1/4", "1/2", "all"}: 0 = pure linear,
    1/4 = "LLLN" repeated, all = pure standard attention (Llama3 baseline).
    """
    if ratio == "0":
        unit = "L"
    elif ratio == "1/8":
        unit = "LLLLLLLN"
    elif ratio == "1/4":
        unit = "LLLN"
    elif ratio == "1/2":
        unit = "LN"
    elif ratio == "all":
        unit = "N"
    else:
        raise ValueError(f"unknown hybrid ratio {ratio}")
    s = (unit * n_layers)[:n_layers]
    return s


# ================================================================== params
def param_specs(cfg: ModelConfig, variant: str, pattern: str):
    """Deterministic flat parameter list: [(name, shape, init)].

    init in {"normal" (0.02), "xavier", "ones", "zeros"} — the rust side
    never initializes params itself; the init_params artifact does.
    """
    d, h = cfg.d_model, cfg.n_heads
    dh = cfg.head_dim
    rq = cfg.qk_dim(variant)
    f = cfg.ffn_dim
    specs = [
        ("embed", (cfg.vocab, d), "normal"),
        ("pos", (cfg.max_seq, d), "normal"),
        ("final_ln", (d,), "ones"),
    ]
    for i, kind in enumerate(pattern):
        p = f"layer{i}"
        specs.append((f"{p}.ln1", (d,), "ones"))
        if kind == "L":
            specs.append((f"{p}.wq", (d, h * rq), "xavier"))
            specs.append((f"{p}.wk", (d, h * rq), "xavier"))
        else:
            specs.append((f"{p}.wq", (d, h * dh), "xavier"))
            specs.append((f"{p}.wk", (d, h * dh), "xavier"))
        specs.append((f"{p}.wv", (d, h * dh), "xavier"))
        specs.append((f"{p}.wo", (h * dh, d), "xavier"))
        if kind == "L" and variant == "gla":
            specs.append((f"{p}.wg", (d, h * rq), "xavier"))
        if kind == "L" and variant == "rebased":
            specs.append((f"{p}.gamma", (rq,), "ones"))
            specs.append((f"{p}.beta", (rq,), "zeros"))
        specs.append((f"{p}.ln2", (d,), "ones"))
        specs.append((f"{p}.w1", (d, f), "xavier"))
        specs.append((f"{p}.w3", (d, f), "xavier"))
        specs.append((f"{p}.w2", (f, d), "xavier"))
    return specs


def unflatten_params(cfg, variant, pattern, flat):
    specs = param_specs(cfg, variant, pattern)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {name: x for (name, _, _), x in zip(specs, flat)}


def init_params_fn(cfg: ModelConfig, variant: str, pattern: str, seed):
    """seed: i32[1] -> tuple of initialized flat params (the init artifact)."""
    key = jax.random.PRNGKey(seed[0])
    specs = param_specs(cfg, variant, pattern)
    out = []
    for name, shape, init in specs:
        key, sub = jax.random.split(key)
        if init == "ones":
            out.append(jnp.ones(shape, jnp.float32))
        elif init == "zeros":
            out.append(jnp.zeros(shape, jnp.float32))
        elif init == "normal":
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:  # xavier
            fan_in, fan_out = shape[0], shape[-1]
            std = (2.0 / (fan_in + fan_out)) ** 0.5
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


# ============================================================= primitives
def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def swiglu(x, w1, w3, w2):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def retention_lambdas(cfg: ModelConfig):
    """Per-head decay, RetNet-style: lambda_h = 1 - 2^(-5-h), floored."""
    h = jnp.arange(cfg.n_heads, dtype=jnp.float32)
    return jnp.maximum(1.0 - jnp.exp2(-5.0 - h), GATE_FLOOR)


def _gates(cfg: ModelConfig, variant: str, h_norm, lp, prefix, c):
    """Per-token decay gates g: [C, H, fk] (ones for non-decay variants)."""
    hh, fk = cfg.n_heads, cfg.feat_dim(variant)
    if variant == "retention":
        lam = retention_lambdas(cfg)                      # [H]
        return jnp.broadcast_to(lam[None, :, None], (c, hh, fk))
    if variant == "gla":
        raw = (h_norm @ lp[f"{prefix}.wg"]).reshape(c, hh, fk)
        sg = jax.nn.sigmoid(raw) ** (1.0 / GLA_TAU)
        return GATE_FLOOR + (1.0 - GATE_FLOOR) * sg
    return jnp.ones((c, hh, fk), jnp.float32)


def _qk_features(cfg, variant, q, k, lp, prefix):
    """Apply the variant's feature map. q,k: [C, H, rq] -> [C, H, fk]."""
    if variant == "based":
        return kf.phi_based(q), kf.phi_based(k)
    if variant == "rebased":
        g, b = lp[f"{prefix}.gamma"], lp[f"{prefix}.beta"]
        return kf.phi_rebased(q, g, b), kf.phi_rebased(k, g, b)
    return q, k


# ======================================================== linear SP phases
def linear_part1(cfg: ModelConfig, variant: str, x, ln1, wq, wk, wv,
                 extra=None):
    """Alg. 2 lines 5-6 for one chunk on one device.

    x: [C, D].  Returns (q~ [C,H,fk], k~ [C,H,fk], v [C,H,dh],
    m_t [H,fk,dh], a_t [H,fk]).

    q~ = q*B and k~ = k/B fold the decay gates so that downstream kernels
    are the BASIC ones for every variant; m_t is the chunk's state
    contribution P_t; a_t the chunk's total decay (all-ones when no decay).
    The rust coordinator AllGathers (m_t, a_t) and computes the gated
    prefix combine.
    """
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    rq = cfg.qk_dim(variant)
    lp = {"x.ln1": ln1, "x.wq": wq, "x.wk": wk, "x.wv": wv}
    if extra is not None:
        lp.update(extra)
    hn = rmsnorm(x, ln1)
    q = (hn @ wq).reshape(c, hh, rq)
    k = (hn @ wk).reshape(c, hh, rq)
    v = (hn @ wv).reshape(c, hh, dh)
    q, k = _qk_features(cfg, variant, q, k, lp, "x")
    g = _gates(cfg, variant, hn, lp, "x", c)
    b = jnp.cumprod(g, axis=0)                 # [C, H, fk]
    a = b[-1]                                  # [H, fk]
    qt = q * b
    kt = k / b
    k_state = kt * a[None]                     # rows scaled for the state
    # chunk state via the Pallas kernel (vmapped over heads)
    m = jax.vmap(ka.chunk_state, in_axes=(1, 1), out_axes=0)(k_state, v)
    return qt, kt, v, m, a


def linear_part2(cfg: ModelConfig, variant: str, x, qt, kt, v, m_prefix,
                 wo, ln2, w1, w3, w2):
    """Alg. 2 lines 8-11 + output projection + residual MLP for one chunk.

    m_prefix: [H, fk, dh] — the gated prefix state M_{1:t-1} produced by the
    coordinator's combine after the AllGather.  Uses the fused Pallas kernel
    (intra + inter in one pass) — or the Lightning tiled kernel when the
    layer's module is Lightning Attention.
    """
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    if variant == "lightning":
        attn = jax.vmap(kl.lightning_chunk_output,
                        in_axes=(1, 1, 1, 0), out_axes=1)(qt, kt, v, m_prefix)
    else:
        attn = jax.vmap(ka.fused_chunk_output,
                        in_axes=(1, 1, 1, 0), out_axes=1)(qt, kt, v, m_prefix)
    y = x + attn.reshape(c, hh * dh) @ wo
    z = y + swiglu(rmsnorm(y, ln2), w1, w3, w2)
    return z


def linear_intra(cfg: ModelConfig, variant: str, qt, kt, v):
    """Alg. 2 line 8 only: O_intra — the compute that OVERLAPS with the
    AllGather (executed on a separate thread by the rust coordinator)."""
    return jax.vmap(ka.intra_chunk, in_axes=(1, 1, 1), out_axes=1)(qt, kt, v)


def linear_part2b(cfg: ModelConfig, x, qt, o_intra, m_prefix, wo, ln2, w1,
                  w3, w2):
    """Alg. 2 lines 10-11 + epilogue, for the overlapped schedule:
    O_t = O_intra + Q~_t M_{1:t-1}, then O-proj + MLP."""
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    inter = jax.vmap(ka.inter_chunk, in_axes=(1, 0), out_axes=1)(qt, m_prefix)
    attn = o_intra + inter
    y = x + attn.reshape(c, hh * dh) @ wo
    z = y + swiglu(rmsnorm(y, ln2), w1, w3, w2)
    return z


def ring_linear_step(qt, k_j, v_j, acc, q_offset, k_offset):
    """Ring-Attention-style SP applied to a LINEAR attention instance
    without the right-product trick (the paper's comparison setup): one ring
    hop accumulates [(Q K_j^T) . Psi_global] V_j into acc.

    qt: [C,H,fk], k_j: [C,H,fk], v_j: [C,H,dh], acc: [C,H,dh]."""
    c = qt.shape[0]
    scores = jnp.einsum("chf,dhf->chd", qt, k_j)        # [Cq, H, Ck]
    qpos = q_offset[0] + jnp.arange(c)[:, None, None]
    kpos = k_offset[0] + jnp.arange(c)[None, None, :]
    scores = jnp.where(qpos >= kpos, scores, jnp.zeros_like(scores))
    return acc + jnp.einsum("chd,dhe->che", scores, v_j)


def linear_part2_nomask(cfg: ModelConfig, variant: str, x, qt, v, m_total,
                        wo, ln2, w1, w3, w2):
    """Alg. 1 line 8 (+ proj/MLP): bidirectional output O_t = Q_t M_{1:T}."""
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    attn = jax.vmap(ka.inter_chunk, in_axes=(1, 0), out_axes=1)(qt, m_total)
    y = x + attn.reshape(c, hh * dh) @ wo
    z = y + swiglu(rmsnorm(y, ln2), w1, w3, w2)
    return z


def linear_bwd1(qt, do):
    """Alg. 4 line 3: dM_t = Q_t^T dO_t.  qt: [C,H,fk], do: [C,H,dh]."""
    return jax.vmap(ka.bwd_chunk_dstate, in_axes=(1, 1), out_axes=0)(qt, do)


def linear_bwd2(qt, kt, v, do, m_prefix, dm_suffix):
    """Alg. 4 lines 5-12: full chunk gradient from the gathered dM states.

    Returns (dq, dk, dv), each [C, H, *].  Basic variant (g = 1): the
    convergence-path training of gated variants goes through jax.grad in
    the train_step artifact instead.
    """
    dqi, dki, dvi = jax.vmap(ka.bwd_intra, in_axes=(1, 1, 1, 1),
                             out_axes=(1, 1, 1))(qt, kt, v, do)
    # inter parts
    dq = dqi + jnp.einsum("chd,hfd->chf", do, m_prefix)
    dk = dki + jnp.einsum("chd,hfd->chf", v, dm_suffix)
    dv = dvi + jnp.einsum("chf,hfd->chd", kt, dm_suffix)
    return dq, dk, dv


# ====================================================== standard SP phases
def std_part1(cfg: ModelConfig, x, ln1, wq, wk, wv):
    """Alg. 7 line 4: per-chunk Q, K, V for a standard-attention layer."""
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    hn = rmsnorm(x, ln1)
    q = (hn @ wq).reshape(c, hh, dh)
    k = (hn @ wk).reshape(c, hh, dh)
    v = (hn @ wv).reshape(c, hh, dh)
    return q, k, v


def std_part2(cfg: ModelConfig, x, q, k_all, v_all, q_offset, wo, ln2, w1,
              w3, w2):
    """Alg. 7 lines 6-7 (+ proj/MLP): local flash attention over the
    gathered K, V.  q_offset: i32[1] global position of this chunk."""
    c = x.shape[0]
    hh, dh = cfg.n_heads, cfg.head_dim
    attn = jax.vmap(
        lambda qh, kh, vh: ks.flash_attention(q_offset, qh, kh, vh),
        in_axes=(1, 1, 1), out_axes=1)(q, k_all, v_all)
    y = x + attn.reshape(c, hh * dh) @ wo
    z = y + swiglu(rmsnorm(y, ln2), w1, w3, w2)
    return z


def mega_attn(cfg: ModelConfig, variant: str, qt, k_all, v_all, q_offset):
    """Megatron-SP baseline attention on a linear-attention instance WITHOUT
    the right-product trick (paper Sec. 4.1): full gathered left product.
    qt: [C,H,fk] (already feature-mapped / decay-folded), k_all: [N,H,fk].
    """
    def per_head(qh, kh, vh):
        return kref.linear_attn_no_trick(qh, kh, vh, q_offset=q_offset[0])
    return jax.vmap(per_head, in_axes=(1, 1, 1), out_axes=1)(qt, k_all, v_all)


def post_attn(cfg: ModelConfig, x, attn, wo, ln2, w1, w3, w2):
    """Shared epilogue for the baseline schedulers: O-proj + MLP block."""
    c = x.shape[0]
    y = x + attn.reshape(c, cfg.n_heads * cfg.head_dim) @ wo
    z = y + swiglu(rmsnorm(y, ln2), w1, w3, w2)
    return z


def ring_step(q, k, v, m, l, acc, q_offset, k_offset):
    """Ring Attention per-hop update (vmapped over heads).

    q: [C,H,dh], k/v: [C,H,dh], m/l: [C,H], acc: [C,H,dh]."""
    def per_head(qh, kh, vh, mh, lh, ah):
        return ks.ring_attention_step(q_offset, k_offset, qh, kh, vh, mh,
                                      lh, ah)
    return jax.vmap(per_head, in_axes=(1, 1, 1, 1, 1, 1),
                    out_axes=(1, 1, 1))(q, k, v, m, l, acc)


def ring_finalize(l, acc):
    return jax.vmap(ks.ring_attention_finalize, in_axes=(1, 1),
                    out_axes=1)(l, acc)


# ============================================================ embed / head
def embed(cfg: ModelConfig, tokens, offset, emb, pos):
    """tokens: i32[C] at global positions offset + [0..C)."""
    c = tokens.shape[0]
    idx = offset[0] + jnp.arange(c)
    return emb[tokens] + pos[idx]


def head_logits(cfg: ModelConfig, x, final_ln, emb):
    """Tied LM head: logits = RMSNorm(x) Emb^T."""
    return rmsnorm(x, final_ln) @ emb.T


def head_loss(cfg: ModelConfig, x, targets, final_ln, emb):
    """Sum of token cross-entropies for this chunk + token count."""
    logits = head_logits(cfg, x, final_ln, emb)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    losses = logz - gold
    return jnp.sum(losses)[None], jnp.asarray(
        [targets.shape[0]], dtype=jnp.float32)


# ================================================= monolithic oracle model
def _state_combine(a1, m1, a2, m2):
    """Gated prefix-combine monoid (what rust does after the AllGather)."""
    return a1 * a2, a2[..., None] * m1 + m2


def _linear_layer_full(cfg, variant, lp, prefix, x, masked=True):
    """Whole-sequence linear layer via the chunked math (oracle).

    x: [N, D]; N must be divisible by chunk_len."""
    n, d = x.shape
    c = cfg.chunk_len
    t = n // c
    hh, dh, fk = cfg.n_heads, cfg.head_dim, cfg.feat_dim(variant)

    # per-chunk part1 (vmapped over chunks = "parallel across devices")
    def p1(xc):
        return linear_part1(cfg, variant, xc, lp[f"{prefix}.ln1"],
                            lp[f"{prefix}.wq"], lp[f"{prefix}.wk"],
                            lp[f"{prefix}.wv"],
                            extra={f"x.{kk}": lp[f"{prefix}.{kk}"]
                                   for kk in ("wg", "gamma", "beta")
                                   if f"{prefix}.{kk}" in lp})
    qt, kt, v, m, a = jax.vmap(p1)(x.reshape(t, c, d))

    if masked:
        # exclusive gated prefix scan over chunk states (the combine)
        am, mm = jax.lax.associative_scan(
            lambda c1, c2: _state_combine(c1[0], c1[1], c2[0], c2[1]),
            (a, m))
        zero_m = jnp.zeros_like(mm[:1])
        m_prefix = jnp.concatenate([zero_m, mm[:-1]], axis=0)
        def p2(xc, qtc, ktc, vc, mp):
            return linear_part2(cfg, variant, xc, qtc, ktc, vc, mp,
                                lp[f"{prefix}.wo"], lp[f"{prefix}.ln2"],
                                lp[f"{prefix}.w1"], lp[f"{prefix}.w3"],
                                lp[f"{prefix}.w2"])
        y = jax.vmap(p2)(x.reshape(t, c, d), qt, kt, v, m_prefix)
    else:
        m_total = jnp.sum(m, axis=0)  # Alg. 1 line 7 (basic variant: a = 1)
        def p2(xc, qtc, vc):
            return linear_part2_nomask(cfg, variant, xc, qtc, vc, m_total,
                                       lp[f"{prefix}.wo"],
                                       lp[f"{prefix}.ln2"],
                                       lp[f"{prefix}.w1"],
                                       lp[f"{prefix}.w3"],
                                       lp[f"{prefix}.w2"])
        y = jax.vmap(p2)(x.reshape(t, c, d), qt, v)
    return y.reshape(n, d)


def _std_layer_full(cfg, lp, prefix, x, masked=True):
    n, d = x.shape
    hh, dh = cfg.n_heads, cfg.head_dim
    hn = rmsnorm(x, lp[f"{prefix}.ln1"])
    q = (hn @ lp[f"{prefix}.wq"]).reshape(n, hh, dh)
    k = (hn @ lp[f"{prefix}.wk"]).reshape(n, hh, dh)
    v = (hn @ lp[f"{prefix}.wv"]).reshape(n, hh, dh)
    attn = jax.vmap(lambda qh, kh, vh: kref.softmax_attn(
        qh, kh, vh, causal=masked), in_axes=(1, 1, 1), out_axes=1)(q, k, v)
    y = x + attn.reshape(n, hh * dh) @ lp[f"{prefix}.wo"]
    return y + swiglu(rmsnorm(y, lp[f"{prefix}.ln2"]), lp[f"{prefix}.w1"],
                      lp[f"{prefix}.w3"], lp[f"{prefix}.w2"])


def forward_tokens(cfg: ModelConfig, variant: str, pattern: str, params,
                   tokens, masked=True):
    """tokens: i32[N] -> logits [N, vocab].  Single-device oracle that the
    distributed pipeline is tested against (allclose)."""
    n = tokens.shape[0]
    x = params["embed"][tokens] + params["pos"][:n]
    for i, kind in enumerate(pattern):
        prefix = f"layer{i}"
        if kind == "L":
            x = _linear_layer_full(cfg, variant, params, prefix, x,
                                   masked=masked)
        else:
            x = _std_layer_full(cfg, params, prefix, x, masked=masked)
    return head_logits(cfg, x, params["final_ln"], params["embed"])


def forward_mono(cfg, variant, pattern, flat_params, tokens, masked=True):
    params = unflatten_params(cfg, variant, pattern, flat_params)
    return (forward_tokens(cfg, variant, pattern, params, tokens,
                           masked=masked),)


# ============================================================== train step
def _loss_fn(cfg, variant, pattern, params, tokens, targets, loss_mask,
             masked):
    def per_seq(tok, tgt, lm):
        logits = forward_tokens(cfg, variant, pattern, params, tok,
                                masked=masked)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[:, None], axis=-1)[:, 0]
        return jnp.sum((logz - gold) * lm), jnp.sum(lm)
    losses, counts = jax.vmap(per_seq)(tokens, targets, loss_mask)
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)


def train_step(cfg: ModelConfig, variant: str, pattern: str, masked: bool,
               n_params: int, *args):
    """Flat-signature Adam train step (the convergence-experiment artifact).

    args = params*P, m*P, v*P, tokens [B,S] i32, targets [B,S] i32,
           loss_mask [B,S] f32, lr f32[1], step f32[1]
    returns (new_params*P, new_m*P, new_v*P, loss f32[1])
    """
    p = n_params
    flat = list(args[:p])
    mom = list(args[p:2 * p])
    vel = list(args[2 * p:3 * p])
    tokens, targets, loss_mask, lr, step = args[3 * p:]
    params = unflatten_params(cfg, variant, pattern, flat)

    loss, grads = jax.value_and_grad(
        lambda prm: _loss_fn(cfg, variant, pattern, prm, tokens, targets,
                             loss_mask, masked))(params)
    specs = param_specs(cfg, variant, pattern)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.1
    t = step[0]
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    new_p, new_m, new_v = [], [], []
    for (name, _, init), pv, mv, vv in zip(specs, flat, mom, vel):
        g = grads[name]
        mv2 = b1 * mv + (1 - b1) * g
        vv2 = b2 * vv + (1 - b2) * jnp.square(g)
        upd = (mv2 / bc1) / (jnp.sqrt(vv2 / bc2) + eps)
        decay = 0.0 if init in ("ones", "zeros") else wd  # no wd on norms
        new_p.append(pv - lr[0] * (upd + decay * pv))
        new_m.append(mv2)
        new_v.append(vv2)
    return tuple(new_p + new_m + new_v + [loss[None]])
