"""AOT lowering: every jax/pallas computation -> HLO TEXT artifact + manifest.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --preset tiny [--group all|core|bench] [--force]

Outputs land in  artifacts/<preset>/*.hlo.txt  plus a flat-text manifest
(artifacts/<preset>/manifest.txt) that the rust runtime parses:

    lasp2-manifest 1
    preset tiny
    field d_model 64
    ...
    artifact l_part1_basic l_part1_basic.hlo.txt
    in x f32 32,64
    ...
    out qt f32 32,2,32
    end

Scalars are passed as rank-1 [1] arrays so the rust literal builder is
uniform.  All functions are lowered with return_tuple=True; the rust side
unwraps the tuple.
"""

from __future__ import annotations

import argparse
import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


F32, I32 = jnp.float32, jnp.int32


class Artifact:
    def __init__(self, name, fn, ins, outs):
        """ins: [(name, ShapeDtypeStruct)], outs: [name] (shapes derived)."""
        self.name = name
        self.fn = fn
        self.ins = ins
        self.out_names = outs


def _dt(dtype) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dtype)]


# ------------------------------------------------------------ registry
def build_registry(cfg: M.ModelConfig, group: str):
    d, hh, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    c, f, vb = cfg.chunk_len, cfg.ffn_dim, cfg.vocab
    arts: list[Artifact] = []

    def add(name, fn, ins, outs):
        arts.append(Artifact(name, fn, ins, outs))

    # ---- embed / head -------------------------------------------------
    add("embed",
        lambda tokens, offset, emb, pos: (M.embed(cfg, tokens, offset, emb,
                                                  pos),),
        [("tokens", _spec((c,), I32)), ("offset", _spec((1,), I32)),
         ("emb", _spec((vb, d))), ("pos", _spec((cfg.max_seq, d)))],
        ["x"])
    add("head",
        lambda x, final_ln, emb: (M.head_logits(cfg, x, final_ln, emb),),
        [("x", _spec((c, d))), ("final_ln", _spec((d,))),
         ("emb", _spec((vb, d)))],
        ["logits"])
    add("head_loss",
        lambda x, targets, final_ln, emb: M.head_loss(cfg, x, targets,
                                                      final_ln, emb),
        [("x", _spec((c, d))), ("targets", _spec((c,), I32)),
         ("final_ln", _spec((d,))), ("emb", _spec((vb, d)))],
        ["loss_sum", "count"])

    # ---- linear phases, per variant -----------------------------------
    for v in M.LINEAR_VARIANTS:
        rq = cfg.qk_dim(v)
        fk = cfg.feat_dim(v)
        extra_ins = []
        if v == "gla":
            extra_ins = [("wg", _spec((d, hh * rq)))]
        elif v == "rebased":
            extra_ins = [("gamma", _spec((rq,))), ("beta", _spec((rq,)))]

        def p1(x, ln1, wq, wk, wv, *extra, _v=v):
            names = (["wg"] if _v == "gla"
                     else ["gamma", "beta"] if _v == "rebased" else [])
            ex = {f"x.{n}": e for n, e in zip(names, extra)}
            return M.linear_part1(cfg, _v, x, ln1, wq, wk, wv, extra=ex)

        add(f"l_part1_{v}", p1,
            [("x", _spec((c, d))), ("ln1", _spec((d,))),
             ("wq", _spec((d, hh * rq))), ("wk", _spec((d, hh * rq))),
             ("wv", _spec((d, hh * dh)))] + extra_ins,
            ["qt", "kt", "v", "m", "a"])

        add(f"l_part2_{v}",
            functools.partial(
                lambda x, qt, kt, vv, mp, wo, ln2, w1, w3, w2, _v=None:
                (M.linear_part2(cfg, _v, x, qt, kt, vv, mp, wo, ln2, w1,
                                w3, w2),), _v=v),
            [("x", _spec((c, d))), ("qt", _spec((c, hh, fk))),
             ("kt", _spec((c, hh, fk))), ("v", _spec((c, hh, dh))),
             ("m_prefix", _spec((hh, fk, dh))), ("wo", _spec((hh * dh, d))),
             ("ln2", _spec((d,))), ("w1", _spec((d, f))),
             ("w3", _spec((d, f))), ("w2", _spec((f, d)))],
            ["y"])

        add(f"l_intra_{v}",
            functools.partial(
                lambda qt, kt, vv, _v=None:
                (M.linear_intra(cfg, _v, qt, kt, vv),), _v=v),
            [("qt", _spec((c, hh, fk))), ("kt", _spec((c, hh, fk))),
             ("v", _spec((c, hh, dh)))],
            ["o_intra"])
        add(f"l_part2b_{v}",
            lambda x, qt, o_intra, mp, wo, ln2, w1, w3, w2:
            (M.linear_part2b(cfg, x, qt, o_intra, mp, wo, ln2, w1, w3, w2),),
            [("x", _spec((c, d))), ("qt", _spec((c, hh, fk))),
             ("o_intra", _spec((c, hh, dh))),
             ("m_prefix", _spec((hh, fk, dh))), ("wo", _spec((hh * dh, d))),
             ("ln2", _spec((d,))), ("w1", _spec((d, f))),
             ("w3", _spec((d, f))), ("w2", _spec((f, d)))],
            ["y"])

    add("ring_linear_step",
        lambda qt, k_j, v_j, acc, qoff, koff:
        (M.ring_linear_step(qt, k_j, v_j, acc, qoff, koff),),
        [("qt", _spec((c, hh, dh))), ("k_j", _spec((c, hh, dh))),
         ("v_j", _spec((c, hh, dh))), ("acc", _spec((c, hh, dh))),
         ("qoff", _spec((1,), I32)), ("koff", _spec((1,), I32))],
        ["acc"])

    # bidirectional (Alg. 1) part2, basic variant
    add("l_part2nm_basic",
        lambda x, qt, vv, mt, wo, ln2, w1, w3, w2:
        (M.linear_part2_nomask(cfg, "basic", x, qt, vv, mt, wo, ln2, w1,
                               w3, w2),),
        [("x", _spec((c, d))), ("qt", _spec((c, hh, dh))),
         ("v", _spec((c, hh, dh))), ("m_total", _spec((hh, dh, dh))),
         ("wo", _spec((hh * dh, d))), ("ln2", _spec((d,))),
         ("w1", _spec((d, f))), ("w3", _spec((d, f))),
         ("w2", _spec((f, d)))],
        ["y"])

    # ---- backward phases (basic variant, Alg. 3/4) --------------------
    add("l_bwd1_basic",
        lambda qt, do: (M.linear_bwd1(qt, do),),
        [("qt", _spec((c, hh, dh))), ("do", _spec((c, hh, dh)))],
        ["dm"])
    add("l_bwd2_basic",
        lambda qt, kt, vv, do, mp, dms: M.linear_bwd2(qt, kt, vv, do, mp,
                                                      dms),
        [("qt", _spec((c, hh, dh))), ("kt", _spec((c, hh, dh))),
         ("v", _spec((c, hh, dh))), ("do", _spec((c, hh, dh))),
         ("m_prefix", _spec((hh, dh, dh))),
         ("dm_suffix", _spec((hh, dh, dh)))],
        ["dq", "dk", "dv"])

    # ---- standard-attention phases (Alg. 7) + baselines ----------------
    add("s_part1",
        lambda x, ln1, wq, wk, wv: M.std_part1(cfg, x, ln1, wq, wk, wv),
        [("x", _spec((c, d))), ("ln1", _spec((d,))),
         ("wq", _spec((d, hh * dh))), ("wk", _spec((d, hh * dh))),
         ("wv", _spec((d, hh * dh)))],
        ["q", "k", "v"])
    for t_world in cfg_sp_sizes(cfg):
        n_all = t_world * c
        add(f"s_part2_T{t_world}",
            lambda x, q, k_all, v_all, offset, wo, ln2, w1, w3, w2:
            (M.std_part2(cfg, x, q, k_all, v_all, offset, wo, ln2, w1, w3,
                         w2),),
            [("x", _spec((c, d))), ("q", _spec((c, hh, dh))),
             ("k_all", _spec((n_all, hh, dh))),
             ("v_all", _spec((n_all, hh, dh))),
             ("offset", _spec((1,), I32)), ("wo", _spec((hh * dh, d))),
             ("ln2", _spec((d,))), ("w1", _spec((d, f))),
             ("w3", _spec((d, f))), ("w2", _spec((f, d)))],
            ["y"])
        add(f"mega_attn_basic_T{t_world}",
            lambda qt, k_all, v_all, offset:
            (M.mega_attn(cfg, "basic", qt, k_all, v_all, offset),),
            [("qt", _spec((c, hh, dh))), ("k_all", _spec((n_all, hh, dh))),
             ("v_all", _spec((n_all, hh, dh))),
             ("offset", _spec((1,), I32))],
            ["attn"])
    add("post_attn",
        lambda x, attn, wo, ln2, w1, w3, w2:
        (M.post_attn(cfg, x, attn, wo, ln2, w1, w3, w2),),
        [("x", _spec((c, d))), ("attn", _spec((c, hh, dh))),
         ("wo", _spec((hh * dh, d))), ("ln2", _spec((d,))),
         ("w1", _spec((d, f))), ("w3", _spec((d, f))),
         ("w2", _spec((f, d)))],
        ["y"])
    add("ring_step",
        lambda q, k, vv, m, l, acc, qoff, koff:
        M.ring_step(q, k, vv, m, l, acc, qoff, koff),
        [("q", _spec((c, hh, dh))), ("k", _spec((c, hh, dh))),
         ("v", _spec((c, hh, dh))), ("m", _spec((c, hh))),
         ("l", _spec((c, hh))), ("acc", _spec((c, hh, dh))),
         ("qoff", _spec((1,), I32)), ("koff", _spec((1,), I32))],
        ["m", "l", "acc"])
    add("ring_finalize",
        lambda l, acc: (M.ring_finalize(l, acc),),
        [("l", _spec((c, hh))), ("acc", _spec((c, hh, dh)))],
        ["attn"])

    # ---- monolithic oracles + training ---------------------------------
    n_mono = c * max(cfg_sp_sizes(cfg))
    for v, pat_ratio in mono_set(cfg, group):
        pattern = M.hybrid_pattern(cfg.n_layers, pat_ratio)
        tag = pat_tag(pat_ratio)
        variant = v if v != "softmax" else "basic"
        specs = M.param_specs(cfg, variant, pattern)
        pins = [(f"p.{n}", _spec(s)) for n, s, _ in specs]
        add(f"forward_mono_{v}_{tag}_N{n_mono}",
            functools.partial(
                lambda *a, _v=None, _p=None:
                M.forward_mono(cfg, _v, _p, a[:-1], a[-1]),
                _v=variant, _p=pattern),
            pins + [("tokens", _spec((n_mono,), I32))],
            ["logits"])

    for v, pat_ratio, masked in train_set(cfg, group):
        pattern = M.hybrid_pattern(cfg.n_layers, pat_ratio)
        tag = pat_tag(pat_ratio) + ("" if masked else "_nm")
        variant = v if v != "softmax" else "basic"
        specs = M.param_specs(cfg, variant, pattern)
        np_ = len(specs)
        pins = [(f"p.{n}", _spec(s)) for n, s, _ in specs]
        mins = [(f"m.{n}", _spec(s)) for n, s, _ in specs]
        vins = [(f"v.{n}", _spec(s)) for n, s, _ in specs]
        bs, sl = cfg.train_batch, cfg.train_seq
        add(f"init_{v}_{tag}",
            functools.partial(
                lambda seed, _v=None, _p=None:
                M.init_params_fn(cfg, _v, _p, seed), _v=variant, _p=pattern),
            [("seed", _spec((1,), I32))],
            [f"p.{n}" for n, _, _ in specs])
        add(f"train_step_{v}_{tag}",
            functools.partial(
                lambda *a, _v=None, _p=None, _m=None, _n=None:
                M.train_step(cfg, _v, _p, _m, _n, *a),
                _v=variant, _p=pattern, _m=masked, _n=np_),
            pins + mins + vins + [
                ("tokens", _spec((bs, sl), I32)),
                ("targets", _spec((bs, sl), I32)),
                ("loss_mask", _spec((bs, sl))),
                ("lr", _spec((1,))), ("step", _spec((1,)))],
            [f"p.{n}" for n, _, _ in specs]
            + [f"m.{n}" for n, _, _ in specs]
            + [f"v.{n}" for n, _, _ in specs] + ["loss"])

    return arts


def cfg_sp_sizes(cfg):
    """SP world sizes for which gathered-KV artifacts are built."""
    return [2, 4] if cfg.name == "tiny" else [4]


def pat_tag(ratio: str) -> str:
    return {"0": "pure", "1/8": "h8", "1/4": "h4", "1/2": "h2",
            "all": "std"}[ratio]


def mono_set(cfg, group):
    """(variant, pattern-ratio) pairs for forward_mono oracles."""
    if cfg.name == "tiny":
        s = [(v, "0") for v in M.LINEAR_VARIANTS]
        # tiny has 2 layers: "1/2" = "LN" exercises the hybrid (LASP-2H)
        s += [("basic", "1/4"), ("basic", "1/2"), ("softmax", "all")]
        return s
    return [("basic", "0"), ("gla", "0"), ("basic", "1/4"),
            ("basic", "1/2"), ("softmax", "all")]


def train_set(cfg, group):
    """(variant, pattern-ratio, masked) for init+train_step artifacts."""
    if cfg.name == "tiny":
        return [("basic", "0", True), ("gla", "0", True),
                ("basic", "1/4", True), ("softmax", "all", True),
                ("basic", "0", False)]
    if cfg.name == "medium":
        return [("basic", "0", True), ("basic", "1/4", True)]
    # small
    core = [("basic", "0", True), ("softmax", "all", True),
            ("basic", "0", False)]
    if group in ("bench", "all"):
        for v in M.LINEAR_VARIANTS:
            core.append((v, "0", True))
            core.append((v, "1/4", True))
        for v in ("basic", "lightning", "retention", "gla"):
            core.append((v, "1/8", True))
            core.append((v, "1/2", True))
        # dedup, keep order
        seen, out = set(), []
        for e in core:
            if e not in seen:
                seen.add(e)
                out.append(e)
        return out
    return core


# ------------------------------------------------------------- lowering
def lower_artifact(art: Artifact, out_dir: str, force: bool) -> dict:
    path = os.path.join(out_dir, f"{art.name}.hlo.txt")
    in_specs = [s for _, s in art.ins]
    out_shapes = jax.eval_shape(art.fn, *in_specs)
    if isinstance(out_shapes, (list, tuple)):
        outs = list(out_shapes)
    else:
        outs = [out_shapes]
    assert len(outs) == len(art.out_names), (
        art.name, len(outs), len(art.out_names))
    if force or not os.path.exists(path):
        t0 = time.time()
        lowered = jax.jit(art.fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  {art.name}: {len(text) / 1e6:.2f} MB "
              f"({time.time() - t0:.1f}s)", flush=True)
    return {
        "ins": [(n, _dt(s.dtype), s.shape) for n, s in art.ins],
        "outs": [(n, _dt(o.dtype), o.shape)
                 for n, o in zip(art.out_names, outs)],
        "file": f"{art.name}.hlo.txt",
    }


def write_manifest(cfg, entries, out_dir):
    lines = ["lasp2-manifest 1", f"preset {cfg.name}"]
    for k in ("d_model", "n_heads", "n_layers", "vocab", "chunk_len",
              "max_seq", "qk_reduced", "train_batch", "train_seq"):
        lines.append(f"field {k} {getattr(cfg, k)}")
    lines.append(f"field head_dim {cfg.head_dim}")
    lines.append(f"field ffn_dim {cfg.ffn_dim}")
    for name, meta in entries.items():
        lines.append(f"artifact {name} {meta['file']}")
        for n, dt, shape in meta["ins"]:
            lines.append(f"in {n} {dt} {','.join(map(str, shape))}")
        for n, dt, shape in meta["outs"]:
            lines.append(f"out {n} {dt} {','.join(map(str, shape))}")
        lines.append("end")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(lines) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny",
                    choices=list(M.PRESETS.keys()))
    ap.add_argument("--group", default="core",
                    choices=["core", "bench", "all"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None,
                    help="output dir (default artifacts/<preset>)")
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    out_dir = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))), "artifacts", cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    arts = build_registry(cfg, args.group)
    print(f"[aot] preset={cfg.name} group={args.group}: "
          f"{len(arts)} artifacts -> {out_dir}", flush=True)
    entries = {}
    for art in arts:
        entries[art.name] = lower_artifact(art, out_dir, args.force)
    write_manifest(cfg, entries, out_dir)
    print(f"[aot] manifest written ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
