"""Pure-jnp oracles for the LASP-2 kernels.

Every Pallas kernel in this package is checked against these references at
build time (pytest + hypothesis).  The references are written as directly as
possible from the paper's equations:

  * `recurrent_linear_attn`   — Eq. (4): token-by-token recurrence
                                M_s = diag(g_s) M_{s-1} + k_s^T v_s,
                                o_s = q_s M_s   (g = 1 for basic linear attn)
  * `full_linear_attn`        — Eq. (3)/(7): masked left-product form
  * `softmax_attn`            — Eq. (1) with causal mask & position offset

The gated formulation covers all linear variants in the paper via
per-token/per-key-dim decay gates g in (0, 1]^{dk}:
  basic linear attention : g = 1
  Retention (RetNet)     : g = lambda (scalar per head)
  GLA                    : g = data-dependent sigmoid gates
Based / ReBased apply a feature map to q, k first (see features.py) and then
use the basic (g = 1) path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def recurrent_linear_attn(q, k, v, g=None, m0=None):
    """Ground-truth recurrence, one token at a time.

    q, k: [N, dk], v: [N, dv], g: [N, dk] or None (ones), m0: [dk, dv] or None.
    Returns (o [N, dv], mT [dk, dv]).

    Recurrence (gate decays the past *before* the current token is added):
        M_s = diag(g_s) M_{s-1} + k_s^T v_s
        o_s = q_s M_s
    """
    n, dk = q.shape
    dv = v.shape[-1]
    if g is None:
        g = jnp.ones((n, dk), dtype=q.dtype)
    if m0 is None:
        m0 = jnp.zeros((dk, dv), dtype=q.dtype)

    def step(m, inp):
        q_s, k_s, v_s, g_s = inp
        m = g_s[:, None] * m + jnp.outer(k_s, v_s)
        o_s = q_s @ m
        return m, o_s

    mT, o = jax.lax.scan(step, m0, (q, k, v, g))
    return o, mT


def full_linear_attn(q, k, v, masked=True):
    """Left-product linear attention, Eq. (3) / Eq. (7) (basic, g = 1).

    O = (Q K^T  [odot tril]) V.  q, k: [N, dk], v: [N, dv].
    """
    scores = q @ k.T
    if masked:
        n = q.shape[0]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        scores = jnp.where(mask, scores, jnp.zeros_like(scores))
    return scores @ v


def gate_prefactors(g):
    """Cumulative gate products B_i = prod_{j<=i} g_j, and carry a = B_{N-1}.

    g: [N, dk] -> (B [N, dk], a [dk]).  With q~ = q * B, k~ = k / B the gated
    recurrence becomes the basic one:
        intra scores s_ij = q~_i . k~_j           (j <= i)
        inter        o_i += (q_i * B_i) M_prev = q~_i M_prev
        chunk state  P    = (k~ * a)^T V ,  M' = diag(a) M_prev + P
    """
    b = jnp.cumprod(g, axis=0)
    return b, b[-1]


def gated_full_linear_attn(q, k, v, g, m0=None):
    """Masked gated linear attention via the prefactor trick (single chunk).

    Matches recurrent_linear_attn exactly (up to fp error).
    Returns (o, mT).
    """
    b, a = gate_prefactors(g)
    qt = q * b
    kt = k / b
    n = q.shape[0]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    scores = jnp.where(mask, qt @ kt.T, jnp.zeros((n, n), dtype=q.dtype))
    o = scores @ v
    p = (kt * a[None, :]).T @ v
    if m0 is not None:
        o = o + qt @ m0
        mT = a[:, None] * m0 + p
    else:
        mT = p
    return o, mT


def chunked_linear_attn(q, k, v, g, num_chunks):
    """Alg. 2 (LASP-2 w/ masking) math over `num_chunks` chunks, pure jnp.

    This mirrors exactly what the distributed system computes:
      per chunk: M_t = (k~ * a)^T v, intra = (q~ k~^T . tril) v
      combine  : M_{1:t-1} via gated prefix scan (Eq. 8/9 generalized)
      inter    : o += q~ M_{1:t-1}
    Returns o [N, dv].
    """
    n, dk = q.shape
    dv = v.shape[-1]
    c = n // num_chunks
    qc = q.reshape(num_chunks, c, dk)
    kc = k.reshape(num_chunks, c, dk)
    vc = v.reshape(num_chunks, c, dv)
    gc = g.reshape(num_chunks, c, dk)

    outs = []
    m_prefix = jnp.zeros((dk, dv), dtype=q.dtype)
    for t in range(num_chunks):
        o_t, _ = gated_full_linear_attn(qc[t], kc[t], vc[t], gc[t], m0=m_prefix)
        # prefix update (what the rust coordinator does after the AllGather)
        b, a = gate_prefactors(gc[t])
        p_t = ((kc[t] / b) * a[None, :]).T @ vc[t]
        m_prefix = a[:, None] * m_prefix + p_t
        outs.append(o_t)
    return jnp.concatenate(outs, axis=0)


def unmasked_chunked_linear_attn(q, k, v, num_chunks):
    """Alg. 1 (LASP-2 w/o masking) math: M_{1:T} = Sum(AllGather([M_t])),
    O_t = Q_t M_{1:T}.  Bidirectional (no causal mask), basic variant."""
    n, dk = q.shape
    dv = v.shape[-1]
    c = n // num_chunks
    kc = k.reshape(num_chunks, c, dk)
    vc = v.reshape(num_chunks, c, dv)
    m_all = jnp.einsum("tcd,tce->de", kc, vc)  # Sum of all chunk states
    return q @ m_all


def softmax_attn(q, k, v, causal=True, q_offset=0, scale=None):
    """Reference softmax attention with global-position causal mask.

    q: [Cq, d] at global positions q_offset + [0..Cq), k, v: [Nk, d] at
    global positions [0..Nk).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = (q @ k.T) * scale
    if causal:
        qpos = q_offset + jnp.arange(q.shape[0])[:, None]
        kpos = jnp.arange(k.shape[0])[None, :]
        scores = jnp.where(qpos >= kpos, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return w @ v


def linear_attn_no_trick(q, k, v, q_offset=0, masked=True):
    """Megatron-SP-on-linear-attention baseline: the left-product form over
    the FULL gathered sequence (no right-product trick), as the paper's
    comparison setup prescribes (Sec. 4.1)."""
    scores = q @ k.T
    if masked:
        qpos = q_offset + jnp.arange(q.shape[0])[:, None]
        kpos = jnp.arange(k.shape[0])[None, :]
        scores = jnp.where(qpos >= kpos, scores, jnp.zeros_like(scores))
    return scores @ v


# ---------------------------------------------------------------- backward
def lasp2_masked_backward(q, k, v, do, num_chunks):
    """Alg. 4 (LASP-2 w/ masking, backward) in pure jnp, basic variant (g=1).

    Returns (dq, dk, dv).  Used as the oracle for the l_bwd1/l_bwd2 artifacts
    and for the rust distributed-backward integration test.
    """
    n, dk_dim = q.shape
    dv = v.shape[-1]
    c = n // num_chunks
    qc = q.reshape(num_chunks, c, dk_dim)
    kc = k.reshape(num_chunks, c, dk_dim)
    vc = v.reshape(num_chunks, c, dv)
    doc = do.reshape(num_chunks, c, dv)
    mask = jnp.tril(jnp.ones((c, c), dtype=bool))
    zeros_cc = jnp.zeros((c, c), dtype=q.dtype)

    # forward states M_t and prefix M_{1:t-1}
    m_t = jnp.einsum("tcd,tce->tde", kc, vc)
    m_prefix = jnp.concatenate(
        [jnp.zeros((1, dk_dim, dv), dtype=q.dtype),
         jnp.cumsum(m_t, axis=0)[:-1]],
        axis=0,
    )
    # dM_t = Q_t^T dO_t ; suffix sums dM_{t+1:T}
    dm_t = jnp.einsum("tcd,tce->tde", qc, doc)
    dm_rev = jnp.cumsum(dm_t[::-1], axis=0)[::-1]
    dm_suffix = jnp.concatenate(
        [dm_rev[1:], jnp.zeros((1, dk_dim, dv), dtype=q.dtype)], axis=0
    )

    dqs, dks, dvs = [], [], []
    for t in range(num_chunks):
        dov = jnp.where(mask, doc[t] @ vc[t].T, zeros_cc)   # (dO V^T) . Psi
        qk = jnp.where(mask, qc[t] @ kc[t].T, zeros_cc)     # (Q K^T) . Psi
        dq = dov @ kc[t] + doc[t] @ m_prefix[t].T
        dk_ = dov.T @ qc[t] + vc[t] @ dm_suffix[t].T
        dv_ = qk.T @ doc[t] + kc[t] @ dm_suffix[t]
        dqs.append(dq)
        dks.append(dk_)
        dvs.append(dv_)
    return (
        jnp.concatenate(dqs, axis=0),
        jnp.concatenate(dks, axis=0),
        jnp.concatenate(dvs, axis=0),
    )
