"""Feature maps for the Based and ReBased linear-attention variants.

Based (Arora et al., 2024) approximates exp(q.k) with its 2nd-order Taylor
expansion, which factors into the feature map

    phi(x) = [1, x, vec(x x^T)/sqrt(2)]          (dim 1 + d + d^2)

applied to a REDUCED head dimension (the paper uses d=16) so the expanded
feature dim stays small.  ReBased (Aksenov et al., 2024) replaces the Taylor
kernel with a learnable quadratic: phi(x) = (gamma . x + beta)^2 (per-dim
affine then square; we keep the feature dim = d).

Both then run through the BASIC (g = 1) chunked linear-attention path — the
memory state simply becomes [feat_dim, dv], and LASP-2's AllGather carries
that state unchanged.  This mirrors the paper's setup where Based/ReBased
are "attention modules" slotted into the same SP machinery.

Note (documented substitution): the original Based adds a softmax-style
denominator and a small sliding-window exact-attention term; we use the
unnormalized form consistent with this paper's Eq. (3) so that ALL variants
share the memory-state interface that LASP-2 communicates.
"""

from __future__ import annotations

import jax.numpy as jnp


def phi_based(x):
    """2nd-order Taylor feature map.  x: [..., d] -> [..., 1 + d + d^2]."""
    d = x.shape[-1]
    ones = jnp.ones(x.shape[:-1] + (1,), dtype=x.dtype)
    outer = (x[..., :, None] * x[..., None, :]).reshape(
        x.shape[:-1] + (d * d,)
    ) / jnp.sqrt(jnp.asarray(2.0, dtype=x.dtype))
    return jnp.concatenate([ones, x, outer], axis=-1)


def based_feature_dim(d: int) -> int:
    return 1 + d + d * d


def phi_rebased(x, gamma, beta):
    """Learnable quadratic feature map.  x: [..., d], gamma/beta: [d]."""
    return jnp.square(x * gamma + beta)
