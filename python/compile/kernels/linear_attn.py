"""Layer-1 Pallas kernels for chunked linear attention (LASP-2 hot spots).

Three kernels implement the per-chunk compute of Alg. 1/2:

  intra_chunk(q, k, v)      ->  O_intra = [(Q K^T) . Psi] V          (line 8)
  chunk_state(k, v)         ->  M_t     = K^T V                      (line 6)
  inter_chunk(q, m)         ->  O_inter = Q M_{1:t-1}                (line 10)

All kernels are single-head ([C, d] operands); multi-head is a `jax.vmap`
at the call site (model.py), which Pallas supports and which lowers to a
batched grid.

Hardware adaptation (paper: Triton/A100; here: Pallas/TPU-style):
  * the intra kernel streams ROW BLOCKS of Q against the whole chunk's K, V
    resident in VMEM — the BlockSpec plays the role of the paper's Triton
    threadblock tiling.  For C<=512, d<=128 the working set is well under
    the ~16MB VMEM budget (see DESIGN.md §8).
  * score and output matmuls are MXU-shaped ([BQ, d] x [d, C], [BQ, C] x
    [C, d]); the mask is applied with a broadcasted-iota compare, not a
    materialized [N, N] mask.
  * `interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
    custom-calls; real-TPU perf is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls.

DEFAULT_BLOCK_Q = 64


def _intra_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int):
    """One program instance computes `block_q` output rows of the masked
    intra-chunk product [(Q K^T) . Psi] V."""
    i = pl.program_id(0)
    q = q_ref[...]            # [block_q, dk]
    k = k_ref[...]            # [C, dk]
    v = v_ref[...]            # [C, dv]
    scores = q @ k.T          # [block_q, C]  (MXU matmul)
    # causal mask: global row index within the chunk vs column index
    rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(rows >= cols, scores, jnp.zeros_like(scores))
    o_ref[...] = scores @ v   # [block_q, dv]


@functools.partial(jax.jit, static_argnames=("block_q",))
def intra_chunk(q, k, v, block_q: int = DEFAULT_BLOCK_Q):
    """O_intra = [(Q K^T) . Psi] V for one chunk.  q, k: [C, dk], v: [C, dv]."""
    c, dk = q.shape
    dv = v.shape[-1]
    bq = min(block_q, c)
    assert c % bq == 0, f"chunk {c} not divisible by block {bq}"
    return pl.pallas_call(
        functools.partial(_intra_kernel, block_q=bq),
        grid=(c // bq,),
        in_specs=[
            pl.BlockSpec((bq, dk), lambda i: (i, 0)),      # Q row block
            pl.BlockSpec((c, dk), lambda i: (0, 0)),       # full K in VMEM
            pl.BlockSpec((c, dv), lambda i: (0, 0)),       # full V in VMEM
        ],
        out_specs=pl.BlockSpec((bq, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, dv), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


def _state_kernel(k_ref, v_ref, m_ref):
    """M = K^T V (one matmul; contraction dim = chunk length C, which keeps
    the MXU's 128-deep systolic contraction busy for C >= 128)."""
    m_ref[...] = k_ref[...].T @ v_ref[...]


@jax.custom_vjp
def chunk_state(k, v):
    """M_t = K_t^T V_t.  k: [C, dk], v: [C, dv] -> [dk, dv].

    Differentiable (custom VJP): dK = V dM^T, dV = K dM — the inter parts
    of Alg. 4 lines 10-11."""
    c, dk = k.shape
    dv = v.shape[-1]
    return pl.pallas_call(
        _state_kernel,
        out_shape=jax.ShapeDtypeStruct((dk, dv), k.dtype),
        interpret=INTERPRET,
    )(k, v)


def _state_fwd(k, v):
    return chunk_state(k, v), (k, v)


def _state_bwd(res, dm):
    k, v = res
    return v @ dm.T, k @ dm


chunk_state.defvjp(_state_fwd, _state_bwd)


def _inter_kernel(q_ref, m_ref, o_ref):
    o_ref[...] = q_ref[...] @ m_ref[...]


@jax.custom_vjp
def inter_chunk(q, m):
    """O_inter = Q M.  q: [C, dk], m: [dk, dv] -> [C, dv].

    Differentiable (custom VJP): the backward is Alg. 3's
    dQ = dO M^T, dM = Q^T dO — the latter via the bwd_chunk_dstate kernel.
    """
    c, dk = q.shape
    dv = m.shape[-1]
    return pl.pallas_call(
        _inter_kernel,
        out_shape=jax.ShapeDtypeStruct((c, dv), q.dtype),
        interpret=INTERPRET,
    )(q, m)


def _inter_fwd(q, m):
    return inter_chunk(q, m), (q, m)


def _inter_bwd(res, do):
    q, m = res
    return do @ m.T, bwd_chunk_dstate(q, do)


inter_chunk.defvjp(_inter_fwd, _inter_bwd)


def _bwd_dstate_kernel(q_ref, do_ref, dm_ref):
    """dM_t = Q_t^T dO_t (Alg. 3/4 line 3)."""
    dm_ref[...] = q_ref[...].T @ do_ref[...]


@jax.jit
def bwd_chunk_dstate(q, do):
    """dM_t = Q_t^T dO_t.  q: [C, dk], do: [C, dv] -> [dk, dv]."""
    c, dk = q.shape
    dv = do.shape[-1]
    return pl.pallas_call(
        _bwd_dstate_kernel,
        out_shape=jax.ShapeDtypeStruct((dk, dv), q.dtype),
        interpret=INTERPRET,
    )(q, do)


def _bwd_intra_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    """Intra-chunk parts of Alg. 4 (lines 5-7), one whole chunk per program:
        dQ_intra = [(dO V^T) . Psi]   K
        dK_intra = [(dO V^T) . Psi]^T Q
        dV_intra = [(Q K^T)  . Psi]^T dO
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    do = do_ref[...]
    c = q.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tril = rows >= cols
    dov = jnp.where(tril, do @ v.T, jnp.zeros((c, c), q.dtype))
    qk = jnp.where(tril, q @ k.T, jnp.zeros((c, c), q.dtype))
    dq_ref[...] = dov @ k
    dk_ref[...] = dov.T @ q
    dv_ref[...] = qk.T @ do


@jax.jit
def bwd_intra(q, k, v, do):
    """Intra-chunk backward.  Returns (dq_intra, dk_intra, dv_intra)."""
    c, dk_dim = q.shape
    dv_dim = v.shape[-1]
    return pl.pallas_call(
        _bwd_intra_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((c, dk_dim), q.dtype),
            jax.ShapeDtypeStruct((c, dk_dim), q.dtype),
            jax.ShapeDtypeStruct((c, dv_dim), q.dtype),
        ),
        interpret=INTERPRET,
    )(q, k, v, do)


# ------------------------------------------------------------------ fused
def _fused_chunk_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, block_q: int):
    """Fused intra + inter for one chunk: O = [(QK^T).Psi]V + Q M_prefix.

    This fusion is the actual LASP-2 per-device hot path (Alg. 2 lines 8-11
    collapsed): one pass over the Q row blocks produces the final output, so
    the intermediate O_intra never round-trips through HBM.
    """
    i = pl.program_id(0)
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    m = m_ref[...]
    scores = q @ k.T
    rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(rows >= cols, scores, jnp.zeros_like(scores))
    o_ref[...] = scores @ v + q @ m


@jax.custom_vjp
def fused_chunk_output(q, k, v, m_prefix):
    """O_t = O_intra + O_inter fused.  q,k: [C,dk], v: [C,dv], m: [dk,dv].

    Differentiable (custom VJP): the backward is exactly Alg. 4 restricted
    to one chunk — intra parts via the bwd_intra Pallas kernel, inter parts
    dQ += dO M^T / dM = Q^T dO via bwd_chunk_dstate.  This makes the L1
    Pallas kernels the training hot path (through the train_step artifact),
    not just the inference path.
    """
    c, dk = q.shape
    dv = v.shape[-1]
    bq = min(DEFAULT_BLOCK_Q, c)
    assert c % bq == 0
    return pl.pallas_call(
        functools.partial(_fused_chunk_kernel, block_q=bq),
        grid=(c // bq,),
        in_specs=[
            pl.BlockSpec((bq, dk), lambda i: (i, 0)),
            pl.BlockSpec((c, dk), lambda i: (0, 0)),
            pl.BlockSpec((c, dv), lambda i: (0, 0)),
            pl.BlockSpec((dk, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, dv), q.dtype),
        interpret=INTERPRET,
    )(q, k, v, m_prefix)


def _fused_fwd(q, k, v, m_prefix):
    return fused_chunk_output(q, k, v, m_prefix), (q, k, v, m_prefix)


def _fused_bwd(res, do):
    q, k, v, m_prefix = res
    dqi, dki, dvi = bwd_intra(q, k, v, do)
    dq = dqi + do @ m_prefix.T
    dm = bwd_chunk_dstate(q, do)
    return dq, dki, dvi, dm


fused_chunk_output.defvjp(_fused_fwd, _fused_bwd)
