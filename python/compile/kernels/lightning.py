"""Lightning-Attention-style tiled kernel (Qin et al., 2024b).

Lightning Attention's contribution is an IO-aware tiling that handles the
intra-block part with the (masked) left product and the inter-block part
with the right product, INSIDE one kernel, carrying the running state
between tiles.  The math is identical to basic linear attention — which is
exactly why the paper lists it as a separate "attention module" with the
same SP treatment: LASP-2 is agnostic to the per-chunk kernel.

Here the kernel walks the chunk in `block` tiles sequentially on ONE grid
step (a `fori_loop` over tiles with a VMEM scratch state), mirroring the
Triton implementation's program-per-head structure.  Equality with
`linear_attn.intra_chunk + inter_chunk` is asserted in the test suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .linear_attn import INTERPRET


def _lightning_kernel(q_ref, k_ref, v_ref, m0_ref, o_ref, *, block: int):
    c, dk = q_ref.shape
    dv = v_ref.shape[-1]
    nb = c // block

    def tile(t, state):
        ds = pl.ds(t * block, block)
        q = q_ref[ds, :]                       # [b, dk]
        k = k_ref[ds, :]                       # [b, dk]
        v = v_ref[ds, :]                       # [b, dv]
        scores = q @ k.T                       # intra-tile, masked
        rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(rows >= cols, scores, jnp.zeros_like(scores))
        o_ref[ds, :] = scores @ v + q @ state  # inter via running state
        return state + k.T @ v                 # right-product state update

    final = jax.lax.fori_loop(0, nb, tile, m0_ref[...])
    del final


DEFAULT_TILE = 32


@jax.custom_vjp
def lightning_chunk_output(q, k, v, m_prefix):
    """Full chunk output (intra + inter) with Lightning-style tiling.

    q, k: [C, dk], v: [C, dv], m_prefix: [dk, dv] -> [C, dv].
    Numerically identical to `fused_chunk_output` (tested).  Differentiable
    via the same Alg.-4 custom VJP as the fused kernel.
    """
    c, dk = q.shape
    dv = v.shape[-1]
    b = min(DEFAULT_TILE, c)
    assert c % b == 0
    return pl.pallas_call(
        functools.partial(_lightning_kernel, block=b),
        out_shape=jax.ShapeDtypeStruct((c, dv), q.dtype),
        interpret=INTERPRET,
    )(q, k, v, m_prefix)


def _lightning_fwd(q, k, v, m_prefix):
    return lightning_chunk_output(q, k, v, m_prefix), (q, k, v, m_prefix)


def _lightning_bwd(res, do):
    from .linear_attn import bwd_chunk_dstate, bwd_intra

    q, k, v, m_prefix = res
    dqi, dki, dvi = bwd_intra(q, k, v, do)
    dq = dqi + do @ m_prefix.T
    dm = bwd_chunk_dstate(q, do)
    return dq, dki, dvi, dm


lightning_chunk_output.defvjp(_lightning_fwd, _lightning_bwd)
