"""Blocked online-softmax (flash-style) Pallas kernel for the hybrid's
standard-attention layers and the Ring Attention / Megatron-SP baselines.

The kernel computes, for one query chunk at global offset `q_offset` against
a gathered key/value sequence of length Nk (Alg. 7, line 7):

    O_t = Softmax(Q_t K^T / sqrt(d) . Psi) V

using the FlashAttention-2 streaming recurrence over KV blocks: running row
max m, running denominator l, rescaled accumulator.  This is the same
algorithm the paper's testbed uses (FlashAttention-2 on A100); here the KV
blocks stream through VMEM instead of SRAM.

`ring_attention_step` exposes a single (m, l, acc) update for one KV block —
the unit of work Ring Attention executes per ring hop; the rust coordinator
chains W of them with P2P communication in between.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .linear_attn import INTERPRET

NEG_INF = -1e30


def _flash_kernel(qoff_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                  causal: bool):
    cq, d = q_ref.shape
    nk = k_ref.shape[0]
    scale = 1.0 / (d ** 0.5)
    q = q_ref[...] * scale
    qoff = qoff_ref[0]

    nb = nk // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        ds = pl.ds(j * block_k, block_k)
        k = k_ref[ds, :]
        v = v_ref[ds, :]
        s = q @ k.T                                      # [cq, bk]
        if causal:
            rows = qoff + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))      # [cq]
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((cq,), NEG_INF, dtype=q.dtype)
    l0 = jnp.zeros((cq,), dtype=q.dtype)
    acc0 = jnp.zeros((cq, v_ref.shape[-1]), dtype=q.dtype)
    m, l, acc = jax.lax.fori_loop(0, nb, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_k", "causal"))
def flash_attention(q_offset, q, k, v, block_k: int = 64, causal: bool = True):
    """Blocked softmax attention.  q: [Cq, d] at global positions
    q_offset+[0..Cq); k, v: [Nk, d] at positions [0..Nk).  q_offset: i32[1].
    """
    cq, d = q.shape
    nk, dv = k.shape[0], v.shape[-1]
    bk = min(block_k, nk)
    assert nk % bk == 0
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_k=bk, causal=causal),
        out_shape=jax.ShapeDtypeStruct((cq, dv), q.dtype),
        interpret=INTERPRET,
    )(q_offset, q, k, v)


def _ring_step_kernel(qoff_ref, koff_ref, q_ref, k_ref, v_ref,
                      m_ref, l_ref, acc_ref,
                      m_out, l_out, acc_out):
    """One online-softmax update against a single KV block that arrived via
    the ring: the per-hop compute of Ring Attention (Liu et al., 2023)."""
    cq, d = q_ref.shape
    scale = 1.0 / (d ** 0.5)
    q = q_ref[...] * scale
    s = q @ k_ref[...].T
    rows = qoff_ref[0] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = koff_ref[0] + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(rows >= cols, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    m_out[...] = m_new
    l_out[...] = alpha * l_ref[...] + p.sum(axis=-1)
    acc_out[...] = acc_ref[...] * alpha[:, None] + p @ v_ref[...]


@jax.jit
def ring_attention_step(q_offset, k_offset, q, k, v, m, l, acc):
    """One ring hop: update (m, l, acc) with KV block at global k_offset.

    q: [Cq, d]; k, v: [Ck, d]; m, l: [Cq]; acc: [Cq, dv].
    Returns (m', l', acc').
    """
    cq, d = q.shape
    dv = v.shape[-1]
    return pl.pallas_call(
        _ring_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((cq,), q.dtype),
            jax.ShapeDtypeStruct((cq,), q.dtype),
            jax.ShapeDtypeStruct((cq, dv), q.dtype),
        ),
        interpret=INTERPRET,
    )(q_offset, k_offset, q, k, v, m, l, acc)


@jax.jit
def ring_attention_finalize(l, acc):
    """O = acc / l — after the last ring hop."""
    return acc / l[:, None]


def ring_attention_init(cq: int, dv: int, dtype=jnp.float32):
    """Initial (m, l, acc) carry for a query chunk."""
    return (
        jnp.full((cq,), NEG_INF, dtype=dtype),
        jnp.zeros((cq,), dtype=dtype),
        jnp.zeros((cq, dv), dtype=dtype),
    )
