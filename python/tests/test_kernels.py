"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (chunk length, key/value dims) and dtypes; fixed
seeds derive from hypothesis-provided integers so failures reproduce.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import features as kf
from compile.kernels import lightning as kl
from compile.kernels import linear_attn as ka
from compile.kernels import ref as kref
from compile.kernels import softmax_attn as ks

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


def assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                               atol=atol)


dims = st.sampled_from([4, 8, 16, 32])
chunks = st.sampled_from([8, 16, 32, 64, 128])
seeds = st.integers(0, 2**16)


# ------------------------------------------------------------- intra-chunk
@settings(max_examples=20, deadline=None)
@given(c=chunks, dk=dims, dv=dims, seed=seeds)
def test_intra_chunk_vs_ref(c, dk, dv, seed):
    q = rand(seed, c, dk)
    k = rand(seed + 1, c, dk)
    v = rand(seed + 2, c, dv)
    got = ka.intra_chunk(q, k, v)
    want = kref.full_linear_attn(q, k, v, masked=True)
    assert_close(got, want)


@settings(max_examples=10, deadline=None)
@given(c=chunks, dk=dims, seed=seeds)
def test_intra_chunk_bf16(c, dk, seed):
    q = rand(seed, c, dk, dtype=jnp.bfloat16)
    k = rand(seed + 1, c, dk, dtype=jnp.bfloat16)
    v = rand(seed + 2, c, dk, dtype=jnp.bfloat16)
    got = ka.intra_chunk(q, k, v).astype(jnp.float32)
    want = kref.full_linear_attn(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), masked=True)
    assert_close(got, want, rtol=0.1, atol=0.5)


def test_intra_chunk_block_sizes():
    """Output is independent of the Q row-block tiling."""
    q, k, v = rand(0, 64, 16), rand(1, 64, 16), rand(2, 64, 8)
    o64 = ka.intra_chunk(q, k, v, block_q=64)
    o16 = ka.intra_chunk(q, k, v, block_q=16)
    o8 = ka.intra_chunk(q, k, v, block_q=8)
    assert_close(o64, o16)
    assert_close(o64, o8)


# ------------------------------------------------------------- chunk state
@settings(max_examples=20, deadline=None)
@given(c=chunks, dk=dims, dv=dims, seed=seeds)
def test_chunk_state_vs_ref(c, dk, dv, seed):
    k = rand(seed, c, dk)
    v = rand(seed + 1, c, dv)
    assert_close(ka.chunk_state(k, v), k.T @ v)


@settings(max_examples=10, deadline=None)
@given(c=chunks, dk=dims, seed=seeds)
def test_inter_chunk_vs_ref(c, dk, seed):
    q = rand(seed, c, dk)
    m = rand(seed + 1, dk, dk)
    assert_close(ka.inter_chunk(q, m), q @ m)


# -------------------------------------------------------------- fused path
@settings(max_examples=20, deadline=None)
@given(c=chunks, dk=dims, dv=dims, seed=seeds)
def test_fused_equals_intra_plus_inter(c, dk, dv, seed):
    q = rand(seed, c, dk)
    k = rand(seed + 1, c, dk)
    v = rand(seed + 2, c, dv)
    m = rand(seed + 3, dk, dv)
    fused = ka.fused_chunk_output(q, k, v, m)
    split = ka.intra_chunk(q, k, v) + ka.inter_chunk(q, m)
    assert_close(fused, split)


@settings(max_examples=20, deadline=None)
@given(c=chunks, dk=dims, dv=dims, seed=seeds)
def test_lightning_equals_fused(c, dk, dv, seed):
    """Lightning Attention is an IO-aware tiling of the same math."""
    q = rand(seed, c, dk)
    k = rand(seed + 1, c, dk)
    v = rand(seed + 2, c, dv)
    m = rand(seed + 3, dk, dv)
    assert_close(kl.lightning_chunk_output(q, k, v, m),
                 ka.fused_chunk_output(q, k, v, m))


def test_fused_matches_recurrence_with_carry():
    """Chunk with carry-in state == token recurrence started from M0."""
    c, dk, dv = 32, 8, 8
    q, k, v = rand(0, c, dk), rand(1, c, dk), rand(2, c, dv)
    m0 = rand(3, dk, dv)
    got = ka.fused_chunk_output(q, k, v, m0)
    want, _ = kref.recurrent_linear_attn(q, k, v, m0=m0)
    assert_close(got, want)


# ------------------------------------------------------------ backward ops
@settings(max_examples=10, deadline=None)
@given(c=st.sampled_from([16, 32]), d=dims, seed=seeds)
def test_custom_vjp_matches_autodiff_of_ref(c, d, seed):
    """grad through the Pallas fused kernel (Alg. 4 custom VJP) must equal
    grad through the pure-jnp reference."""
    q, k, v = rand(seed, c, d), rand(seed + 1, c, d), rand(seed + 2, c, d)
    m = rand(seed + 3, d, d)

    def loss_pallas(q, k, v, m):
        return jnp.sum(jnp.tanh(ka.fused_chunk_output(q, k, v, m)))

    def loss_ref(q, k, v, m):
        o = kref.full_linear_attn(q, k, v, masked=True) + q @ m
        return jnp.sum(jnp.tanh(o))

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(q, k, v, m)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, m)
    for a, b in zip(gp, gr):
        assert_close(a, b, rtol=1e-3, atol=1e-3)


def test_bwd_chunk_dstate():
    q, do = rand(0, 32, 8), rand(1, 32, 8)
    assert_close(ka.bwd_chunk_dstate(q, do), q.T @ do)


def test_lasp2_backward_oracle_matches_jax_grad():
    """Alg. 4 (chunked SP backward) == jax.grad of full linear attention."""
    n, d, t = 64, 8, 4
    q, k, v = rand(0, n, d), rand(1, n, d), rand(2, n, d)
    do = rand(3, n, d)

    def fwd(q, k, v):
        return jnp.vdot(kref.full_linear_attn(q, k, v, masked=True), do)

    dq_ref, dk_ref, dv_ref = jax.grad(fwd, argnums=(0, 1, 2))(q, k, v)
    dq, dk, dv = kref.lasp2_masked_backward(q, k, v, do, num_chunks=t)
    assert_close(dq, dq_ref, rtol=1e-3, atol=1e-3)
    assert_close(dk, dk_ref, rtol=1e-3, atol=1e-3)
    assert_close(dv, dv_ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- softmax
@settings(max_examples=15, deadline=None)
@given(c=st.sampled_from([16, 32, 64]), d=dims,
       t=st.sampled_from([1, 2, 4]), seed=seeds)
def test_flash_vs_softmax_ref(c, d, t, seed):
    """Blocked online-softmax kernel vs reference, incl. chunk offsets."""
    nk = t * c
    k = rand(seed + 1, nk, d)
    v = rand(seed + 2, nk, d)
    for ti in range(t):
        q = rand(seed + 10 + ti, c, d)
        off = jnp.array([ti * c], dtype=jnp.int32)
        got = ks.flash_attention(off, q, k, v)
        want = kref.softmax_attn(q, k, v, causal=True, q_offset=ti * c)
        assert_close(got, want, rtol=1e-4, atol=1e-4)


def test_flash_noncausal():
    q, k, v = rand(0, 32, 16), rand(1, 64, 16), rand(2, 64, 16)
    got = ks.flash_attention(jnp.array([0], jnp.int32), q, k, v,
                             causal=False)
    want = kref.softmax_attn(q, k, v, causal=False)
    assert_close(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(c=st.sampled_from([16, 32]), d=dims, w=st.sampled_from([2, 4]),
       seed=seeds)
def test_ring_attention_chain_vs_ref(c, d, w, seed):
    """W ring hops (what the rust Ring Attention scheduler executes) must
    reproduce exact softmax attention over the full sequence."""
    n = w * c
    k = rand(seed + 1, n, d)
    v = rand(seed + 2, n, d)
    for ti in range(w):
        q = rand(seed + 10 + ti, c, d)
        m, l, acc = ks.ring_attention_init(c, d)
        qoff = jnp.array([ti * c], jnp.int32)
        for hop in range(w):
            koff = jnp.array([hop * c], jnp.int32)
            m, l, acc = ks.ring_attention_step(
                qoff, koff, q, k[hop * c:(hop + 1) * c],
                v[hop * c:(hop + 1) * c], m, l, acc)
        got = ks.ring_attention_finalize(l, acc)
        want = kref.softmax_attn(q, k, v, causal=True, q_offset=ti * c)
        assert_close(got, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- feature maps
def test_based_feature_dim():
    x = rand(0, 10, 4)
    assert kf.phi_based(x).shape == (10, kf.based_feature_dim(4))


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([2, 4, 8]), seed=seeds)
def test_based_taylor_identity(d, seed):
    """phi(q).phi(k) == 1 + q.k + (q.k)^2/2 — the 2nd-order Taylor of exp."""
    q = rand(seed, d)
    k = rand(seed + 1, d)
    got = jnp.dot(kf.phi_based(q), kf.phi_based(k))
    s = jnp.dot(q, k)
    want = 1.0 + s + 0.5 * s * s
    assert_close(got, want, rtol=1e-4, atol=1e-4)


def test_rebased_feature_map():
    x = rand(0, 6, 4)
    gamma = jnp.ones(4) * 2.0
    beta = jnp.ones(4) * 0.5
    assert_close(kf.phi_rebased(x, gamma, beta), jnp.square(2.0 * x + 0.5))
