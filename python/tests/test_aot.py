"""AOT layer sanity: registry consistency and manifest round-trip."""

import os

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def test_registry_builds_and_shapes_check():
    """eval_shape must succeed for every artifact (shape consistency of the
    whole registry) and output arity must match declared names."""
    arts = aot.build_registry(CFG, "core")
    assert len(arts) > 30
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    for art in arts:
        outs = jax.eval_shape(art.fn, *[s for _, s in art.ins])
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        assert len(outs) == len(art.out_names), art.name


def test_required_artifacts_present():
    arts = {a.name for a in aot.build_registry(CFG, "core")}
    for v in M.LINEAR_VARIANTS:
        assert f"l_part1_{v}" in arts
        assert f"l_part2_{v}" in arts
    for need in ("embed", "head", "head_loss", "s_part1", "s_part2_T4",
                 "ring_step", "ring_finalize", "mega_attn_basic_T4",
                 "post_attn", "l_bwd1_basic", "l_bwd2_basic",
                 "l_part2nm_basic", "train_step_basic_pure",
                 "init_basic_pure", "forward_mono_basic_pure_N128"):
        assert need in arts, need


def test_manifest_written():
    """If artifacts were built (make artifacts), the manifest must parse."""
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))), "artifacts", "tiny")
    man = os.path.join(root, "manifest.txt")
    if not os.path.exists(man):
        pytest.skip("tiny artifacts not built yet")
    lines = open(man).read().strip().splitlines()
    assert lines[0] == "lasp2-manifest 1"
    assert lines[1] == "preset tiny"
    n_art = sum(1 for ln in lines if ln.startswith("artifact "))
    n_end = sum(1 for ln in lines if ln == "end")
    assert n_art == n_end and n_art > 30
    for ln in lines:
        if ln.startswith("artifact "):
            fname = ln.split()[2]
            assert os.path.exists(os.path.join(root, fname)), fname


def test_scalar_inputs_are_rank1():
    """Rust builds every literal from a flat vec + reshape; scalars must be
    declared as [1] arrays."""
    for art in aot.build_registry(CFG, "core"):
        for name, spec in art.ins:
            assert len(spec.shape) >= 1, (art.name, name)
