"""L2 correctness: variant math, phase composition, SP identities, training.

The key identities:
  * gated chunked formulation == token-by-token recurrence (every variant);
  * part1/part2 phases composed with the rust-side combine rule == the
    monolithic forward (this is exactly what the rust integration test does
    against the real artifacts — here we prove the math end-to-end in jnp);
  * train_step reduces the loss on a learnable synthetic task.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import features as kf
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


def assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                               atol=atol)


# ------------------------------------------------- variant math identities
@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([16, 32, 64]), d=st.sampled_from([4, 8]),
       t=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_chunked_equals_recurrent_basic(n, d, t, seed):
    q, k, v = rand(seed, n, d), rand(seed + 1, n, d), rand(seed + 2, n, d)
    g = jnp.ones((n, d))
    got = kref.chunked_linear_attn(q, k, v, g, num_chunks=t)
    want, _ = kref.recurrent_linear_attn(q, k, v)
    assert_close(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([16, 32, 64]), d=st.sampled_from([4, 8]),
       t=st.sampled_from([2, 4]), lam=st.floats(0.9, 0.999),
       seed=st.integers(0, 2**16))
def test_chunked_equals_recurrent_retention(n, d, t, lam, seed):
    """Retention = constant scalar decay gates."""
    q, k, v = rand(seed, n, d), rand(seed + 1, n, d), rand(seed + 2, n, d)
    g = jnp.full((n, d), lam, dtype=jnp.float32)
    got = kref.chunked_linear_attn(q, k, v, g, num_chunks=t)
    want, _ = kref.recurrent_linear_attn(q, k, v, g=g)
    assert_close(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([32, 64]), d=st.sampled_from([4, 8]),
       t=st.sampled_from([2, 4]), seed=st.integers(0, 2**16))
def test_chunked_equals_recurrent_gla(n, d, t, seed):
    """GLA = data-dependent per-dim gates (floored, as in the model)."""
    q, k, v = rand(seed, n, d), rand(seed + 1, n, d), rand(seed + 2, n, d)
    raw = rand(seed + 3, n, d)
    g = M.GATE_FLOOR + (1 - M.GATE_FLOOR) * jax.nn.sigmoid(raw)
    got = kref.chunked_linear_attn(q, k, v, g, num_chunks=t)
    want, _ = kref.recurrent_linear_attn(q, k, v, g=g)
    assert_close(got, want, rtol=1e-3, atol=1e-3)


def test_unmasked_chunked_is_allgather_sum():
    """Alg. 1: O = Q * Sum(M_t) — bidirectional case."""
    n, d, t = 64, 8, 4
    q, k, v = rand(0, n, d), rand(1, n, d), rand(2, n, d)
    got = kref.unmasked_chunked_linear_attn(q, k, v, num_chunks=t)
    want = kref.full_linear_attn(q, k, v, masked=False)
    assert_close(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_chunk_count_invariance(t, seed):
    """LASP-2's result must not depend on the SP world size."""
    n, d = 64, 8
    q, k, v = rand(seed, n, d), rand(seed + 1, n, d), rand(seed + 2, n, d)
    g = jnp.ones((n, d))
    got = kref.chunked_linear_attn(q, k, v, g, num_chunks=t)
    want = kref.chunked_linear_attn(q, k, v, g, num_chunks=1)
    assert_close(got, want, rtol=1e-3, atol=1e-3)


# ----------------------------------------------- phase composition == mono
def make_params(variant, pattern, seed=0):
    flat = M.init_params_fn(CFG, variant, pattern,
                            jnp.array([seed], jnp.int32))
    return flat, M.unflatten_params(CFG, variant, pattern, flat)


def combine_states(a_list, m_list):
    """The rust coordinator's gated prefix combine after the AllGather."""
    t = len(a_list)
    prefixes = []
    a_acc = jnp.ones_like(a_list[0])
    m_acc = jnp.zeros_like(m_list[0])
    for i in range(t):
        prefixes.append(m_acc)
        m_acc = a_list[i][..., None] * m_acc + m_list[i]
        a_acc = a_acc * a_list[i]
    return prefixes, m_acc


@pytest.mark.parametrize("variant", M.LINEAR_VARIANTS)
def test_phases_compose_to_mono_forward(variant):
    """Drive part1 -> (simulated AllGather+combine) -> part2 per chunk and
    compare with the monolithic forward — the LASP-2 workflow in python."""
    pattern = "LL"
    flat, params = make_params(variant, pattern, seed=3)
    n = CFG.chunk_len * 4
    tokens = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, CFG.vocab)

    want = M.forward_tokens(CFG, variant, pattern, params, tokens)

    # distributed-style execution
    c = CFG.chunk_len
    t = n // c
    x = params["embed"][tokens] + params["pos"][:n]
    xc = [x[i * c:(i + 1) * c] for i in range(t)]
    for li, kind in enumerate(pattern):
        p = f"layer{li}"
        extra = {f"x.{kk}": params[f"{p}.{kk}"]
                 for kk in ("wg", "gamma", "beta") if f"{p}.{kk}" in params}
        outs = [M.linear_part1(CFG, variant, xc[i], params[f"{p}.ln1"],
                               params[f"{p}.wq"], params[f"{p}.wk"],
                               params[f"{p}.wv"], extra=extra)
                for i in range(t)]
        a_list = [o[4] for o in outs]
        m_list = [o[3] for o in outs]
        prefixes, _ = combine_states(a_list, m_list)
        xc = [M.linear_part2(CFG, variant, xc[i], outs[i][0], outs[i][1],
                             outs[i][2], prefixes[i], params[f"{p}.wo"],
                             params[f"{p}.ln2"], params[f"{p}.w1"],
                             params[f"{p}.w3"], params[f"{p}.w2"])
              for i in range(t)]
    h = jnp.concatenate(xc, axis=0)
    got = M.head_logits(CFG, h, params["final_ln"], params["embed"])
    assert_close(got, want, rtol=2e-3, atol=2e-3)


def test_std_phases_compose_to_mono_forward():
    """Alg. 7 phases (standard attention hybrid layer) == mono forward."""
    pattern = "NN"
    flat, params = make_params("basic", pattern, seed=5)
    n = CFG.chunk_len * 4
    tokens = jax.random.randint(jax.random.PRNGKey(11), (n,), 0, CFG.vocab)
    want = M.forward_tokens(CFG, "basic", pattern, params, tokens)

    c = CFG.chunk_len
    t = n // c
    x = params["embed"][tokens] + params["pos"][:n]
    xc = [x[i * c:(i + 1) * c] for i in range(t)]
    for li in range(len(pattern)):
        p = f"layer{li}"
        qkv = [M.std_part1(CFG, xc[i], params[f"{p}.ln1"], params[f"{p}.wq"],
                           params[f"{p}.wk"], params[f"{p}.wv"])
               for i in range(t)]
        k_all = jnp.concatenate([o[1] for o in qkv], axis=0)  # AllGather K
        v_all = jnp.concatenate([o[2] for o in qkv], axis=0)  # AllGather V
        xc = [M.std_part2(CFG, xc[i], qkv[i][0], k_all, v_all,
                          jnp.array([i * c], jnp.int32), params[f"{p}.wo"],
                          params[f"{p}.ln2"], params[f"{p}.w1"],
                          params[f"{p}.w3"], params[f"{p}.w2"])
              for i in range(t)]
    h = jnp.concatenate(xc, axis=0)
    got = M.head_logits(CFG, h, params["final_ln"], params["embed"])
    assert_close(got, want, rtol=2e-3, atol=2e-3)


def test_bwd_phases_match_grad():
    """Alg. 3/4 phase functions composed == jax.grad of full linear attn."""
    c, hh, dh, t = CFG.chunk_len, CFG.n_heads, CFG.head_dim, 4
    n = c * t
    q = rand(0, n, hh, dh)
    k = rand(1, n, hh, dh)
    v = rand(2, n, hh, dh)
    do = rand(3, n, hh, dh)

    def fwd(q, k, v):
        def per_head(qh, kh, vh, doh):
            return jnp.vdot(kref.full_linear_attn(qh, kh, vh, masked=True),
                            doh)
        return jnp.sum(jax.vmap(per_head, in_axes=(1, 1, 1, 1))(q, k, v,
                                                                do))

    dq_ref, dk_ref, dv_ref = jax.grad(fwd, argnums=(0, 1, 2))(q, k, v)

    qc = q.reshape(t, c, hh, dh)
    kc = k.reshape(t, c, hh, dh)
    vc = v.reshape(t, c, hh, dh)
    doc = do.reshape(t, c, hh, dh)
    # forward states + prefix (as the rust forward pass caches them)
    m_t = [jnp.einsum("chd,che->hde", kc[i], vc[i]) for i in range(t)]
    m_prefix = [jnp.zeros_like(m_t[0])]
    for i in range(t - 1):
        m_prefix.append(m_prefix[-1] + m_t[i])
    # bwd1 on every device, then AllGather + suffix sums
    dm = [M.linear_bwd1(qc[i], doc[i]) for i in range(t)]
    dm_suffix = [jnp.zeros_like(dm[0]) for _ in range(t)]
    acc = jnp.zeros_like(dm[0])
    for i in reversed(range(t - 1)):
        acc = acc + dm[i + 1]
        dm_suffix[i] = acc
    for i in range(t):
        dq, dk, dv = M.linear_bwd2(qc[i], kc[i], vc[i], doc[i],
                                   m_prefix[i], dm_suffix[i])
        assert_close(dq, dq_ref.reshape(t, c, hh, dh)[i], rtol=1e-3,
                     atol=1e-3)
        assert_close(dk, dk_ref.reshape(t, c, hh, dh)[i], rtol=1e-3,
                     atol=1e-3)
        assert_close(dv, dv_ref.reshape(t, c, hh, dh)[i], rtol=1e-3,
                     atol=1e-3)


# ------------------------------------------------------------- params/init
@pytest.mark.parametrize("variant", ["basic", "gla", "rebased"])
def test_param_specs_roundtrip(variant):
    pattern = M.hybrid_pattern(CFG.n_layers, "1/4")
    specs = M.param_specs(CFG, variant, pattern)
    names = [s[0] for s in specs]
    assert len(names) == len(set(names))
    flat = M.init_params_fn(CFG, variant, pattern,
                            jnp.array([0], jnp.int32))
    assert len(flat) == len(specs)
    for (nm, shape, _), arr in zip(specs, flat):
        assert arr.shape == shape, nm


def test_hybrid_patterns():
    assert M.hybrid_pattern(16, "0") == "L" * 16
    assert M.hybrid_pattern(16, "1/4") == "LLLN" * 4
    assert M.hybrid_pattern(16, "1/2") == "LN" * 8
    assert M.hybrid_pattern(16, "1/8") == "LLLLLLLN" * 2
    assert M.hybrid_pattern(16, "all") == "N" * 16
    assert M.hybrid_pattern(2, "1/4") == "LL"


# ---------------------------------------------------------------- training
@pytest.mark.parametrize("variant,pattern_ratio,masked", [
    ("basic", "0", True),
    ("gla", "0", True),
    ("basic", "1/4", True),
    ("basic", "0", False),
])
def test_train_step_reduces_loss(variant, pattern_ratio, masked):
    """A few Adam steps on a trivially learnable task must reduce loss."""
    pattern = M.hybrid_pattern(CFG.n_layers, pattern_ratio)
    specs = M.param_specs(CFG, variant, pattern)
    np_ = len(specs)
    flat = list(M.init_params_fn(CFG, variant, pattern,
                                 jnp.array([1], jnp.int32)))
    mom = [jnp.zeros_like(p) for p in flat]
    vel = [jnp.zeros_like(p) for p in flat]
    bs, sl = CFG.train_batch, CFG.train_seq
    # learnable task: constant repeating token pattern
    base = jnp.arange(sl) % 7
    tokens = jnp.broadcast_to(base, (bs, sl)).astype(jnp.int32)
    targets = jnp.broadcast_to((jnp.arange(sl) + 1) % 7, (bs, sl)).astype(
        jnp.int32)
    loss_mask = jnp.ones((bs, sl), jnp.float32)
    lr = jnp.array([3e-3], jnp.float32)

    step_fn = jax.jit(lambda *a: M.train_step(CFG, variant, pattern,
                                              masked, np_, *a))
    losses = []
    for it in range(8):
        out = step_fn(*flat, *mom, *vel, tokens, targets, loss_mask, lr,
                      jnp.array([it + 1.0], jnp.float32))
        flat = list(out[:np_])
        mom = list(out[np_:2 * np_])
        vel = list(out[2 * np_:3 * np_])
        losses.append(float(out[-1][0]))
    assert losses[-1] < losses[0] * 0.9, losses
    assert np.isfinite(losses).all()
